"""Paged vs dense KV-cache benchmark.

Two claims, recorded in ``BENCH_paged.json``:

* **Capacity at equal memory** — a dense replica reserves
  ``max_batch x max_len`` KV entries; a paged replica with the *same*
  pool bytes admits by free pages, so short requests pack it. The same
  heavy short-request workload is driven through both at identical KV
  memory and the peak resident count is compared (the paged engine
  should hold >= 2x).
* **Throughput at batch 16** — tokens/s for a drained 16-slot workload,
  dense vs paged (block-table gather must not cost throughput).

``--smoke`` shrinks the workload for CI and skips the JSON rewrite.
"""

from __future__ import annotations

import argparse
import pathlib
import time

import jax
import numpy as np

from repro.serving import PipelineServer

from .common import (
    csv_row,
    drain_requests as _drain,
    smoke_serving_model as _model,
    write_bench,
)

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_paged.json"


def _kv_bytes(server: PipelineServer) -> int:
    """Persistent KV allocation of one replica's cache (group 0)."""
    leaves = jax.tree_util.tree_leaves(server._caches[(0, 0)])
    return sum(x.nbytes for x in leaves)


def capacity_at_equal_memory(
    *, n_requests: int, n_tokens: int, prompt_len: int,
    kv_dtype: str | None = None,
) -> dict:
    """Dense (max_batch=4, max_len=128) vs paged with the same pool
    BYTES — the page budget is the dense fp32 reservation's bytes
    divided by the actual per-page cost (``kv_page_bytes``, so int8
    pages fit ~4x as many in the same budget), minus one so the
    reserved scratch page is counted inside it — but 16 admission
    slots."""
    from repro.serving import kv_page_bytes

    cfg, model, params = _model()
    page_size = 16
    dense_batch, max_len = 4, 128
    budget = (dense_batch * max_len // page_size) * kv_page_bytes(
        page_size, cfg.n_kv_heads, cfg.head_dim, cfg.n_layers, "float32"
    )
    max_pages = budget // kv_page_bytes(
        page_size, cfg.n_kv_heads, cfg.head_dim, cfg.n_layers,
        kv_dtype or "float32",
    ) - 1
    kw = dict(
        n_groups=2, n_replicas=1, policy="uniform",
        harvest_bounds=(60.0, 80.0), max_len=max_len, seed=0,
    )
    out = {}
    for mode in ("dense", "paged"):
        if mode == "dense":
            server = PipelineServer(model, params, max_batch=dense_batch, **kw)
        else:
            server = PipelineServer(
                model, params, max_batch=16, paged=True,
                page_size=page_size, kv_dtype=kv_dtype,
                max_pages=max_pages, **kw
            )
        reqs = [
            server.submit((np.arange(prompt_len) + i) % cfg.vocab_size, n_tokens)
            for i in range(n_requests)
        ]
        _drain(server, reqs)
        assert all(r.done for r in reqs)
        out[mode] = {
            "kv_bytes_per_replica": _kv_bytes(server),
            "peak_resident": server.stats.peak_active,
            "completed": server.stats.completed_jobs,
            "preempted": server.stats.preempted_jobs,
        }
    out["capacity_gain"] = round(
        out["paged"]["peak_resident"] / max(out["dense"]["peak_resident"], 1), 2
    )
    return out


def throughput_at_batch(
    batch: int, *, n_requests: int, n_tokens: int, prompt_len: int,
    repeat: int = 3, kv_dtype: str | None = None,
) -> dict:
    """Steady-state tokens/s for the same workload, dense vs paged,
    equal max_batch. A full warmup wave is drained first on the same
    server so every prefill/decode shape is compiled; the measured waves
    then see only dispatch + compute (best-of-``repeat``: sub-second
    drains are scheduler-noise-dominated on CPU)."""
    cfg, model, params = _model()
    kw = dict(
        n_groups=2, n_replicas=1, policy="uniform",
        harvest_bounds=(60.0, 80.0), max_len=128, max_batch=batch, seed=0,
    )

    def wave(server):
        reqs = [
            server.submit((np.arange(prompt_len) + i) % cfg.vocab_size, n_tokens)
            for i in range(n_requests)
        ]
        t0 = time.perf_counter()
        _drain(server, reqs)
        return time.perf_counter() - t0

    out = {}
    for mode in ("dense", "paged"):
        extra = (
            dict(paged=True, page_size=16, kv_dtype=kv_dtype)
            if mode == "paged"
            else {}
        )
        server = PipelineServer(model, params, **kw, **extra)
        wave(server)  # warmup: compiles every dispatch shape
        tokens = n_requests * n_tokens
        best = min(wave(server) for _ in range(repeat))
        out[mode] = {
            "tokens_per_s": round(tokens / best, 1),
            "wall_s": round(best, 3),
            "tokens": tokens,
        }
    out["paged_vs_dense"] = round(
        out["paged"]["tokens_per_s"] / max(out["dense"]["tokens_per_s"], 1e-9), 3
    )
    return out


def run(smoke: bool = False, kv_dtype: str | None = None) -> list[str]:
    rows = []
    cap = capacity_at_equal_memory(
        n_requests=8 if smoke else 24,
        n_tokens=4 if smoke else 8,
        prompt_len=6,
        kv_dtype=kv_dtype,
    )
    rows.append(
        csv_row(
            "paged/capacity",
            0.0,
            f"peak_resident paged={cap['paged']['peak_resident']} "
            f"dense={cap['dense']['peak_resident']} "
            f"gain={cap['capacity_gain']}x at "
            f"{cap['paged']['kv_bytes_per_replica']}B vs "
            f"{cap['dense']['kv_bytes_per_replica']}B per replica",
        )
    )
    tp = throughput_at_batch(
        16,
        n_requests=8 if smoke else 16,
        n_tokens=8 if smoke else 32,
        prompt_len=6,
        kv_dtype=kv_dtype,
    )
    rows.append(
        csv_row(
            "paged/batch16",
            1e6 / max(tp["paged"]["tokens_per_s"], 1e-9),
            f"paged={tp['paged']['tokens_per_s']} tok/s "
            f"dense={tp['dense']['tokens_per_s']} tok/s "
            f"ratio={tp['paged_vs_dense']}",
        )
    )
    if not smoke and kv_dtype is None:
        report = {
            "model": "stablelm-1.6b(smoke)",
            "capacity_at_equal_memory": cap,
            "throughput_batch16": tp,
        }
        write_bench(BENCH_JSON, "paged_kv", report)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke", action="store_true",
        help="small CI run: fewer requests/tokens, no BENCH_paged.json",
    )
    ap.add_argument(
        "--kv-dtype", choices=["compute", "int8"], default="compute",
        help="page dtype for the paged servers (int8 = quantized pages; "
             "the CI main lane smoke-runs this path); BENCH_paged.json is "
             "only rewritten at the default dtype",
    )
    args = ap.parse_args()
    kv = None if args.kv_dtype == "compute" else args.kv_dtype
    for row in run(smoke=args.smoke, kv_dtype=kv):
        print(row, flush=True)


if __name__ == "__main__":
    main()
