"""Paper Fig. 2a: power-modes study on a single device.

Compares fixed 15/30/60 W and the dynamic mode over 100 slots: completed
jobs + average battery. Paper reference values: 15 W = (31 jobs, 89 %),
30 W = (45, 42 %), 60 W = (58, 16 %), dynamic = (47, ~60 %).

All four strategies run as one ``simulate_sweep`` grid (the fixed-mode
PM tables are padded to the dynamic table's length), so the study costs
a single jit compile.

Note (EXPERIMENTS.md): the paper's 60 W jobs/battery pair violates energy
conservation under its own (kappa, CE) table — 58x23 kJ exceeds battery +
maximum harvest; the reproduction preserves the throughput ordering and
the downtime/risk structure instead.
"""

from __future__ import annotations

from repro.core.simulator import simulate_sweep

from .common import FIG2A_ARRIVALS, FIG2A_P, PM_STRATEGIES, csv_row, lower_strategies, timed

PAPER = {"15W": (31, 89), "30W": (45, 42), "60W": (58, 16), "dynamic": (47, 60)}


def run(n_runs: int = 300) -> list[str]:
    scenarios = lower_strategies(100, FIG2A_P, *FIG2A_ARRIVALS)
    res, dt = timed(
        simulate_sweep, None, scenarios, n_runs=n_runs, n_steps=100, repeat=1
    )
    rows = []
    for i, name in enumerate(PM_STRATEGIES):
        jobs = res.completed[i].mean()
        batt = res.mean_battery[i].mean()
        pj, pb = PAPER[name]
        rows.append(
            csv_row(
                f"fig2a/{name}",
                dt * 1e6 / (len(PM_STRATEGIES) * n_runs),
                f"jobs={jobs:.1f} (paper {pj}); battery={batt:.0f}% (paper {pb}%); "
                f"downtime={res.downtime_fraction[i].mean():.3f}",
            )
        )
    return rows


def main() -> None:
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
