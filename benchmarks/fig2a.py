"""Paper Fig. 2a: power-modes study on a single device.

Compares fixed 15/30/60 W and the dynamic mode over 100 slots: completed
jobs + average battery. Paper reference values: 15 W = (31 jobs, 89 %),
30 W = (45, 42 %), 60 W = (58, 16 %), dynamic = (47, ~60 %).

Note (EXPERIMENTS.md): the paper's 60 W jobs/battery pair violates energy
conservation under its own (kappa, CE) table — 58x23 kJ exceeds battery +
maximum harvest; the reproduction preserves the throughput ordering and
the downtime/risk structure instead.
"""

from __future__ import annotations

import dataclasses

from repro.core.simulator import SimConfig, simulate_single_device

from .common import FIG2A_ARRIVALS, FIG2A_P, csv_row, timed

STRATEGIES = {
    "15W": ((), (1,)),
    "30W": ((), (2,)),
    "60W": ((), (3,)),
    "dynamic": ((40.0, 60.0), (1, 2, 3)),
}

PAPER = {"15W": (31, 89), "30W": (45, 42), "60W": (58, 16), "dynamic": (47, 60)}


def run(n_runs: int = 300) -> list[str]:
    rows = []
    for name, (thr, allowed) in STRATEGIES.items():
        cfg = SimConfig(
            n_groups=1,
            n_per_group=1,
            n_steps=100,
            p_arrival=FIG2A_P,
            pm_thresholds=thr,
            pm_allowed=allowed,
        )
        res, dt = timed(
            simulate_single_device, cfg, *FIG2A_ARRIVALS, n_runs=n_runs, repeat=1
        )
        jobs = res.completed.mean()
        batt = res.mean_battery.mean()
        pj, pb = PAPER[name]
        rows.append(
            csv_row(
                f"fig2a/{name}",
                dt * 1e6 / n_runs,
                f"jobs={jobs:.1f} (paper {pj}); battery={batt:.0f}% (paper {pb}%); "
                f"downtime={res.downtime_fraction.mean():.3f}",
            )
        )
    return rows


def main() -> None:
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
