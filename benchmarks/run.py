"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:

  fig2a  — power-modes study (paper Fig. 2a)
  fig2b  — q_lim via Brent under xi_lim (paper Fig. 2b)
  fig3   — downtime fraction vs energy/job arrivals (paper Fig. 3)
  fig4   — throughput / dropped jobs (paper Fig. 4)
  serve  — engine integration: scheduler driving real decode + failover
  async  — async vs sync engine: dispatch gaps + tokens/s (BENCH_async.json)
  paged  — paged vs dense KV cache: capacity + throughput (BENCH_paged.json)
  chunked — chunked vs whole-prompt prefill under mixed traffic
            (BENCH_chunked.json)
  quant_kv — int8 vs compute-dtype KV pages: capacity at equal bytes,
            throughput, greedy agreement (BENCH_quant_kv.json)
  spec   — speculative draft-verify vs plain paged decode: accepted
            tokens/s + energy per accepted token (BENCH_spec.json)
  sweep  — per-scenario re-jit vs one vmapped sweep (writes BENCH_sweep.json)
  mesh   — tensor-parallel stage width sweep on forced-host devices:
            exactness, dispatch gaps, collectives, multi-process
            kill-failover (BENCH_mesh.json)
  roofline — per-cell dry-run roofline terms (deliverable g)

``--summary`` skips the benchmarks and prints the perf trajectory
recorded across every ``BENCH_*.json`` at the repo root (all share the
``{name, commit, metrics{}}`` envelope from :mod:`benchmarks.common`).
``--summary --json`` emits the same trajectory as one consolidated,
schema-validated JSON document on stdout — CI uploads it as the
``perf-trajectory`` artifact.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import traceback


def _flat_metrics(metrics, prefix="", out=None):
    """Numeric leaves of a metrics tree as dotted keys."""
    if out is None:
        out = {}
    if isinstance(metrics, dict):
        for k, v in metrics.items():
            _flat_metrics(v, f"{prefix}{k}.", out)
    elif isinstance(metrics, (int, float)) and not isinstance(metrics, bool):
        out[prefix[:-1]] = metrics
    return out


def collect_records() -> list[dict]:
    """Load + schema-validate every BENCH_*.json at the repo root."""
    from .common import BENCH_SCHEMA_KEYS

    root = pathlib.Path(__file__).resolve().parent.parent
    records = []
    for path in sorted(root.glob("BENCH_*.json")):
        data = json.loads(path.read_text())
        missing = [k for k in BENCH_SCHEMA_KEYS if k not in data]
        if missing:
            raise SystemExit(f"{path.name}: missing envelope keys {missing}")
        if not isinstance(data["metrics"], dict):
            raise SystemExit(f"{path.name}: metrics must be an object")
        data["file"] = path.name
        records.append(data)
    return records


def summary(as_json: bool = False) -> None:
    """Print the recorded perf trajectory across all BENCH_*.json files."""
    records = collect_records()
    if not records:
        print("no BENCH_*.json records found", file=sys.stderr)
        return
    if as_json:
        doc = {
            "schema": "repro-perf-trajectory/v1",
            "records": [
                {
                    "name": d["name"],
                    "commit": d["commit"],
                    "file": d["file"],
                    "metrics": _flat_metrics(d["metrics"]),
                }
                for d in records
            ],
        }
        print(json.dumps(doc, indent=2))
        return
    for data in records:
        path = pathlib.Path(data["file"])
        print(f"{data['name']} @ {data['commit']} ({path.name})")
        flat = _flat_metrics(data["metrics"])
        # Headline ratios/speedups first, then the rest, alphabetical.
        headline = {
            k: v for k, v in flat.items()
            if any(t in k for t in ("speedup", "gain", "ratio", "agreement",
                                    "_vs_"))
        }
        for k in sorted(headline):
            print(f"  {k} = {headline[k]}")
        for k in sorted(set(flat) - set(headline)):
            print(f"  {k} = {flat[k]}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--summary", action="store_true",
        help="print the perf trajectory across existing BENCH_*.json "
             "records instead of running the benchmarks",
    )
    ap.add_argument(
        "--json", action="store_true",
        help="with --summary: one consolidated schema-validated JSON "
             "document on stdout (the CI perf-trajectory artifact)",
    )
    args = ap.parse_args()
    if args.summary:
        summary(as_json=args.json)
        return
    if args.json:
        ap.error("--json requires --summary")

    from . import (
        async_bench,
        chunked_bench,
        fig2a,
        fig2b,
        fig3,
        fig4,
        mesh_bench,
        paged_bench,
        quant_kv_bench,
        roofline_table,
        serve_bench,
        spec_bench,
        sweep_bench,
    )

    print("name,us_per_call,derived")
    failures = 0
    for mod in (
        fig2a,
        fig2b,
        fig3,
        fig4,
        serve_bench,
        async_bench,
        paged_bench,
        chunked_bench,
        quant_kv_bench,
        spec_bench,
        sweep_bench,
        mesh_bench,
        roofline_table,
    ):
        try:
            for row in mod.run():
                print(row, flush=True)
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{mod.__name__},nan,FAILED: {e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benchmark modules failed")


if __name__ == "__main__":
    main()
