"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:

  fig2a  — power-modes study (paper Fig. 2a)
  fig2b  — q_lim via Brent under xi_lim (paper Fig. 2b)
  fig3   — downtime fraction vs energy/job arrivals (paper Fig. 3)
  fig4   — throughput / dropped jobs (paper Fig. 4)
  serve  — engine integration: scheduler driving real decode + failover
  paged  — paged vs dense KV cache: capacity + throughput (BENCH_paged.json)
  chunked — chunked vs whole-prompt prefill under mixed traffic
            (BENCH_chunked.json)
  sweep  — per-scenario re-jit vs one vmapped sweep (writes BENCH_sweep.json)
  roofline — per-cell dry-run roofline terms (deliverable g)
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (
        chunked_bench,
        fig2a,
        fig2b,
        fig3,
        fig4,
        paged_bench,
        roofline_table,
        serve_bench,
        sweep_bench,
    )

    print("name,us_per_call,derived")
    failures = 0
    for mod in (
        fig2a,
        fig2b,
        fig3,
        fig4,
        serve_bench,
        paged_bench,
        chunked_bench,
        sweep_bench,
        roofline_table,
    ):
        try:
            for row in mod.run():
                print(row, flush=True)
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{mod.__name__},nan,FAILED: {e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benchmark modules failed")


if __name__ == "__main__":
    main()
