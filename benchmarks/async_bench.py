"""Async-engine benchmark: dispatch gaps and tokens/s at batch 64.

The async engine's win is *host-side*: with ``async_depth >= 1`` the
producer dispatches every replica's next jitted call back-to-back and
the committer drains argmax readbacks afterwards, so the host never
blocks on device results between dispatches. This benchmark measures
exactly that seam: the same continuous-batching workload is drained
through the legacy synchronous engine (``async_depth=0``, readback
inside the dispatch phase) and the async engine, recording

* the mean/median gap between consecutive dispatches *within one step*
  (the window where the sync engine stalls on its own readbacks), read
  from ``PipelineServer.dispatch_log``;
* end-to-end tokens/s, which must not regress (>= 1.0x).

On a single-core CI container host and "device" timeshare the same
silicon, so total tokens/s is parity by construction (same work, same
core) — the structural async win is the gap metric. Passes are
interleaved sync/async and the headline ratios are medians over
*temporally adjacent pairs*, which cancels container drift that
best-of-N across a whole run cannot.

Results land in ``BENCH_async.json`` via the shared envelope.
"""

from __future__ import annotations

import argparse
import dataclasses
import pathlib
import time

import numpy as np

from repro.serving import PipelineServer, reset_trace_counts

from .common import csv_row, write_bench

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_async.json"

_MODEL = None


def _model():
    """Serving model for the async A/B — the shared smoke model scaled
    up (4 layers x d256) until device compute per batch-64 decode call
    is a multiple of the ~5 ms per-dispatch host assembly cost. The
    2-layer d64 smoke model's calls are sub-millisecond, so with it the
    seam under test (the eager readback between dispatches) is invisible
    under scheduler noise and the A/B measures nothing."""
    global _MODEL
    if _MODEL is None:
        import jax

        from repro.configs import get_smoke_config
        from repro.models import build_model, init_from_template

        cfg = dataclasses.replace(
            get_smoke_config("stablelm-1.6b"),
            dtype="float32",
            param_dtype="float32",
            n_layers=4,
            d_model=256,
            n_heads=8,
            n_kv_heads=8,
            d_ff=1024,
        )
        model = build_model(cfg)
        params = init_from_template(
            model.template, jax.random.PRNGKey(0), "float32"
        )
        _MODEL = (cfg, model, params)
    return _MODEL


def _drain_measured(
    depth: int,
    *,
    max_batch: int,
    n_requests: int,
    n_tokens: int,
    prompt_len: int = 6,
    warmup_slots: int = 6,
) -> dict:
    """Drain one workload at the given async depth, measuring per-step
    inter-dispatch gaps (post-warmup) and end-to-end tokens/s."""
    cfg, model, params = _model()
    reset_trace_counts()  # each depth run is its own compile universe
    # Two replicas per group: every step dispatches one call per
    # resident replica, so the inter-dispatch gap *within a step* is
    # observable — at depth 0 the eager readback of replica 0's call
    # sits between the two dispatches; at depth >= 1 they go
    # back-to-back and the readbacks drain at the commit boundary.
    server = PipelineServer(
        model,
        params,
        n_groups=2,
        n_replicas=2,
        policy="uniform",
        harvest_bounds=(60.0, 80.0),  # energy-unconstrained: pure compute
        max_len=128,
        max_batch=max_batch,
        async_depth=depth,
        seed=0,
    )
    reqs = [
        server.submit((np.arange(prompt_len) + i) % cfg.vocab_size, n_tokens)
        for i in range(n_requests)
    ]
    for _ in range(warmup_slots):  # compile prefill/decode dispatches
        server.step()
    # accepted_tokens == tokens_generated for the plain engines compared
    # here; using it keeps the denominator shared with spec_bench.
    warm_tokens = server.stats.accepted_tokens
    gaps: list[float] = []
    t0 = time.perf_counter()
    steps = 0
    while not all(r.done or r.dropped for r in reqs):
        mark = len(server.dispatch_log)
        server.step()
        ts = [t for _, _, t in server.dispatch_log[mark:]]
        gaps.extend(np.diff(ts))
        steps += 1
        if steps > 100 * n_requests * n_tokens:  # pragma: no cover
            raise RuntimeError("async bench did not drain")
    dt = time.perf_counter() - t0
    tokens = server.stats.accepted_tokens - warm_tokens
    gaps_us = np.asarray(gaps) * 1e6
    return {
        "tokens_per_s": round(tokens / dt, 1),
        "accepted_tokens_per_s": round(tokens / dt, 1),
        "wall_s": round(dt, 3),
        "tokens": tokens,
        "steps": steps,
        "dispatches": len(server.dispatch_log),
        "inflight_peak": server.stats.inflight_peak,
        "mean_dispatch_gap_us": round(float(gaps_us.mean()), 1) if len(gaps_us) else 0.0,
        "p50_dispatch_gap_us": round(float(np.median(gaps_us)), 1) if len(gaps_us) else 0.0,
    }


def run(smoke: bool = False, depth: int = 2, repeats: int | None = None) -> list[str]:
    if smoke:
        max_batch, n_requests, n_tokens = 8, 8, 8
    else:
        max_batch, n_requests, n_tokens = 64, 64, 16
    if repeats is None:
        repeats = 1 if smoke else 3
    # Interleave sync/async passes: the two modes run identical device
    # work, so the A/B is about host-side stalls. Headline ratios are
    # medians over temporally adjacent (sync, async) pairs — drift in
    # container CPU steal hits both members of a pair about equally.
    sync_passes, async_passes = [], []
    for _ in range(repeats):
        sync_passes.append(_drain_measured(
            0, max_batch=max_batch, n_requests=n_requests, n_tokens=n_tokens
        ))
        async_passes.append(_drain_measured(
            depth, max_batch=max_batch, n_requests=n_requests, n_tokens=n_tokens
        ))
    sync = max(sync_passes, key=lambda d: d["tokens_per_s"])
    asyn = max(async_passes, key=lambda d: d["tokens_per_s"])
    gap_ratio = float(np.median([
        s["mean_dispatch_gap_us"] / max(a["mean_dispatch_gap_us"], 1e-9)
        for s, a in zip(sync_passes, async_passes)
    ]))
    tps_ratio = float(np.median([
        a["tokens_per_s"] / max(s["tokens_per_s"], 1e-9)
        for s, a in zip(sync_passes, async_passes)
    ]))
    report = {
        "max_batch": max_batch,
        "n_requests": n_requests,
        "n_tokens": n_tokens,
        "async_depth": depth,
        "smoke": smoke,
        "repeats": repeats,
        "sync": sync,
        "async": asyn,
        "sync_passes_tokens_per_s": [p["tokens_per_s"] for p in sync_passes],
        "async_passes_tokens_per_s": [p["tokens_per_s"] for p in async_passes],
        "dispatch_gap_ratio_sync_vs_async": round(gap_ratio, 2),
        "tokens_per_s_ratio_async_vs_sync": round(tps_ratio, 2),
    }
    rows = [
        csv_row(
            f"async/sync_batch{max_batch}",
            sync["mean_dispatch_gap_us"],
            f"tokens_per_s={sync['tokens_per_s']} "
            f"gap_us={sync['mean_dispatch_gap_us']}",
        ),
        csv_row(
            f"async/depth{depth}_batch{max_batch}",
            asyn["mean_dispatch_gap_us"],
            f"tokens_per_s={asyn['tokens_per_s']} "
            f"gap_us={asyn['mean_dispatch_gap_us']} "
            f"inflight_peak={asyn['inflight_peak']}",
        ),
        csv_row(
            "async/gap_shrink",
            0.0,
            f"sync_vs_async={gap_ratio:.2f}x tps_async_vs_sync={tps_ratio:.2f}x",
        ),
    ]
    if not smoke:
        write_bench(BENCH_JSON, "async_engine", report)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="small CI run: batch 8, fewer requests/tokens, no BENCH_async.json",
    )
    ap.add_argument(
        "--depth", type=int, default=2,
        help="async_depth for the async side of the comparison",
    )
    args = ap.parse_args()
    for row in run(smoke=args.smoke, depth=args.depth):
        print(row, flush=True)


if __name__ == "__main__":
    main()
