"""Paper Fig. 2b: maximum input rate q_lim under risk xi_lim = 0.01.

Brent's method on the semi-Markov risk curve (Eq. 3) + the delay bound
(Eqs. 4-5). Paper markers: 15 W = 1/3 (time-bound), 30 W = 1/2
(time-bound), 60 W ~ 0.33 (energy-bound), dynamic ~ 0.64 ~ 1/kappa_bar.

In addition to the analytics, a saturated-input Monte-Carlo sweep (one
``simulate_sweep`` call over all four strategies, one jit compile)
cross-checks each marker: with p = 1 the empirical service rate
``completed / n_steps`` should approach the analytic ceiling.
"""

from __future__ import annotations

from repro.core.energy import uniform_mdf
from repro.core.power import dynamic_policy, fixed_policy
from repro.core.rates import q_lim, q_lim_stable
from repro.core.semi_markov import DeviceModel
from repro.core.simulator import simulate_sweep

from .common import FIG2B_ARRIVALS, PM_STRATEGIES, XI_LIM, csv_row, lower_strategies, timed

PAPER = {"15W": 1 / 3, "30W": 1 / 2, "60W": 0.33, "dynamic": 0.64}

SIM_STEPS = 400


def device(policy):
    return DeviceModel(
        mdf=uniform_mdf(*FIG2B_ARRIVALS), policy=policy, e_max=100
    )


def empirical_rates(n_runs: int = 100) -> dict[str, float]:
    """Saturated-input service rate per strategy, one sweep / one compile."""
    scenarios = lower_strategies(SIM_STEPS, 1.0, *FIG2B_ARRIVALS)
    res = simulate_sweep(None, scenarios, n_runs=n_runs, n_steps=SIM_STEPS)
    rate = res.completed.mean(axis=1) / SIM_STEPS
    return dict(zip(PM_STRATEGIES, rate))


def run() -> list[str]:
    rows = []
    sim_rate = empirical_rates()
    for name, pol in (
        ("15W", fixed_policy(1)),
        ("30W", fixed_policy(2)),
        ("60W", fixed_policy(3)),
    ):
        lims, dt = timed(q_lim, device(pol), XI_LIM, repeat=1)
        rows.append(
            csv_row(
                f"fig2b/{name}",
                dt * 1e6,
                f"q_lim={lims.q_lim:.3f} (paper {PAPER[name]:.3f}); "
                f"binding={lims.binding}; q_energy={lims.q_energy:.3f}; "
                f"sim_rate={sim_rate[name]:.3f}",
            )
        )
    # Dynamic mode: paper's blue circle 0.64 ~ 1/kappa_bar (Eq. 4 at the
    # stable operating point); the self-consistent stable-queue rate is
    # also reported.
    dyn = device(dynamic_policy(100))
    kb, dt = timed(lambda: dyn.chain(0.34).kappa_bar(), repeat=1)
    stable = q_lim_stable(dyn, XI_LIM)
    rows.append(
        csv_row(
            "fig2b/dynamic",
            dt * 1e6,
            f"1/kappa_bar={1/kb:.3f} (paper 0.64); kappa_bar={kb:.2f} (paper ~1.56); "
            f"q_stable={stable.q_lim:.3f}; q_energy={stable.q_energy:.3f} "
            f"(risk threshold unreachable - energy gate); "
            f"sim_rate={sim_rate['dynamic']:.3f}",
        )
    )
    return rows


def main() -> None:
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
