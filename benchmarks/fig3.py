"""Paper Fig. 3: fraction of devices in power-saving mode vs (a) average
energy arrivals and (b) job arrival probability, for the three scheduling
policies on the 3x3 heterogeneous network.

Paper claims: long-term reduces downtime vs uniform (roughly halved when
varying job arrivals); adaptive gains up to ~10 % more; adaptive holds
~1 % downtime even at p = 1.

The full 6-setting x 3-policy grid (both sub-figures, including the two
different harvest topologies) runs as ONE ``simulate_sweep`` call — one
jit compile for the 3x3 shape instead of the 18 the per-scenario path
paid.
"""

from __future__ import annotations

from repro.core import simulator
from repro.core.network import paper_topology
from repro.core.simulator import SimConfig, simulate_sweep

from .common import FIG34_RUNS, FIG34_STEPS, XI_LIM, csv_row, sweep_grid, timed

POLICIES = ("uniform", "long_term", "adaptive")


def grid() -> tuple[list[str], list]:
    """The 18-scenario Fig. 3 grid: (labels, ScenarioParams list)."""
    base = SimConfig(n_groups=3, n_per_group=3, n_steps=FIG34_STEPS, p_arrival=0.7)
    points = []
    # (a) vary mean energy arrival, p fixed — a different topology per
    # mean; harvest bounds are runtime params, so they sweep too.
    for mean in (4.0, 6.0, 8.0):
        topo = paper_topology(arrival_means=(mean - 2, mean, mean + 2), half_width=2)
        points.append(
            (f"fig3a/mean_arrival={mean:.0f}", topo, topo.long_term_rates(XI_LIM), {})
        )
    # (b) vary job arrival probability, arrivals fixed heterogeneous and
    # lean (downtime only occurs when harvest is scarce; the paper's Fig 3b
    # shows nonzero downtime across p, implying a lean per-figure setting).
    topo = paper_topology(arrival_means=(3.0, 5.0, 7.0), half_width=2)
    rates = topo.long_term_rates(XI_LIM)
    for p in (0.4, 0.7, 1.0):
        points.append((f"fig3b/p={p:.1f}", topo, rates, {"p_arrival": p}))
    return sweep_grid(points, POLICIES, base)


def run() -> list[str]:
    labels, scenarios = grid()
    simulator.reset_trace_counts()
    res, dt = timed(
        simulate_sweep, None, scenarios, n_runs=FIG34_RUNS, n_steps=FIG34_STEPS,
        repeat=1,
    )
    compiles = sum(simulator.trace_counts().values())
    down = res.downtime_fraction.mean(axis=1)  # [18]

    rows = []
    for point in ("fig3a/mean_arrival=4", "fig3a/mean_arrival=6", "fig3a/mean_arrival=8",
                  "fig3b/p=0.4", "fig3b/p=0.7", "fig3b/p=1.0"):
        vals = {
            pol: down[labels.index(f"{point}/{pol}")] for pol in POLICIES
        }
        rows.append(
            csv_row(
                point,
                dt * 1e6 / len(labels),
                "downtime " + " ".join(f"{p}={vals[p]:.4f}" for p in POLICIES)
                + f" (sweep compiles={compiles})",
            )
        )
    return rows


def main() -> None:
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
