"""Paper Fig. 3: fraction of devices in power-saving mode vs (a) average
energy arrivals and (b) job arrival probability, for the three scheduling
policies on the 3x3 heterogeneous network.

Paper claims: long-term reduces downtime vs uniform (roughly halved when
varying job arrivals); adaptive gains up to ~10 % more; adaptive holds
~1 % downtime even at p = 1.
"""

from __future__ import annotations

import numpy as np

from repro.core.network import paper_topology
from repro.core.simulator import SimConfig, simulate

from .common import XI_LIM, csv_row, timed

POLICIES = ("uniform", "long_term", "adaptive")


def _run_network(topo, policy, p_arrival, n_steps=300, n_runs=200, rates=None):
    cfg = SimConfig(
        n_groups=topo.n_groups,
        n_per_group=topo.n_per_group,
        n_steps=n_steps,
        p_arrival=p_arrival,
        policy=policy,
    )
    return simulate(topo, cfg, n_runs=n_runs, long_term_rates=rates, xi_lim=XI_LIM)


def run() -> list[str]:
    rows = []
    # (a) vary mean energy arrival, p fixed.
    for mean in (4.0, 6.0, 8.0):
        topo = paper_topology(arrival_means=(mean - 2, mean, mean + 2), half_width=2)
        rates = topo.long_term_rates(XI_LIM)
        downs = {}
        for pol in POLICIES:
            res, dt = timed(
                _run_network, topo, pol, 0.7, rates=rates, repeat=1
            )
            downs[pol] = res.downtime_fraction.mean()
        rows.append(
            csv_row(
                f"fig3a/mean_arrival={mean:.0f}",
                dt * 1e6,
                "downtime " + " ".join(f"{p}={downs[p]:.4f}" for p in POLICIES),
            )
        )
    # (b) vary job arrival probability, arrivals fixed heterogeneous and
    # lean (downtime only occurs when harvest is scarce; the paper's Fig 3b
    # shows nonzero downtime across p, implying a lean per-figure setting).
    topo = paper_topology(arrival_means=(3.0, 5.0, 7.0), half_width=2)
    rates = topo.long_term_rates(XI_LIM)
    for p in (0.4, 0.7, 1.0):
        downs = {}
        for pol in POLICIES:
            res, dt = timed(_run_network, topo, pol, p, rates=rates, repeat=1)
            downs[pol] = res.downtime_fraction.mean()
        rows.append(
            csv_row(
                f"fig3b/p={p:.1f}",
                dt * 1e6,
                "downtime " + " ".join(f"{p_}={downs[p_]:.4f}" for p_ in POLICIES),
            )
        )
    return rows


def main() -> None:
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
