"""Paper Fig. 4: network processing capacity — (a) normalized throughput
vs energy arrivals, (b) dropped jobs vs job arrival probability.

Paper claims: model-based policies gain ~10 % throughput at low energy;
adaptive ~2 % over long-term; drops: long-term ~3 and adaptive ~7 fewer
jobs than uniform; drop elbow at p ~ 0.65.

The whole 7-setting x 3-policy grid (both sub-figures, 21 scenarios over
four topologies) runs as ONE ``simulate_sweep`` call / one jit compile.
"""

from __future__ import annotations

from repro.core.network import paper_topology
from repro.core.simulator import SimConfig, simulate_sweep

from .common import FIG34_RUNS, FIG34_STEPS, XI_LIM, csv_row, sweep_grid, timed

POLICIES = ("uniform", "long_term", "adaptive")


def run() -> list[str]:
    base = SimConfig(n_groups=3, n_per_group=3, n_steps=FIG34_STEPS, p_arrival=0.7)
    points = []
    # (a) normalized throughput vs energy arrivals.
    for mean in (4.0, 6.0, 8.0):
        topo = paper_topology(arrival_means=(mean - 2, mean, mean + 2), half_width=2)
        points.append(
            (f"fig4a/mean_arrival={mean:.0f}", topo, topo.long_term_rates(XI_LIM), {})
        )
    # (b) dropped jobs vs arrival probability.
    topo = paper_topology()
    rates = topo.long_term_rates(XI_LIM)
    for p in (0.5, 0.65, 0.8, 1.0):
        points.append((f"fig4b/p={p:.2f}", topo, rates, {"p_arrival": p}))
    labels, scenarios = sweep_grid(points, POLICIES, base)

    res, dt = timed(
        simulate_sweep, None, scenarios, n_runs=FIG34_RUNS, n_steps=FIG34_STEPS,
        repeat=1,
    )
    thr = res.normalized_throughput.mean(axis=1)
    drops = res.dropped.mean(axis=1)

    rows = []
    for mean in (4, 6, 8):
        vals = {p: thr[labels.index(f"fig4a/mean_arrival={mean}/{p}")] for p in POLICIES}
        rows.append(
            csv_row(
                f"fig4a/mean_arrival={mean}",
                dt * 1e6 / len(labels),
                "throughput " + " ".join(f"{p}={vals[p]:.3f}" for p in POLICIES),
            )
        )
    for p in (0.5, 0.65, 0.8, 1.0):
        vals = {p_: drops[labels.index(f"fig4b/p={p:.2f}/{p_}")] for p_ in POLICIES}
        rows.append(
            csv_row(
                f"fig4b/p={p:.2f}",
                dt * 1e6 / len(labels),
                "dropped " + " ".join(f"{p_}={vals[p_]:.1f}" for p_ in POLICIES),
            )
        )
    return rows


def main() -> None:
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
