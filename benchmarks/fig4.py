"""Paper Fig. 4: network processing capacity — (a) normalized throughput
vs energy arrivals, (b) dropped jobs vs job arrival probability.

Paper claims: model-based policies gain ~10 % throughput at low energy;
adaptive ~2 % over long-term; drops: long-term ~3 and adaptive ~7 fewer
jobs than uniform; drop elbow at p ~ 0.65.
"""

from __future__ import annotations

from repro.core.network import paper_topology
from repro.core.simulator import SimConfig, simulate

from .common import XI_LIM, csv_row, timed

POLICIES = ("uniform", "long_term", "adaptive")


def _run(topo, policy, p_arrival, rates, n_steps=300, n_runs=200):
    cfg = SimConfig(
        n_groups=topo.n_groups,
        n_per_group=topo.n_per_group,
        n_steps=n_steps,
        p_arrival=p_arrival,
        policy=policy,
    )
    return simulate(topo, cfg, n_runs=n_runs, long_term_rates=rates, xi_lim=XI_LIM)


def run() -> list[str]:
    rows = []
    # (a) normalized throughput vs energy arrivals.
    for mean in (4.0, 6.0, 8.0):
        topo = paper_topology(arrival_means=(mean - 2, mean, mean + 2), half_width=2)
        rates = topo.long_term_rates(XI_LIM)
        thr = {}
        for pol in POLICIES:
            res, dt = timed(_run, topo, pol, 0.7, rates, repeat=1)
            thr[pol] = res.normalized_throughput.mean()
        rows.append(
            csv_row(
                f"fig4a/mean_arrival={mean:.0f}",
                dt * 1e6,
                "throughput " + " ".join(f"{p}={thr[p]:.3f}" for p in POLICIES),
            )
        )
    # (b) dropped jobs vs arrival probability.
    topo = paper_topology()
    rates = topo.long_term_rates(XI_LIM)
    for p in (0.5, 0.65, 0.8, 1.0):
        drops = {}
        for pol in POLICIES:
            res, dt = timed(_run, topo, pol, p, rates, repeat=1)
            drops[pol] = res.dropped.mean()
        rows.append(
            csv_row(
                f"fig4b/p={p:.2f}",
                dt * 1e6,
                "dropped " + " ".join(f"{p_}={drops[p_]:.1f}" for p_ in POLICIES),
            )
        )
    return rows


def main() -> None:
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
