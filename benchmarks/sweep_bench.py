"""Sweep-engine benchmark: per-scenario re-jitting vs one vmapped sweep.

Runs the Fig. 3 grid (6 settings x 3 policies on the 3x3 network) both
ways and writes wall-clock + compile counts to ``BENCH_sweep.json``:

* **per_point** emulates the pre-sweep code path: a *fresh* ``jax.jit``
  wrapper per scenario (exactly what the old ``build_runner(config)``
  did, since each config produced a new jitted closure), so every grid
  point pays trace + XLA compile.
* **sweep** is one ``simulate_sweep`` call: the whole grid is a single
  compiled executable (vmap over the scenario axis x Monte-Carlo axis).

Both paths share Monte-Carlo keys, so their downtime numbers must agree
bit-for-bit; the benchmark asserts that before recording timings.
"""

from __future__ import annotations

import pathlib
import time

import jax
import numpy as np

from repro.core import simulator
from repro.core.simulator import _make_run, simulate_sweep

from .common import FIG34_RUNS as N_RUNS
from .common import FIG34_STEPS as N_STEPS
from .common import csv_row, write_bench


def _fig3_grid():
    from .fig3 import grid

    return grid()


def _per_point(scenarios, keys):
    """Old-style path: one fresh jit (compile) per grid point."""
    out = []
    for params in scenarios:
        G, N = params.network_shape
        run = jax.jit(jax.vmap(_make_run(G, N, N_STEPS, 2 * N), in_axes=(None, 0)))
        out.append(jax.tree_util.tree_map(np.asarray, run(params, keys)))
    return out


def run(write_json: bool = True) -> list[str]:
    labels, scenarios = _fig3_grid()
    keys = jax.random.split(jax.random.PRNGKey(0), N_RUNS)

    simulator.reset_trace_counts()
    t0 = time.perf_counter()
    before_out = _per_point(scenarios, keys)
    before_s = time.perf_counter() - t0
    before_compiles = sum(simulator.trace_counts().values())

    # Drop the sweep engine's shape cache so "after" pays its (single)
    # compile inside the timed region — a cold-start comparison.
    simulator._sweep_runner.cache_clear()
    simulator.reset_trace_counts()
    t0 = time.perf_counter()
    after = simulate_sweep(None, scenarios, n_runs=N_RUNS, n_steps=N_STEPS, seed=0)
    after_s = time.perf_counter() - t0
    after_compiles = sum(simulator.trace_counts().values())

    down_before = np.array([o["downtime_fraction"].mean() for o in before_out])
    down_after = after.downtime_fraction.mean(axis=1)
    if not np.array_equal(
        np.stack([o["downtime_fraction"] for o in before_out]),
        after.downtime_fraction,
    ):
        raise AssertionError("sweep result diverged from per-point path")

    record = {
        "grid": "fig3 (6 settings x 3 policies, 3x3 network)",
        "n_scenarios": len(scenarios),
        "n_runs": N_RUNS,
        "n_steps": N_STEPS,
        "before": {
            "path": "per-scenario fresh jit (old build_runner behavior)",
            "wall_s": round(before_s, 3),
            "compiles": before_compiles,
        },
        "after": {
            "path": "single vmapped simulate_sweep",
            "wall_s": round(after_s, 3),
            "compiles": after_compiles,
        },
        "speedup": round(before_s / after_s, 2),
        "bitwise_equal": True,
        "downtime_range": [float(down_after.min()), float(down_after.max())],
        "max_abs_diff": float(np.abs(down_before - down_after).max()),
    }
    if write_json:
        out = pathlib.Path(__file__).resolve().parent.parent / "BENCH_sweep.json"
        write_bench(out, "sweep", record)

    return [
        csv_row(
            "sweep/fig3_grid",
            after_s * 1e6 / len(scenarios),
            f"before={before_s:.1f}s/{before_compiles}x-compile "
            f"after={after_s:.1f}s/{after_compiles}x-compile "
            f"speedup={record['speedup']}x bitwise_equal=True",
        )
    ]


def main() -> None:
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
