"""Shared helpers for the paper-reproduction benchmarks.

Calibration (EXPERIMENTS.md §Paper-validation): the paper gives battery
100 kJ, delta = 100 s, kappa = (3,2,1), CE = (26,22,23) kJ but not the
per-figure arrival parameters. We use:

* Fig. 2b (semi-Markov analytics): arrivals U{6..10} (mean 8) — matches
  all four of the paper's q_lim markers;
* Fig. 2a (single-device sim):    p = 0.62, arrivals U{7..13} (mean 10)
  — matches the 15 W jobs count exactly and the throughput ordering;
* Fig. 3/4 (network sim):         heterogeneous means (6, 8, 10).
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import subprocess
import time

import numpy as np

from repro.core.simulator import SimConfig, scenario_from_config, scenario_params

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent

# Every BENCH_*.json at the repo root shares this envelope so
# ``benchmarks/run.py --summary`` can aggregate the perf trajectory and
# ``tests/test_bench_schema.py`` can validate every record (tier-1).
BENCH_SCHEMA_KEYS = ("name", "commit", "metrics")


def _git_commit() -> str:
    # A record produced from an uncommitted tree must not be attributed
    # to the clean commit it happens to sit on — except for the
    # BENCH_*.json records themselves, whose rewrite is the very point
    # of the run (they land in the next commit).
    try:
        head = subprocess.check_output(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=REPO_ROOT, text=True, stderr=subprocess.DEVNULL,
        ).strip()
        status = subprocess.check_output(
            ["git", "status", "--porcelain"],
            cwd=REPO_ROOT, text=True, stderr=subprocess.DEVNULL,
        )
        dirty = any(
            line and not line[3:].startswith("BENCH_")
            for line in status.splitlines()
        )
        return head + ("-dirty" if dirty else "")
    except Exception:
        return "unknown"


def bench_envelope(name: str, metrics: dict) -> dict:
    """The common BENCH_*.json envelope: {name, commit, metrics{}}."""
    return {"name": name, "commit": _git_commit(), "metrics": metrics}


def write_bench(path: pathlib.Path, name: str, metrics: dict) -> None:
    path.write_text(json.dumps(bench_envelope(name, metrics), indent=2) + "\n")


def drain_requests(server, reqs, limit: int = 200_000) -> None:
    """Step the server until every request is done or dropped."""
    steps = 0
    while not all(r.done or r.dropped for r in reqs):
        server.step()
        steps += 1
        if steps > limit:  # pragma: no cover
            raise RuntimeError("workload did not drain")


def timed(fn, *args, repeat: int = 3, **kw):
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def smoke_serving_model(name: str = "stablelm-1.6b"):
    """fp32 smoke model + params shared by the serving benchmarks."""
    import jax

    from repro.configs import get_smoke_config
    from repro.models import build_model, init_from_template

    cfg = dataclasses.replace(
        get_smoke_config(name), dtype="float32", param_dtype="float32"
    )
    model = build_model(cfg)
    params = init_from_template(model.template, jax.random.PRNGKey(0), "float32")
    return cfg, model, params


FIG2A_P = 0.62
FIG2A_ARRIVALS = (7, 13)
FIG2B_ARRIVALS = (6, 10)
FIG34_MEANS = (6.0, 8.0, 10.0)
XI_LIM = 0.01

# Fig. 2 power-mode strategies: name -> (pm_thresholds, pm_allowed).
PM_STRATEGIES = {
    "15W": ((), (1,)),
    "30W": ((), (2,)),
    "60W": ((), (3,)),
    "dynamic": ((40.0, 60.0), (1, 2, 3)),
}

# Shared Monte-Carlo scale for the Fig. 3/4 network sweeps.
FIG34_STEPS = 300
FIG34_RUNS = 200


def lower_strategies(n_steps: int, p_arrival: float, lo: int, hi: int):
    """All PM strategies as one stackable single-device scenario list
    (fixed-mode tables padded to the dynamic table's length)."""
    n_thr = max(len(thr) for thr, _ in PM_STRATEGIES.values())
    return [
        scenario_from_config(
            SimConfig(
                n_groups=1,
                n_per_group=1,
                n_steps=n_steps,
                p_arrival=p_arrival,
                pm_thresholds=thr,
                pm_allowed=allowed,
            ),
            np.array([[lo]]),
            np.array([[hi]]),
            n_thresholds=n_thr,
        )
        for thr, allowed in PM_STRATEGIES.values()
    ]


def sweep_grid(points, policies, base: SimConfig):
    """Cross sweep points with policies -> (labels, ScenarioParams list).

    ``points`` is ``[(label, topology, rates, config_overrides)]``; each
    point expands to one scenario per policy, labelled ``{label}/{policy}``.
    """
    labels, scenarios = [], []
    for label, topo, rates, overrides in points:
        for pol in policies:
            labels.append(f"{label}/{pol}")
            scenarios.append(
                scenario_params(
                    topo,
                    dataclasses.replace(base, policy=pol, **overrides),
                    long_term_rates=rates,
                )
            )
    return labels, scenarios
