"""Shared helpers for the paper-reproduction benchmarks.

Calibration (EXPERIMENTS.md §Paper-validation): the paper gives battery
100 kJ, delta = 100 s, kappa = (3,2,1), CE = (26,22,23) kJ but not the
per-figure arrival parameters. We use:

* Fig. 2b (semi-Markov analytics): arrivals U{6..10} (mean 8) — matches
  all four of the paper's q_lim markers;
* Fig. 2a (single-device sim):    p = 0.62, arrivals U{7..13} (mean 10)
  — matches the 15 W jobs count exactly and the throughput ordering;
* Fig. 3/4 (network sim):         heterogeneous means (6, 8, 10).
"""

from __future__ import annotations

import time


def timed(fn, *args, repeat: int = 3, **kw):
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


FIG2A_P = 0.62
FIG2A_ARRIVALS = (7, 13)
FIG2B_ARRIVALS = (6, 10)
FIG34_MEANS = (6.0, 8.0, 10.0)
XI_LIM = 0.01
