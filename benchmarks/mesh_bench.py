"""Mesh-sharded serving benchmark: tensor-parallel stage width sweep.

For each model-axis width w in {1, 2, 4, 8} (forced-host CPU devices:
``--xla_force_host_platform_device_count=8``), drain the same
continuous-batching workload through ``PipelineServer(mesh=...)`` —
params sharded per stage with ``SERVE_RULES``, KV state committed to
per-replica submeshes, one jitted dispatch per stage lowering to
collectives — and record

* end-to-end tokens/s and wall time;
* the mean/median inter-stage dispatch gap (``dispatch_log`` deltas
  within a step — the seam the async ring keeps sync-free);
* token-exactness against the single-device engine (dense AND paged
  substrates — widths must be bit-for-bit, not approximately equal);
* the number of collective ops in the compiled stage-0 decode HLO
  (0 at width 1; > 0 is the proof the dispatch actually lowered to
  cross-device communication).

One more record covers the multi-process engine: a 2x2 grid of real
worker processes, one SIGKILLed mid-stream — the drained token streams
must still match the single-device reference exactly (loss-free
re-prefill failover), and the router must have observed the membership
leave.

Forced-host widths share the same silicon, so tokens/s across widths is
reported, not asserted — the structural claims are exactness and the
collective count. If the current process has too few devices the sweep
re-execs itself in a subprocess with the forced-device flag set, so
``benchmarks.run`` works from any parent environment.

Results land in ``BENCH_mesh.json`` via the shared envelope.
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import re
import subprocess
import sys
import time

import numpy as np

from .common import csv_row, smoke_serving_model, write_bench

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_mesh.json"
WIDTHS = (1, 2, 4, 8)
N_DEVICES = 8

_COLLECTIVES = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|collective-permute)\b"
)


def _count_collectives(jitted, *args) -> int:
    """Collective ops in the compiled HLO of one jitted dispatch."""
    text = jitted.lower(*args).compile().as_text()
    return len(_COLLECTIVES.findall(text))


def _workload(cfg, smoke: bool):
    rng = np.random.default_rng(0)
    n_req, n_tok = (6, 6) if smoke else (12, 12)
    lens = rng.integers(4, 12, size=n_req)
    prompts = [rng.integers(0, cfg.vocab_size, size=int(n)) for n in lens]
    return prompts, n_tok


def _drain(server, reqs, gaps: list[float] | None = None, limit: int = 20_000):
    steps = 0
    while not all(r.done or r.dropped for r in reqs):
        mark = len(server.dispatch_log)
        server.step()
        if gaps is not None:
            ts = [t for _, _, t in server.dispatch_log[mark:]]
            gaps.extend(np.diff(ts))
        steps += 1
        if steps > limit:  # pragma: no cover
            raise RuntimeError("mesh bench did not drain")
    return [list(r.generated) for r in reqs]


def _measure(width: int, paged: bool, smoke: bool, reference) -> dict:
    """One (width, substrate) cell: drain, compare, count collectives."""
    from repro.launch.mesh import make_serving_mesh
    from repro.serving import PipelineServer

    cfg, model, params = smoke_serving_model()
    prompts, n_tok = _workload(cfg, smoke)
    mesh = None if width == 1 else make_serving_mesh(model_axis=width)
    server = PipelineServer(
        model,
        params,
        mesh=mesh,
        n_groups=2,
        n_replicas=2,
        policy="uniform",
        harvest_bounds=(60.0, 80.0),  # energy-unconstrained: pure compute
        max_len=64,
        max_batch=4,
        paged=paged,
        page_size=8,
        seed=3,
    )
    reqs = [server.submit(p, n_tokens=n_tok) for p in prompts]
    # Warm the compile caches so tokens/s measures steady-state dispatch.
    for _ in range(4):
        server.step()
    warm = server.stats.accepted_tokens
    gaps: list[float] = []
    t0 = time.perf_counter()
    toks = _drain(server, reqs, gaps)
    dt = time.perf_counter() - t0
    tokens = server.stats.accepted_tokens - warm
    gaps_us = np.asarray(gaps) * 1e6
    ncoll = None
    if not paged:
        # Stage-0 decode is the steady-state dispatch: re-lower it with
        # the live (placed) arguments and count collectives in the HLO.
        ex = server._exec[0]
        W = server.max_batch
        import jax.numpy as jnp

        inp = server._place(0, jnp.zeros((W, 1, 1), jnp.int32))
        mask = server._place(0, jnp.ones((W,), bool))
        ncoll = _count_collectives(
            ex.decode_masked,
            server._params_for(0, 0),
            inp,
            server._caches[(0, 0)],
            mask,
        )
    out = {
        "tokens_per_s": round(tokens / dt, 1),
        "wall_s": round(dt, 3),
        "tokens": tokens,
        "mean_dispatch_gap_us": round(float(gaps_us.mean()), 1) if len(gaps_us) else 0.0,
        "p50_dispatch_gap_us": round(float(np.median(gaps_us)), 1) if len(gaps_us) else 0.0,
        "token_exact_vs_single_device": int(toks == reference),
    }
    if ncoll is not None:
        out["decode_collectives"] = ncoll
    return out, toks


def _measure_mp(smoke: bool, reference) -> dict:
    """Multi-process cell: real workers, one killed mid-stream."""
    from repro.serving.mpserve import MPPipelineServer

    cfg, _, _ = smoke_serving_model()
    prompts, n_tok = _workload(cfg, smoke)
    spec = {
        "arch": "stablelm-1.6b",
        "smoke": True,
        "overrides": {"dtype": "float32", "param_dtype": "float32"},
        "seed": 0,
    }
    server = MPPipelineServer(
        spec,
        n_groups=2,
        n_replicas=2,
        policy="uniform",
        harvest_bounds=(60.0, 80.0),
        max_len=64,
        max_batch=4,
        seed=3,
    )
    try:
        reqs = [server.submit(p, n_tokens=n_tok) for p in prompts]
        v0 = server.router.membership_version
        for _ in range(4):
            server.step()
        # Kill the real OS process behind (0, 0); the ProcessMonitor
        # turns the exit into a membership leave on the next step.
        proc = server._workers[(0, 0)].proc
        proc.kill()
        proc.wait()
        t0 = time.perf_counter()
        toks = _drain(server, reqs)
        dt = time.perf_counter() - t0
        return {
            "token_exact_after_kill": int(toks == reference),
            "membership_events": server.router.membership_version - v0,
            "rerouted_stages": server.stats.rerouted_stages,
            "tokens": server.stats.tokens_generated,
            "wall_s": round(dt, 3),
        }
    finally:
        server.close()


def _sweep(smoke: bool) -> dict:
    import jax

    n_dev = jax.device_count()
    widths = [w for w in WIDTHS if w <= n_dev]
    cfg, model, params = smoke_serving_model()
    report: dict = {"smoke": smoke, "n_devices": n_dev, "widths": {}}
    refs = {}
    for paged in (False, True):
        # width-1, no mesh: the single-device reference stream
        cell, refs[paged] = _measure(1, paged, smoke, None)
        cell["token_exact_vs_single_device"] = 1
        report["widths"].setdefault("1", {})["paged" if paged else "dense"] = cell
    for w in widths[1:]:
        for paged in (False, True):
            cell, _ = _measure(w, paged, smoke, refs[paged])
            report["widths"].setdefault(str(w), {})[
                "paged" if paged else "dense"
            ] = cell
    report["mp_failover"] = _measure_mp(smoke, refs[False])
    return report


def _reexec_forced(smoke: bool) -> dict:
    """Run the sweep in a subprocess with 8 forced-host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={N_DEVICES}"
    root = pathlib.Path(__file__).resolve().parent.parent
    env["PYTHONPATH"] = (
        str(root / "src") + os.pathsep + str(root)
        + os.pathsep + env.get("PYTHONPATH", "")
    )
    cmd = [sys.executable, "-m", "benchmarks.mesh_bench", "--emit-json"]
    if smoke:
        cmd.append("--smoke")
    out = subprocess.run(
        cmd, cwd=root, env=env, capture_output=True, text=True, check=True
    )
    return json.loads(out.stdout)


def _rows(report: dict) -> list[str]:
    rows = []
    for w in sorted(report["widths"], key=int):
        for sub in ("dense", "paged"):
            cell = report["widths"][w].get(sub)
            if cell is None:
                continue
            extras = (
                f"tokens_per_s={cell['tokens_per_s']} "
                f"exact={cell['token_exact_vs_single_device']}"
            )
            if "decode_collectives" in cell:
                extras += f" collectives={cell['decode_collectives']}"
            rows.append(
                csv_row(f"mesh/{sub}_w{w}", cell["mean_dispatch_gap_us"], extras)
            )
    mp = report["mp_failover"]
    rows.append(
        csv_row(
            "mesh/mp_kill_failover",
            0.0,
            f"exact={mp['token_exact_after_kill']} "
            f"membership_events={mp['membership_events']} "
            f"rerouted={mp['rerouted_stages']}",
        )
    )
    return rows


def run(smoke: bool = False) -> list[str]:
    import jax

    if jax.device_count() >= N_DEVICES:
        report = _sweep(smoke)
    else:
        report = _reexec_forced(smoke)
    write_bench(BENCH_JSON, "mesh_bench", report)
    return _rows(report)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument(
        "--emit-json",
        action="store_true",
        help="print the report JSON to stdout instead of writing "
        "BENCH_mesh.json (internal: the forced-device re-exec child)",
    )
    args = ap.parse_args()
    if args.emit_json:
        print(json.dumps(_sweep(args.smoke)))
        return
    for row in run(smoke=args.smoke):
        print(row)


if __name__ == "__main__":
    main()
