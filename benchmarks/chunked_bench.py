"""Chunked-prefill benchmark: mixed prefill/decode traffic at batch 16.

The edge-serving regime (EdgeShard, the Network Edge Inference survey)
is a stream of prompts of *many different lengths* joining a batch of
resident decodes. Whole-prompt prefill issues one vmapped dispatch per
distinct prompt length, so every new length re-jits mid-traffic and a
long prompt's prefill head-of-line blocks every resident's next token.
Chunked prefill rides one fixed call shape, so the compile count is
independent of the workload's lengths and per-step prefill work is
bounded by the chunk.

Two claims, recorded in ``BENCH_chunked.json`` for dense and paged:

* **No tokens/s regression** — the same staggered mixed-length workload
  drained through ``prefill_chunk=None`` vs ``prefill_chunk=8`` servers
  at ``max_batch=16``; tokens/s must not drop under chunking.
* **Improved time-to-first-decode** — mean wall-clock TTFT over the
  workload drops because residents' decodes are never parked behind a
  fresh prompt-length compile or an unbounded prefill.

Also reported: the number of *traced prefill computations* per mode
(via ``repro.serving.trace_counts``) — the compile-count story behind
the wall-clock one. ``--smoke`` shrinks the workload for CI and skips
the JSON rewrite.
"""

from __future__ import annotations

import argparse
import pathlib
import time

import numpy as np

from repro.serving import PipelineServer, reset_trace_counts, trace_counts

from .common import csv_row, smoke_serving_model as _model, write_bench

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_chunked.json"

# Mixed traffic: eight distinct prompt lengths, cycled.
PROMPT_LENS = (4, 8, 12, 20, 28, 36, 48, 60)


def _prefill_traces() -> tuple[int, int]:
    """(distinct prefill shapes, total prefill traces) since last reset."""
    keys = [
        k for k in trace_counts()
        if k[0] in ("prefill", "prefill_pages", "chunk", "chunk_paged")
    ]
    return len(keys), sum(trace_counts()[k] for k in keys)


def mixed_traffic(
    *,
    paged: bool,
    prefill_chunk: int | None,
    n_requests: int,
    n_tokens: int,
    stagger: int = 2,
) -> dict:
    """Drain a staggered mixed-length workload; measure tokens/s + TTFT.

    ``stagger`` requests are submitted every slot (after an initial
    seed of 4), so later prompts' prefills genuinely interleave with
    resident decodes — the head-of-line regime chunking targets.
    """
    cfg, model, params = _model()
    reset_trace_counts()
    server = PipelineServer(
        model, params,
        n_groups=2, n_replicas=1, policy="uniform",
        harvest_bounds=(60.0, 80.0),  # energy-unconstrained: pure compute
        max_len=128, max_batch=16,
        paged=paged, page_size=16,
        prefill_chunk=prefill_chunk, seed=0,
    )
    prompts = [
        (np.arange(PROMPT_LENS[i % len(PROMPT_LENS)]) * 3 + i) % cfg.vocab_size
        for i in range(n_requests)
    ]
    t0 = time.perf_counter()
    reqs = [server.submit(p, n_tokens) for p in prompts[:4]]
    next_i, steps = 4, 0
    while not all(r.done for r in reqs) or next_i < n_requests:
        for _ in range(stagger):
            if next_i < n_requests:
                reqs.append(server.submit(prompts[next_i], n_tokens))
                next_i += 1
        server.step()
        steps += 1
        if steps > 200 * n_requests * n_tokens:  # pragma: no cover
            raise RuntimeError("mixed workload did not drain")
    wall = time.perf_counter() - t0
    if prefill_chunk is not None:
        # Compile-count budget: chunked runs must hold one compiled
        # shape per (kind, stage) — a length-keyed re-jit fails here.
        from repro.analysis import check_trace_budgets, load_budgets

        findings = check_trace_budgets(
            trace_counts(), load_budgets(),
            context=f"chunked_bench:{'paged' if paged else 'dense'}",
        )
        if findings:
            raise SystemExit("\n".join(f"FAIL {f}" for f in findings))
    ttfts = [r.ttft for r in reqs if r.ttft is not None]
    shapes, traces = _prefill_traces()
    tokens = server.stats.tokens_generated
    return {
        "tokens_per_s": round(tokens / wall, 1),
        "wall_s": round(wall, 3),
        "tokens": tokens,
        "mean_ttft_s": round(float(np.mean(ttfts)), 4),
        "p95_ttft_s": round(float(np.percentile(ttfts, 95)), 4),
        "prefill_shapes_compiled": shapes,
        "prefill_traces": traces,
        "chunk_prefill_calls": server.stats.chunk_prefill_calls,
        "prefill_calls": server.stats.prefill_calls,
    }


def run(smoke: bool = False) -> list[str]:
    n_requests = 8 if smoke else 24
    n_tokens = 4 if smoke else 12
    chunk = 8
    rows, report = [], {
        "model": "stablelm-1.6b(smoke)",
        "max_batch": 16,
        "prompt_lens": list(PROMPT_LENS),
        "n_requests": n_requests,
        "n_tokens": n_tokens,
        "prefill_chunk": chunk,
        "smoke": smoke,
    }
    for mode in ("dense", "paged"):
        paged = mode == "paged"
        whole = mixed_traffic(
            paged=paged, prefill_chunk=None,
            n_requests=n_requests, n_tokens=n_tokens,
        )
        chunked = mixed_traffic(
            paged=paged, prefill_chunk=chunk,
            n_requests=n_requests, n_tokens=n_tokens,
        )
        ratio = chunked["tokens_per_s"] / max(whole["tokens_per_s"], 1e-9)
        ttfd = whole["mean_ttft_s"] / max(chunked["mean_ttft_s"], 1e-9)
        report[mode] = {
            "whole_prompt": whole,
            "chunked": chunked,
            "tokens_per_s_ratio": round(ratio, 3),
            "ttft_speedup": round(ttfd, 2),
        }
        rows.append(
            csv_row(
                f"chunked/{mode}",
                1e6 / max(chunked["tokens_per_s"], 1e-9),
                f"chunked={chunked['tokens_per_s']} tok/s "
                f"whole={whole['tokens_per_s']} tok/s ratio={ratio:.3f} "
                f"ttft {chunked['mean_ttft_s']}s vs {whole['mean_ttft_s']}s "
                f"({ttfd:.2f}x) prefill_shapes "
                f"{chunked['prefill_shapes_compiled']} vs "
                f"{whole['prefill_shapes_compiled']}",
            )
        )
    if not smoke:
        write_bench(BENCH_JSON, "chunked_prefill", report)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke", action="store_true",
        help="small CI run: fewer requests/tokens, no BENCH_chunked.json",
    )
    args = ap.parse_args()
    for row in run(smoke=args.smoke):
        print(row, flush=True)


if __name__ == "__main__":
    main()
