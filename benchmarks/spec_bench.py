"""Speculative draft-verify decoding benchmark: accepted tokens/s.

The speculative engine's win is *dispatch-count*: a round commits up to
``spec_k + 1`` tokens from two dispatches (one scanned draft call, one
``verify_step_paged`` chunk), where plain paged decode pays one dispatch
per token. On the CI container's CPU "device" the per-dispatch host
assembly cost dominates the smoke model's sub-millisecond kernels, so
the A/B below isolates exactly that seam — the same seeded workload is
drained through plain paged decode and through the speculative engine,
recording

* accepted tokens/s (``ServerStats.accepted_tokens``; identical to
  ``tokens_generated`` in both engines — greedy accept makes the two
  streams bit-for-bit equal, so the benchmark compares like for like);
* energy per accepted token (``energy_charged / accepted_tokens``:
  the scheduler charges CE(PM)/kappa per *call*, so committing more
  tokens per call divides the same energy over more tokens);
* round acceptance rate and dispatch counts.

The draft is the target's live 1-layer prefix (see ``_models``): a
genuinely quarter-depth draft with ~1.0 acceptance by construction —
the random-weights stand-in for a distilled draft pairing. The
cross-model pairing sweep in ``tests/test_spec_decode.py`` covers the
acceptance<1 regimes. Passes
are interleaved plain/spec and the headline ratio is the median over
temporally adjacent pairs (cancels container drift); per-batch bests of
3 land in ``BENCH_spec.json`` via the shared envelope.
"""

from __future__ import annotations

import argparse
import pathlib
import time

import numpy as np

from repro.serving import PipelineServer, reset_trace_counts

from .common import csv_row, write_bench

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_spec.json"

_MODELS = None


def _models():
    """Target + draft for the speculative A/B.

    Target: the async-bench-scaled smoke model (4 layers x d256) —
    enough per-layer compute that depth, not fixed per-call overhead,
    dominates a dispatch. Draft: the target's *live 1-layer prefix* —
    the deeper layers' residual writers (``attn/wo``, ``mlp/wo``) are
    zeroed in the target, so its function collapses to the first layer
    while its cost stays full-depth, and the draft (the sliced first
    layer sharing embed/unembed/final-norm) predicts the same greedy
    tokens at a quarter of the depth. This is the random-weights
    stand-in for a distilled draft pairing: acceptance ~1.0 with a
    genuinely cheaper draft, the regime the registry's
    ``SPEC_DRAFT_PAIRS`` targets. The acceptance<1 regimes are covered
    by the pairing sweep in ``tests/test_spec_decode.py``."""
    global _MODELS
    if _MODELS is None:
        import dataclasses

        import jax

        from repro.configs import get_smoke_config
        from repro.models import build_model, init_from_template

        tcfg = dataclasses.replace(
            get_smoke_config("stablelm-1.6b"),
            dtype="float32",
            param_dtype="float32",
            n_layers=4,
            d_model=256,
            n_heads=8,
            n_kv_heads=8,
            d_ff=1024,
        )
        target = build_model(tcfg)
        params = init_from_template(
            target.template, jax.random.PRNGKey(0), "float32"
        )
        c0 = dict(params["classes"]["c0"])
        c0["attn"] = {**c0["attn"], "wo": c0["attn"]["wo"].at[1:].set(0.0)}
        c0["mlp"] = {**c0["mlp"], "wo": c0["mlp"]["wo"].at[1:].set(0.0)}
        params = {**params, "classes": {**params["classes"], "c0": c0}}
        draft = build_model(dataclasses.replace(tcfg, n_layers=1))
        dparams = {
            **params,
            "classes": {
                **params["classes"],
                "c0": jax.tree_util.tree_map(lambda x: x[:1], c0),
            },
        }
        _MODELS = (tcfg, target, params, draft, dparams)
    return _MODELS


def _drain_measured(
    spec_k: int | None,
    *,
    max_batch: int,
    n_requests: int,
    n_tokens: int,
    prompt_len: int = 6,
) -> dict:
    """Drain one workload (plain paged when ``spec_k`` is None, else
    speculative), measuring post-warmup accepted tokens/s and energy per
    accepted token. Warmup is a throwaway wave of the same batch shape
    drained to completion first, so every dispatch shape (prefill, draft
    ingest/round, verify, decode) is compiled before the clock starts."""
    cfg, model, params, draft, dparams = _models()
    reset_trace_counts()  # each engine run is its own compile universe
    server = PipelineServer(
        model,
        params,
        n_groups=1,
        n_replicas=1,
        policy="uniform",
        harvest_bounds=(60.0, 80.0),  # energy-unconstrained: pure compute
        max_len=128,
        max_batch=max_batch,
        paged=True,
        page_size=16,
        async_depth=2,
        seed=0,
        **(
            dict(spec_draft=(draft, dparams), spec_k=spec_k)
            if spec_k is not None
            else {}
        ),
    )

    def drain(wave_tokens: int, offset: int) -> int:
        reqs = [
            server.submit(
                (np.arange(prompt_len) + offset + i) % cfg.vocab_size,
                wave_tokens,
            )
            for i in range(n_requests)
        ]
        steps = 0
        while not all(r.done or r.dropped for r in reqs):
            server.step()
            steps += 1
            if steps > 100 * n_requests * wave_tokens:  # pragma: no cover
                raise RuntimeError("spec bench did not drain")
        return steps

    # Warmup wave: one full speculative round per request (spec_k + 1
    # tokens) compiles every dispatch shape the measured wave reuses.
    drain((spec_k or 4) + 1, offset=0)
    warm_tokens = server.stats.accepted_tokens
    warm_energy = server.stats.energy_charged
    t0 = time.perf_counter()
    steps = drain(n_tokens, offset=1)
    dt = time.perf_counter() - t0
    tokens = server.stats.accepted_tokens - warm_tokens
    energy = server.stats.energy_charged - warm_energy
    st = server.stats
    return {
        "accepted_tokens_per_s": round(tokens / dt, 1),
        "wall_s": round(dt, 3),
        "accepted_tokens": tokens,
        "steps": steps,
        "decode_calls": st.decode_calls,
        "draft_calls": st.draft_calls,
        "verify_calls": st.verify_calls,
        "spec_rounds": st.spec_rounds,
        "acceptance_rate": round(st.acceptance_rate, 3),
        "energy_per_accepted_token": round(energy / max(tokens, 1), 3),
    }


def _ab_at_batch(
    max_batch: int, n_tokens: int, spec_k: int, repeats: int
) -> dict:
    """Interleaved plain/spec passes at one batch size; bests of N plus
    a drift-cancelling median-of-adjacent-pairs ratio."""
    plain_passes, spec_passes = [], []
    for _ in range(repeats):
        plain_passes.append(_drain_measured(
            None, max_batch=max_batch, n_requests=max_batch,
            n_tokens=n_tokens,
        ))
        spec_passes.append(_drain_measured(
            spec_k, max_batch=max_batch, n_requests=max_batch,
            n_tokens=n_tokens,
        ))
    plain = max(plain_passes, key=lambda d: d["accepted_tokens_per_s"])
    spec = max(spec_passes, key=lambda d: d["accepted_tokens_per_s"])
    ratio = float(np.median([
        s["accepted_tokens_per_s"] / max(p["accepted_tokens_per_s"], 1e-9)
        for p, s in zip(plain_passes, spec_passes)
    ]))
    energy_ratio = float(np.median([
        s["energy_per_accepted_token"]
        / max(p["energy_per_accepted_token"], 1e-9)
        for p, s in zip(plain_passes, spec_passes)
    ]))
    return {
        "plain": plain,
        "spec": spec,
        "plain_passes_tokens_per_s": [
            p["accepted_tokens_per_s"] for p in plain_passes
        ],
        "spec_passes_tokens_per_s": [
            p["accepted_tokens_per_s"] for p in spec_passes
        ],
        "accepted_tokens_per_s_ratio_spec_vs_plain": round(ratio, 2),
        "energy_per_token_ratio_spec_vs_plain": round(energy_ratio, 2),
    }


def run(smoke: bool = False, spec_k: int = 4, repeats: int | None = None) -> list[str]:
    if smoke:
        batches, n_tokens = (8,), 10
    else:
        # n_tokens a multiple of spec_k + 1: at ~full acceptance every
        # round runs the one already-compiled verify width.
        batches, n_tokens = (16, 64), 50
    if repeats is None:
        repeats = 1 if smoke else 3
    report: dict = {"spec_k": spec_k, "n_tokens": n_tokens, "smoke": smoke,
                    "repeats": repeats, "batches": {}}
    rows: list[str] = []
    for max_batch in batches:
        ab = _ab_at_batch(max_batch, n_tokens, spec_k, repeats)
        report["batches"][str(max_batch)] = ab
        plain, spec = ab["plain"], ab["spec"]
        rows.append(csv_row(
            f"spec/plain_batch{max_batch}",
            0.0,
            f"accepted_tokens_per_s={plain['accepted_tokens_per_s']} "
            f"energy_per_token={plain['energy_per_accepted_token']}",
        ))
        rows.append(csv_row(
            f"spec/k{spec_k}_batch{max_batch}",
            0.0,
            f"accepted_tokens_per_s={spec['accepted_tokens_per_s']} "
            f"acceptance={spec['acceptance_rate']} "
            f"energy_per_token={spec['energy_per_accepted_token']}",
        ))
        rows.append(csv_row(
            f"spec/speedup_batch{max_batch}",
            0.0,
            f"spec_vs_plain="
            f"{ab['accepted_tokens_per_s_ratio_spec_vs_plain']:.2f}x "
            f"energy_ratio="
            f"{ab['energy_per_token_ratio_spec_vs_plain']:.2f}x",
        ))
    if not smoke:
        write_bench(BENCH_JSON, "spec_decode", report)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="small CI run: batch 8, 1 repeat, no BENCH_spec.json",
    )
    ap.add_argument(
        "--spec-k", type=int, default=4,
        help="draft tokens proposed per speculative round",
    )
    args = ap.parse_args()
    for row in run(smoke=args.smoke, spec_k=args.spec_k):
        print(row, flush=True)


if __name__ == "__main__":
    main()
