"""Serving-engine benchmark: the paper's scheduler driving real decode
compute on a tiny model — tokens/s and downtime per policy, a failover
run (tokens keep flowing after a replica dies), and the continuous-
batching sweep (tokens/s at max_batch 1/4/16; the speedup is recorded in
``BENCH_serve_batch.json``).

Before the heavy real-compute runs, the abstract network simulator
predicts each policy's downtime for the same fleet shape via one
``simulate_sweep`` call (one jit compile for every candidate policy) —
the sweep engine doubles as the serving fleet's capacity planner."""

from __future__ import annotations

import argparse
import pathlib
import time

import numpy as np

from repro.analysis import check_trace_budgets, load_budgets
from repro.core.network import paper_topology
from repro.core.simulator import SimConfig, simulate_sweep
from repro.serving import PipelineServer, reset_trace_counts, trace_counts

from .common import csv_row, smoke_serving_model as _model, timed, write_bench

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_serve_batch.json"


def _server(policy: str, seed: int = 0, harvest=(6.0, 10.0), **kw):
    _, model, params = _model()
    return PipelineServer(
        model,
        params,
        n_groups=kw.pop("n_groups", 3),
        n_replicas=kw.pop("n_replicas", 3),
        policy=policy,
        harvest_bounds=harvest,
        max_len=kw.pop("max_len", 64),
        seed=seed,
        **kw,
    )


def _planned_downtime(
    policies: tuple[str, ...],
    harvest=(6.0, 10.0),
    arrival_p: float = 0.5,
    n_slots: int = 60,
    n_runs: int = 64,
) -> dict[str, float]:
    """Abstract-model downtime forecast for the server's (G=3, R=3) fleet:
    one vmapped sweep over the candidate policies, one compile."""
    mean = (harvest[0] + harvest[1]) / 2
    topo = paper_topology(
        n_groups=3, n_per_group=3, arrival_means=(mean,) * 3, half_width=2
    )
    cfgs = [
        SimConfig(
            n_groups=3, n_per_group=3, n_steps=n_slots, p_arrival=arrival_p, policy=p
        )
        for p in policies
    ]
    res = simulate_sweep(topo, cfgs, n_runs=n_runs)
    return {p: float(res.downtime_fraction[i].mean()) for i, p in enumerate(policies)}


def batch_sweep(
    batch_sizes=(1, 4, 16),
    *,
    n_requests: int = 16,
    n_tokens: int = 32,
    prompt_len: int = 6,
    warmup_slots: int = 6,
    smoke: bool = False,
    prefill_chunk: int | None = None,
    async_depth: int = 2,
) -> tuple[list[str], dict]:
    """Continuous-batching throughput: the same n_requests × n_tokens
    workload drained through servers of increasing ``max_batch``. One
    masked decode dispatch serves every resident request, so tokens/s
    scales with occupancy while the per-slot dispatch count stays flat."""
    cfg, model, params = _model()
    trace_budgets = load_budgets()
    rows, report = [], {}
    for mb in batch_sizes:
        reset_trace_counts()  # each max_batch is its own compile universe
        server = PipelineServer(
            model,
            params,
            n_groups=2,
            n_replicas=1,
            policy="uniform",
            harvest_bounds=(60.0, 80.0),  # energy-unconstrained: pure compute
            max_len=128,
            max_batch=mb,
            prefill_chunk=prefill_chunk,
            async_depth=async_depth,
            seed=0,
        )
        reqs = [
            server.submit((np.arange(prompt_len) + i) % cfg.vocab_size, n_tokens)
            for i in range(n_requests)
        ]
        for _ in range(warmup_slots):  # compile prefill/decode dispatches
            server.step()
        # accepted_tokens is the cross-engine comparison metric: equal to
        # tokens_generated under plain decode, accepted tokens under the
        # speculative engine (see spec_bench) — same denominator either way.
        warm_tokens = server.stats.accepted_tokens
        warm_decode_calls = server.stats.decode_calls
        t0 = time.perf_counter()
        steps = 0
        while not all(r.done for r in reqs):
            server.step()
            steps += 1
            if steps > 100 * n_requests * n_tokens:  # pragma: no cover
                raise RuntimeError("batch sweep did not drain")
        dt = time.perf_counter() - t0
        findings = check_trace_budgets(
            trace_counts(), trace_budgets, context=f"serve_bench:batch{mb}"
        )
        if findings:  # compile-count budget: one decode shape per stage
            raise SystemExit("\n".join(f"FAIL {f}" for f in findings))
        tokens = server.stats.accepted_tokens - warm_tokens
        tps = tokens / dt
        report[str(mb)] = {
            "tokens_per_s": round(tps, 1),
            "accepted_tokens_per_s": round(tps, 1),
            "wall_s": round(dt, 3),
            "tokens": tokens,
            "decode_calls": server.stats.decode_calls - warm_decode_calls,
            "queued_jobs": server.stats.queued_jobs,
        }
        rows.append(
            csv_row(
                f"serve/batch{mb}",
                1e6 / max(tps, 1e-9),
                f"tokens_per_s={tps:.1f} tokens={tokens} "
                f"decode_calls={report[str(mb)]['decode_calls']} "
                f"queued={server.stats.queued_jobs}",
            )
        )
    lo, hi = str(batch_sizes[0]), str(batch_sizes[-1])
    speedup = report[hi]["tokens_per_s"] / max(report[lo]["tokens_per_s"], 1e-9)
    report_full = {
        "model": cfg.name,
        "n_requests": n_requests,
        "n_tokens": n_tokens,
        "prompt_len": prompt_len,
        "prefill_chunk": prefill_chunk,
        "async_depth": async_depth,
        "smoke": smoke,
        "batch": report,
        f"speedup_{hi}_vs_{lo}": round(speedup, 2),
    }
    rows.append(
        csv_row(
            "serve/batch_speedup",
            0.0,
            f"batch{hi}_vs_batch{lo}={speedup:.2f}x",
        )
    )
    if not smoke:
        write_bench(BENCH_JSON, "serve_batch", report_full)
    return rows, report_full


def run(
    smoke: bool = False,
    prefill_chunk: int | None = None,
    async_depth: int = 2,
) -> list[str]:
    rows = []
    n_slots = 20 if smoke else 60
    policies = ("uniform", "adaptive")
    plan = _planned_downtime(policies, n_slots=n_slots, n_runs=16 if smoke else 64)
    for policy in policies:
        server = _server(policy)
        stats, dt = timed(
            server.run, n_slots, arrival_p=0.5, prompt_len=6, n_tokens=2, repeat=1
        )
        rows.append(
            csv_row(
                f"serve/{policy}",
                dt * 1e6 / max(stats.tokens_generated, 1),
                f"tokens={stats.tokens_generated} "
                f"accepted={stats.accepted_tokens} "
                f"jobs={stats.completed_jobs} "
                f"dropped={stats.dropped_jobs} queued={stats.queued_jobs} "
                f"downtime={stats.downtime_fraction:.3f} "
                f"planned_downtime={plan[policy]:.3f}",
            )
        )
    # Failover: kill a replica mid-run; throughput must continue.
    server = _server("adaptive", seed=3, harvest=(20.0, 30.0))
    req = server.submit(np.arange(6), n_tokens=6)
    for _ in range(4):
        server.step()
    server.fail_replica(req.stage, req.replicas[req.stage])
    stats, dt = timed(
        server.run, 30 if smoke else 80, arrival_p=0.3, n_tokens=2, repeat=1
    )
    rows.append(
        csv_row(
            "serve/failover",
            dt * 1e6 / max(stats.tokens_generated, 1),
            f"tokens={stats.tokens_generated} rerouted={stats.rerouted_stages} "
            f"job_done={req.done}",
        )
    )
    # Continuous-batching throughput sweep.
    if smoke:
        batch_rows, _ = batch_sweep(
            (1, 4, 16), n_requests=8, n_tokens=8, smoke=True,
            prefill_chunk=prefill_chunk, async_depth=async_depth,
        )
    else:
        batch_rows, _ = batch_sweep(
            (1, 4, 16), prefill_chunk=prefill_chunk, async_depth=async_depth,
        )
    rows.extend(batch_rows)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="small CI run: fewer requests/tokens, no BENCH_serve_batch.json",
    )
    ap.add_argument(
        "--prefill-chunk", type=int, default=None,
        help="run the batch sweep with chunked prefill (fixed N-token chunks)",
    )
    ap.add_argument(
        "--async-depth", type=int, default=2,
        help="in-flight calls per replica in the batch sweep "
             "(0 = legacy synchronous engine)",
    )
    args = ap.parse_args()
    for row in run(
        smoke=args.smoke,
        prefill_chunk=args.prefill_chunk,
        async_depth=args.async_depth,
    ):
        print(row, flush=True)


if __name__ == "__main__":
    main()
