"""Serving-engine benchmark: the paper's scheduler driving real decode
compute on a tiny model — tokens/s and downtime per policy, plus a
failover run (tokens keep flowing after a replica dies).

Before the heavy real-compute runs, the abstract network simulator
predicts each policy's downtime for the same fleet shape via one
``simulate_sweep`` call (one jit compile for every candidate policy) —
the sweep engine doubles as the serving fleet's capacity planner."""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.network import paper_topology
from repro.core.simulator import SimConfig, simulate_sweep
from repro.models import build_model, init_from_template
from repro.serving import PipelineServer

from .common import csv_row, timed


def _server(policy: str, seed: int = 0, harvest=(6.0, 10.0)):
    cfg = dataclasses.replace(
        get_smoke_config("stablelm-1.6b"), dtype="float32", param_dtype="float32"
    )
    model = build_model(cfg)
    params = init_from_template(model.template, jax.random.PRNGKey(0), "float32")
    return PipelineServer(
        model,
        params,
        n_groups=3,
        n_replicas=3,
        policy=policy,
        harvest_bounds=harvest,
        max_len=64,
        seed=seed,
    )


def _planned_downtime(
    policies: tuple[str, ...], harvest=(6.0, 10.0), arrival_p: float = 0.5
) -> dict[str, float]:
    """Abstract-model downtime forecast for the server's (G=3, R=3) fleet:
    one vmapped sweep over the candidate policies, one compile."""
    mean = (harvest[0] + harvest[1]) / 2
    topo = paper_topology(
        n_groups=3, n_per_group=3, arrival_means=(mean,) * 3, half_width=2
    )
    cfgs = [
        SimConfig(
            n_groups=3, n_per_group=3, n_steps=60, p_arrival=arrival_p, policy=p
        )
        for p in policies
    ]
    res = simulate_sweep(topo, cfgs, n_runs=64)
    return {p: float(res.downtime_fraction[i].mean()) for i, p in enumerate(policies)}


def run() -> list[str]:
    rows = []
    policies = ("uniform", "adaptive")
    plan = _planned_downtime(policies)
    for policy in policies:
        server = _server(policy)
        stats, dt = timed(
            server.run, 60, arrival_p=0.5, prompt_len=6, n_tokens=2, repeat=1
        )
        rows.append(
            csv_row(
                f"serve/{policy}",
                dt * 1e6 / max(stats.tokens_generated, 1),
                f"tokens={stats.tokens_generated} jobs={stats.completed_jobs} "
                f"dropped={stats.dropped_jobs} downtime={stats.downtime_fraction:.3f} "
                f"planned_downtime={plan[policy]:.3f}",
            )
        )
    # Failover: kill a replica mid-run; throughput must continue.
    server = _server("adaptive", seed=3, harvest=(20.0, 30.0))
    req = server.submit(np.arange(6), n_tokens=6)
    for _ in range(4):
        server.step()
    server.fail_replica(req.stage, req.replicas[req.stage])
    stats, dt = timed(server.run, 80, arrival_p=0.3, n_tokens=2, repeat=1)
    rows.append(
        csv_row(
            "serve/failover",
            dt * 1e6 / max(stats.tokens_generated, 1),
            f"tokens={stats.tokens_generated} rerouted={stats.rerouted_stages} "
            f"job_done={req.done}",
        )
    )
    return rows


def main() -> None:
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
