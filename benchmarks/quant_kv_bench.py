"""int8 KV pages vs compute-dtype pages (paged serving).

Three claims, recorded in ``BENCH_quant_kv.json``:

* **Capacity at equal KV bytes** — an int8 page costs
  ``page_size * (KV * Dh + 4)`` bytes per pool per layer (values + one
  fp32 scale per row) against fp32's ``page_size * KV * Dh * 4``, so
  the same byte budget holds ~3.8x the pages at fp32 compute.
  ``kv_page_bytes`` sizes the int8 pool to the fp32 pool's bytes and
  the same heavy short-request workload is driven through both; the
  acceptance bar is >= 1.8x peak residents.
* **Throughput at batch 16** — tokens/s for the same drained workload,
  compute-dtype vs int8 pages, interleaved in one process
  (scatter-quant + gather-dequant must not cost throughput).
* **Greedy agreement** — teacher-forced argmax agreement vs fp32-KV
  pages over 64 decode steps (the per-step flip probability of int8 KV
  noise; the free-running compounding variant is what
  ``tests/test_quant_kv.py`` sweeps per registry model).

``--smoke`` shrinks the workload for CI and skips the JSON rewrite.
"""

from __future__ import annotations

import argparse
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving import PipelineServer, kv_page_bytes

from .common import (
    csv_row,
    drain_requests as _drain,
    smoke_serving_model as _model,
    write_bench,
)

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_quant_kv.json"


def _kv_bytes(server: PipelineServer) -> int:
    """Persistent KV allocation of one replica's pools, scales included."""
    leaves = jax.tree_util.tree_leaves(server._caches[(0, 0)])
    return sum(x.nbytes for x in leaves)


def capacity_at_equal_kv_bytes(
    *, n_requests: int, n_tokens: int, prompt_len: int, max_batch: int
) -> dict:
    """Same pool BYTES, fp32 vs int8 pages: the int8 pool's ``max_pages``
    is sized by :func:`repro.serving.kv_page_bytes` to fit the fp32
    pool's budget, and peak concurrent residents are compared."""
    cfg, model, params = _model()
    page_size = 16
    fp_pages = 4 * 128 // page_size - 1  # the dense-equivalent budget
    pb_fp = kv_page_bytes(
        page_size, cfg.n_kv_heads, cfg.head_dim, cfg.n_layers, "float32"
    )
    pb_i8 = kv_page_bytes(
        page_size, cfg.n_kv_heads, cfg.head_dim, cfg.n_layers, "int8"
    )
    i8_pages = (fp_pages + 1) * pb_fp // pb_i8 - 1
    kw = dict(
        n_groups=2, n_replicas=1, policy="uniform",
        harvest_bounds=(60.0, 80.0), max_len=128, max_batch=max_batch,
        paged=True, page_size=page_size, seed=0,
    )
    out = {}
    for mode, kv_dtype, pages in (
        ("fp32", None, fp_pages), ("int8", "int8", i8_pages)
    ):
        server = PipelineServer(
            model, params, kv_dtype=kv_dtype, max_pages=pages, **kw
        )
        reqs = [
            server.submit((np.arange(prompt_len) + i) % cfg.vocab_size, n_tokens)
            for i in range(n_requests)
        ]
        _drain(server, reqs)
        assert all(r.done for r in reqs)
        out[mode] = {
            "max_pages": int(pages),
            "kv_bytes_per_replica": _kv_bytes(server),
            "peak_resident": server.stats.peak_active,
            "completed": server.stats.completed_jobs,
            "preempted": server.stats.preempted_jobs,
        }
    assert out["int8"]["kv_bytes_per_replica"] <= out["fp32"]["kv_bytes_per_replica"]
    out["resident_gain"] = round(
        out["int8"]["peak_resident"] / max(out["fp32"]["peak_resident"], 1), 2
    )
    return out


def throughput_at_batch(
    batch: int, *, n_requests: int, n_tokens: int, prompt_len: int,
    repeat: int = 5,
) -> dict:
    """Steady-state tokens/s, compute-dtype vs int8 pages, equal
    max_batch, interleaved in one process (cross-process timing is not
    trustworthy on a shared box); warmup wave first, best-of-repeat."""
    cfg, model, params = _model()
    kw = dict(
        n_groups=2, n_replicas=1, policy="uniform",
        harvest_bounds=(60.0, 80.0), max_len=128, max_batch=batch,
        paged=True, page_size=16, seed=0,
    )

    def wave(server):
        reqs = [
            server.submit((np.arange(prompt_len) + i) % cfg.vocab_size, n_tokens)
            for i in range(n_requests)
        ]
        t0 = time.perf_counter()
        _drain(server, reqs)
        return time.perf_counter() - t0

    servers = {
        "fp32": PipelineServer(model, params, kv_dtype=None, **kw),
        "int8": PipelineServer(model, params, kv_dtype="int8", **kw),
    }
    for s in servers.values():
        wave(s)  # warmup: compiles every dispatch shape
    tokens = n_requests * n_tokens
    best = {mode: float("inf") for mode in servers}
    ratios = []
    for r in range(repeat):  # interleave the A/B waves
        # Alternate the order each round: each wave is sub-second, so a
        # background blip hitting "whichever mode runs second" would
        # otherwise bias the comparison one way.
        order = list(servers) if r % 2 == 0 else list(servers)[::-1]
        t = {}
        for mode in order:
            t[mode] = wave(servers[mode])
            best[mode] = min(best[mode], t[mode])
        ratios.append(t["fp32"] / t["int8"])
    out = {
        mode: {
            "tokens_per_s": round(tokens / best[mode], 1),
            "wall_s": round(best[mode], 3),
            "tokens": tokens,
        }
        for mode in servers
    }
    # Median of per-round paired ratios: drift hits both modes of a
    # round together, so the pairing cancels it where best-of cannot.
    out["int8_vs_fp32"] = round(float(np.median(ratios)), 3)
    return out


def greedy_agreement_for(
    name: str, n_steps: int = 64, prompt_len: int = 12, page: int = 8
) -> float:
    """Teacher-forced argmax agreement, int8 vs fp32 KV pages, at the
    model level: W=2 lanes share one pool per dtype and both consume
    the fp32 stream, so a flip at step t cannot compound into steps
    > t. Shared with ``tests/test_quant_kv.py`` (the registry sweep),
    so the bench and the accuracy test measure the same thing."""
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.models import build_model, init_from_template

    cfg = dataclasses.replace(
        get_smoke_config(name), dtype="float32", param_dtype="float32"
    )
    model = build_model(cfg)
    params = init_from_template(model.template, jax.random.PRNGKey(0), "float32")
    W = 2
    NB = (prompt_len + n_steps) // page + 2
    shape = (cfg.n_layers, W * NB + 1, page, cfg.n_kv_heads, cfg.head_dim)
    bt = jnp.asarray(np.arange(W * NB, dtype=np.int32).reshape(W, NB))
    pools = {
        "fp32": {"k": jnp.zeros(shape, jnp.float32),
                 "v": jnp.zeros(shape, jnp.float32)},
        "int8": {"k": jnp.zeros(shape, jnp.int8),
                 "v": jnp.zeros(shape, jnp.int8),
                 "k_scale": jnp.ones(shape[:3], jnp.float32),
                 "v_scale": jnp.ones(shape[:3], jnp.float32)},
    }
    prompts = jnp.asarray(
        np.stack([(np.arange(prompt_len) * 3 + i) % cfg.vocab_size
                  for i in range(W)]).astype(np.int32)
    )
    offs = jnp.zeros((W,), jnp.int32)
    valids = jnp.full((W,), prompt_len, jnp.int32)
    chunk_fn = jax.jit(model.prefill_chunk_paged)
    decode_fn = jax.jit(model.decode_paged)
    toks, agreements = {}, []
    for kv in pools:
        out, pools[kv] = chunk_fn(params, prompts, pools[kv], offs, valids, bt)
        toks[kv] = np.asarray(jnp.argmax(out[:, prompt_len - 1], axis=-1))
    agreements.append(float(np.mean(toks["fp32"] == toks["int8"])))
    feed = toks["fp32"]  # teacher forcing: both consume the fp32 stream
    for i in range(n_steps - 1):
        lens = jnp.full((W,), prompt_len + i, jnp.int32)
        for kv in pools:
            out, pools[kv] = decode_fn(
                params, jnp.asarray(feed)[:, None], pools[kv], lens, bt
            )
            toks[kv] = np.asarray(jnp.argmax(out[:, 0], axis=-1))
        agreements.append(float(np.mean(toks["fp32"] == toks["int8"])))
        feed = toks["fp32"]
    return float(np.mean(agreements))


def greedy_agreement(n_steps: int = 64, prompt_len: int = 12) -> dict:
    return {
        "n_steps": n_steps,
        "lanes": 2,
        "teacher_forced_agreement": round(
            greedy_agreement_for("stablelm-1.6b", n_steps, prompt_len), 4
        ),
    }


def run(smoke: bool = False) -> list[str]:
    rows = []
    # Throughput FIRST, in a fresh process state: the capacity phase's
    # W=64 churn (big pools allocated and dropped) measurably flattens
    # a later A/B comparison on this box. 64 decode tokens per request:
    # steady-state decode is where int8's 4x-smaller gather pays;
    # sub-second waves of short decodes are scheduler-noise-dominated.
    tp = throughput_at_batch(
        16,
        n_requests=8 if smoke else 16,
        n_tokens=8 if smoke else 64,
        prompt_len=6,
    )
    # Slots must not bind before pages do (max_batch > the fp32 pool's
    # 31 pages), or both modes plateau at max_batch and the gain hides.
    cap = capacity_at_equal_kv_bytes(
        n_requests=48 if smoke else 80,
        n_tokens=2 if smoke else 8,
        prompt_len=6,
        max_batch=48 if smoke else 64,
    )
    rows.append(
        csv_row(
            "quant_kv/capacity",
            0.0,
            f"peak_resident int8={cap['int8']['peak_resident']} "
            f"fp32={cap['fp32']['peak_resident']} "
            f"gain={cap['resident_gain']}x at "
            f"{cap['int8']['kv_bytes_per_replica']}B vs "
            f"{cap['fp32']['kv_bytes_per_replica']}B per replica",
        )
    )
    rows.append(
        csv_row(
            "quant_kv/batch16",
            1e6 / max(tp["int8"]["tokens_per_s"], 1e-9),
            f"int8={tp['int8']['tokens_per_s']} tok/s "
            f"fp32={tp['fp32']['tokens_per_s']} tok/s "
            f"ratio={tp['int8_vs_fp32']}",
        )
    )
    acc = greedy_agreement(n_steps=16 if smoke else 64)
    rows.append(
        csv_row(
            "quant_kv/agreement",
            0.0,
            f"teacher_forced_agreement={acc['teacher_forced_agreement']} "
            f"over {acc['n_steps']} steps",
        )
    )
    if not smoke:
        report = {
            "model": "stablelm-1.6b(smoke)",
            "capacity_at_equal_kv_bytes": cap,
            "throughput_batch16": tp,
            "greedy_agreement": acc,
        }
        write_bench(BENCH_JSON, "quant_kv", report)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke", action="store_true",
        help="small CI run: fewer requests/tokens, no BENCH_quant_kv.json",
    )
    args = ap.parse_args()
    for row in run(smoke=args.smoke):
        print(row, flush=True)


if __name__ == "__main__":
    main()
