"""Deliverable (g): render the roofline table from the dry-run artifacts.

Reads artifacts/dryrun/*.json (produced by ``repro.launch.dryrun``) and
prints, per (arch × shape × mesh): the three roofline terms, the dominant
bottleneck, and the MODEL/HLO FLOP ratio.
"""

from __future__ import annotations

import glob
import json
import os

ARTIFACT_DIR = os.path.join(
    os.path.dirname(__file__), "..", "artifacts", "dryrun"
)


def load_cells(mesh: str | None = None) -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(ARTIFACT_DIR, "*.json"))):
        with open(path) as f:
            d = json.load(f)
        if mesh and d.get("mesh") != mesh:
            continue
        cells.append(d)
    return cells


def run() -> list[str]:
    rows = []
    for d in load_cells():
        tag = f"/{d['tag']}" if d.get("tag") else ""
        name = f"roofline/{d['arch']}/{d['shape']}/{d['mesh']}{tag}"
        if "error" in d:
            rows.append(f"{name},nan,ERROR: {d['error'][:80]}")
            continue
        r = d["roofline"]
        rows.append(
            f"{name},{r['step_time_s']*1e6:.0f},"
            f"compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
            f"collective={r['collective_s']:.4f}s dominant={r['dominant']} "
            f"useful_flop_ratio={d['useful_flop_ratio']:.2f}"
        )
    if not rows:
        rows.append("roofline/none,0,run `python -m repro.launch.dryrun --all` first")
    return rows


def main() -> None:
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
