"""Encoder-decoder transformer (seamless-m4t backbone, [audio] family).

The modality frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings (``batch["frames"]: [B, S_src, frontend_dim]``)
— a linear projection stands in for the speech feature extractor.

Encoder: bidirectional self-attention layers. Decoder: causal
self-attention + cross-attention to the encoder output + FFN. Decode
serves one token against (self-KV cache, precomputed cross-KV cache).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed.sharding import logical
from .attention import (
    attention_block,
    attn_template,
    cross_attention_block,
    project_kv,
)
from .common import ModelConfig, ParamSpec
from .layers import embed_template, mlp_template, rmsnorm, swiglu_mlp, gelu_mlp

__all__ = [
    "encdec_template",
    "encode",
    "forward",
    "prefill",
    "decode_step",
    "init_cache_shapes",
]


def _enc_cfg(cfg: ModelConfig) -> ModelConfig:
    import dataclasses

    return dataclasses.replace(cfg, n_layers=cfg.encoder_layers)


def encdec_template(cfg: ModelConfig) -> dict:
    cfg.validate()
    D = cfg.d_model
    Le, Ld = cfg.encoder_layers, cfg.n_layers
    enc_cfg = _enc_cfg(cfg)
    enc_layers = {
        "ln1": ParamSpec((Le, D), ("layers", "embed"), init="ones"),
        "attn": attn_template(enc_cfg, n_layers=Le),
        "ln2": ParamSpec((Le, D), ("layers", "embed"), init="ones"),
        "mlp": {
            k: ParamSpec((Le,) + v.shape[1:], v.axes, v.init, v.scale)
            for k, v in mlp_template(enc_cfg).items()
        },
    }
    dec_layers = {
        "ln1": ParamSpec((Ld, D), ("layers", "embed"), init="ones"),
        "self_attn": attn_template(cfg, n_layers=Ld),
        "ln_cross": ParamSpec((Ld, D), ("layers", "embed"), init="ones"),
        "cross_attn": attn_template(cfg, n_layers=Ld),
        "ln2": ParamSpec((Ld, D), ("layers", "embed"), init="ones"),
        "mlp": mlp_template(cfg),
    }
    return {
        "frontend_proj": ParamSpec((cfg.frontend_dim, D), ("frontend", "embed")),
        "enc_final_norm": ParamSpec((D,), ("embed",), init="ones"),
        "embed": embed_template(cfg),
        "encoder": enc_layers,
        "decoder": dec_layers,
        "final_norm": ParamSpec((D,), ("embed",), init="ones"),
    }


def _ffn(x, p_layer, cfg: ModelConfig):
    if cfg.act == "swiglu":
        return swiglu_mlp(x, p_layer["mlp"], cfg.compute_dtype)
    return gelu_mlp(x, p_layer["mlp"], cfg.compute_dtype)


def encode(params, frames, cfg: ModelConfig):
    """frames: [B, S_src, frontend_dim] -> encoder output [B, S_src, D]."""
    dtype = cfg.compute_dtype
    x = jnp.einsum("bsf,fd->bsd", frames.astype(dtype), params["frontend_proj"].astype(dtype))
    x = logical(x, ("batch", "act_seq", "embed"))
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    enc_cfg = _enc_cfg(cfg)

    def body(x, p_layer):
        h = rmsnorm(x, p_layer["ln1"], cfg.rms_eps)
        out, _ = attention_block(
            h, p_layer["attn"], enc_cfg,
            positions=positions, window=None, causal=False,
        )
        x = x + out
        h2 = rmsnorm(x, p_layer["ln2"], cfg.rms_eps)
        x = x + _ffn(h2, p_layer, cfg)
        return logical(x, ("batch", "act_seq", "embed")), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return rmsnorm(x, params["enc_final_norm"], cfg.rms_eps)


def _decoder_stack(params, x, cfg: ModelConfig, *, positions, enc_out=None,
                   cross_kv=None, self_cache=None, collect_kv=False):
    """Shared decoder body. Either enc_out (compute cross-KV per layer) or
    cross_kv (precomputed, stacked [L,...]) must be provided."""

    def body(x, scanned):
        if self_cache is None:
            p_layer = scanned
            kv = None
        else:
            p_layer, c_layer = scanned
            kv = (c_layer["k"], c_layer["v"], c_layer["len"])
        h = rmsnorm(x, p_layer["ln1"], cfg.rms_eps)
        out, new_kv = attention_block(
            h, p_layer["self_attn"], cfg,
            positions=positions, window=None, cache=kv, causal=True,
        )
        x = x + out
        hc = rmsnorm(x, p_layer["ln_cross"], cfg.rms_eps)
        if enc_out is not None:
            ckv = project_kv(enc_out, p_layer["cross_attn"], cfg)
        else:
            ckv = (p_layer["_ck"], p_layer["_cv"])
        x = x + cross_attention_block(hc, ckv, p_layer["cross_attn"], cfg)
        h2 = rmsnorm(x, p_layer["ln2"], cfg.rms_eps)
        x = x + _ffn(h2, p_layer, cfg)
        x = logical(x, ("batch", "act_seq", "embed"))
        ys = {}
        if collect_kv:
            ys = {"k": new_kv[0], "v": new_kv[1], "ck": ckv[0], "cv": ckv[1]}
        elif self_cache is not None:
            ys = {"k": new_kv[0], "v": new_kv[1]}
        return x, ys

    if self_cache is None:
        scanned = params["decoder"]
        if cross_kv is not None:
            scanned = dict(scanned, _ck=cross_kv[0], _cv=cross_kv[1])
        if cfg.remat:
            body = jax.checkpoint(body)
        return jax.lax.scan(body, x, scanned)
    scanned_layers = dict(params["decoder"])
    if cross_kv is not None:
        scanned_layers = dict(scanned_layers, _ck=cross_kv[0], _cv=cross_kv[1])
    return jax.lax.scan(body, x, (scanned_layers, self_cache))


def forward(params, batch, cfg: ModelConfig):
    """Teacher forcing: frames + decoder tokens -> logits [B,S_tgt,V]."""
    enc_out = encode(params, batch["frames"], cfg)
    tokens = batch["tokens"]
    dtype = cfg.compute_dtype
    x = params["embed"]["tok"].astype(dtype)[tokens]
    x = logical(x, ("batch", "act_seq", "embed"))
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
    x, _ = _decoder_stack(params, x, cfg, positions=positions, enc_out=enc_out)
    x = rmsnorm(x, params["final_norm"], cfg.rms_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["tok"].astype(dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["embed"]["lm_head"].astype(dtype))
    return logical(logits, ("batch", "seq", "vocab")), {"lb_loss": jnp.zeros((), jnp.float32)}


def init_cache_shapes(cfg: ModelConfig, batch: int, max_len: int, enc_len: int) -> dict:
    L, KV, Dh = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    dt = cfg.compute_dtype
    return {
        "len": jax.ShapeDtypeStruct((), jnp.int32),
        "k": jax.ShapeDtypeStruct((L, batch, max_len, KV, Dh), dt),
        "v": jax.ShapeDtypeStruct((L, batch, max_len, KV, Dh), dt),
        "ck": jax.ShapeDtypeStruct((L, batch, enc_len, KV, Dh), dt),
        "cv": jax.ShapeDtypeStruct((L, batch, enc_len, KV, Dh), dt),
    }


def prefill(params, batch, cfg: ModelConfig, *, max_len: int):
    """Encode + decoder prompt pass. Returns (logits, cache)."""
    enc_out = encode(params, batch["frames"], cfg)
    tokens = batch["tokens"]
    B, S = tokens.shape
    dtype = cfg.compute_dtype
    x = params["embed"]["tok"].astype(dtype)[tokens]
    positions = jnp.arange(S, dtype=jnp.int32)
    x, stacked = _decoder_stack(
        params, x, cfg, positions=positions, enc_out=enc_out, collect_kv=True
    )
    x = rmsnorm(x[:, -1:], params["final_norm"], cfg.rms_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["tok"].astype(dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["embed"]["lm_head"].astype(dtype))
    pad = [(0, 0), (0, 0), (0, max_len - S), (0, 0), (0, 0)]
    cache = {
        "len": jnp.int32(S),
        "k": jnp.pad(stacked["k"], pad),
        "v": jnp.pad(stacked["v"], pad),
        "ck": stacked["ck"],
        "cv": stacked["cv"],
    }
    return logits, cache


def decode_step(params, token, cache, cfg: ModelConfig):
    """One decoder token vs (self cache, cross cache)."""
    dtype = cfg.compute_dtype
    x = params["embed"]["tok"].astype(dtype)[token]
    positions = cache["len"][None].astype(jnp.int32)
    new_len = cache["len"] + 1
    x, stacked = _decoder_stack(
        params, x, cfg,
        positions=positions,
        cross_kv=(cache["ck"], cache["cv"]),
        self_cache={"k": cache["k"], "v": cache["v"],
                    "len": jnp.broadcast_to(new_len, (cfg.n_layers,))},
    )
    x = rmsnorm(x, params["final_norm"], cfg.rms_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["tok"].astype(dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["embed"]["lm_head"].astype(dtype))
    new_cache = dict(cache, k=stacked["k"], v=stacked["v"], len=new_len)
    return logits, new_cache
