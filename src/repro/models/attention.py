"""Attention: GQA with RoPE, chunked online-softmax (flash-style) XLA
path, sliding windows, and single-token decode against a KV cache.

The chunked path never materializes the full [S, S] score matrix: it
scans KV chunks carrying running (max, denom, accumulator) — the same
algorithm the Pallas kernel (:mod:`repro.kernels.flash_attention`)
implements with VMEM tiles, so it doubles as the kernel's oracle at the
model level.

Sliding windows are dynamic values (not static branches) so layer stacks
with mixed window/global layers (hymba) run under one ``lax.scan``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed.sharding import logical
from .common import ModelConfig, ParamSpec
from .layers import apply_rope, rmsnorm

__all__ = [
    "attn_template",
    "attention_block",
    "paged_attention_block",
    "chunk_attention_block",
    "paged_chunk_attention_block",
    "cross_attention_block",
    "project_kv",
    "chunked_attention",
    "decode_attention",
    "NEG_INF",
]

NEG_INF = -1e30


def attn_template(cfg: ModelConfig, n_layers: int | None = None) -> dict:
    L = n_layers if n_layers is not None else cfg.n_layers
    D, H, KV, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    t = {
        "wq": ParamSpec((L, D, H, Dh), ("layers", "embed_fsdp", "heads", "head_dim")),
        "wk": ParamSpec((L, D, KV, Dh), ("layers", "embed_fsdp", "kv_heads", "head_dim")),
        "wv": ParamSpec((L, D, KV, Dh), ("layers", "embed_fsdp", "kv_heads", "head_dim")),
        "wo": ParamSpec((L, H, Dh, D), ("layers", "heads", "head_dim", "embed_fsdp")),
    }
    if cfg.qkv_bias:
        t["bq"] = ParamSpec((L, H, Dh), ("layers", "heads", "head_dim"), init="zeros")
        t["bk"] = ParamSpec((L, KV, Dh), ("layers", "kv_heads", "head_dim"), init="zeros")
        t["bv"] = ParamSpec((L, KV, Dh), ("layers", "kv_heads", "head_dim"), init="zeros")
    if cfg.qk_norm:
        t["q_norm"] = ParamSpec((L, Dh), ("layers", "head_dim"), init="ones")
        t["k_norm"] = ParamSpec((L, Dh), ("layers", "head_dim"), init="ones")
    return t


def _project_qkv(x, p, cfg: ModelConfig, positions):
    """x [B,S,D] -> q [B,S,H,Dh], k/v [B,S,KV,Dh] with RoPE applied."""
    dtype = cfg.compute_dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dtype)
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.rms_eps)
        k = rmsnorm(k, p["k_norm"], cfg.rms_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: jax.Array | int | None = None,
    chunk: int = 1024,
    q_offset: jax.Array | int = 0,
    kv_stream: bool = False,
) -> jax.Array:
    """Online-softmax attention over KV chunks (no [S,S] materialization).

    q: [B,Sq,H,Dh]; k, v: [B,Skv,KV,Dh]; H = G * KV (GQA).
    ``window``: dynamic sliding-window size (None/huge = full attention).
    ``q_offset``: absolute position of q[0] (prefill continuation) — a
    scalar, or a per-batch [B] vector when each lane continues from its
    own offset (chunked prefill over a shared-width call).
    ``kv_stream``: slice K/V per chunk inside the scan (no stacked
    transposed copies of the whole K/V) and keep dot operands bf16 with
    fp32 accumulation — see EXPERIMENTS.md §Perf.
    Returns [B,Sq,H,Dh].
    """
    B, Sq, H, Dh = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    scale = Dh**-0.5
    if window is None:
        window = jnp.int32(2**30)
    window = jnp.asarray(window, jnp.int32)
    q_offset = jnp.asarray(q_offset, jnp.int32)

    # [Sq] for a scalar offset, [B, Sq] for per-batch offsets.
    q_pos = q_offset[..., None] + jnp.arange(Sq, dtype=jnp.int32)
    if q_offset.ndim == 0:
        q_pos = q_pos.reshape(Sq)

    chunk = min(chunk, Skv)
    n_chunks = -(-Skv // chunk)
    pad = n_chunks * chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    if kv_stream:
        qg = (q.astype(jnp.float32) * scale).astype(q.dtype).reshape(B, Sq, KV, G, Dh)
    else:
        qg = q.reshape(B, Sq, KV, G, Dh).astype(jnp.float32) * scale
        # [C, B, chunk, KV, Dh] chunks as scan inputs (baseline: one
        # transposed copy of K and V).
        kc = k.reshape(B, n_chunks, chunk, KV, Dh).transpose(1, 0, 2, 3, 4)
        vc = v.reshape(B, n_chunks, chunk, KV, Dh).transpose(1, 0, 2, 3, 4)

    def attend(carry, ci, k_i, v_i):
        m, l, acc = carry
        kv_pos = ci * chunk + jnp.arange(chunk, dtype=jnp.int32)
        if kv_stream:
            s = jnp.einsum(
                "bqkgd,bckd->bqkgc", qg, k_i, preferred_element_type=jnp.float32
            )
        else:
            s = jnp.einsum("bqkgd,bckd->bqkgc", qg, k_i.astype(jnp.float32))
        valid = kv_pos[None, :] < Skv  # padding mask [1, chunk]
        delta = q_pos[..., :, None] - kv_pos[None, :]  # [(B,) Sq, chunk]
        mask = valid
        if causal:
            mask = mask & (delta >= 0)
        mask = mask & (delta < window)
        if mask.ndim == 2:
            mask_b = mask[None, :, None, None, :]
        else:  # per-batch q offsets
            mask_b = mask[:, :, None, None, :]
        s = jnp.where(mask_b, s, NEG_INF)
        m_i = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, m_i)
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        if kv_stream:
            pv = jnp.einsum(
                "bqkgc,bckd->bqkgd",
                p.astype(v_i.dtype),
                v_i,
                preferred_element_type=jnp.float32,
            )
        else:
            pv = jnp.einsum("bqkgc,bckd->bqkgd", p, v_i.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new)

    m0 = jnp.full((B, Sq, KV, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, KV, G), jnp.float32)
    acc0 = jnp.zeros((B, Sq, KV, G, Dh), jnp.float32)

    if kv_stream:
        def body(carry, ci):
            k_i = jax.lax.dynamic_slice_in_dim(k, ci * chunk, chunk, axis=1)
            v_i = jax.lax.dynamic_slice_in_dim(v, ci * chunk, chunk, axis=1)
            return attend(carry, ci, k_i, v_i), None

        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, acc0), jnp.arange(n_chunks, dtype=jnp.int32)
        )
    else:
        def body(carry, inp):
            ci, k_i, v_i = inp
            return attend(carry, ci, k_i, v_i), None

        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, acc0), (jnp.arange(n_chunks, dtype=jnp.int32), kc, vc)
        )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, Sq, H, Dh).astype(q.dtype)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len: jax.Array,
    *,
    window: jax.Array | int | None = None,
    mulsum: bool = False,
    kv_stream: bool = False,
) -> jax.Array:
    """One-token attention against a KV cache.

    q: [B,1,H,Dh]; caches: [B,Smax,KV,Dh]; cache_len: scalar int32 —
    number of valid cache entries *including* the token being decoded.

    ``mulsum=True``: compute scores/output with broadcast multiply +
    reduce rather than dot_general — GQA decode has arithmetic intensity
    ~G, far below the MXU roofline, and the dot's batch-dim layout forces
    XLA to materialize a transposed copy of the whole cache; the VPU
    mul-reduce streams the cache once in its stored layout.
    """
    B, _, H, Dh = q.shape
    _, Smax, KV, _ = k_cache.shape
    G = H // KV
    scale = Dh**-0.5
    if window is None:
        window = jnp.int32(2**30)
    window = jnp.asarray(window, jnp.int32)

    qg = q.reshape(B, KV, G, Dh).astype(jnp.float32) * scale
    pos = jnp.arange(Smax, dtype=jnp.int32)
    mask = (pos[None, :] < cache_len) & (pos[None, :] >= cache_len - window)
    if mulsum:
        # [B,S,KV,G] = sum_d k[B,S,KV,1,D] * q[B,1,KV,G,D]
        s = jnp.sum(
            k_cache.astype(jnp.float32)[:, :, :, None, :]
            * qg[:, None, :, :, :],
            axis=-1,
        )
        # Anchor the score layout to the cache layout (batch over data,
        # seq over model) — without this the partitioner replicates the
        # broadcasted product (iteration 1 regression, EXPERIMENTS.md).
        s = logical(s, ("cache_batch", "cache_seq", None, None))
        s = jnp.where(mask[:, :, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=1)
        out = jnp.sum(
            p[..., None] * v_cache.astype(jnp.float32)[:, :, :, None, :], axis=1
        )  # [B,KV,G,D]
        return out.reshape(B, 1, H, Dh).astype(q.dtype)
    if kv_stream:
        # bf16 operands, fp32 accumulation: any layout copies the dot
        # needs happen at bf16 width (2x less traffic than upcasting the
        # cache first); MXU accumulates fp32 natively.
        s = jnp.einsum(
            "bkgd,bskd->bkgs", qg.astype(k_cache.dtype), k_cache,
            preferred_element_type=jnp.float32,
        )
        s = jnp.where(mask[:, None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum(
            "bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
            preferred_element_type=jnp.float32,
        )
        return out.reshape(B, 1, H, Dh).astype(q.dtype)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache.astype(jnp.float32))
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, Dh).astype(q.dtype)


def cross_attention_block(
    x: jax.Array,
    kv_cache: tuple[jax.Array, jax.Array],
    p: dict,
    cfg: ModelConfig,
):
    """Cross-attention against precomputed encoder K/V (no RoPE, no mask).

    x: [B,Sq,D]; kv_cache: (k, v) each [B,Skv,KV,Dh] from the encoder.
    """
    dtype = cfg.compute_dtype
    k, v = kv_cache
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dtype)
    out = chunked_attention(
        q, k, v, causal=False, window=None, chunk=cfg.attn_chunk
    )
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dtype))


def project_kv(x: jax.Array, p: dict, cfg: ModelConfig):
    """K/V projections only (encoder output -> cross-attention cache)."""
    dtype = cfg.compute_dtype
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dtype))
    if cfg.qkv_bias:
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    return k, v


def _use_interpret() -> bool:
    """Pallas kernels execute for real on TPU, in interpret mode elsewhere."""
    return jax.default_backend() != "tpu"


def attention_block(
    x: jax.Array,
    p: dict,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    window: jax.Array | int | None,
    cache: tuple[jax.Array, jax.Array, jax.Array] | None = None,
    causal: bool = True,
    window_static: int | None = None,
):
    """Full attention sub-block: qkv -> attn -> o_proj.

    Without ``cache``: self-attention over x (train/prefill); returns
    (out, (k, v)) so prefill can populate the cache.
    With ``cache=(k_cache, v_cache, cache_len)``: single-token decode —
    computes k/v for the current token, writes them into the cache at
    ``cache_len - 1``, attends; returns (out, (k_cache, v_cache)).
    """
    dtype = cfg.compute_dtype
    q, k, v = _project_qkv(x, p, cfg, positions)
    q = logical(q, ("batch", "seq", "heads", "head_dim"))
    if cache is None:
        if cfg.attn_impl == "pallas":
            from ..kernels.flash_attention import flash_attention

            out = flash_attention(
                q, k, v, causal=causal, window=window_static,
                interpret=_use_interpret(),
            )
        else:
            out = chunked_attention(
                q, k, v, causal=causal, window=window, chunk=cfg.attn_chunk,
                kv_stream=cfg.attn_kv_stream,
            )
        out = logical(out, ("batch", "seq", "heads", "head_dim"))
        o = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dtype))
        return o, (k, v)
    if len(cache) == 4:
        k_cache, v_cache, cache_len, write_idx = cache
    else:
        k_cache, v_cache, cache_len = cache
        write_idx = cache_len - 1  # plain cache: append position
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k.astype(k_cache.dtype), (0, write_idx, 0, 0)
    )
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v.astype(v_cache.dtype), (0, write_idx, 0, 0)
    )
    if cfg.attn_impl == "pallas":
        from ..kernels.decode_attention import decode_attention as decode_kernel

        out = decode_kernel(
            q, k_cache, v_cache, cache_len, window=window_static,
            interpret=_use_interpret(),
        )
    else:
        out = decode_attention(
            q, k_cache, v_cache, cache_len, window=window,
            mulsum=cfg.decode_mulsum, kv_stream=cfg.attn_kv_stream,
        )
    o = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dtype))
    return o, (k_cache, v_cache)


def _scatter_kv_pages(
    pages: dict, k: jax.Array, v: jax.Array, write_pages, write_offs
) -> dict:
    """Write K/V rows into the shared pool at (write_pages, write_offs).

    ``pages``: {"k", "v"} (+ {"k_scale", "v_scale"} for int8 pools —
    the presence of scales *is* the quantization switch). k/v rows are
    [..., KV, Dh]; int8 pools quantize each row at scatter time
    (per-row amax, :func:`repro.kernels.decode_attention.quantize_kv`)
    and store its fp32 scale alongside, so a row is quantized exactly
    once and never requantized.
    """
    out = dict(pages)
    if "k_scale" in pages:
        from ..kernels.decode_attention import quantize_kv

        qk, ks = quantize_kv(k)
        qv, vs = quantize_kv(v)
        out["k"] = pages["k"].at[write_pages, write_offs].set(qk)
        out["v"] = pages["v"].at[write_pages, write_offs].set(qv)
        out["k_scale"] = pages["k_scale"].at[write_pages, write_offs].set(ks)
        out["v_scale"] = pages["v_scale"].at[write_pages, write_offs].set(vs)
    else:
        out["k"] = pages["k"].at[write_pages, write_offs].set(
            k.astype(pages["k"].dtype)
        )
        out["v"] = pages["v"].at[write_pages, write_offs].set(
            v.astype(pages["v"].dtype)
        )
    return out


def paged_attention_block(
    x: jax.Array,
    p: dict,
    cfg: ModelConfig,
    *,
    positions: jax.Array,  # [B, 1] per-request absolute position (>= 0)
    pages: dict,  # {"k","v"[,"k_scale","v_scale"]} shared pool (one layer)
    block_tables: jax.Array,  # [B, NB] int32
    write_pages: jax.Array,  # [B] physical page for this token's K/V
    write_offs: jax.Array,  # [B] offset within that page
):
    """Single-token attention sub-block against a paged KV pool.

    The batch dimension is the engine's slot width: every request has
    its own context length (``positions``) and block table. The new
    token's K/V land at (write_pages, write_offs), precomputed by
    :func:`repro.models.transformer.decode_step_paged` (layer-invariant;
    masked lanes point at the pool's scratch page so a batched scatter
    never corrupts a live page). int8 pools (``k_scale`` present)
    quantize at scatter and dequantize inside the page gather — kernel
    and fallback alike. Returns (out [B,1,D], updated pages).
    """
    dtype = cfg.compute_dtype
    q, k, v = _project_qkv(x, p, cfg, positions)
    pages = _scatter_kv_pages(pages, k[:, 0], v[:, 0], write_pages, write_offs)
    attn_len = positions[:, 0] + 1  # valid entries incl. the new token
    if cfg.attn_impl == "pallas":
        from ..kernels.decode_attention import paged_decode_attention

        out = paged_decode_attention(
            q, pages["k"], pages["v"], block_tables, attn_len,
            k_scales=pages.get("k_scale"), v_scales=pages.get("v_scale"),
            interpret=_use_interpret(),
        )
    else:
        # XLA path: gather the pages (dequantizing int8 rows), then the
        # dense decode oracle with per-request lengths ([B,1] broadcasts
        # against the position row).
        from ..kernels.decode_attention import gather_pages

        k_cache = gather_pages(pages["k"], block_tables, pages.get("k_scale"))
        v_cache = gather_pages(pages["v"], block_tables, pages.get("v_scale"))
        out = decode_attention(
            q, k_cache, v_cache, attn_len[:, None],
            mulsum=cfg.decode_mulsum, kv_stream=cfg.attn_kv_stream,
        )
    o = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dtype))
    return o, pages


def chunk_attention_block(
    x: jax.Array,
    p: dict,
    cfg: ModelConfig,
    *,
    offset: jax.Array,  # scalar (vmapped lane) or [B] absolute chunk start
    k_cache: jax.Array,  # [B, L, KV, Dh] dense per-request cache
    v_cache: jax.Array,
):
    """Multi-token prefill-continuation sub-block against a dense cache.

    The chunked-prefill middle ground between :func:`attention_block`'s
    two modes: like prefill it processes ``C = x.shape[1]`` new tokens,
    like decode it extends an existing cache. K/V for the chunk are
    scattered at absolute positions ``offset .. offset + C - 1``
    (out-of-bounds padding writes are dropped), then the chunk attends
    causally over the whole cache with ``q_offset = offset`` — every
    earlier entry is real by construction, and queries past the caller's
    valid count produce garbage the engine discards. Returns
    (out [B, C, D], (k_cache, v_cache)).
    """
    dtype = cfg.compute_dtype
    B, C = x.shape[:2]
    offset = jnp.asarray(offset, jnp.int32)
    positions = offset[..., None] + jnp.arange(C, dtype=jnp.int32)  # [(B,) C]
    q, k, v = _project_qkv(x, p, cfg, positions)
    idx = jnp.broadcast_to(positions, (B, C))
    rows = jnp.arange(B, dtype=jnp.int32)[:, None]
    k_cache = k_cache.at[rows, idx].set(k.astype(k_cache.dtype), mode="drop")
    v_cache = v_cache.at[rows, idx].set(v.astype(v_cache.dtype), mode="drop")
    out = chunked_attention(
        q, k_cache, v_cache, causal=True, q_offset=offset,
        chunk=cfg.attn_chunk, kv_stream=cfg.attn_kv_stream,
    )
    o = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dtype))
    return o, (k_cache, v_cache)


def paged_chunk_attention_block(
    x: jax.Array,
    p: dict,
    cfg: ModelConfig,
    *,
    positions: jax.Array,  # [B, C] absolute position per chunk token
    pages: dict,  # {"k","v"[,"k_scale","v_scale"]} shared pool (one layer)
    block_tables: jax.Array,  # [B, NB] int32
    write_pages: jax.Array,  # [B, C] physical page per chunk token
    write_offs: jax.Array,  # [B, C] offset within that page
):
    """Chunked-prefill sub-block against a paged KV pool.

    The paged sibling of :func:`chunk_attention_block`: the chunk's K/V
    are scattered into each request's reserved pages (masked lanes and
    padding positions land on the scratch page, precomputed by
    :func:`repro.models.transformer.prefill_chunk_paged`; int8 pools
    quantize per row at scatter), then the chunk attends over the paged
    prefix. On the Pallas path that is
    :func:`repro.kernels.decode_attention.paged_prefill_attention_pallas`
    — the block-table walk happens in the kernel's DMA index map, so no
    contiguous copy of the prefix is ever materialized; off TPU the
    gather fallback computes the identical masked softmax. Returns
    (out [B, C, D], updated pages).
    """
    dtype = cfg.compute_dtype
    q, k, v = _project_qkv(x, p, cfg, positions)
    pages = _scatter_kv_pages(pages, k, v, write_pages, write_offs)
    if cfg.attn_impl == "pallas":
        from ..kernels.decode_attention import paged_prefill_attention_pallas

        out = paged_prefill_attention_pallas(
            q, pages["k"], pages["v"], block_tables, positions[:, 0],
            k_scales=pages.get("k_scale"), v_scales=pages.get("v_scale"),
            interpret=_use_interpret(),
        )
    else:
        from ..kernels.decode_attention import paged_prefill_attention

        out = paged_prefill_attention(
            q, pages["k"], pages["v"], block_tables, positions[:, 0],
            k_scales=pages.get("k_scale"), v_scales=pages.get("v_scale"),
        )
    o = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dtype))
    return o, pages
