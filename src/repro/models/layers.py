"""Shared layers: norms, RoPE, activations, MLP, embeddings."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed.sharding import logical
from .common import ModelConfig, ParamSpec

__all__ = [
    "rmsnorm",
    "apply_rope",
    "rope_freqs",
    "swiglu_mlp",
    "gelu_mlp",
    "mlp_template",
    "embed_template",
]


def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with fp32 accumulation."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies for rotary embeddings [head_dim/2]."""
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float
) -> jax.Array:
    """Rotary position embedding.

    x: [..., seq, heads, head_dim]; positions: [..., seq] (int).
    """
    head_dim = x.shape[-1]
    inv = rope_freqs(head_dim, theta)  # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * inv  # [..., seq, hd/2]
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_template(cfg: ModelConfig, n_layers: int | None = None) -> dict:
    L = n_layers if n_layers is not None else cfg.n_layers
    D, F = cfg.d_model, cfg.d_ff
    if cfg.act == "swiglu":
        return {
            "wi_gate": ParamSpec((L, D, F), ("layers", "embed_fsdp", "ff")),
            "wi_up": ParamSpec((L, D, F), ("layers", "embed_fsdp", "ff")),
            "wo": ParamSpec((L, F, D), ("layers", "ff", "embed_fsdp")),
        }
    return {
        "wi": ParamSpec((L, D, F), ("layers", "embed_fsdp", "ff")),
        "wo": ParamSpec((L, F, D), ("layers", "ff", "embed_fsdp")),
    }


def swiglu_mlp(x: jax.Array, p: dict, dtype) -> jax.Array:
    """SwiGLU feed-forward (LLaMA-style). x: [B,S,D]; p leaves unstacked."""
    gate = jnp.einsum("bsd,df->bsf", x, p["wi_gate"].astype(dtype))
    up = jnp.einsum("bsd,df->bsf", x, p["wi_up"].astype(dtype))
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(dtype) * up
    h = logical(h, ("batch", "seq", "ff"))
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(dtype))


def gelu_mlp(x: jax.Array, p: dict, dtype) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(dtype))
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(dtype)
    h = logical(h, ("batch", "seq", "ff"))
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(dtype))


def embed_template(cfg: ModelConfig) -> dict:
    t = {
        "tok": ParamSpec(
            (cfg.vocab_size, cfg.d_model), ("vocab", "embed"), scale=1.0
        )
    }
    if not cfg.tie_embeddings:
        t["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab_size), ("embed_fsdp", "vocab"))
    return t
