"""Mamba-1 selective state-space block (falcon-mamba / hymba substrate).

Prefill/train uses a *chunked* selective scan: within a chunk of
``cfg.scan_chunk`` tokens the recurrence is evaluated with an associative
scan held in registers/VMEM; chunk boundaries carry the [d_inner, N]
state through a sequential ``lax.scan``. This bounds the materialized
state history to one chunk (the TPU-native answer to Mamba's fused CUDA
recurrence — see DESIGN.md Sec. 7). Decode is the O(1) single-step
recurrence over (conv_state, ssm_state).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed.sharding import logical
from .common import ModelConfig, ParamSpec

__all__ = [
    "ssm_template",
    "mamba_block",
    "mamba_decode_step",
    "selective_scan",
]


def ssm_template(cfg: ModelConfig, n_layers: int | None = None) -> dict:
    L = n_layers if n_layers is not None else cfg.n_layers
    D = cfg.d_model
    Din, N, K, R = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv, cfg.dt_rank_actual
    return {
        "in_proj_x": ParamSpec((L, D, Din), ("layers", "embed_fsdp", "ssm_inner")),
        "in_proj_z": ParamSpec((L, D, Din), ("layers", "embed_fsdp", "ssm_inner")),
        "conv_w": ParamSpec((L, K, Din), ("layers", "conv", "ssm_inner"), scale=0.2),
        "conv_b": ParamSpec((L, Din), ("layers", "ssm_inner"), init="zeros"),
        "x_proj_dt": ParamSpec((L, Din, R), ("layers", "ssm_inner", None)),
        "x_proj_b": ParamSpec((L, Din, N), ("layers", "ssm_inner", "ssm_state")),
        "x_proj_c": ParamSpec((L, Din, N), ("layers", "ssm_inner", "ssm_state")),
        "dt_proj": ParamSpec((L, R, Din), ("layers", None, "ssm_inner")),
        "dt_bias": ParamSpec((L, Din), ("layers", "ssm_inner"), init="zeros"),
        "A_log": ParamSpec((L, Din, N), ("layers", "ssm_inner", "ssm_state"), init="ones"),
        "D_skip": ParamSpec((L, Din), ("layers", "ssm_inner"), init="ones"),
        "out_proj": ParamSpec((L, Din, D), ("layers", "ssm_inner", "embed_fsdp")),
    }


def _ssm_inputs(x_act: jax.Array, p: dict, dtype):
    """Selective parameters from the activated conv stream.

    x_act: [B,S,Din] -> dt [B,S,Din] (softplus), Bmat/Cmat [B,S,N].
    """
    dt_low = jnp.einsum("bsd,dr->bsr", x_act, p["x_proj_dt"].astype(dtype))
    dt = jnp.einsum("bsr,rd->bsd", dt_low, p["dt_proj"].astype(dtype))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    Bmat = jnp.einsum("bsd,dn->bsn", x_act, p["x_proj_b"].astype(dtype)).astype(
        jnp.float32
    )
    Cmat = jnp.einsum("bsd,dn->bsn", x_act, p["x_proj_c"].astype(dtype)).astype(
        jnp.float32
    )
    return dt, Bmat, Cmat


def selective_scan(
    x_act: jax.Array,
    dt: jax.Array,
    Bmat: jax.Array,
    Cmat: jax.Array,
    A: jax.Array,
    h0: jax.Array | None = None,
    *,
    chunk: int = 256,
):
    """Chunked selective scan.

    x_act, dt: [B,S,Din]; Bmat, Cmat: [B,S,N]; A: [Din,N] (negative).
    h0: [B,Din,N] initial state. Returns (y [B,S,Din], h_final).
    """
    B, S, Din = x_act.shape
    N = A.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((B, Din, N), jnp.float32)

    chunk = min(chunk, S)
    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S
    xf = x_act.astype(jnp.float32)
    if pad:
        xf = jnp.pad(xf, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))

    def reshape_c(t):  # [B, n_chunks*chunk, ...] -> [n_chunks, B, chunk, ...]
        return t.reshape(B, n_chunks, chunk, *t.shape[2:]).transpose(
            1, 0, 2, *range(3, t.ndim + 1)
        )

    xc, dtc, Bc, Cc = map(reshape_c, (xf, dt, Bmat, Cmat))

    def chunk_body(h, inp):
        x_i, dt_i, B_i, C_i = inp  # [B,chunk,...]
        a = jnp.exp(dt_i[..., None] * A)  # [B,chunk,Din,N]
        b = (dt_i * x_i)[..., None] * B_i[:, :, None, :]  # [B,chunk,Din,N]

        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, br + ar * bl

        a_cum, b_cum = jax.lax.associative_scan(combine, (a, b), axis=1)
        hs = a_cum * h[:, None] + b_cum  # [B,chunk,Din,N]
        y = jnp.einsum("bcdn,bcn->bcd", hs, C_i)
        return hs[:, -1], y

    h_final, ys = jax.lax.scan(chunk_body, h0, (xc, dtc, Bc, Cc))
    y = ys.transpose(1, 0, 2, 3).reshape(B, n_chunks * chunk, Din)
    if pad:
        y = y[:, :S]
    return y, h_final


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array, dtype) -> jax.Array:
    """Depthwise causal 1-D conv. x: [B,S,Din], w: [K,Din]."""
    K, Din = w.shape
    out = jax.lax.conv_general_dilated(
        x.astype(dtype),
        w[:, None, :].astype(dtype),  # [K, 1, Din] (HIO)
        window_strides=(1,),
        padding=[(K - 1, 0)],
        dimension_numbers=("NHC", "HIO", "NHC"),
        feature_group_count=Din,
    )
    return out + b.astype(dtype)


def mamba_block(x: jax.Array, p: dict, cfg: ModelConfig):
    """Full Mamba-1 block (train/prefill). x: [B,S,D] -> ([B,S,D], cache).

    cache = (conv_tail [B,K-1,Din], h_final [B,Din,N]) for decode resume.
    """
    dtype = cfg.compute_dtype
    x_in = jnp.einsum("bsd,de->bse", x, p["in_proj_x"].astype(dtype))
    z = jnp.einsum("bsd,de->bse", x, p["in_proj_z"].astype(dtype))
    x_in = logical(x_in, ("batch", "seq", "ssm_inner"))

    x_conv = _causal_conv(x_in, p["conv_w"], p["conv_b"], dtype)
    x_act = jax.nn.silu(x_conv.astype(jnp.float32)).astype(dtype)

    dt, Bmat, Cmat = _ssm_inputs(x_act, p, dtype)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    if cfg.attn_impl == "pallas":
        from ..kernels.selective_scan import selective_scan as scan_kernel

        y, h_final = scan_kernel(
            x_act.astype(jnp.float32), dt, Bmat, Cmat, A,
            chunk=cfg.scan_chunk,
            interpret=jax.default_backend() != "tpu",
        )
        y = y.astype(jnp.float32)
    else:
        y, h_final = selective_scan(
            x_act, dt, Bmat, Cmat, A, chunk=cfg.scan_chunk
        )
    y = y + p["D_skip"].astype(jnp.float32) * x_act.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(dtype))

    K = cfg.ssm_conv
    S = x_in.shape[1]
    if K > 1:
        if S >= K - 1:
            conv_tail = x_in[:, -(K - 1):, :]
        else:  # short prompt: left-pad with zeros
            conv_tail = jnp.pad(x_in, ((0, 0), (K - 1 - S, 0), (0, 0)))
    else:
        conv_tail = x_in[:, :0, :]
    return out, (conv_tail, h_final)


def mamba_decode_step(x: jax.Array, p: dict, cfg: ModelConfig, cache):
    """O(1) decode. x: [B,1,D]; cache = (conv_state [B,K-1,Din], h [B,Din,N])."""
    dtype = cfg.compute_dtype
    conv_state, h = cache
    x_in = jnp.einsum("bsd,de->bse", x, p["in_proj_x"].astype(dtype))  # [B,1,Din]
    z = jnp.einsum("bsd,de->bse", x, p["in_proj_z"].astype(dtype))

    window = jnp.concatenate([conv_state.astype(dtype), x_in], axis=1)  # [B,K,Din]
    w = p["conv_w"].astype(dtype)  # [K,Din]
    x_conv = jnp.einsum("bkd,kd->bd", window, w)[:, None, :] + p["conv_b"].astype(dtype)
    x_act = jax.nn.silu(x_conv.astype(jnp.float32)).astype(dtype)

    dt, Bmat, Cmat = _ssm_inputs(x_act, p, dtype)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dt[:, 0, :, None] * A)  # [B,Din,N]
    b = (dt[:, 0] * x_act.astype(jnp.float32)[:, 0])[..., None] * Bmat[:, 0, None, :]
    h_new = a * h + b
    y = jnp.einsum("bdn,bn->bd", h_new, Cmat[:, 0])[:, None, :]
    y = y + p["D_skip"].astype(jnp.float32) * x_act.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(dtype))

    conv_state_new = window[:, 1:, :] if cfg.ssm_conv > 1 else conv_state
    return out, (conv_state_new, h_new)
