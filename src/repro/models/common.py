"""Model configuration, parameter templates, and init machinery.

Models are pure-functional JAX: a declarative *parameter template* (a
pytree of :class:`ParamSpec` leaves) drives three consumers that can never
diverge:

* :func:`init_from_template` — materialize real parameters;
* :func:`abstract_params` — ``ShapeDtypeStruct`` stand-ins (dry-run: no
  allocation);
* :func:`repro.distributed.sharding.param_shardings` — NamedShardings
  from each leaf's logical axes.

Layer stacks store parameters stacked on a leading ``layers`` dim and run
under ``lax.scan`` — keeps the HLO (and SPMD-partitioner work at 512
devices) small.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ModelConfig",
    "ParamSpec",
    "init_from_template",
    "abstract_params",
    "count_params",
    "template_bytes",
]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One architecture. Field groups cover every assigned family."""

    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int | None = None  # default: d_model // n_heads
    act: str = "swiglu"  # swiglu | gelu
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    rms_eps: float = 1e-6
    tie_embeddings: bool = False
    # attention pattern
    attn_window: int | None = None  # sliding-window size (tokens)
    global_attn_layers: tuple[int, ...] = ()  # full-attn layer ids (window archs)
    attn_impl: str = "xla"  # xla | pallas (TPU target)
    attn_chunk: int = 1024  # kv-chunk for the online-softmax XLA path
    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    # SSM (Mamba-1)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int | None = None
    scan_chunk: int = 256  # chunked selective-scan block
    # block layout
    block: str = "attn"  # attn | mamba | hymba (parallel attn+ssm)
    # encoder-decoder (audio family)
    encoder_layers: int = 0
    # modality frontend stubs (audio frames / vision patches)
    frontend: str | None = None  # None | "patches" | "frames"
    frontend_dim: int = 0
    n_frontend_tokens: int = 0
    # pipeline-stage I/O (Petals-style layer groups, serving/partition.py):
    # a middle stage consumes/produces hidden states instead of tokens/logits.
    stage_embed: bool = True  # this slice embeds tokens (first stage)
    stage_unembed: bool = True  # this slice produces logits (last stage)
    # perf-iteration knobs (EXPERIMENTS.md §Perf):
    # decode scores/out via broadcast-multiply+reduce instead of dot —
    # avoids the transposed fp32 cache copy XLA materializes for the
    # dot's batch-dim layout (decode is bandwidth-bound; VPU mul-reduce
    # reads the cache exactly once).
    decode_mulsum: bool = False
    # ring-buffer update via direct slot indexing instead of roll pairs
    # (rolls on a seq-sharded ring lower to collective-permute chains).
    ring_impl: str = "roll"  # roll | index
    # MoE dispatch: dense one-hot einsums (baseline) vs gather/scatter
    # (removes the O(T*E*C*D) dispatch matmul FLOPs).
    moe_impl: str = "einsum"  # einsum | gather
    # Chunked attention: slice K/V per chunk inside the scan (no stacked
    # transposed copies) and feed bf16 operands to fp32-accumulating dots
    # (no fp32 operand materialization).
    attn_kv_stream: bool = False
    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    remat: bool = True  # checkpoint layers in train_step
    # Block remat: checkpoint whole groups of `remat_block` layers — only
    # one residual carry per group is stored, the rest recomputed in the
    # backward pass (required for 70B-class train cells on 16 GB chips;
    # recompute overhead shows up in the roofline's MODEL/HLO FLOP ratio).
    remat_block: int = 1

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank_actual(self) -> int:
        return self.dt_rank if self.dt_rank is not None else max(1, self.d_model // 16)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    def window_for_layer(self, layer: int) -> int | None:
        """Effective attention window for a layer (None = full)."""
        if self.attn_window is None or layer in self.global_attn_layers:
            return None
        return self.attn_window

    def validate(self) -> None:
        if self.block in ("attn", "hymba") and self.n_heads % self.n_kv_heads != 0:
            raise ValueError("n_heads must be a multiple of n_kv_heads (GQA)")
        if self.is_moe and not (0 < self.moe_top_k <= self.n_experts):
            raise ValueError("need 0 < moe_top_k <= n_experts")
        if self.block in ("mamba", "hymba") and self.ssm_state <= 0:
            raise ValueError("ssm blocks need ssm_state > 0")


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declarative parameter leaf: shape + logical axes + initializer."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"  # normal | zeros | ones
    scale: float | None = None  # std for "normal"; default fan-in

    def __post_init__(self) -> None:
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape/axes rank mismatch: {self.shape} vs {self.axes}")

    def initializer_std(self) -> float:
        if self.scale is not None:
            return self.scale
        fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
        return 1.0 / np.sqrt(max(fan_in, 1))


def _is_spec(v: Any) -> bool:
    return isinstance(v, ParamSpec)


def init_from_template(template, key: jax.Array, param_dtype: str = "bfloat16"):
    """Materialize parameters (deterministic per-leaf keys by path)."""
    leaves, treedef = jax.tree_util.tree_flatten(template, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    dtype = jnp.dtype(param_dtype)

    def one(spec: ParamSpec, k):
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dtype)
        std = spec.initializer_std()
        return (jax.random.normal(k, spec.shape, jnp.float32) * std).astype(dtype)

    return jax.tree_util.tree_unflatten(
        treedef, [one(s, k) for s, k in zip(leaves, keys)]
    )


def abstract_params(template, param_dtype: str = "bfloat16"):
    """ShapeDtypeStruct tree — dry-run stand-ins, no allocation."""
    dtype = jnp.dtype(param_dtype)
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), template, is_leaf=_is_spec
    )


def count_params(template) -> int:
    leaves = jax.tree_util.tree_leaves(template, is_leaf=_is_spec)
    return int(sum(np.prod(s.shape) for s in leaves))


def template_bytes(template, param_dtype: str = "bfloat16") -> int:
    return count_params(template) * jnp.dtype(param_dtype).itemsize
