"""Model registry: one uniform API over all families.

``Model`` bundles the per-family entry points so the launcher, trainer,
serving engine, and dry-run never branch on family.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

from . import encdec, transformer
from .common import ModelConfig

__all__ = ["Model", "build_model"]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    template: Any  # ParamSpec tree
    forward: Callable  # (params, batch) -> (logits, aux)
    prefill: Callable  # (params, batch, max_len) -> (logits, cache)
    decode_step: Callable  # (params, token, cache) -> (logits, cache)
    cache_shapes: Callable  # (batch, max_len, [enc_len]) -> SDS tree

    @property
    def name(self) -> str:
        return self.cfg.name


def build_model(cfg: ModelConfig) -> Model:
    cfg.validate()
    if cfg.is_encdec:
        return Model(
            cfg=cfg,
            template=encdec.encdec_template(cfg),
            forward=lambda p, b: encdec.forward(p, b, cfg),
            prefill=lambda p, b, max_len: encdec.prefill(p, b, cfg, max_len=max_len),
            decode_step=lambda p, t, c: encdec.decode_step(p, t, c, cfg),
            cache_shapes=lambda batch, max_len, enc_len=None: encdec.init_cache_shapes(
                cfg, batch, max_len, enc_len if enc_len is not None else max_len
            ),
        )
    return Model(
        cfg=cfg,
        template=transformer.lm_template(cfg),
        forward=lambda p, b: transformer.forward(p, b, cfg),
        prefill=lambda p, b, max_len: transformer.prefill(p, b, cfg, max_len=max_len),
        decode_step=lambda p, t, c: transformer.decode_step(p, t, c, cfg),
        cache_shapes=lambda batch, max_len, enc_len=None: transformer.init_cache_shapes(
            cfg, batch, max_len
        ),
    )
