"""Model registry: one uniform API over all families.

``Model`` bundles the per-family entry points so the launcher, trainer,
serving engine, and dry-run never branch on family.

Batched serving layout
----------------------
``prefill_batch`` / ``decode_batch`` are the continuous-batching entry
points: every per-request cache (inner batch dim 1) is stacked on a new
leading *slot* axis and the whole stack advances in one call via
``jax.vmap`` over the single-request functions. Slots are fully
independent — per-slot context lengths live in the stacked ``cache["len"]``
vector — so the batched step is numerically the per-request step, just
dispatched once for the whole resident batch.

``decode_paged`` is the paged-serving alternative to ``decode_batch``:
the replica's full-attention KV lives in one shared page pool and every
slot addresses it through a block table, so the step is natively batched
(vmap cannot thread a shared mutable pool through independent lanes).
``None`` for families the pager does not cover (encdec, SSM, hybrid,
sliding-window) — :func:`repro.models.transformer.supports_paged`.
The pools dict is dtype-parametric: int8 pools carry per-row fp32
``k_scale``/``v_scale`` arrays alongside ``k``/``v`` (quantized at
scatter, dequantized inside the page gather) and flow through the same
entry points unchanged.

``prefill_chunk`` / ``prefill_chunk_batch`` / ``prefill_chunk_paged``
are the chunked-prefill entry points (Sarathi-style): a fixed-width
slice of a prompt advances an existing cache, so every chunk of every
prompt shares one compiled shape and long prompts stop head-of-line
blocking resident decodes. Same coverage as ``decode_paged`` (uniform
full attention); ``None`` elsewhere.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax

from . import encdec, transformer
from .common import ModelConfig

__all__ = ["Model", "build_model", "SPEC_DRAFT_PAIRS", "default_draft_for"]

# Speculative decoding: draft-model pairing per target architecture.
# A draft must share the target's tokenizer/vocab family so draft token
# ids are target token ids (the repo's configs all use one vocab space);
# it should be far cheaper than its target so k draft steps cost less
# than the one verify call they save. The self-pairings are the
# degenerate-but-useful case: with randomly initialized weights (tests,
# benchmarks) only a self-draft agrees with its target's greedy chain,
# so acceptance-rate plumbing can be exercised end to end — real
# deployments point small-at-large (see ``default_draft_for``).
SPEC_DRAFT_PAIRS: dict[str, str] = {
    "qwen2.5-14b": "stablelm-1.6b",
    "granite-20b": "stablelm-1.6b",
    "internvl2-76b": "stablelm-1.6b",
    "qwen3-moe-30b-a3b": "phi4-mini-3.8b",
    "stablelm-1.6b": "stablelm-1.6b",
    "phi4-mini-3.8b": "phi4-mini-3.8b",
}


def default_draft_for(target: str) -> str:
    """The registry's draft architecture for ``target`` (speculative
    decoding); targets without a declared pairing draft for themselves."""
    return SPEC_DRAFT_PAIRS.get(target, target)


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    template: Any  # ParamSpec tree
    forward: Callable  # (params, batch) -> (logits, aux)
    prefill: Callable  # (params, batch, max_len) -> (logits, cache)
    decode_step: Callable  # (params, token, cache) -> (logits, cache)
    cache_shapes: Callable  # (batch, max_len, [enc_len]) -> SDS tree
    prefill_batch: Callable  # (params, batch [N,1,...], max_len) -> stacked
    decode_batch: Callable  # (params, token [N,1,1(,D)], caches [N,...]) -> stacked
    decode_paged: Callable | None = None  # (params, token [W,1(,D)], pools,
    #   lengths [W] (-1 = masked lane), block_tables [W,NB])
    prefill_chunk: Callable | None = None  # (params, chunk [B,C(,D)], cache,
    #   offset, valid) -> ([B,C,·], cache) — one request's chunk step
    prefill_chunk_batch: Callable | None = None  # vmapped over the slot axis:
    #   (params, chunk [N,1,C(,D)], caches [N,...], offsets [N], valids [N])
    prefill_chunk_paged: Callable | None = None  # (params, chunk [W,C(,D)],
    #   pools, offsets [W] (-1 = masked), valids [W], block_tables [W,NB])
    verify_step_paged: Callable | None = None  # speculative verify: same
    #   signature as prefill_chunk_paged; lane w holds [last_token, d_1..d_k]
    #   at positions offsets[w].. — one chunk call verifies k+1 positions
    cache_axes: Callable | None = None  # () -> logical axis names for the
    #   slot-stacked serving cache, mirroring cache_shapes leaf-for-leaf
    #   (transformer.slot_cache_logical_axes) — the mesh engine resolves
    #   them through serve_cache_spec; None = commit caches replicated

    @property
    def name(self) -> str:
        return self.cfg.name


def _batched_entry_points(prefill: Callable, decode_step: Callable):
    """vmap the single-request entry points over a leading slot axis."""

    def prefill_batch(params, batch, max_len):
        return jax.vmap(lambda b: prefill(params, b, max_len))(batch)

    def decode_batch(params, token, caches):
        return jax.vmap(lambda t, c: decode_step(params, t, c))(token, caches)

    return prefill_batch, decode_batch


def build_model(cfg: ModelConfig) -> Model:
    cfg.validate()
    if cfg.is_encdec:
        prefill = lambda p, b, max_len: encdec.prefill(p, b, cfg, max_len=max_len)
        decode = lambda p, t, c: encdec.decode_step(p, t, c, cfg)
        prefill_batch, decode_batch = _batched_entry_points(prefill, decode)
        return Model(
            cfg=cfg,
            template=encdec.encdec_template(cfg),
            forward=lambda p, b: encdec.forward(p, b, cfg),
            prefill=prefill,
            decode_step=decode,
            cache_shapes=lambda batch, max_len, enc_len=None: encdec.init_cache_shapes(
                cfg, batch, max_len, enc_len if enc_len is not None else max_len
            ),
            prefill_batch=prefill_batch,
            decode_batch=decode_batch,
        )
    prefill = lambda p, b, max_len: transformer.prefill(p, b, cfg, max_len=max_len)
    decode = lambda p, t, c: transformer.decode_step(p, t, c, cfg)
    prefill_batch, decode_batch = _batched_entry_points(prefill, decode)
    decode_paged = None
    prefill_chunk = prefill_chunk_batch = prefill_chunk_paged = None
    verify_step_paged = None
    if transformer.supports_paged(cfg):
        decode_paged = lambda p, t, pools, lens, bt: (
            transformer.decode_step_paged(p, t, pools, lens, bt, cfg)
        )
        prefill_chunk = lambda p, ch, c, off, val: (
            transformer.prefill_chunk(p, ch, c, off, val, cfg)
        )

        def prefill_chunk_batch(params, chunk, caches, offsets, valids):
            return jax.vmap(
                lambda ch, c, o, v: transformer.prefill_chunk(
                    params, ch, c, o, v, cfg
                )
            )(chunk, caches, offsets, valids)

        prefill_chunk_paged = lambda p, ch, pools, offs, vals, bt: (
            transformer.prefill_chunk_paged(p, ch, pools, offs, vals, bt, cfg)
        )
        verify_step_paged = lambda p, ch, pools, offs, vals, bt: (
            transformer.verify_step_paged(p, ch, pools, offs, vals, bt, cfg)
        )
    return Model(
        cfg=cfg,
        template=transformer.lm_template(cfg),
        forward=lambda p, b: transformer.forward(p, b, cfg),
        prefill=prefill,
        decode_step=decode,
        cache_shapes=lambda batch, max_len, enc_len=None: transformer.init_cache_shapes(
            cfg, batch, max_len
        ),
        prefill_batch=prefill_batch,
        decode_batch=decode_batch,
        decode_paged=decode_paged,
        prefill_chunk=prefill_chunk,
        prefill_chunk_batch=prefill_chunk_batch,
        prefill_chunk_paged=prefill_chunk_paged,
        verify_step_paged=verify_step_paged,
        cache_axes=lambda: transformer.slot_cache_logical_axes(cfg),
    )
