"""Mixture-of-Experts FFN: top-k routing with capacity-bounded einsum
dispatch (GShard/Switch style).

The dispatch is expressed as dense one-hot einsums so it lowers cleanly
under pjit: with experts sharded over the ``model`` mesh axis and tokens
over ``data``, XLA inserts the canonical all-to-all pair around the
expert computation. Tokens over capacity are dropped (residual passes
them through); top-k gate values are renormalized.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed.sharding import logical
from .common import ModelConfig, ParamSpec

__all__ = ["moe_template", "moe_ffn", "load_balance_loss"]


def moe_template(cfg: ModelConfig, n_layers: int | None = None) -> dict:
    L = n_layers if n_layers is not None else cfg.n_layers
    D, E, Fe = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    return {
        "router": ParamSpec((L, D, E), ("layers", "embed", None), scale=0.02),
        "wi_gate": ParamSpec((L, E, D, Fe), ("layers", "experts", "embed_fsdp", "expert_ff")),
        "wi_up": ParamSpec((L, E, D, Fe), ("layers", "experts", "embed_fsdp", "expert_ff")),
        "wo": ParamSpec((L, E, Fe, D), ("layers", "experts", "expert_ff", "embed_fsdp")),
    }


def _route(x, p, cfg: ModelConfig):
    """Shared routing: top-k gates, per-expert positions, keep mask."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.moe_top_k
    T = B * S
    xt = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # [T,E] fp32
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # [T,k]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    capacity = max(1, int(cfg.capacity_factor * k * T / E))
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)  # [T,k,E]
    # Position of each assignment within its expert's buffer. Choice-major
    # priority (all 1st choices first), GShard-style.
    flat = onehot.transpose(1, 0, 2).reshape(k * T, E)
    pos_flat = jnp.cumsum(flat, axis=0) - flat  # position BEFORE this entry
    pos = (pos_flat * flat).sum(-1).reshape(k, T).T  # [T,k]
    keep = pos < capacity
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)
    return xt, probs, gate_vals, expert_idx, onehot, pos, keep, capacity


def _expert_ffn(expert_in, p, cfg: ModelConfig):
    dtype = cfg.compute_dtype
    gate = jnp.einsum("ecd,edf->ecf", expert_in, p["wi_gate"].astype(dtype))
    up = jnp.einsum("ecd,edf->ecf", expert_in, p["wi_up"].astype(dtype))
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(dtype) * up
    h = logical(h, ("experts", None, "expert_ff"))
    return jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(dtype))


def moe_ffn(x: jax.Array, p: dict, cfg: ModelConfig):
    """x: [B,S,D] -> (out [B,S,D], aux metrics dict).

    p leaves are per-layer (unstacked): router [D,E], wi_* [E,D,Fe], wo
    [E,Fe,D]. Dispatch implementation per ``cfg.moe_impl``:

    * "einsum" (baseline, GShard-style): one-hot [T,E,C] dispatch/combine
      matmuls — simple and shardable, but costs O(T*E*C*D) dense FLOPs
      that dwarf the expert math at scale;
    * "gather": slot tables built from the same routing, token rows
      gathered into [E,C,D] and scatter-added back — O(E*C*D) data
      movement, no dispatch FLOPs (see EXPERIMENTS.md §Perf).
    """
    B, S, D = x.shape
    dtype = cfg.compute_dtype
    xt, probs, gate_vals, expert_idx, onehot, pos, keep, capacity = _route(x, p, cfg)
    T = xt.shape[0]
    E, k = cfg.n_experts, cfg.moe_top_k

    if cfg.moe_impl == "gather":
        # Slot tables: slot (e, c) -> source token id (T = sentinel/empty).
        e_flat = expert_idx.T.reshape(-1)  # [k*T] choice-major
        pos_flat = pos.T.reshape(-1).astype(jnp.int32)
        tok_flat = jnp.tile(jnp.arange(T, dtype=jnp.int32), k)
        gate_flat = gate_vals.T.reshape(-1)
        slot_tok = jnp.full((E, capacity), T, jnp.int32)
        # Out-of-capacity entries have pos >= capacity -> dropped.
        slot_tok = slot_tok.at[e_flat, pos_flat].set(tok_flat, mode="drop")
        slot_gate = jnp.zeros((E, capacity), jnp.float32)
        slot_gate = slot_gate.at[e_flat, pos_flat].set(gate_flat, mode="drop")

        x_pad = jnp.concatenate([xt, jnp.zeros((1, D), xt.dtype)], axis=0)
        expert_in = x_pad[slot_tok]  # [E, C, D] gather
        expert_in = logical(expert_in, ("experts", None, "embed"))
        expert_out = _expert_ffn(expert_in, p, cfg)
        weighted = expert_out.astype(jnp.float32) * slot_gate[..., None]
        y = jnp.zeros((T + 1, D), jnp.float32)
        y = y.at[slot_tok.reshape(-1)].add(weighted.reshape(-1, D))
        out = y[:T].astype(dtype)
    else:
        pos_clip = jnp.minimum(pos, capacity - 1).astype(jnp.int32)
        pos_onehot = jax.nn.one_hot(pos_clip, capacity, dtype=jnp.float32)  # [T,k,C]
        # dispatch[t,e,c] = 1 iff token t goes to expert e at slot c
        dispatch = jnp.einsum(
            "tke,tkc->tec", onehot * keep[..., None].astype(jnp.float32), pos_onehot
        )
        combine = jnp.einsum(
            "tke,tkc,tk->tec", onehot, pos_onehot, gate_vals.astype(jnp.float32)
        )
        expert_in = jnp.einsum("tec,td->ecd", dispatch.astype(dtype), xt)
        expert_in = logical(expert_in, ("experts", None, "embed"))
        expert_out = _expert_ffn(expert_in, p, cfg)
        out = jnp.einsum("tec,ecd->td", combine.astype(dtype), expert_out)

    aux = {
        "lb_loss": load_balance_loss(probs, onehot),
        "dropped_frac": 1.0 - jnp.mean(keep.astype(jnp.float32)),
    }
    return out.reshape(B, S, D), aux


def load_balance_loss(probs: jax.Array, onehot: jax.Array) -> jax.Array:
    """Switch-Transformer load-balance loss: E * sum_e f_e * P_e."""
    E = probs.shape[-1]
    f = jnp.mean(jnp.sum(onehot, axis=1), axis=0)  # fraction routed per expert
    p = jnp.mean(probs, axis=0)  # mean router prob per expert
    return E * jnp.sum(f * p)
