"""Model zoo substrate: pure-JAX architectures for all assigned families."""

from .common import (
    ModelConfig,
    ParamSpec,
    abstract_params,
    count_params,
    init_from_template,
    template_bytes,
)
from .registry import Model, build_model

__all__ = [
    "ModelConfig",
    "ParamSpec",
    "abstract_params",
    "count_params",
    "init_from_template",
    "template_bytes",
    "Model",
    "build_model",
]
