"""Input specs per (architecture × shape cell).

``input_specs`` returns ShapeDtypeStruct stand-ins (dry-run: weak-type
correct, shardable, no device allocation); ``make_inputs`` materializes
small concrete batches for tests/examples.

Modality frontends are stubs per the assignment: [audio] provides
precomputed frame embeddings, [vlm] precomputed patch embeddings.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ShapeCell
from .common import ModelConfig

__all__ = ["input_specs", "make_inputs", "ENC_LEN_DECODE"]

# Encoder length backing the cross-attention cache in enc-dec decode cells
# (a ~100 s utterance at 40 Hz frames; documented in DESIGN.md).
ENC_LEN_DECODE = 4096


def _token_batch(cfg: ModelConfig, B: int, S: int, *, train: bool) -> dict:
    spec: dict = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if train:
        spec["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.frontend == "patches":
        P = min(cfg.n_frontend_tokens, S)
        spec["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, P, cfg.frontend_dim), cfg.compute_dtype
        )
    if cfg.is_encdec:
        spec["frames"] = jax.ShapeDtypeStruct(
            (B, S, cfg.frontend_dim), cfg.compute_dtype
        )
    return spec


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """Abstract inputs for one shape cell.

    train:   {"tokens","labels"[, "frames"|"patch_embeds"]}
    prefill: {"tokens"[, ...]} over the full seq_len
    decode:  {"token": [B,1]} (cache specs come from the model registry)
    """
    B, S = cell.global_batch, cell.seq_len
    if cell.kind == "train":
        return _token_batch(cfg, B, S, train=True)
    if cell.kind == "prefill":
        return _token_batch(cfg, B, S, train=False)
    if cell.kind == "decode":
        return {"token": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
    raise ValueError(f"unknown cell kind {cell.kind}")


def make_inputs(cfg: ModelConfig, cell: ShapeCell, seed: int = 0) -> dict:
    """Concrete random inputs matching :func:`input_specs`."""
    rng = np.random.default_rng(seed)
    specs = input_specs(cfg, cell)
    out = {}
    for name, s in specs.items():
        if jnp.issubdtype(s.dtype, jnp.integer):
            out[name] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, size=s.shape), dtype=s.dtype
            )
        else:
            out[name] = jnp.asarray(
                rng.standard_normal(size=s.shape), dtype=jnp.float32
            ).astype(s.dtype)
    return out
