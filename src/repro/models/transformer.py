"""Decoder-only LM assembly: dense / MoE / SSM / hybrid / VLM-backbone.

One parameter template + three entry points per architecture:

* :func:`forward` — teacher-forcing logits (training);
* :func:`prefill` — forward + cache construction;
* :func:`decode_step` — one token against the cache.

**Layer plan.** Layers are grouped into *classes* by attention window
(full vs sliding). Each class stores its parameters stacked on a leading
axis and allocates its own decode cache: full-attention layers get a
``max_len`` KV cache, sliding-window layers get an O(window) ring buffer
— this is what makes hybrid archs (hymba: 29 SWA + 3 global layers)
feasible at 32k/500k contexts. Execution follows the original layer
order as a sequence of *runs*, each a ``lax.scan`` over a contiguous
slice of one class (uniform archs collapse to a single scan; the HLO
stays small for SPMD partitioning at 512 devices).
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

import jax
import jax.numpy as jnp

from ..distributed.sharding import logical
from .attention import (
    attention_block,
    attn_template,
    chunk_attention_block,
    paged_attention_block,
    paged_chunk_attention_block,
)
from .common import ModelConfig, ParamSpec
from .layers import (
    embed_template,
    gelu_mlp,
    mlp_template,
    rmsnorm,
    swiglu_mlp,
)
from .moe import moe_ffn, moe_template
from .ssm import mamba_block, mamba_decode_step, ssm_template

__all__ = [
    "lm_template",
    "forward",
    "prefill",
    "decode_step",
    "decode_step_paged",
    "prefill_chunk",
    "prefill_chunk_paged",
    "verify_step_paged",
    "supports_paged",
    "init_cache_shapes",
    "cache_logical_axes",
    "slot_cache_logical_axes",
    "layer_plan",
    "LayerPlan",
]

FULL_WINDOW = 2**30


# ---------------------------------------------------------------------------
# Layer plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ClassSpec:
    window: int | None  # None = full attention
    layer_ids: tuple[int, ...]  # original layer indices, ascending

    @property
    def count(self) -> int:
        return len(self.layer_ids)


@dataclasses.dataclass(frozen=True)
class RunSpec:
    class_idx: int
    offset: int  # start within the class stack
    count: int


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    classes: tuple[ClassSpec, ...]
    runs: tuple[RunSpec, ...]


@functools.lru_cache(maxsize=None)
def layer_plan(cfg: ModelConfig) -> LayerPlan:
    windows = [cfg.window_for_layer(l) for l in range(cfg.n_layers)]
    uniq = sorted(set(windows), key=lambda w: (w is not None, w))
    by_window = {w: [] for w in uniq}
    for l, w in enumerate(windows):
        by_window[w].append(l)
    classes = tuple(ClassSpec(w, tuple(by_window[w])) for w in uniq)
    cls_of = {l: ci for ci, c in enumerate(classes) for l in c.layer_ids}
    pos_in_cls = {l: c.layer_ids.index(l) for c in classes for l in c.layer_ids}

    runs: list[RunSpec] = []
    l = 0
    while l < cfg.n_layers:
        ci = cls_of[l]
        start = pos_in_cls[l]
        n = 1
        while (
            l + n < cfg.n_layers
            and cls_of[l + n] == ci
            and pos_in_cls[l + n] == start + n
        ):
            n += 1
        runs.append(RunSpec(ci, start, n))
        l += n
    return LayerPlan(classes, tuple(runs))


def _class_layers_template(cfg: ModelConfig, n: int) -> dict:
    """Template for one class of ``n`` layers."""
    D = cfg.d_model
    layers: dict = {"ln1": ParamSpec((n, D), ("layers", "embed"), init="ones")}
    if cfg.block in ("attn", "hymba"):
        layers["attn"] = attn_template(cfg, n_layers=n)
        layers["ln2"] = ParamSpec((n, D), ("layers", "embed"), init="ones")
        if cfg.is_moe:
            layers["moe"] = moe_template(cfg, n_layers=n)
        else:
            layers["mlp"] = mlp_template(cfg, n_layers=n)
    if cfg.block in ("mamba", "hymba"):
        layers["ssm"] = ssm_template(cfg, n_layers=n)
    if cfg.block == "hymba":
        layers["norm_attn"] = ParamSpec((n, D), ("layers", "embed"), init="ones")
        layers["norm_ssm"] = ParamSpec((n, D), ("layers", "embed"), init="ones")
        layers["beta_attn"] = ParamSpec((n, D), ("layers", "embed"), init="ones")
        layers["beta_ssm"] = ParamSpec((n, D), ("layers", "embed"), init="ones")
    return layers


def lm_template(cfg: ModelConfig) -> dict:
    """Full parameter template for a decoder-only architecture."""
    cfg.validate()
    plan = layer_plan(cfg)
    t: dict = {
        "classes": {
            f"c{i}": _class_layers_template(cfg, c.count)
            for i, c in enumerate(plan.classes)
        },
    }
    emb = embed_template(cfg)
    keep_emb: dict = {}
    if cfg.stage_embed or (cfg.stage_unembed and cfg.tie_embeddings):
        keep_emb["tok"] = emb["tok"]
    if cfg.stage_unembed and not cfg.tie_embeddings:
        keep_emb["lm_head"] = emb["lm_head"]
    if keep_emb:
        t["embed"] = keep_emb
    if cfg.stage_unembed:
        t["final_norm"] = ParamSpec((cfg.d_model,), ("embed",), init="ones")
    if cfg.stage_embed and cfg.frontend == "patches":
        t["vision_proj"] = ParamSpec(
            (cfg.frontend_dim, cfg.d_model), ("frontend", "embed")
        )
    return t


# ---------------------------------------------------------------------------
# Shared pieces
# ---------------------------------------------------------------------------

def _embed(params, batch_or_tokens, cfg: ModelConfig, batch=None):
    """First-stage input: token embedding (+modality merge) — or, for a
    middle pipeline stage, the hidden states passed through verbatim."""
    dtype = cfg.compute_dtype
    if not cfg.stage_embed:
        hidden = batch["hidden"] if batch is not None else batch_or_tokens
        return logical(hidden.astype(dtype), ("batch", "act_seq", "embed"))
    tokens = batch_or_tokens
    x = params["embed"]["tok"].astype(dtype)[tokens]
    if cfg.frontend == "patches" and batch is not None and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(dtype)  # [B, P, frontend_dim]
        proj = jnp.einsum("bpf,fd->bpd", pe, params["vision_proj"].astype(dtype))
        P = proj.shape[1]
        x = jnp.concatenate([proj, x[:, P:]], axis=1)
    return logical(x, ("batch", "act_seq", "embed"))


def _unembed(params, x, cfg: ModelConfig):
    """Last-stage output: logits — or raw hidden states mid-pipeline."""
    if not cfg.stage_unembed:
        return x
    dtype = cfg.compute_dtype
    x = rmsnorm(x, params["final_norm"], cfg.rms_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["tok"].astype(dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["embed"]["lm_head"].astype(dtype))
    return logical(logits, ("batch", "seq", "vocab"))


def _ffn(x, p_layer, cfg: ModelConfig):
    if cfg.is_moe:
        return moe_ffn(x, p_layer["moe"], cfg)
    if cfg.act == "swiglu":
        return swiglu_mlp(x, p_layer["mlp"], cfg.compute_dtype), {}
    return gelu_mlp(x, p_layer["mlp"], cfg.compute_dtype), {}


def _mixer(x_norm, p_layer, cfg: ModelConfig, *, positions, window, cache=None,
           window_static=None):
    """Token mixing by family. Returns (out, cache_parts dict)."""
    parts = {}
    if cfg.block in ("attn", "hymba"):
        if cache is None:
            kv = None
        elif "_write_idx" in cache:
            kv = (cache["k"], cache["v"], cache["_attn_len"], cache["_write_idx"])
        else:
            kv = (cache["k"], cache["v"], cache["_attn_len"])
        a_out, (k, v) = attention_block(
            x_norm, p_layer["attn"], cfg,
            positions=positions, window=window, cache=kv,
            window_static=window_static,
        )
        parts["k"], parts["v"] = k, v
        if cfg.block == "attn":
            return a_out, parts
    if cfg.block in ("mamba", "hymba"):
        if cache is None:
            m_out, (conv, ssm) = mamba_block(x_norm, p_layer["ssm"], cfg)
        else:
            m_out, (conv, ssm) = mamba_decode_step(
                x_norm, p_layer["ssm"], cfg, (cache["conv"], cache["ssm"])
            )
        parts["conv"], parts["ssm"] = conv, ssm
        if cfg.block == "mamba":
            return m_out, parts
    # hymba fusion: per-branch norm + learned gains, averaged.
    a_out = rmsnorm(a_out, p_layer["norm_attn"], cfg.rms_eps) * p_layer[
        "beta_attn"
    ].astype(a_out.dtype)
    m_out = rmsnorm(m_out, p_layer["norm_ssm"], cfg.rms_eps) * p_layer[
        "beta_ssm"
    ].astype(m_out.dtype)
    return 0.5 * (a_out + m_out), parts


def _layer_body(x, p_layer, cfg: ModelConfig, *, positions, window, cache=None,
                window_static=None):
    h = rmsnorm(x, p_layer["ln1"], cfg.rms_eps)
    mix, parts = _mixer(
        h, p_layer, cfg, positions=positions, window=window, cache=cache,
        window_static=window_static,
    )
    x = x + mix
    aux = {}
    if cfg.block in ("attn", "hymba"):
        h2 = rmsnorm(x, p_layer["ln2"], cfg.rms_eps)
        ff, aux = _ffn(h2, p_layer, cfg)
        x = x + ff
    return logical(x, ("batch", "act_seq", "embed")), parts, aux


def _slice_stack(tree, offset: int, count: int):
    return jax.tree_util.tree_map(lambda a: a[offset : offset + count], tree)


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def forward(params, batch, cfg: ModelConfig):
    """Teacher-forcing logits. batch: {"tokens": [B,S], ...} -> [B,S,V]."""
    x_in = batch["tokens"] if cfg.stage_embed else batch["hidden"]
    S = x_in.shape[1]
    x = _embed(params, x_in, cfg, batch)
    positions = jnp.arange(S, dtype=jnp.int32)
    plan = layer_plan(cfg)

    lb_total = jnp.zeros((), jnp.float32)
    for run in plan.runs:
        cls = plan.classes[run.class_idx]
        window = jnp.int32(cls.window if cls.window is not None else FULL_WINDOW)
        p_run = _slice_stack(params["classes"][f"c{run.class_idx}"], run.offset, run.count)

        def body(x, p_layer, window=window, ws=cls.window):
            x, _, aux = _layer_body(
                x, p_layer, cfg, positions=positions, window=window,
                window_static=ws,
            )
            lb = aux.get("lb_loss", jnp.zeros((), jnp.float32))
            return x, lb

        kb = cfg.remat_block
        if cfg.remat and kb > 1 and run.count % kb == 0 and run.count > kb:
            # Block remat: one stored carry per kb layers; the inner scan
            # is recomputed during backward.
            p_blocked = jax.tree_util.tree_map(
                lambda a: a.reshape(run.count // kb, kb, *a.shape[1:]), p_run
            )

            @jax.checkpoint
            def block_body(x, p_chunk, body=body):
                x, lbs = jax.lax.scan(body, x, p_chunk)
                return x, jnp.sum(lbs)

            x, lbs = jax.lax.scan(block_body, x, p_blocked)
        else:
            if cfg.remat:
                body = jax.checkpoint(body)
            x, lbs = jax.lax.scan(body, x, p_run)
        lb_total = lb_total + jnp.sum(lbs)
    return _unembed(params, x, cfg), {"lb_loss": lb_total / max(cfg.n_layers, 1)}


def _class_cache_len(cls: ClassSpec, max_len: int) -> int:
    if cls.window is None:
        return max_len
    return min(max_len, cls.window)


def init_cache_shapes(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Abstract cache layout (ShapeDtypeStructs) for serve lowering."""
    plan = layer_plan(cfg)
    KV, Dh = cfg.n_kv_heads, cfg.head_dim
    dt = cfg.compute_dtype
    c: dict = {"len": jax.ShapeDtypeStruct((), jnp.int32)}
    for i, cls in enumerate(plan.classes):
        n = cls.count
        entry: dict = {}
        if cfg.block in ("attn", "hymba"):
            Lc = _class_cache_len(cls, max_len)
            entry["k"] = jax.ShapeDtypeStruct((n, batch, Lc, KV, Dh), dt)
            entry["v"] = jax.ShapeDtypeStruct((n, batch, Lc, KV, Dh), dt)
        if cfg.block in ("mamba", "hymba"):
            entry["conv"] = jax.ShapeDtypeStruct(
                (n, batch, cfg.ssm_conv - 1, cfg.d_inner), dt
            )
            entry["ssm"] = jax.ShapeDtypeStruct(
                (n, batch, cfg.d_inner, cfg.ssm_state), jnp.float32
            )
        c[f"c{i}"] = entry
    return c


def cache_logical_axes(cfg: ModelConfig) -> dict:
    plan = layer_plan(cfg)
    c: dict = {"len": ()}
    for i, _cls in enumerate(plan.classes):
        entry: dict = {}
        if cfg.block in ("attn", "hymba"):
            kv = ("layers", "cache_batch", "cache_seq", "kv_heads", "head_dim")
            entry["k"] = kv
            entry["v"] = kv
        if cfg.block in ("mamba", "hymba"):
            entry["conv"] = ("layers", "cache_batch", "conv", "ssm_inner")
            entry["ssm"] = ("layers", "cache_batch", "ssm_inner", "ssm_state")
        c[f"c{i}"] = entry
    return c


def slot_cache_logical_axes(cfg: ModelConfig) -> dict:
    """Axis names for the serving engine's slot-stacked decode cache.

    The engine stacks per-request caches (inner batch dim 1) on a new
    leading *slot* axis; that slot axis is the continuous-batching
    batch, so it takes the ``cache_batch`` name and the degenerate
    inner batch dim drops to None. The tree mirrors
    :func:`init_cache_shapes` leaf-for-leaf — the serving engine zips
    the two to commit each leaf to its replica submesh under
    :func:`repro.distributed.sharding.serve_cache_spec` (shards only
    ``cache_batch``; everything else replicates within the slice).
    """
    per_req = cache_logical_axes(cfg)
    out: dict = {"len": ("cache_batch",)}
    for key, entry in per_req.items():
        if key == "len":
            continue
        out[key] = {
            name: ("cache_batch",)
            + tuple(None if a == "cache_batch" else a for a in axes)
            for name, axes in entry.items()
        }
    return out


def prefill(params, batch, cfg: ModelConfig, *, max_len: int):
    """Forward over a prompt, building the decode cache.

    Full-attention classes keep the whole prompt's K/V (padded to
    ``max_len``); sliding-window classes keep an O(window) ring buffer of
    the last ``window`` positions.
    """
    x_in = batch["tokens"] if cfg.stage_embed else batch["hidden"]
    B, S = x_in.shape[:2]
    if max_len < S:
        raise ValueError("max_len must cover the prompt")
    x = _embed(params, x_in, cfg, batch)
    positions = jnp.arange(S, dtype=jnp.int32)
    plan = layer_plan(cfg)

    # Collect per-class stacked cache parts across runs.
    collected: dict[int, list] = {i: [] for i in range(len(plan.classes))}
    for run in plan.runs:
        cls = plan.classes[run.class_idx]
        window = jnp.int32(cls.window if cls.window is not None else FULL_WINDOW)
        p_run = _slice_stack(params["classes"][f"c{run.class_idx}"], run.offset, run.count)

        def body(x, p_layer, window=window, ws=cls.window):
            x, parts, _ = _layer_body(
                x, p_layer, cfg, positions=positions, window=window,
                window_static=ws,
            )
            return x, parts

        x, stacked = jax.lax.scan(body, x, p_run)
        collected[run.class_idx].append(stacked)

    # Last stage: logits for the final position; middle pipeline stages:
    # the full hidden sequence (the next stage prefills from it).
    logits = _unembed(params, x[:, -1:] if cfg.stage_unembed else x, cfg)

    cache: dict = {"len": jnp.int32(S)}
    for i, cls in enumerate(plan.classes):
        runs_parts = collected[i]
        stacked = jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs, axis=0), *runs_parts
        )
        entry: dict = {}
        if "k" in stacked:
            Lc = _class_cache_len(cls, max_len)
            k, v = stacked["k"], stacked["v"]  # [n, B, S, KV, Dh]
            if cls.window is None or S <= Lc:
                pad = [(0, 0), (0, 0), (0, Lc - S), (0, 0), (0, 0)]
                entry["k"], entry["v"] = jnp.pad(k, pad), jnp.pad(v, pad)
            else:
                # Ring buffer of the last Lc positions: slot = pos % Lc.
                k_t, v_t = k[:, :, -Lc:], v[:, :, -Lc:]
                shift = S % Lc
                entry["k"] = jnp.roll(k_t, shift, axis=2)
                entry["v"] = jnp.roll(v_t, shift, axis=2)
            axes = cache_logical_axes(cfg)[f"c{i}"]
            entry["k"] = logical(entry["k"], axes["k"])
            entry["v"] = logical(entry["v"], axes["v"])
        if "conv" in stacked:
            entry["conv"] = stacked["conv"]
            entry["ssm"] = stacked["ssm"]
        cache[f"c{i}"] = entry
    return logits, cache


def decode_step(params, token, cache, cfg: ModelConfig):
    """One decode step. token: [B,1] -> (logits [B,1,V], updated cache).

    ``cache["len"]`` = number of tokens already in context (the new token
    gets position ``len`` and the cache grows to ``len + 1``).
    """
    x = _embed(params, token, cfg)
    positions = cache["len"][None].astype(jnp.int32)
    new_len = cache["len"] + 1
    plan = layer_plan(cfg)

    new_cache: dict = {"len": new_len}
    updated: dict[int, list] = {i: [] for i in range(len(plan.classes))}
    for run in plan.runs:
        cls = plan.classes[run.class_idx]
        p_run = _slice_stack(params["classes"][f"c{run.class_idx}"], run.offset, run.count)
        c_run = _slice_stack(
            {k: v for k, v in cache[f"c{run.class_idx}"].items()}, run.offset, run.count
        )
        if cls.window is None:
            # Plain cache: write at len, attend over new_len entries.
            attn_len = jnp.broadcast_to(new_len, (run.count,))
            window = jnp.broadcast_to(jnp.int32(FULL_WINDOW), (run.count,))
        else:
            Lc = None  # ring: length handled below
            ring = c_run.get("k")
            Lc = ring.shape[2] if ring is not None else cls.window
            # Write slot = len % Lc; valid entries = min(new_len, Lc).
            attn_len = jnp.broadcast_to(jnp.minimum(new_len, Lc), (run.count,))
            window = jnp.broadcast_to(jnp.int32(FULL_WINDOW), (run.count,))

        def body(x, scanned, cls=cls):
            p_layer, c_layer, a_len, win = scanned
            c_layer = dict(c_layer, _attn_len=a_len)
            if cls.window is not None and "k" in c_layer:
                Lc_ = c_layer["k"].shape[1]
                slot = jnp.mod(positions[0], Lc_)
                if cfg.ring_impl == "index":
                    # Direct slot addressing: write at len % Lc; all valid
                    # entries are in-window by ring construction.
                    c_layer["_write_idx"] = slot
                    return _layer_body(
                        x, p_layer, cfg,
                        positions=positions, window=win, cache=c_layer,
                    )[:2]
                # Baseline "roll": rotate so the write (at _attn_len - 1)
                # lands on slot len % Lc, then rotate back.
                tgt = a_len - 1
                shift = tgt - slot
                c_layer["k"] = jnp.roll(c_layer["k"], shift, axis=1)
                c_layer["v"] = jnp.roll(c_layer["v"], shift, axis=1)
                x, parts, _ = _layer_body(
                    x, p_layer, cfg, positions=positions, window=win, cache=c_layer
                )
                if "k" in parts:
                    parts["k"] = jnp.roll(parts["k"], -shift, axis=1)
                    parts["v"] = jnp.roll(parts["v"], -shift, axis=1)
                return x, parts
            x, parts, _ = _layer_body(
                x, p_layer, cfg, positions=positions, window=win, cache=c_layer
            )
            return x, parts

        x, stacked = jax.lax.scan(body, x, (p_run, c_run, attn_len, window))
        updated[run.class_idx].append(stacked)

    logits = _unembed(params, x, cfg)
    for i in range(len(plan.classes)):
        new_cache[f"c{i}"] = jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs, axis=0), *updated[i]
        )
    return logits, new_cache


# ---------------------------------------------------------------------------
# Paged decode
# ---------------------------------------------------------------------------

def supports_paged(cfg: ModelConfig) -> bool:
    """Paged serving pages the *unbounded* full-attention KV. It covers
    every pure-attention architecture (dense, GQA, MoE, VLM backbone)
    whose layers all attend globally; sliding-window ring buffers and
    SSM states are already O(window)/O(1) and keep the dense slot
    layout, so hybrid/mamba archs serve dense."""
    if cfg.is_encdec or cfg.block != "attn":
        return False
    plan = layer_plan(cfg)
    return len(plan.classes) == 1 and plan.classes[0].window is None


def decode_step_paged(
    params,
    token,
    pools: dict,
    lengths,
    block_tables,
    cfg: ModelConfig,
):
    """One decode step for a whole slot batch against a shared page pool.

    Unlike :func:`decode_step` (per-request cache, vmapped by the
    engine), the paged step is natively batched: the W requests share
    the replica's page pool and cannot be vmapped over it (each lane
    scatters into the common arrays). Per-request state is ``lengths``
    [W] (tokens already in context; ``-1`` marks a masked lane, which
    reads/writes only the scratch page) and ``block_tables`` [W, NB] —
    write coordinates are derived in-graph.

    token: [W, 1] ids (stage 0) or hidden [W, 1, D] (later stages);
    pools: {"k": [n_layers, P+1, page, KV, Dh], "v": ...} — int8 pools
    additionally carry {"k_scale", "v_scale": [n_layers, P+1, page]}
    per-row fp32 scales (quantized at scatter, dequantized in the page
    gather). Returns (logits/hidden [W, 1, V|D], updated pools).
    """
    if not supports_paged(cfg):
        raise ValueError(f"{cfg.name}: paged decode needs uniform full attention")
    x = _embed(params, token, cfg)
    lengths = jnp.asarray(lengths, jnp.int32)
    active = lengths >= 0
    pos = jnp.maximum(lengths, 0)
    positions = pos[:, None]  # [W, 1]
    # Write coordinates are layer-invariant: derive them once here, not
    # inside the layer scan. Masked lanes go to the scratch page.
    W = pos.shape[0]
    page = pools["k"].shape[2]
    scratch = pools["k"].shape[1] - 1
    write_pages = jnp.where(
        active, block_tables[jnp.arange(W), pos // page], scratch
    )
    write_offs = pos % page
    p_run = params["classes"]["c0"]

    def body(x, scanned):
        p_layer, pages = scanned
        h = rmsnorm(x, p_layer["ln1"], cfg.rms_eps)
        a, pages = paged_attention_block(
            h, p_layer["attn"], cfg,
            positions=positions, pages=pages,
            block_tables=block_tables,
            write_pages=write_pages, write_offs=write_offs,
        )
        x = x + a
        h2 = rmsnorm(x, p_layer["ln2"], cfg.rms_eps)
        ff, _ = _ffn(h2, p_layer, cfg)
        return x + ff, pages

    x, pools = jax.lax.scan(body, x, (p_run, pools))
    return _unembed(params, x, cfg), pools


# ---------------------------------------------------------------------------
# Chunked prefill
# ---------------------------------------------------------------------------

def prefill_chunk(params, chunk, cache, offset, valid, cfg: ModelConfig):
    """Advance one request's dense cache by a fixed-width prompt chunk.

    The Sarathi-style middle ground between :func:`prefill` (whole
    prompt, one shape per length) and :func:`decode_step` (one token):
    ``C = chunk width`` tokens join an existing cache at absolute
    positions ``offset .. offset + C - 1``. ``valid <= C`` of them are
    real — the padding tail's K/V writes are dropped or overwritten by
    the next chunk, and its outputs are garbage the engine discards —
    so every chunk of every prompt shares one compiled shape.

    Uniform full-attention architectures only (the same coverage as
    :func:`supports_paged`): ring buffers and SSM states advance
    token-by-token and keep whole-prompt prefill.

    chunk: {"tokens": [B, C]} (stage 0) or {"hidden": [B, C, D]};
    offset, valid: int32 scalars (per-lane under the engine's vmap).
    Returns ([B, C, V|D] per-position outputs, updated cache with
    ``len = offset + valid``).
    """
    if not supports_paged(cfg):
        raise ValueError(
            f"{cfg.name}: chunked prefill needs uniform full attention"
        )
    x_in = chunk["tokens"] if cfg.stage_embed else chunk["hidden"]
    x = _embed(params, x_in, cfg, chunk)
    offset = jnp.asarray(offset, jnp.int32)
    p_run = params["classes"]["c0"]
    c0 = cache["c0"]

    def body(x, scanned):
        p_layer, k_cache, v_cache = scanned
        h = rmsnorm(x, p_layer["ln1"], cfg.rms_eps)
        a, (k_cache, v_cache) = chunk_attention_block(
            h, p_layer["attn"], cfg,
            offset=offset, k_cache=k_cache, v_cache=v_cache,
        )
        x = x + a
        h2 = rmsnorm(x, p_layer["ln2"], cfg.rms_eps)
        ff, _ = _ffn(h2, p_layer, cfg)
        return x + ff, (k_cache, v_cache)

    x, (k, v) = jax.lax.scan(body, x, (p_run, c0["k"], c0["v"]))
    new_cache = {
        "len": (offset + jnp.asarray(valid, jnp.int32)).astype(cache["len"].dtype),
        "c0": {"k": k, "v": v},
    }
    return _unembed(params, x, cfg), new_cache


def prefill_chunk_paged(
    params, chunk, pools: dict, offsets, valids, block_tables, cfg: ModelConfig
):
    """Advance a whole slot batch's paged caches by one prompt chunk.

    The paged sibling of :func:`prefill_chunk`, natively batched like
    :func:`decode_step_paged` (the W lanes share the replica's page
    pool): each lane's chunk K/V are scattered incrementally into its
    reserved pages — write coordinates come from the block table, masked
    lanes (``offsets == -1``) and padding positions (``>= valids``) land
    on the scratch page — and the chunk attends over the paged prefix
    through the gather fallback in :mod:`repro.kernels.decode_attention`.

    chunk: [W, C] ids (stage 0) or [W, C, D] hidden; offsets [W] int32
    (tokens already in context; -1 = masked lane); valids [W] int32;
    pools: {"k": [n_layers, P+1, page, KV, Dh], "v": ...} — int8 pools
    additionally carry per-row fp32 scales (see :func:`decode_step_paged`).
    Returns ([W, C, V|D] per-position outputs, updated pools).
    """
    if not supports_paged(cfg):
        raise ValueError(
            f"{cfg.name}: chunked prefill needs uniform full attention"
        )
    x = _embed(params, chunk, cfg)
    offsets = jnp.asarray(offsets, jnp.int32)
    valids = jnp.asarray(valids, jnp.int32)
    active = offsets >= 0
    pos0 = jnp.maximum(offsets, 0)
    W, C = x.shape[:2]
    positions = pos0[:, None] + jnp.arange(C, dtype=jnp.int32)  # [W, C]
    # Write coordinates are layer-invariant: derive them once here, not
    # inside the layer scan. Only real chunk tokens of active lanes
    # touch reserved pages; everything else goes to the scratch page.
    page = pools["k"].shape[2]
    scratch = pools["k"].shape[1] - 1
    writable = active[:, None] & (jnp.arange(C)[None, :] < valids[:, None])
    rows = jnp.arange(W, dtype=jnp.int32)[:, None]
    table_pages = block_tables[rows, jnp.minimum(positions // page,
                                                 block_tables.shape[1] - 1)]
    write_pages = jnp.where(writable, table_pages, scratch)
    write_offs = positions % page
    p_run = params["classes"]["c0"]

    def body(x, scanned):
        p_layer, pages = scanned
        h = rmsnorm(x, p_layer["ln1"], cfg.rms_eps)
        a, pages = paged_chunk_attention_block(
            h, p_layer["attn"], cfg,
            positions=positions, pages=pages,
            block_tables=block_tables,
            write_pages=write_pages, write_offs=write_offs,
        )
        x = x + a
        h2 = rmsnorm(x, p_layer["ln2"], cfg.rms_eps)
        ff, _ = _ffn(h2, p_layer, cfg)
        return x + ff, pages

    x, pools = jax.lax.scan(body, x, (p_run, pools))
    return _unembed(params, x, cfg), pools


def verify_step_paged(
    params, chunk, pools: dict, offsets, valids, block_tables, cfg: ModelConfig
):
    """Verify ``k + 1`` speculative positions in one paged chunk call.

    Draft-verify decoding's target step: the chunk call shape (C query
    positions against a growing paged prefix, causal-masked in-kernel
    with LSE merge) *is* the verification step, so this delegates to
    :func:`prefill_chunk_paged` — no new kernel. Lane ``w`` carries
    ``[last_committed_token, d_1 .. d_k]`` at absolute positions
    ``offsets[w] .. offsets[w] + k``; position ``j``'s output row is the
    logits the sequential decode path would have produced after
    consuming the first ``j + 1`` of those inputs, **bit-for-bit**
    (per-row softmax/matmul reductions are independent of the other
    rows, and the scattered K/V page rows are byte-identical to the ones
    :func:`decode_step_paged` writes — ``tests/test_spec_decode.py``
    asserts both), which is what makes greedy accept/reject exact:
    accepting the longest prefix with ``d_j == argmax(row[j - 1])`` and
    rewinding the rest reproduces plain decode's token stream exactly.

    Same signature and coverage as :func:`prefill_chunk_paged`
    (``supports_paged`` families; ``valids[w] - 1`` drafts per lane,
    ``offsets[w] == -1`` masks a lane; int8 pools quantize rows at
    scatter, so rewound-and-rewritten rows stay exact). Returns
    ``([W, C, V|D] per-position outputs, updated pools)``.
    """
    return prefill_chunk_paged(
        params, chunk, pools, offsets, valids, block_tables, cfg
    )
