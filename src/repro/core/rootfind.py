"""Brent's method for scalar root finding (paper ref. [14]).

The paper retrieves the maximum acceptable input rate ``q_lim^energy``
relative to a tolerable risk ``xi_lim`` via Brent's method on the risk
function Eq. (3). We implement Brent (1973) directly — inverse quadratic
interpolation / secant / bisection with the usual safeguards — so the
framework has no scipy dependency.
"""

from __future__ import annotations

from typing import Callable

__all__ = ["brentq", "find_rate_for_risk"]


def brentq(
    f: Callable[[float], float],
    a: float,
    b: float,
    *,
    xtol: float = 1e-10,
    rtol: float = 8.881784197001252e-16,
    maxiter: int = 200,
) -> float:
    """Find a root of ``f`` in ``[a, b]`` with ``f(a) * f(b) <= 0``."""
    fa, fb = f(a), f(b)
    if fa == 0.0:
        return a
    if fb == 0.0:
        return b
    if fa * fb > 0.0:
        raise ValueError(f"f(a) and f(b) must have opposite signs: f({a})={fa}, f({b})={fb}")

    if abs(fa) < abs(fb):
        a, b, fa, fb = b, a, fb, fa
    c, fc = a, fa
    d = e = b - a

    for _ in range(maxiter):
        if fb * fc > 0.0:
            c, fc = a, fa
            d = e = b - a
        if abs(fc) < abs(fb):
            a, b, c = b, c, b
            fa, fb, fc = fb, fc, fb

        tol = 2.0 * rtol * abs(b) + 0.5 * xtol
        m = 0.5 * (c - b)
        if abs(m) <= tol or fb == 0.0:
            return b

        if abs(e) < tol or abs(fa) <= abs(fb):
            # Bisection
            d = e = m
        else:
            s = fb / fa
            if a == c:
                # Secant
                p = 2.0 * m * s
                q = 1.0 - s
            else:
                # Inverse quadratic interpolation
                q0 = fa / fc
                r = fb / fc
                p = s * (2.0 * m * q0 * (q0 - r) - (b - a) * (r - 1.0))
                q = (q0 - 1.0) * (r - 1.0) * (s - 1.0)
            if p > 0.0:
                q = -q
            else:
                p = -p
            if 2.0 * p < min(3.0 * m * q - abs(tol * q), abs(e * q)):
                e = d
                d = p / q
            else:
                d = e = m

        a, fa = b, fb
        b = b + (d if abs(d) > tol else (tol if m > 0 else -tol))
        fb = f(b)
    return b


def find_rate_for_risk(
    risk_fn: Callable[[float], float],
    xi_lim: float,
    *,
    q_lo: float = 1e-6,
    q_hi: float = 1.0,
    xtol: float = 1e-6,
) -> float:
    """Largest input rate ``q`` with ``risk_fn(q) <= xi_lim``.

    ``risk_fn`` is assumed non-decreasing in ``q``. Returns ``q_hi`` if even
    the max rate is safe, ``q_lo`` if no rate is safe.
    """
    g = lambda q: risk_fn(q) - xi_lim
    g_hi = g(q_hi)
    if g_hi <= 0.0:
        return q_hi
    g_lo = g(q_lo)
    if g_lo >= 0.0:
        return q_lo
    return brentq(g, q_lo, q_hi, xtol=xtol)
