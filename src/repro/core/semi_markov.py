"""Semi-Markov model of an energy-harvesting edge device (paper Sec. III).

State ``S = (Q, E, gamma)``:

* ``Q in {0, 1}`` — queue occupancy (one-job queue, paper Sec. II);
* ``E in {0..E_max}`` — discrete battery level in energy units;
* ``gamma in {0, 1}`` — 0: power-saving, 1: active.

Dynamics per processing stage ``m`` (dwell ``kappa_m`` slots):

* active & processing (``gamma=1, Q=1``): dwell ``kappa(PM)`` slots, consume
  ``CE(PM)``, battery update Eq. (1); a new job arrives within the stage
  w.p. ``p_m = 1 - (1-q)^kappa_m``;
* active & idle (``gamma=1, Q=0``): dwell 1 slot, no consumption;
* power saving (``gamma=0``): dwell 1 slot, arrivals rejected, pending job
  (if any) held, recover until ``E > E'_th`` (hysteresis; entry at
  ``E < E_th``).

The active power mode ``PM >= 1`` is a deterministic function of ``E``
(:class:`repro.core.power.PowerModePolicy`) — fixed modes and the paper's
dynamic mode are both instances.

From the embedded chain's stationary distribution the paper's metrics are
derived: Eq. (2) mean energy, Eq. (3) downtime risk ``xi``, Eq. (4)
expected processing slots ``kappa_bar``.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

from .energy import DiscreteMDF
from .power import PowerModePolicy

__all__ = ["DeviceModel", "SemiMarkovChain", "state_index", "state_tuple"]


def state_index(q: int, e: int, gamma: int, e_max: int) -> int:
    """Flat index of state ``(Q, E, gamma)``."""
    return (gamma * 2 + q) * (e_max + 1) + e


def state_tuple(idx: int, e_max: int) -> tuple[int, int, int]:
    """Inverse of :func:`state_index` -> ``(Q, E, gamma)``."""
    e = idx % (e_max + 1)
    rest = idx // (e_max + 1)
    q = rest % 2
    gamma = rest // 2
    return q, e, gamma


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    """Static description of one edge device for the semi-Markov analysis."""

    mdf: DiscreteMDF  # per-slot energy arrival distribution f(e)
    policy: PowerModePolicy  # battery level -> active PM
    e_max: int = 100  # battery capacity in units
    e_th: int = 10  # power-save entry threshold (E < e_th)
    e_th_hi: int = 25  # power-save exit threshold (E > e_th_hi)

    def __post_init__(self) -> None:
        if not (0 <= self.e_th < self.e_th_hi <= self.e_max):
            raise ValueError("need 0 <= e_th < e_th_hi <= e_max (hysteresis)")

    def chain(self, q: float) -> "SemiMarkovChain":
        """Build the chain for device-level job arrival probability ``q``."""
        return SemiMarkovChain(self, q)


class SemiMarkovChain:
    """Embedded-chain transition structure + stationary metrics."""

    def __init__(self, device: DeviceModel, q: float):
        if not (0.0 <= q <= 1.0):
            raise ValueError(f"arrival probability q must be in [0,1], got {q}")
        self.device = device
        self.q = float(q)
        self.n_states = 4 * (device.e_max + 1)
        self._P: np.ndarray | None = None
        self._pi: np.ndarray | None = None

    # ------------------------------------------------------------------
    # Transition matrix
    # ------------------------------------------------------------------
    def transition_matrix(self) -> np.ndarray:
        if self._P is not None:
            return self._P
        dev = self.device
        e_max, e_th, e_th_hi = dev.e_max, dev.e_th, dev.e_th_hi
        q = self.q
        n = self.n_states
        P = np.zeros((n, n), dtype=np.float64)

        # Pre-compute per-kappa convolved income PMFs.
        kappas = sorted({m.kappa for m in dev.policy.modes} | {1})
        income = {k: dev.mdf.convolve(k) for k in kappas}

        for e in range(e_max + 1):
            pm_active = int(dev.policy.pm_for_energy(e))
            mode = dev.policy.mode(pm_active)

            # --- gamma = 1, Q = 0: idle active, dwell 1 slot, no consumption
            src = state_index(0, e, 1, e_max)
            g = income[1]
            for inc, prob in enumerate(g):
                if prob == 0.0:
                    continue
                e2 = min(e + inc, e_max)
                # Case 1 (paper): stay idle w.p. (1-q), accept arrival w.p. q.
                P[src, state_index(0, e2, 1, e_max)] += prob * (1.0 - q)
                P[src, state_index(1, e2, 1, e_max)] += prob * q

            # --- gamma = 1, Q = 1: processing, dwell kappa(PM), consume CE(PM)
            src = state_index(1, e, 1, e_max)
            kappa, ce = mode.kappa, mode.ce
            if e < ce:
                # Energy gate (paper Sec. III: "CE(PM) <= E_m"): the job
                # waits one slot for the battery to cover its stage cost.
                # Queue full => new arrivals rejected.
                g = income[1]
                for inc, prob in enumerate(g):
                    if prob == 0.0:
                        continue
                    e2 = min(e + inc, e_max)
                    P[src, state_index(1, e2, 1, e_max)] += prob
            else:
                p_m = 1.0 - (1.0 - q) ** kappa
                g = income[kappa]
                for inc, prob in enumerate(g):
                    if prob == 0.0:
                        continue
                    e2 = int(np.clip(e + inc - ce, 0, e_max))  # Eq. (1)
                    gamma2 = 0 if e2 < e_th else 1
                    # Job completes; new arrival during the stage w.p. p_m.
                    P[src, state_index(0, e2, gamma2, e_max)] += prob * (1.0 - p_m)
                    P[src, state_index(1, e2, gamma2, e_max)] += prob * p_m

            # --- gamma = 0: power saving (Q preserved), dwell 1 slot
            g = income[1]
            for qq in (0, 1):
                src = state_index(qq, e, 0, e_max)
                for inc, prob in enumerate(g):
                    if prob == 0.0:
                        continue
                    e2 = min(e + inc, e_max)
                    gamma2 = 1 if e2 > e_th_hi else 0  # hysteresis exit
                    P[src, state_index(qq, e2, gamma2, e_max)] += prob

        # Each row must be a distribution.
        np.testing.assert_allclose(P.sum(axis=1), 1.0, atol=1e-9)
        self._P = P
        return P

    # ------------------------------------------------------------------
    # Stationary distribution of the embedded chain
    # ------------------------------------------------------------------
    def stationary(self) -> np.ndarray:
        """pi of the recurrent class reachable from (Q=0, E=E_max, active).

        The reachable set (BFS over the transition sparsity) is closed, so
        pi solves the linear system ``pi (I - P_R) = 0, sum(pi) = 1`` on it.
        Falls back to repeated squaring of P if the direct solve is
        singular (multiple recurrent classes).
        """
        if self._pi is not None:
            return self._pi
        P = self.transition_matrix()
        start = state_index(0, self.device.e_max, 1, self.device.e_max)

        # BFS reachability — the reachable set is closed under P.
        reach = np.zeros(self.n_states, dtype=bool)
        frontier = [start]
        reach[start] = True
        while frontier:
            s = frontier.pop()
            for t in np.nonzero(P[s] > 0.0)[0]:
                if not reach[t]:
                    reach[t] = True
                    frontier.append(int(t))
        idx = np.nonzero(reach)[0]
        Pr = P[np.ix_(idx, idx)]

        pi_r = None
        try:
            A = np.eye(len(idx)) - Pr.T
            A[-1, :] = 1.0
            b = np.zeros(len(idx))
            b[-1] = 1.0
            cand = np.linalg.solve(A, b)
            if np.all(cand > -1e-9):
                pi_r = np.maximum(cand, 0.0)
        except np.linalg.LinAlgError:
            pi_r = None
        if pi_r is None:
            # Repeated squaring fallback (robust to reducibility).
            M = Pr.copy()
            local_start = int(np.searchsorted(idx, start))
            prev = M[local_start]
            for _ in range(64):
                M = M @ M
                M /= M.sum(axis=1, keepdims=True)
                cur = M[local_start]
                if np.max(np.abs(cur - prev)) < 1e-14:
                    break
                prev = cur
            pi_r = np.maximum(M[local_start], 0.0)

        pi = np.zeros(self.n_states)
        pi[idx] = pi_r / pi_r.sum()
        self._pi = pi
        return pi

    # ------------------------------------------------------------------
    # Dwell times and metrics (Eqs. 2-4)
    # ------------------------------------------------------------------
    @functools.cached_property
    def _processing_mask(self) -> np.ndarray:
        """States actually processing: Q=1, gamma=1 and E covers CE(PM)."""
        dev = self.device
        m = np.zeros(self.n_states, dtype=bool)
        for e in range(dev.e_max + 1):
            pm = int(dev.policy.pm_for_energy(e))
            if e >= dev.policy.mode(pm).ce:
                m[state_index(1, e, 1, dev.e_max)] = True
        return m

    @functools.cached_property
    def dwell_slots(self) -> np.ndarray:
        """T_S in slots: kappa(PM) for processing states, 1 otherwise
        (idle, power-save, and energy-gated waiting states)."""
        dev = self.device
        t = np.ones(self.n_states, dtype=np.float64)
        for e in range(dev.e_max + 1):
            pm = int(dev.policy.pm_for_energy(e))
            if e >= dev.policy.mode(pm).ce:
                t[state_index(1, e, 1, dev.e_max)] = dev.policy.mode(pm).kappa
        return t

    @functools.cached_property
    def energy_levels(self) -> np.ndarray:
        return np.array(
            [state_tuple(i, self.device.e_max)[1] for i in range(self.n_states)],
            dtype=np.float64,
        )

    def mean_energy(self) -> float:
        """Time-averaged battery level (semi-Markov time average).

        Note: the paper's Eq. (2) prints ``sum(pi*E) / sum(pi*T)`` which is
        not a time average; we implement the standard
        ``sum(pi*E*T) / sum(pi*T)`` (see DESIGN.md Sec. 6) and expose the
        literal form as :meth:`mean_energy_embedded`.
        """
        pi, t, e = self.stationary(), self.dwell_slots, self.energy_levels
        return float(np.dot(pi * t, e) / np.dot(pi, t))

    def mean_energy_embedded(self) -> float:
        """Paper Eq. (2) as printed."""
        pi, t, e = self.stationary(), self.dwell_slots, self.energy_levels
        return float(np.dot(pi, e) / np.dot(pi, t))

    def risk(self, e_lim: int | None = None) -> float:
        """Eq. (3): total time-fraction with ``E <= e_lim``.

        Defaults to the power-save entry threshold minus one so the metric
        is exactly "fraction of time at a level that has triggered (or
        would trigger) power saving".
        """
        if e_lim is None:
            e_lim = self.device.e_th - 1
        pi, t, e = self.stationary(), self.dwell_slots, self.energy_levels
        mask = e <= e_lim
        return float(np.dot(pi[mask], t[mask]) / np.dot(pi, t))

    def downtime_fraction(self) -> float:
        """Time fraction spent in power-saving mode (gamma = 0)."""
        pi, t = self.stationary(), self.dwell_slots
        gam = np.array(
            [state_tuple(i, self.device.e_max)[2] for i in range(self.n_states)]
        )
        mask = gam == 0
        return float(np.dot(pi[mask], t[mask]) / np.dot(pi, t))

    def kappa_bar(self) -> float:
        """Eq. (4): expected processing slots over active processing states."""
        pi, t = self.stationary(), self.dwell_slots
        sel = self._processing_mask
        num = np.dot(pi[sel], t[sel])
        den = pi[sel].sum()
        if den <= 0.0:
            # No processing mass (q = 0): fall back to the best-energy mode.
            dev = self.device
            return float(dev.policy.kappa_for_energy(dev.e_max))
        return float(num / den)

    def throughput(self) -> float:
        """Long-run completed jobs per slot."""
        pi, t = self.stationary(), self.dwell_slots
        sel = self._processing_mask
        # One job completes per visit to a processing state.
        return float(pi[sel].sum() / np.dot(pi, t))
