"""Power modes and power-mode selection policies (paper Secs. II, V).

The paper measures a 100-encoder + 100-decoder LLM block on a Jetson AGX
Orin and derives, per power mode, the per-job processing time (in slots of
delta = 100 s) and energy (in units of 1 kJ):

    15 W -> (300 s, 26 kJ)  => kappa = 3, CE = 26
    30 W -> (200 s, 22 kJ)  => kappa = 2, CE = 22
    50 W -> (205 s, 23.5 kJ)   dominated by 30 W -> excluded (paper Sec. V)
    60 W -> (100 s, 23 kJ)  => kappa = 1, CE = 23

``PM = 0`` is the power-saving state (computation suspended, jobs
rejected); active modes are indexed ``PM = 1..M``.

The *dynamic* power mode (paper contribution #4) picks the active mode
from the current battery level through a lookup table with thresholds at
40 % and 60 % of capacity.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = [
    "PowerMode",
    "ORIN_POWER_MODES",
    "POWER_SAVE",
    "PowerModePolicy",
    "fixed_policy",
    "dynamic_policy",
]

POWER_SAVE = 0  # PM index of the power-saving state


@dataclasses.dataclass(frozen=True)
class PowerMode:
    """One active power mode: per-job slots ``kappa`` and energy ``ce``."""

    name: str
    watts: float
    kappa: int  # slots to process one job at this mode
    ce: int  # energy units consumed per job at this mode

    def __post_init__(self) -> None:
        if self.kappa < 1:
            raise ValueError("kappa must be >= 1")
        if self.ce < 0:
            raise ValueError("ce must be >= 0")


# Paper's measured table (50 W excluded as dominated).
ORIN_POWER_MODES: tuple[PowerMode, ...] = (
    PowerMode("15W", 15.0, kappa=3, ce=26),
    PowerMode("30W", 30.0, kappa=2, ce=22),
    PowerMode("60W", 60.0, kappa=1, ce=23),
)


@dataclasses.dataclass(frozen=True)
class PowerModePolicy:
    """Deterministic map battery level -> active PM index (1-based).

    ``thresholds`` are battery levels (in energy units): the policy picks
    active mode ``i+1`` where ``i`` is the number of thresholds strictly
    below-or-equal to the current level, i.e. with thresholds ``(40, 60)``
    and 3 modes:  E < 40 -> PM1,  40 <= E < 60 -> PM2,  E >= 60 -> PM3.

    A fixed policy is the degenerate case with no thresholds and a single
    allowed mode.
    """

    modes: tuple[PowerMode, ...]
    thresholds: tuple[int, ...]  # ascending battery-level breakpoints
    allowed: tuple[int, ...]  # active PM indices (1-based), len = len(thresholds)+1

    def __post_init__(self) -> None:
        if len(self.allowed) != len(self.thresholds) + 1:
            raise ValueError("need len(allowed) == len(thresholds) + 1")
        if list(self.thresholds) != sorted(self.thresholds):
            raise ValueError("thresholds must be ascending")
        for pm in self.allowed:
            if not (1 <= pm <= len(self.modes)):
                raise ValueError(f"PM index {pm} out of range")

    def pm_for_energy(self, e: int | np.ndarray) -> int | np.ndarray:
        """Active PM index for battery level ``e`` (vectorized)."""
        idx = np.searchsorted(np.asarray(self.thresholds), np.asarray(e), side="right")
        allowed = np.asarray(self.allowed)
        out = allowed[idx]
        if np.isscalar(e) or np.ndim(e) == 0:
            return int(out)
        return out

    def mode(self, pm_index: int) -> PowerMode:
        """The :class:`PowerMode` for a 1-based active PM index."""
        return self.modes[pm_index - 1]

    def kappa_for_energy(self, e: int) -> int:
        return self.mode(int(self.pm_for_energy(e))).kappa

    def ce_for_energy(self, e: int) -> int:
        return self.mode(int(self.pm_for_energy(e))).ce

    @property
    def kappa_table(self) -> np.ndarray:
        """kappa per active PM index (index 0 unused -> 0)."""
        return np.array([0] + [m.kappa for m in self.modes], dtype=np.int32)

    @property
    def ce_table(self) -> np.ndarray:
        return np.array([0] + [m.ce for m in self.modes], dtype=np.int32)


def fixed_policy(pm_index: int, modes: Sequence[PowerMode] = ORIN_POWER_MODES) -> PowerModePolicy:
    """Always run at active mode ``pm_index`` (1-based)."""
    return PowerModePolicy(modes=tuple(modes), thresholds=(), allowed=(pm_index,))


def dynamic_policy(
    e_max: int,
    modes: Sequence[PowerMode] = ORIN_POWER_MODES,
    fractions: Sequence[float] = (0.4, 0.6),
) -> PowerModePolicy:
    """Paper's dynamic mode: thresholds at 40 % / 60 % of capacity.

    E < 0.4*E_max -> PM1 (15 W), 0.4*E_max <= E < 0.6*E_max -> PM2 (30 W),
    E >= 0.6*E_max -> PM3 (60 W).
    """
    if len(fractions) != len(modes) - 1:
        raise ValueError("need len(fractions) == len(modes) - 1")
    thresholds = tuple(int(round(f * e_max)) for f in fractions)
    return PowerModePolicy(
        modes=tuple(modes),
        thresholds=thresholds,
        allowed=tuple(range(1, len(modes) + 1)),
    )
