"""Maximum sustainable input rates (paper Sec. IV, Eqs. 3-5).

``q_lim^energy``: the largest per-slot job arrival probability keeping the
risk (Eq. 3) that the battery is at/below the power-save threshold under a
user-defined ``xi_lim`` — found with Brent's method on the monotone risk
curve.

``q_lim = min(q_lim^energy, 1/kappa_bar)`` (Eq. 5) additionally enforces
queue stability under the processing delay. For dynamic power-mode
policies ``kappa_bar`` depends on the operating point, so we run a short
fixed-point iteration (the paper evaluates the same quantities once; the
iteration converges in 2-3 steps and is idempotent for fixed policies).
"""

from __future__ import annotations

import dataclasses

from .rootfind import find_rate_for_risk
from .semi_markov import DeviceModel

__all__ = [
    "RateLimits",
    "q_lim_energy",
    "q_lim",
    "q_lim_stable",
    "kappa_bar_curve",
    "risk_curve",
]


@dataclasses.dataclass(frozen=True)
class RateLimits:
    q_energy: float  # energy-constrained limit (Brent on Eq. 3)
    q_time: float  # 1 / kappa_bar (queue stability)
    q_lim: float  # Eq. (5)
    kappa_bar: float

    @property
    def binding(self) -> str:
        return "energy" if self.q_energy <= self.q_time else "time"


def risk_curve(device: DeviceModel, qs, e_lim: int | None = None):
    """Risk (Eq. 3) evaluated at each arrival rate in ``qs``."""
    return [device.chain(float(q)).risk(e_lim) for q in qs]


def q_lim_energy(
    device: DeviceModel,
    xi_lim: float,
    e_lim: int | None = None,
    *,
    xtol: float = 1e-4,
) -> float:
    """Largest q with risk(q) <= xi_lim, via Brent's method (paper ref [14])."""

    def risk_fn(q: float) -> float:
        return device.chain(q).risk(e_lim)

    return find_rate_for_risk(risk_fn, xi_lim, xtol=xtol)


def q_lim(
    device: DeviceModel,
    xi_lim: float,
    e_lim: int | None = None,
    *,
    xtol: float = 1e-4,
) -> RateLimits:
    """Eq. (5): min of the energy-constrained and delay-constrained rates.

    Following the paper, ``kappa_bar`` (Eq. 4) is evaluated once, at the
    energy-constrained operating point ``q_lim^energy`` (for fixed power
    modes it is independent of ``q``; for the dynamic mode this matches the
    paper's reported ``q_lim ~ 1/kappa_bar ~ 0.64``).
    """
    q_energy = q_lim_energy(device, xi_lim, e_lim, xtol=xtol)
    kb = device.chain(q_energy).kappa_bar()
    return RateLimits(
        q_energy=q_energy,
        q_time=1.0 / kb,
        q_lim=min(q_energy, 1.0 / kb),
        kappa_bar=kb,
    )


def kappa_bar_curve(device: DeviceModel, qs):
    """Eq. (4) evaluated across arrival rates (dynamic modes are load-
    dependent: the battery distribution, hence the PM mix, shifts with q)."""
    return [device.chain(float(q)).kappa_bar() for q in qs]


def q_lim_stable(
    device: DeviceModel,
    xi_lim: float,
    e_lim: int | None = None,
    *,
    xtol: float = 1e-3,
) -> RateLimits:
    """Self-consistent variant of Eq. (5).

    The paper describes ``1/kappa_bar`` as "the average maximum rate that
    can be tolerated for a stable input queue". For load-dependent
    (dynamic) power modes ``kappa_bar`` itself depends on the operating
    rate, so the stable-queue condition is the fixed point
    ``q* = min(q_energy, 1/kappa_bar(q*))``, found by bisection on the
    monotone-decreasing ``h(q) = 1/kappa_bar(q) - q``. For fixed power
    modes this coincides exactly with :func:`q_lim`.
    """
    q_energy = q_lim_energy(device, xi_lim, e_lim, xtol=max(xtol, 1e-4))

    def h(q: float) -> float:
        return 1.0 / device.chain(q).kappa_bar() - q

    lo_q, hi_q = 1e-3, 1.0
    if h(hi_q) >= 0.0:  # stable even at saturation
        q_star = hi_q
    else:
        from .rootfind import brentq

        q_star = brentq(h, lo_q, hi_q, xtol=xtol)
    kb = device.chain(q_star).kappa_bar()
    return RateLimits(
        q_energy=q_energy,
        q_time=q_star,
        q_lim=min(q_energy, q_star),
        kappa_bar=kb,
    )
