"""Scheduling policies for replica selection (paper Sec. IV, Algorithm 1).

All three policies return a probability distribution over the devices of
one group/layer, restricted to the currently *available* devices (active
and queue-empty). They are written in ``jax.numpy`` so the same code runs
concretely (router) and traced (inside the jitted network simulator).

* ``uniform``   — 1/|available| over available devices.
* ``long_term`` — Eq. (6): ``r_i = q_lim,i / sum_j q_lim,j`` over available.
* ``adaptive``  — Alg. 1 lines 20-28: start from long-term, scale every
  device currently in the critical power mode PM1 by ``z = alpha/N_l``
  (``alpha`` defaults to the number of PM1 devices), re-normalize.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "uniform_probs",
    "long_term_probs",
    "adaptive_probs",
    "POLICIES",
    "POLICY_LIST",
    "POLICY_IDS",
]

_EPS = 1e-12


def _masked_normalize(x, mask):
    x = jnp.where(mask, x, 0.0)
    total = jnp.sum(x)
    n_avail = jnp.sum(mask.astype(x.dtype))
    # Fall back to uniform-over-available if all mass was zeroed out.
    fallback = jnp.where(mask, 1.0, 0.0) / jnp.maximum(n_avail, 1.0)
    return jnp.where(total > _EPS, x / jnp.maximum(total, _EPS), fallback)


def uniform_probs(q_lims, pm, available):
    """Uniform over available devices (q_lims/pm unused, kept for API parity)."""
    del q_lims, pm
    mask = available.astype(jnp.float32)
    return mask / jnp.maximum(jnp.sum(mask), 1.0)


def long_term_probs(q_lims, pm, available):
    """Eq. (6) restricted to available devices."""
    del pm
    return _masked_normalize(jnp.asarray(q_lims, dtype=jnp.float32), available)


def adaptive_probs(q_lims, pm, available, alpha=None):
    """Algorithm 1 ``ADAPTIVE``: down-weight critical-mode (PM1) devices.

    ``pm`` is each device's *current* active power mode index (1-based);
    devices in PM1 (the lowest-energy mode) get their long-term rate scaled
    by ``z = alpha / N_l`` and the vector is re-normalized.
    """
    x = long_term_probs(q_lims, None, available)
    pm = jnp.asarray(pm)
    critical = (pm == 1) & available
    n_l = x.shape[-1]
    if alpha is None:
        alpha = jnp.sum(critical.astype(jnp.float32))
    z = alpha / n_l
    x = jnp.where(critical, x * z, x)
    return _masked_normalize(x, available)


POLICIES = {
    "uniform": uniform_probs,
    "long_term": long_term_probs,
    "adaptive": adaptive_probs,
}

# Signature-uniform ordering for traced dispatch: the simulator selects a
# policy at runtime via ``jax.lax.switch(policy_id, ...)`` over this tuple,
# so a sweep can mix policies inside one compiled executable. All three
# share the positional signature ``(q_lims, pm, available) -> probs``.
POLICY_LIST = (uniform_probs, long_term_probs, adaptive_probs)
POLICY_IDS = {name: POLICY_LIST.index(fn) for name, fn in POLICIES.items()}
