"""Heterogeneous decentralized-inference topology (paper Secs. II, V).

A network is ``G`` consecutive groups (pipeline stages, Petals-style) of
``N`` devices each. Devices within a group replicate the same LLM block;
devices are heterogeneous in their energy-arrival distributions.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .energy import DiscreteMDF, uniform_mdf
from .power import PowerModePolicy, dynamic_policy
from .rates import RateLimits, q_lim
from .semi_markov import DeviceModel

__all__ = ["DeviceSpec", "NetworkTopology", "paper_topology"]


@dataclasses.dataclass(frozen=True)
class _RateKey:
    spec: "DeviceSpec"
    xi_lim: float


_RATE_CACHE: dict[_RateKey, RateLimits] = {}


def _cached_rate_limits(spec: "DeviceSpec", xi_lim: float) -> RateLimits:
    """Devices repeat across groups; q_lim (Brent + stationary solves) is
    cached by (spec, xi_lim) — the paper notes the stationary distribution
    only needs recomputing when network parameters change."""
    key = _RateKey(spec, xi_lim)
    if key not in _RATE_CACHE:
        _RATE_CACHE[key] = q_lim(spec.model, xi_lim)
    return _RATE_CACHE[key]


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    """One battery-powered edge device."""

    arrival_lo: int  # uniform energy-arrival lower bound (units/slot)
    arrival_hi: int  # upper bound
    policy: PowerModePolicy
    e_max: int = 100
    e_th: int = 10
    e_th_hi: int = 25

    @property
    def mdf(self) -> DiscreteMDF:
        return uniform_mdf(self.arrival_lo, self.arrival_hi)

    @property
    def model(self) -> DeviceModel:
        return DeviceModel(
            mdf=self.mdf,
            policy=self.policy,
            e_max=self.e_max,
            e_th=self.e_th,
            e_th_hi=self.e_th_hi,
        )

    def rate_limits(self, xi_lim: float) -> RateLimits:
        return _cached_rate_limits(self, xi_lim)


@dataclasses.dataclass(frozen=True)
class NetworkTopology:
    """Rectangular topology: ``groups[g][i]`` is device ``i`` of stage ``g``."""

    groups: tuple[tuple[DeviceSpec, ...], ...]

    def __post_init__(self) -> None:
        sizes = {len(g) for g in self.groups}
        if len(sizes) != 1:
            raise ValueError("all groups must have the same number of devices")

    @property
    def n_groups(self) -> int:
        return len(self.groups)

    @property
    def n_per_group(self) -> int:
        return len(self.groups[0])

    def arrival_bounds(self) -> tuple[np.ndarray, np.ndarray]:
        """(lo, hi) arrays of shape [G, N]."""
        lo = np.array([[d.arrival_lo for d in g] for g in self.groups], dtype=np.int32)
        hi = np.array([[d.arrival_hi for d in g] for g in self.groups], dtype=np.int32)
        return lo, hi

    def long_term_rates(self, xi_lim: float) -> np.ndarray:
        """Per-device q_lim matrix [G, N] feeding Eq. (6)."""
        return np.array(
            [[d.rate_limits(xi_lim).q_lim for d in g] for g in self.groups],
            dtype=np.float64,
        )


def paper_topology(
    n_groups: int = 3,
    n_per_group: int = 3,
    arrival_means: tuple[float, ...] | None = None,
    half_width: int = 2,
    e_max: int = 100,
    policy: PowerModePolicy | None = None,
) -> NetworkTopology:
    """The paper's Sec. V setup: 3 groups x 3 nodes, distinct uniform means.

    ``arrival_means`` lists the per-node mean arrival (units/slot) reused
    across groups; defaults spread nodes around the calibrated mean of 8.
    """
    if policy is None:
        policy = dynamic_policy(e_max)
    if arrival_means is None:
        arrival_means = (6.0, 8.0, 10.0)
    if len(arrival_means) != n_per_group:
        raise ValueError("need one arrival mean per device in a group")
    groups = []
    for _ in range(n_groups):
        devs = []
        for mean in arrival_means:
            lo = max(0, int(round(mean)) - half_width)
            hi = int(round(mean)) + half_width
            devs.append(
                DeviceSpec(arrival_lo=lo, arrival_hi=hi, policy=policy, e_max=e_max)
            )
        groups.append(tuple(devs))
    return NetworkTopology(tuple(groups))
