"""The paper's contribution: energy-aware scheduling for decentralized
LLM inference (Khoshsirat, Perin, Rossi — 2024).

Layers:
  * :mod:`repro.core.energy` / :mod:`repro.core.power` — energy arrivals,
    battery dynamics (Eq. 1), Jetson Orin power-mode table, dynamic PM.
  * :mod:`repro.core.semi_markov` — the device semi-Markov chain and its
    stationary metrics (Eqs. 2-4).
  * :mod:`repro.core.rates` — q_lim via Brent's method (Eq. 5).
  * :mod:`repro.core.policies` — uniform / long-term / adaptive (Alg. 1).
  * :mod:`repro.core.simulator` — vectorized JAX network simulation.
"""

from .energy import DiscreteMDF, battery_update, convolve_mdf, uniform_mdf
from .network import DeviceSpec, NetworkTopology, paper_topology
from .policies import (
    POLICIES,
    POLICY_IDS,
    POLICY_LIST,
    adaptive_probs,
    long_term_probs,
    uniform_probs,
)
from .power import (
    ORIN_POWER_MODES,
    POWER_SAVE,
    PowerMode,
    PowerModePolicy,
    dynamic_policy,
    fixed_policy,
)
from .rates import RateLimits, q_lim, q_lim_energy, risk_curve
from .rootfind import brentq, find_rate_for_risk
from .semi_markov import DeviceModel, SemiMarkovChain, state_index, state_tuple
from .simulator import (
    ScenarioParams,
    SimConfig,
    SimResult,
    SweepResult,
    build_runner,
    scenario_from_config,
    scenario_params,
    simulate,
    simulate_single_device,
    simulate_sweep,
    stack_scenarios,
)

__all__ = [
    "DiscreteMDF",
    "battery_update",
    "convolve_mdf",
    "uniform_mdf",
    "DeviceSpec",
    "NetworkTopology",
    "paper_topology",
    "POLICIES",
    "POLICY_IDS",
    "POLICY_LIST",
    "adaptive_probs",
    "long_term_probs",
    "uniform_probs",
    "ORIN_POWER_MODES",
    "POWER_SAVE",
    "PowerMode",
    "PowerModePolicy",
    "dynamic_policy",
    "fixed_policy",
    "RateLimits",
    "q_lim",
    "q_lim_energy",
    "risk_curve",
    "brentq",
    "find_rate_for_risk",
    "DeviceModel",
    "SemiMarkovChain",
    "state_index",
    "state_tuple",
    "ScenarioParams",
    "SimConfig",
    "SimResult",
    "SweepResult",
    "build_runner",
    "scenario_from_config",
    "scenario_params",
    "simulate",
    "simulate_single_device",
    "simulate_sweep",
    "stack_scenarios",
]
