"""JAX simulator of the decentralized inference network (paper Secs. II-V).

Semantics (faithful to the paper's model):

* Time advances in slots of length delta. Job arrivals are Bernoulli(p)
  per slot (Sec. III).
* A job needs one device from each of the ``G`` groups (Petals-style
  pipeline). On arrival, a device is *designated* in every group by the
  scheduling policy (Sec. IV); the job occupies that device's one-slot
  queue (``Q = 1``) until the device starts the job's stage. A device is
  *available* for designation iff it is active and its queue is empty —
  a device that is currently processing but has an empty queue can accept
  a designation (transition case ``Q_m = Q_{m+1} = 1`` of Sec. III).
* If any group has no available device, the job is **dropped**.
* Stage ``g`` starts once stage ``g-1`` is complete and the designated
  device is free; it runs for ``kappa(PM)`` slots at the power mode chosen
  from the device's battery level at stage start, consuming ``CE(PM)``
  (spread uniformly over the stage's slots — battery telemetry only; the
  per-stage total matches Eq. (1)).
* Hysteresis: battery below ``E_th`` puts the device in power-saving mode
  (processing pauses, designations rejected) until it recovers above
  ``E'_th``.

Sweep architecture
------------------

Every scenario knob — job-arrival probability, battery thresholds,
per-device power-mode tables, harvest bounds, scheduling policy — lives
in a :class:`ScenarioParams` pytree of **traced runtime inputs**. The
compiled step function closes only over the network *shape*
``(G, N, n_steps, n_jobs)``, so an entire figure's parameter grid is one
``vmap`` over a leading scenario axis (times the Monte-Carlo axis) and
costs exactly one ``jax.jit`` compile per shape. The scheduling policy is
selected *inside* the trace via ``jax.lax.switch`` over
:data:`repro.core.policies.POLICY_LIST`.

Because PM/harvest tables are per-device (``[G, N, ...]``), heterogeneous
fleets (e.g. one group of 60 W devices feeding a group of 15 W devices)
are expressible directly — build :class:`ScenarioParams` by hand or via
:func:`scenario_from_config` and edit the arrays.

The whole network steps inside one ``lax.scan``; Monte-Carlo repetitions
(the paper uses 1000) are ``vmap``-ed over seeds; scenario grids are
``vmap``-ed over the params pytree.
"""

from __future__ import annotations

import dataclasses
import functools
from collections import Counter
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .network import NetworkTopology
from .policies import POLICIES, POLICY_IDS, POLICY_LIST

__all__ = [
    "ScenarioParams",
    "SimConfig",
    "SimResult",
    "SweepResult",
    "build_runner",
    "reset_trace_counts",
    "scenario_from_config",
    "scenario_params",
    "simulate",
    "simulate_single_device",
    "simulate_sweep",
    "stack_scenarios",
    "trace_counts",
]


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Declarative description of one simulation scenario.

    Since the sweep refactor this is a plain description — none of its
    fields are baked into the compiled program except the shape
    ``(n_groups, n_per_group, n_steps)``; everything else becomes traced
    runtime input via :func:`scenario_from_config`.
    """

    n_groups: int
    n_per_group: int
    n_steps: int = 100
    p_arrival: float = 0.6
    e_max: float = 100.0
    e_th: float = 10.0
    e_th_hi: float = 25.0
    e_init: float | None = None  # default: full battery
    policy: str = "uniform"  # uniform | long_term | adaptive
    # PM tables; index 0 = power save (unused entries 0).
    kappa_table: tuple[int, ...] = (0, 3, 2, 1)
    ce_table: tuple[float, ...] = (0.0, 26.0, 22.0, 23.0)
    # Battery thresholds for the active-PM lookup (dynamic mode); a fixed
    # mode is expressed as thresholds=() allowed=(pm,).
    pm_thresholds: tuple[float, ...] = (40.0, 60.0)
    pm_allowed: tuple[int, ...] = (1, 2, 3)

    def __post_init__(self) -> None:
        if self.policy not in POLICIES:
            raise ValueError(f"unknown policy {self.policy!r}")
        if len(self.pm_allowed) != len(self.pm_thresholds) + 1:
            raise ValueError("need len(pm_allowed) == len(pm_thresholds) + 1")
        if not (0 <= self.e_th < self.e_th_hi <= self.e_max):
            raise ValueError("need 0 <= e_th < e_th_hi <= e_max (hysteresis)")
        if self.e_init is not None and not (0 <= self.e_init <= self.e_max):
            raise ValueError("need 0 <= e_init <= e_max")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ScenarioParams:
    """One scenario's runtime inputs — a pytree of arrays, all traced.

    Tables are **per device** (leading ``[G, N]`` axes), so devices may
    be heterogeneous in battery size, hysteresis thresholds, power-mode
    tables and harvest bounds. Stack several scenarios along a new
    leading axis (:func:`stack_scenarios`) to form a sweep grid.
    """

    p_arrival: jax.Array  # [] f32, Bernoulli job-arrival probability
    e_max: jax.Array  # [G, N] f32 battery capacity
    e_th: jax.Array  # [G, N] f32 power-save entry threshold
    e_th_hi: jax.Array  # [G, N] f32 power-save exit threshold
    e_init: jax.Array  # [G, N] f32 initial battery
    kappa: jax.Array  # [G, N, P] f32 slots per stage by PM
    ce: jax.Array  # [G, N, P] f32 energy per stage by PM
    pm_thresholds: jax.Array  # [G, N, T] f32 (+inf padded)
    pm_allowed: jax.Array  # [G, N, T+1] i32
    arrival_lo: jax.Array  # [G, N] i32 harvest lower bound
    arrival_hi: jax.Array  # [G, N] i32 harvest upper bound
    rates: jax.Array  # [G, N] f32 long-term rates (Eq. 6 numerators)
    policy_id: jax.Array  # [] i32 index into POLICY_LIST

    @property
    def grid_shape(self) -> tuple[int, ...]:
        """Leading scenario axes, if any (empty for a single scenario)."""
        return self.arrival_lo.shape[:-2]

    @property
    def network_shape(self) -> tuple[int, int]:
        return self.arrival_lo.shape[-2:]


def _per_device(x, G: int, N: int, *, dtype) -> jnp.ndarray:
    """Broadcast a scalar / table to per-device ``[G, N, ...]`` layout."""
    arr = jnp.asarray(x, dtype=dtype)
    if arr.ndim <= 1:  # scalar or shared table -> tile over devices
        return jnp.broadcast_to(arr, (G, N) + arr.shape)
    return arr.reshape((G, N) + arr.shape[2:])


def scenario_from_config(
    config: SimConfig,
    arrival_lo: np.ndarray,
    arrival_hi: np.ndarray,
    long_term_rates: np.ndarray | None = None,
    *,
    n_thresholds: int | None = None,
) -> ScenarioParams:
    """Lower a :class:`SimConfig` to its traced :class:`ScenarioParams`.

    ``n_thresholds`` pads the PM-threshold table to a common length so
    scenarios with different dynamic-mode tables (e.g. fixed 30 W vs the
    3-mode dynamic policy) can be stacked into one sweep grid: thresholds
    are padded with ``+inf`` and ``pm_allowed`` by repeating its last
    entry, which leaves the lookup unchanged.
    """
    G, N = config.n_groups, config.n_per_group
    thr = list(config.pm_thresholds)
    allowed = list(config.pm_allowed)
    if n_thresholds is not None:
        if n_thresholds < len(thr):
            raise ValueError(f"n_thresholds={n_thresholds} < {len(thr)} in config")
        pad = n_thresholds - len(thr)
        thr = thr + [np.inf] * pad
        allowed = allowed + [allowed[-1]] * pad
    if long_term_rates is None:
        long_term_rates = np.ones((G, N))
    e_init = config.e_max if config.e_init is None else config.e_init
    f32, i32 = jnp.float32, jnp.int32
    return ScenarioParams(
        p_arrival=jnp.asarray(config.p_arrival, f32),
        e_max=_per_device(config.e_max, G, N, dtype=f32),
        e_th=_per_device(config.e_th, G, N, dtype=f32),
        e_th_hi=_per_device(config.e_th_hi, G, N, dtype=f32),
        e_init=_per_device(e_init, G, N, dtype=f32),
        kappa=_per_device(config.kappa_table, G, N, dtype=f32),
        ce=_per_device(config.ce_table, G, N, dtype=f32),
        pm_thresholds=_per_device(thr, G, N, dtype=f32),
        pm_allowed=_per_device(allowed, G, N, dtype=i32),
        arrival_lo=jnp.asarray(arrival_lo, i32).reshape(G, N),
        arrival_hi=jnp.asarray(arrival_hi, i32).reshape(G, N),
        rates=jnp.asarray(long_term_rates, f32).reshape(G, N),
        policy_id=jnp.asarray(POLICY_IDS[config.policy], i32),
    )


def scenario_params(
    topology: NetworkTopology,
    config: SimConfig,
    *,
    long_term_rates: np.ndarray | None = None,
    xi_lim: float = 0.01,
    n_thresholds: int | None = None,
) -> ScenarioParams:
    """Build :class:`ScenarioParams` for ``config`` on ``topology``.

    Computes the semi-Markov long-term rates (Eq. 6) when the policy
    needs them and none are supplied.
    """
    if config.n_groups != topology.n_groups or config.n_per_group != topology.n_per_group:
        raise ValueError("config/topology shape mismatch")
    lo, hi = topology.arrival_bounds()
    if long_term_rates is None and config.policy in ("long_term", "adaptive"):
        long_term_rates = topology.long_term_rates(xi_lim)
    return scenario_from_config(
        config, lo, hi, long_term_rates, n_thresholds=n_thresholds
    )


def stack_scenarios(scenarios: Sequence[ScenarioParams]) -> ScenarioParams:
    """Stack scenarios along a new leading sweep axis.

    All scenarios must share the network shape and table lengths — pad
    heterogeneous PM tables via ``n_thresholds`` in
    :func:`scenario_from_config`.
    """
    if not scenarios:
        raise ValueError("need at least one scenario")
    shapes = {s.pm_thresholds.shape for s in scenarios}
    if len(shapes) != 1:
        raise ValueError(
            f"scenario table shapes differ ({sorted(shapes)}); pad with "
            "n_thresholds= so all scenarios share one threshold length"
        )
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *scenarios)


@dataclasses.dataclass
class SimResult:
    """Per-run metric arrays (leading axis = Monte-Carlo runs)."""

    completed: np.ndarray
    dropped: np.ndarray
    arrivals: np.ndarray
    downtime_fraction: np.ndarray  # mean fraction of devices in power save
    mean_battery: np.ndarray  # time-averaged mean battery level (units)

    @property
    def normalized_throughput(self) -> np.ndarray:
        """Fig. 4a metric: completed / total input jobs."""
        return self.completed / np.maximum(self.arrivals, 1)

    def summary(self) -> dict[str, Any]:
        return {
            "completed": float(self.completed.mean()),
            "dropped": float(self.dropped.mean()),
            "arrivals": float(self.arrivals.mean()),
            "normalized_throughput": float(self.normalized_throughput.mean()),
            "downtime_fraction": float(self.downtime_fraction.mean()),
            "mean_battery": float(self.mean_battery.mean()),
            "completed_std": float(self.completed.std()),
            "downtime_std": float(self.downtime_fraction.std()),
        }


@dataclasses.dataclass
class SweepResult:
    """Sweep metrics with leading axes ``[n_scenarios, n_runs]``.

    Index with ``result[i]`` to get scenario ``i``'s :class:`SimResult`.
    """

    completed: np.ndarray
    dropped: np.ndarray
    arrivals: np.ndarray
    downtime_fraction: np.ndarray
    mean_battery: np.ndarray

    def __len__(self) -> int:
        return self.completed.shape[0]

    def __getitem__(self, i: int) -> SimResult:
        return SimResult(
            completed=self.completed[i],
            dropped=self.dropped[i],
            arrivals=self.arrivals[i],
            downtime_fraction=self.downtime_fraction[i],
            mean_battery=self.mean_battery[i],
        )

    @property
    def normalized_throughput(self) -> np.ndarray:
        return self.completed / np.maximum(self.arrivals, 1)


# --- compile accounting ---------------------------------------------------
# Incremented inside the traced step builder, so it counts actual jit cache
# misses (= XLA compiles) per network shape. Used by the compile-count
# regression test and BENCH_sweep.json.
_TRACE_COUNTS: Counter = Counter()


def trace_counts() -> dict[tuple, int]:
    """jit trace (cache-miss) count per ``(G, N, n_steps, n_jobs)``."""
    return dict(_TRACE_COUNTS)


def reset_trace_counts() -> None:
    _TRACE_COUNTS.clear()


def _make_run(G: int, N: int, n_steps: int, n_jobs: int):
    """The un-jitted single-scenario, single-run step program.

    Closes over the network shape only; every scenario parameter arrives
    through the traced ``params`` pytree.
    """

    def run(params: ScenarioParams, key):
        _TRACE_COUNTS[(G, N, n_steps, n_jobs)] += 1  # trace-time only
        kappa, ce = params.kappa, params.ce  # [G, N, P]
        thr, allowed = params.pm_thresholds, params.pm_allowed

        def pm_of_grid(E):
            """Active PM per device from battery level (paper's lookup)."""
            idx = jnp.sum(thr <= E[..., None], axis=-1)  # searchsorted right
            return jnp.take_along_axis(allowed, idx[..., None], axis=-1)[..., 0]

        def policy_probs(policy_id, rates, pm_now, avail):
            branches = tuple(
                (lambda r, p, a, f=f: jax.vmap(f)(r, p, a)) for f in POLICY_LIST
            )
            return jax.lax.switch(policy_id, branches, rates, pm_now, avail)

        def step(carry, key):
            (E, gamma, queued, j_act, j_proc, j_stage, j_dev, j_rem, j_pm, ctr) = carry
            completed, dropped, arrivals, ps_sum, batt_sum = ctr
            k_inc, k_arr, k_pick = jax.random.split(key, 3)

            # 1) harvest energy
            inc = jax.random.randint(
                k_inc, (G, N), params.arrival_lo, params.arrival_hi + 1
            ).astype(jnp.float32)

            # 2) progress processing jobs (paused while the device power-saves)
            stage_c = jnp.clip(j_stage, 0, G - 1)
            d_cur = jnp.take_along_axis(j_dev, stage_c[:, None], axis=1)[:, 0]
            dev_active = gamma[stage_c, d_cur]
            running = j_act & j_proc & dev_active
            cons_j = jnp.where(
                running,
                ce[stage_c, d_cur, j_pm] / kappa[stage_c, d_cur, j_pm],
                0.0,
            )
            cons = jnp.zeros((G, N), jnp.float32).at[stage_c, d_cur].add(cons_j)
            j_rem = j_rem - running.astype(j_rem.dtype)

            # 3) completions
            done = j_act & j_proc & (j_rem <= 0.0)
            j_proc = j_proc & ~done
            j_stage = j_stage + done.astype(jnp.int32)
            finished = done & (j_stage >= G)
            completed = completed + jnp.sum(finished).astype(jnp.int32)
            j_act = j_act & ~finished

            # 4) battery + hysteresis (Eq. (1) totals per stage; per-slot spread)
            E = jnp.clip(E + inc - cons, 0.0, params.e_max)
            gamma = jnp.where(
                E < params.e_th, False, jnp.where(E > params.e_th_hi, True, gamma)
            )

            # 5) stage starts for waiting jobs
            busy = jnp.zeros((G, N), jnp.int32).at[
                jnp.clip(j_stage, 0, G - 1),
                jnp.take_along_axis(
                    j_dev, jnp.clip(j_stage, 0, G - 1)[:, None], axis=1
                )[:, 0],
            ].add((j_act & j_proc).astype(jnp.int32)) > 0
            stage_w = jnp.clip(j_stage, 0, G - 1)
            d_wait = jnp.take_along_axis(j_dev, stage_w[:, None], axis=1)[:, 0]
            pm_grid = pm_of_grid(E)
            pm_try = pm_grid[stage_w, d_wait]
            # Energy gate (paper: CE(PM) <= E): a stage starts only once the
            # battery covers its full cost.
            gate_ok = E[stage_w, d_wait] >= ce[stage_w, d_wait, pm_try]
            can_start = (
                j_act & ~j_proc & gamma[stage_w, d_wait] & ~busy[stage_w, d_wait] & gate_ok
            )
            # Tie-break: at most one waiting job per device by construction
            # (queue capacity 1); see tests/test_simulator.py invariants.
            pm_new = pm_try
            j_pm = jnp.where(can_start, pm_new, j_pm)
            j_rem = jnp.where(can_start, kappa[stage_w, d_wait, pm_new], j_rem)
            j_proc = j_proc | can_start
            started = jnp.zeros((G, N), jnp.int32).at[stage_w, d_wait].add(
                can_start.astype(jnp.int32)
            ) > 0
            queued = queued & ~started

            # 6) new arrival + designation (Alg. 1)
            arrive = jax.random.bernoulli(k_arr, params.p_arrival)
            arrivals = arrivals + arrive.astype(jnp.int32)
            avail = gamma & ~queued
            all_ok = jnp.all(jnp.any(avail, axis=1))
            slot = jnp.argmin(j_act)  # first free job slot
            has_slot = ~j_act[slot]
            accept = arrive & all_ok & has_slot
            dropped = dropped + (arrive & ~(all_ok & has_slot)).astype(jnp.int32)

            probs = policy_probs(params.policy_id, params.rates, pm_grid, avail)
            logits = jnp.where(probs > 0, jnp.log(jnp.maximum(probs, 1e-12)), -1e9)
            pick_keys = jax.random.split(k_pick, G)
            choice = jax.vmap(jax.random.categorical)(pick_keys, logits)  # [G]

            designate = jnp.zeros((G, N), bool).at[jnp.arange(G), choice].set(True)
            queued = queued | (designate & accept)
            j_act = j_act.at[slot].set(jnp.where(accept, True, j_act[slot]))
            j_proc = j_proc.at[slot].set(jnp.where(accept, False, j_proc[slot]))
            j_stage = j_stage.at[slot].set(jnp.where(accept, 0, j_stage[slot]))
            j_dev = j_dev.at[slot].set(jnp.where(accept, choice, j_dev[slot]))
            j_rem = j_rem.at[slot].set(jnp.where(accept, 0.0, j_rem[slot]))

            # 7) telemetry
            ps_sum = ps_sum + jnp.sum(~gamma).astype(jnp.int32)
            batt_sum = batt_sum + jnp.mean(E)

            ctr = (completed, dropped, arrivals, ps_sum, batt_sum)
            return (E, gamma, queued, j_act, j_proc, j_stage, j_dev, j_rem, j_pm, ctr), None

        carry = (
            params.e_init.astype(jnp.float32),  # E
            jnp.ones((G, N), bool),  # gamma (active)
            jnp.zeros((G, N), bool),  # queued
            jnp.zeros((n_jobs,), bool),  # j_act
            jnp.zeros((n_jobs,), bool),  # j_proc
            jnp.zeros((n_jobs,), jnp.int32),  # j_stage
            jnp.zeros((n_jobs, G), jnp.int32),  # j_dev
            jnp.zeros((n_jobs,), jnp.float32),  # j_rem
            jnp.ones((n_jobs,), jnp.int32),  # j_pm
            (
                jnp.int32(0),
                jnp.int32(0),
                jnp.int32(0),
                jnp.int32(0),
                jnp.float32(0.0),
            ),
        )
        keys = jax.random.split(key, n_steps)
        carry, _ = jax.lax.scan(step, carry, keys)
        completed, dropped, arrivals, ps_sum, batt_sum = carry[-1]
        return {
            "completed": completed,
            "dropped": dropped,
            "arrivals": arrivals,
            "downtime_fraction": ps_sum / (n_steps * G * N),
            "mean_battery": batt_sum / n_steps,
        }

    return run


@functools.lru_cache(maxsize=None)
def build_runner(
    n_groups: int, n_per_group: int, n_steps: int, n_jobs: int | None = None
):
    """Jitted ``run(params, key) -> metrics`` for one network *shape*.

    Cached by shape, so repeated calls share one compiled executable.
    """
    if n_jobs is None:
        n_jobs = 2 * n_per_group  # <= N queued + N processing per group
    return jax.jit(_make_run(n_groups, n_per_group, n_steps, n_jobs))


@functools.lru_cache(maxsize=None)
def _sweep_runner(n_groups: int, n_per_group: int, n_steps: int, n_jobs: int | None):
    """Jitted ``(stacked_params [S,...], keys [R]) -> metrics [S, R]``."""
    if n_jobs is None:
        n_jobs = 2 * n_per_group
    run = _make_run(n_groups, n_per_group, n_steps, n_jobs)
    mc = jax.vmap(run, in_axes=(None, 0))  # Monte-Carlo axis
    return jax.jit(jax.vmap(mc, in_axes=(0, None)))  # scenario axis


def _run_sweep(
    stacked: ScenarioParams, n_steps: int, n_runs: int, seed: int
) -> SweepResult:
    G, N = stacked.network_shape
    runner = _sweep_runner(G, N, n_steps, None)
    keys = jax.random.split(jax.random.PRNGKey(seed), n_runs)
    out = jax.tree_util.tree_map(np.asarray, runner(stacked, keys))
    return SweepResult(
        completed=out["completed"],
        dropped=out["dropped"],
        arrivals=out["arrivals"],
        downtime_fraction=out["downtime_fraction"],
        mean_battery=out["mean_battery"],
    )


def simulate_sweep(
    topology: NetworkTopology | None,
    scenarios: Sequence[SimConfig | ScenarioParams] | ScenarioParams,
    *,
    n_runs: int = 100,
    seed: int = 0,
    n_steps: int | None = None,
    long_term_rates: np.ndarray | None = None,
    xi_lim: float = 0.01,
) -> SweepResult:
    """Run a whole scenario grid as ONE compiled executable.

    ``scenarios`` may be a sequence of :class:`SimConfig` (lowered on
    ``topology``), a sequence of prebuilt :class:`ScenarioParams` (which
    may come from *different* same-shape topologies — pass any or no
    topology), or an already-stacked :class:`ScenarioParams` with a
    leading sweep axis. All scenarios share one Monte-Carlo key set, so a
    1-element sweep is bit-for-bit identical to :func:`simulate` with the
    same seed.

    ``n_steps`` is the only non-shape static left: required when passing
    raw :class:`ScenarioParams`, inferred (and checked uniform) from
    :class:`SimConfig` entries.
    """
    if isinstance(scenarios, ScenarioParams):
        if not scenarios.grid_shape:
            raise ValueError("stacked ScenarioParams needs a leading sweep axis")
        if n_steps is None:
            raise ValueError("n_steps is required with raw ScenarioParams")
        return _run_sweep(scenarios, n_steps, n_runs, seed)

    scenarios = list(scenarios)
    if not scenarios:
        raise ValueError("need at least one scenario")
    configs = [s for s in scenarios if isinstance(s, SimConfig)]
    if configs:
        steps = {c.n_steps for c in configs}
        if n_steps is None:
            if len(steps) != 1:
                raise ValueError(f"scenarios disagree on n_steps: {sorted(steps)}")
            (n_steps,) = steps
        elif steps - {n_steps}:
            raise ValueError(f"scenarios disagree on n_steps: {sorted(steps)}")
        if topology is None:
            raise ValueError("SimConfig scenarios need a topology")
    if n_steps is None:
        raise ValueError("n_steps is required with raw ScenarioParams")
    # Pad configs to the widest threshold table in the whole mixed list —
    # including prebuilt ScenarioParams — so they stack.
    n_thr = max(
        [len(c.pm_thresholds) for c in configs]
        + [
            int(s.pm_thresholds.shape[-1])
            for s in scenarios
            if isinstance(s, ScenarioParams)
        ],
        default=0,
    )
    lowered = [
        scenario_params(
            topology,
            s,
            long_term_rates=long_term_rates,
            xi_lim=xi_lim,
            n_thresholds=n_thr,
        )
        if isinstance(s, SimConfig)
        else s
        for s in scenarios
    ]
    return _run_sweep(stack_scenarios(lowered), n_steps, n_runs, seed)


def simulate(
    topology: NetworkTopology,
    config: SimConfig,
    *,
    n_runs: int = 100,
    seed: int = 0,
    long_term_rates: np.ndarray | None = None,
    xi_lim: float = 0.01,
) -> SimResult:
    """Run ``n_runs`` Monte-Carlo repetitions of one scenario.

    A thin wrapper over the sweep engine (a 1-element grid), so scalar
    and sweep runs share one compiled executable per network shape.
    """
    params = scenario_params(
        topology, config, long_term_rates=long_term_rates, xi_lim=xi_lim
    )
    sweep = _run_sweep(stack_scenarios([params]), config.n_steps, n_runs, seed)
    return sweep[0]


def simulate_single_device(
    config: SimConfig,
    arrival_lo: int,
    arrival_hi: int,
    *,
    n_runs: int = 100,
    seed: int = 0,
) -> SimResult:
    """Paper Fig. 2a: one device, one group (power-mode study)."""
    cfg = dataclasses.replace(config, n_groups=1, n_per_group=1, policy="uniform")
    params = scenario_from_config(
        cfg, np.array([[arrival_lo]]), np.array([[arrival_hi]]), np.ones((1, 1))
    )
    sweep = _run_sweep(stack_scenarios([params]), cfg.n_steps, n_runs, seed)
    return sweep[0]
