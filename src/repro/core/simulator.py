"""JAX simulator of the decentralized inference network (paper Secs. II-V).

Semantics (faithful to the paper's model):

* Time advances in slots of length delta. Job arrivals are Bernoulli(p)
  per slot (Sec. III).
* A job needs one device from each of the ``G`` groups (Petals-style
  pipeline). On arrival, a device is *designated* in every group by the
  scheduling policy (Sec. IV); the job occupies that device's one-slot
  queue (``Q = 1``) until the device starts the job's stage. A device is
  *available* for designation iff it is active and its queue is empty —
  a device that is currently processing but has an empty queue can accept
  a designation (transition case ``Q_m = Q_{m+1} = 1`` of Sec. III).
* If any group has no available device, the job is **dropped**.
* Stage ``g`` starts once stage ``g-1`` is complete and the designated
  device is free; it runs for ``kappa(PM)`` slots at the power mode chosen
  from the device's battery level at stage start, consuming ``CE(PM)``
  (spread uniformly over the stage's slots — battery telemetry only; the
  per-stage total matches Eq. (1)).
* Hysteresis: battery below ``E_th`` puts the device in power-saving mode
  (processing pauses, designations rejected) until it recovers above
  ``E'_th``.

The whole network steps inside one ``lax.scan``; Monte-Carlo repetitions
(the paper uses 1000) are ``vmap``-ed over seeds.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .network import NetworkTopology
from .policies import POLICIES

__all__ = ["SimConfig", "SimResult", "build_runner", "simulate", "simulate_single_device"]


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Static simulation parameters (hashable -> one jit per config)."""

    n_groups: int
    n_per_group: int
    n_steps: int = 100
    p_arrival: float = 0.6
    e_max: float = 100.0
    e_th: float = 10.0
    e_th_hi: float = 25.0
    e_init: float | None = None  # default: full battery
    policy: str = "uniform"  # uniform | long_term | adaptive
    # PM tables; index 0 = power save (unused entries 0).
    kappa_table: tuple[int, ...] = (0, 3, 2, 1)
    ce_table: tuple[float, ...] = (0.0, 26.0, 22.0, 23.0)
    # Battery thresholds for the active-PM lookup (dynamic mode); a fixed
    # mode is expressed as thresholds=() allowed=(pm,).
    pm_thresholds: tuple[float, ...] = (40.0, 60.0)
    pm_allowed: tuple[int, ...] = (1, 2, 3)

    def __post_init__(self) -> None:
        if self.policy not in POLICIES:
            raise ValueError(f"unknown policy {self.policy!r}")
        if len(self.pm_allowed) != len(self.pm_thresholds) + 1:
            raise ValueError("need len(pm_allowed) == len(pm_thresholds) + 1")


@dataclasses.dataclass
class SimResult:
    """Per-run metric arrays (leading axis = Monte-Carlo runs)."""

    completed: np.ndarray
    dropped: np.ndarray
    arrivals: np.ndarray
    downtime_fraction: np.ndarray  # mean fraction of devices in power save
    mean_battery: np.ndarray  # time-averaged mean battery level (units)

    @property
    def normalized_throughput(self) -> np.ndarray:
        """Fig. 4a metric: completed / total input jobs."""
        return self.completed / np.maximum(self.arrivals, 1)

    def summary(self) -> dict[str, Any]:
        return {
            "completed": float(self.completed.mean()),
            "dropped": float(self.dropped.mean()),
            "arrivals": float(self.arrivals.mean()),
            "normalized_throughput": float(self.normalized_throughput.mean()),
            "downtime_fraction": float(self.downtime_fraction.mean()),
            "mean_battery": float(self.mean_battery.mean()),
            "completed_std": float(self.completed.std()),
            "downtime_std": float(self.downtime_fraction.std()),
        }


def build_runner(
    config: SimConfig,
    arrival_lo: np.ndarray,
    arrival_hi: np.ndarray,
    long_term_rates: np.ndarray | None = None,
):
    """Build a jitted ``run(key) -> metrics dict`` for one network."""
    G, N = config.n_groups, config.n_per_group
    n_jobs = 2 * N  # <= N queued + N processing per group (see module doc)

    kappa = jnp.asarray(config.kappa_table, dtype=jnp.float32)
    ce = jnp.asarray(config.ce_table, dtype=jnp.float32)
    thr = jnp.asarray(config.pm_thresholds, dtype=jnp.float32)
    allowed = jnp.asarray(config.pm_allowed, dtype=jnp.int32)
    lo = jnp.asarray(arrival_lo, dtype=jnp.int32).reshape(G, N)
    hi = jnp.asarray(arrival_hi, dtype=jnp.int32).reshape(G, N)
    if long_term_rates is None:
        long_term_rates = np.ones((G, N))
    rates = jnp.asarray(long_term_rates, dtype=jnp.float32).reshape(G, N)
    policy_fn = POLICIES[config.policy]
    e_init = config.e_max if config.e_init is None else config.e_init

    def pm_of(e):
        """Active PM index from battery level (paper's lookup table)."""
        idx = jnp.searchsorted(thr, e, side="right") if thr.size else jnp.zeros_like(
            jnp.asarray(e, dtype=jnp.int32)
        )
        return allowed[idx]

    def step(carry, key):
        (E, gamma, queued, j_act, j_proc, j_stage, j_dev, j_rem, j_pm, ctr) = carry
        completed, dropped, arrivals, ps_sum, batt_sum = ctr
        k_inc, k_arr, k_pick = jax.random.split(key, 3)

        # 1) harvest energy
        inc = jax.random.randint(k_inc, (G, N), lo, hi + 1).astype(jnp.float32)

        # 2) progress processing jobs (paused while the device power-saves)
        stage_c = jnp.clip(j_stage, 0, G - 1)
        d_cur = jnp.take_along_axis(j_dev, stage_c[:, None], axis=1)[:, 0]
        dev_active = gamma[stage_c, d_cur]
        running = j_act & j_proc & dev_active
        cons_j = jnp.where(running, ce[j_pm] / kappa[j_pm], 0.0)
        cons = jnp.zeros((G, N), jnp.float32).at[stage_c, d_cur].add(cons_j)
        j_rem = j_rem - running.astype(j_rem.dtype)

        # 3) completions
        done = j_act & j_proc & (j_rem <= 0.0)
        j_proc = j_proc & ~done
        j_stage = j_stage + done.astype(jnp.int32)
        finished = done & (j_stage >= G)
        completed = completed + jnp.sum(finished).astype(jnp.int32)
        j_act = j_act & ~finished

        # 4) battery + hysteresis (Eq. (1) totals per stage; per-slot spread)
        E = jnp.clip(E + inc - cons, 0.0, config.e_max)
        gamma = jnp.where(E < config.e_th, False, jnp.where(E > config.e_th_hi, True, gamma))

        # 5) stage starts for waiting jobs
        busy = jnp.zeros((G, N), jnp.int32).at[
            jnp.clip(j_stage, 0, G - 1),
            jnp.take_along_axis(j_dev, jnp.clip(j_stage, 0, G - 1)[:, None], axis=1)[:, 0],
        ].add((j_act & j_proc).astype(jnp.int32)) > 0
        stage_w = jnp.clip(j_stage, 0, G - 1)
        d_wait = jnp.take_along_axis(j_dev, stage_w[:, None], axis=1)[:, 0]
        pm_try = pm_of(E[stage_w, d_wait])
        # Energy gate (paper: CE(PM) <= E): a stage starts only once the
        # battery covers its full cost.
        gate_ok = E[stage_w, d_wait] >= ce[pm_try]
        can_start = (
            j_act & ~j_proc & gamma[stage_w, d_wait] & ~busy[stage_w, d_wait] & gate_ok
        )
        # Tie-break: at most one waiting job per device by construction
        # (queue capacity 1); see tests/test_simulator.py invariants.
        pm_new = pm_try
        j_pm = jnp.where(can_start, pm_new, j_pm)
        j_rem = jnp.where(can_start, kappa[pm_new], j_rem)
        j_proc = j_proc | can_start
        started = jnp.zeros((G, N), jnp.int32).at[stage_w, d_wait].add(
            can_start.astype(jnp.int32)
        ) > 0
        queued = queued & ~started

        # 6) new arrival + designation (Alg. 1)
        arrive = jax.random.bernoulli(k_arr, config.p_arrival)
        arrivals = arrivals + arrive.astype(jnp.int32)
        avail = gamma & ~queued
        all_ok = jnp.all(jnp.any(avail, axis=1))
        slot = jnp.argmin(j_act)  # first free job slot
        has_slot = ~j_act[slot]
        accept = arrive & all_ok & has_slot
        dropped = dropped + (arrive & ~(all_ok & has_slot)).astype(jnp.int32)

        pm_now = pm_of(E)
        probs = jax.vmap(policy_fn)(rates, pm_now, avail)  # [G, N]
        logits = jnp.where(probs > 0, jnp.log(jnp.maximum(probs, 1e-12)), -1e9)
        pick_keys = jax.random.split(k_pick, G)
        choice = jax.vmap(jax.random.categorical)(pick_keys, logits)  # [G]

        designate = jnp.zeros((G, N), bool).at[jnp.arange(G), choice].set(True)
        queued = queued | (designate & accept)
        j_act = j_act.at[slot].set(jnp.where(accept, True, j_act[slot]))
        j_proc = j_proc.at[slot].set(jnp.where(accept, False, j_proc[slot]))
        j_stage = j_stage.at[slot].set(jnp.where(accept, 0, j_stage[slot]))
        j_dev = j_dev.at[slot].set(jnp.where(accept, choice, j_dev[slot]))
        j_rem = j_rem.at[slot].set(jnp.where(accept, 0.0, j_rem[slot]))

        # 7) telemetry
        ps_sum = ps_sum + jnp.sum(~gamma).astype(jnp.int32)
        batt_sum = batt_sum + jnp.mean(E)

        ctr = (completed, dropped, arrivals, ps_sum, batt_sum)
        return (E, gamma, queued, j_act, j_proc, j_stage, j_dev, j_rem, j_pm, ctr), None

    def run(key):
        carry = (
            jnp.full((G, N), e_init, jnp.float32),  # E
            jnp.ones((G, N), bool),  # gamma (active)
            jnp.zeros((G, N), bool),  # queued
            jnp.zeros((n_jobs,), bool),  # j_act
            jnp.zeros((n_jobs,), bool),  # j_proc
            jnp.zeros((n_jobs,), jnp.int32),  # j_stage
            jnp.zeros((n_jobs, G), jnp.int32),  # j_dev
            jnp.zeros((n_jobs,), jnp.float32),  # j_rem
            jnp.ones((n_jobs,), jnp.int32),  # j_pm
            (
                jnp.int32(0),
                jnp.int32(0),
                jnp.int32(0),
                jnp.int32(0),
                jnp.float32(0.0),
            ),
        )
        keys = jax.random.split(key, config.n_steps)
        carry, _ = jax.lax.scan(step, carry, keys)
        completed, dropped, arrivals, ps_sum, batt_sum = carry[-1]
        return {
            "completed": completed,
            "dropped": dropped,
            "arrivals": arrivals,
            "downtime_fraction": ps_sum / (config.n_steps * G * N),
            "mean_battery": batt_sum / config.n_steps,
        }

    return jax.jit(run)


def simulate(
    topology: NetworkTopology,
    config: SimConfig,
    *,
    n_runs: int = 100,
    seed: int = 0,
    long_term_rates: np.ndarray | None = None,
    xi_lim: float = 0.01,
) -> SimResult:
    """Run ``n_runs`` Monte-Carlo repetitions of the network simulation.

    ``long_term_rates`` (Eq. 6 numerators) are computed from the semi-Markov
    model when needed and not provided.
    """
    if config.n_groups != topology.n_groups or config.n_per_group != topology.n_per_group:
        raise ValueError("config/topology shape mismatch")
    lo, hi = topology.arrival_bounds()
    if long_term_rates is None and config.policy in ("long_term", "adaptive"):
        long_term_rates = topology.long_term_rates(xi_lim)
    runner = build_runner(config, lo, hi, long_term_rates)
    keys = jax.random.split(jax.random.PRNGKey(seed), n_runs)
    out = jax.vmap(runner)(keys)
    out = jax.tree_util.tree_map(np.asarray, out)
    return SimResult(
        completed=out["completed"],
        dropped=out["dropped"],
        arrivals=out["arrivals"],
        downtime_fraction=out["downtime_fraction"],
        mean_battery=out["mean_battery"],
    )


def simulate_single_device(
    config: SimConfig,
    arrival_lo: int,
    arrival_hi: int,
    *,
    n_runs: int = 100,
    seed: int = 0,
) -> SimResult:
    """Paper Fig. 2a: one device, one group (power-mode study)."""
    cfg = dataclasses.replace(config, n_groups=1, n_per_group=1, policy="uniform")
    runner = build_runner(
        cfg, np.array([[arrival_lo]]), np.array([[arrival_hi]]), np.ones((1, 1))
    )
    keys = jax.random.split(jax.random.PRNGKey(seed), n_runs)
    out = jax.vmap(runner)(keys)
    out = jax.tree_util.tree_map(np.asarray, out)
    return SimResult(
        completed=out["completed"],
        dropped=out["dropped"],
        arrivals=out["arrivals"],
        downtime_fraction=out["downtime_fraction"],
        mean_battery=out["mean_battery"],
    )
