"""Discrete energy-arrival models and battery dynamics.

Implements the energy side of the paper's system model (Sec. III):

* energy arrivals in a slot are i.i.d. samples from a discrete mass
  distribution function (MDF) ``f(e)``, ``e >= 0`` integer units
  (1 unit = 1 kJ in the paper's calibration);
* the MDF of the energy inflow over a stage of ``kappa`` slots is the
  ``kappa``-fold convolution of ``f``;
* the battery update is Eq. (1):
  ``E' = max(min(E + dIE - CE(PM), E_max), 0)``.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = [
    "DiscreteMDF",
    "uniform_mdf",
    "convolve_mdf",
    "battery_update",
]


@dataclasses.dataclass(frozen=True)
class DiscreteMDF:
    """A discrete mass distribution over non-negative integer energy units.

    ``pmf[e]`` is the probability of harvesting exactly ``e`` units in one
    slot. The support is ``0..len(pmf)-1``.
    """

    pmf: tuple[float, ...]

    def __post_init__(self) -> None:
        arr = np.asarray(self.pmf, dtype=np.float64)
        if arr.ndim != 1 or arr.size == 0:
            raise ValueError("pmf must be a non-empty 1-D sequence")
        if np.any(arr < -1e-12):
            raise ValueError("pmf entries must be non-negative")
        total = float(arr.sum())
        if not np.isclose(total, 1.0, atol=1e-9):
            raise ValueError(f"pmf must sum to 1 (got {total})")

    @property
    def support(self) -> np.ndarray:
        return np.arange(len(self.pmf))

    @property
    def array(self) -> np.ndarray:
        return np.asarray(self.pmf, dtype=np.float64)

    @property
    def mean(self) -> float:
        return float(np.dot(self.support, self.array))

    @property
    def max_units(self) -> int:
        return len(self.pmf) - 1

    def convolve(self, k: int) -> np.ndarray:
        """PMF of the total inflow over ``k`` independent slots."""
        return convolve_mdf(self.array, k)

    def sample(self, rng: np.random.Generator, size=None) -> np.ndarray:
        return rng.choice(len(self.pmf), size=size, p=self.array)


def uniform_mdf(lo: int, hi: int) -> DiscreteMDF:
    """Uniform integer arrivals on ``{lo, .., hi}`` (paper Sec. II).

    Each node draws its per-slot harvest from a uniform distribution
    bounded by two node-specific values.
    """
    if not (0 <= lo <= hi):
        raise ValueError(f"need 0 <= lo <= hi, got ({lo}, {hi})")
    pmf = np.zeros(hi + 1, dtype=np.float64)
    pmf[lo : hi + 1] = 1.0 / (hi - lo + 1)
    return DiscreteMDF(tuple(pmf.tolist()))


def convolve_mdf(pmf: Sequence[float], k: int) -> np.ndarray:
    """``k``-fold convolution of a PMF (stage inflow, Sec. III)."""
    if k < 1:
        raise ValueError("k must be >= 1")
    base = np.asarray(pmf, dtype=np.float64)
    out = base.copy()
    for _ in range(k - 1):
        out = np.convolve(out, base)
    return out


def battery_update(e: int, income: int, consumption: int, e_max: int) -> int:
    """Paper Eq. (1), scalar integer form."""
    return int(max(min(e + income - consumption, e_max), 0))
