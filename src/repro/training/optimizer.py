"""AdamW in pure JAX (no optax dependency) with decoupled weight decay,
global-norm clipping, and a warmup + cosine-decay schedule.

Optimizer state (m, v) is fp32 regardless of parameter dtype; under the
training sharding rules the big state tensors are additionally sharded
over the ``data`` axis (ZeRO-1 style) — see distributed/sharding.py.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "lr_schedule", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    clip_norm: float = 1.0


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to ``min_lr_frac * lr``."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def adamw_init(params: Any) -> dict:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(f32, params),
        "v": jax.tree_util.tree_map(f32, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def adamw_update(
    grads: Any, opt_state: dict, params: Any, cfg: AdamWConfig
) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_opt_state, metrics)."""
    count = opt_state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_schedule(cfg, count)

    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1.0 - cfg.b1) * g
        v_new = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g)
        m_hat = m_new / b1c
        v_hat = v_new / b2c
        step = m_hat / (jnp.sqrt(v_hat) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return p_new, m_new, v_new

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "count": count}, metrics
