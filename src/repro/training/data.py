"""Synthetic deterministic data pipeline.

Batches are generated from a counter-keyed PRNG (fully reproducible,
restart-safe: the stream is a pure function of (seed, step)) and placed
with the activation sharding of the active mesh — the multi-host analogue
would feed per-host shards through ``jax.make_array_from_process_local_data``
with the identical layout.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..distributed.sharding import logical_sharding
from ..models.common import ModelConfig

__all__ = ["SyntheticLM", "make_batch"]


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    """Markov-ish synthetic token stream (non-uniform so loss can drop)."""

    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch(self, step: int) -> dict:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        k1, k2 = jax.random.split(key)
        B, S = self.global_batch, self.seq_len
        # Zipf-flavored marginals: low token ids much more likely.
        ranks = jnp.arange(self.vocab_size, dtype=jnp.float32) + 1.0
        logits = -1.2 * jnp.log(ranks)
        base = jax.random.categorical(k1, logits, shape=(B, S + 1))
        # Local structure: with p=0.5 repeat previous token + 1 (learnable).
        rep = jax.random.bernoulli(k2, 0.5, (B, S + 1))
        shifted = jnp.roll(base, 1, axis=1)
        tokens = jnp.where(rep, (shifted + 1) % self.vocab_size, base)
        return {"tokens": tokens[:, :S], "labels": tokens[:, 1:]}


def make_batch(cfg: ModelConfig, data: SyntheticLM, step: int, extras: dict | None = None) -> dict:
    """Batch + modality-stub extras, constrained to the batch sharding."""
    b = data.batch(step)
    if cfg.frontend == "patches":
        key = jax.random.fold_in(jax.random.PRNGKey(data.seed + 7), step)
        P = min(cfg.n_frontend_tokens, data.seq_len)
        b["patch_embeds"] = jax.random.normal(
            key, (data.global_batch, P, cfg.frontend_dim), jnp.float32
        ).astype(cfg.compute_dtype)
    if cfg.is_encdec:
        key = jax.random.fold_in(jax.random.PRNGKey(data.seed + 13), step)
        b["frames"] = jax.random.normal(
            key, (data.global_batch, data.seq_len, cfg.frontend_dim), jnp.float32
        ).astype(cfg.compute_dtype)
    if extras:
        b.update(extras)
    s = logical_sharding(("batch", "seq"))
    if s is not None:
        b = {
            k: jax.lax.with_sharding_constraint(v, s) if v.ndim == 2 else v
            for k, v in b.items()
        }
    return b
