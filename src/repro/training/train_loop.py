"""Train-step factory: CE loss (vocab-sharded logits, fp32 reductions),
MoE load-balance auxiliary, AdamW update, metrics.

The returned ``train_step(state, batch)`` is pure (jit/pjit-able); remat
of each layer is handled inside the model (``cfg.remat``).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..models.registry import Model
from .optimizer import AdamWConfig, adamw_init, adamw_update

__all__ = ["TrainState", "make_train_step", "init_train_state", "cross_entropy"]

MOE_AUX_WEIGHT = 0.01


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: dict
    step: jax.Array


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token CE in fp32. logits [B,S,V], labels [B,S]."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def init_train_state(model: Model, params: Any) -> TrainState:
    return TrainState(params=params, opt=adamw_init(params), step=jnp.zeros((), jnp.int32))


def make_train_step(model: Model, opt_cfg: AdamWConfig):
    moe = model.cfg.is_moe

    def loss_fn(params, batch):
        logits, aux = model.forward(params, batch)
        ce = cross_entropy(logits, batch["labels"])
        loss = ce
        if moe:
            loss = loss + MOE_AUX_WEIGHT * aux["lb_loss"]
        return loss, {"ce": ce, "lb_loss": aux["lb_loss"]}

    def train_step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params, batch
        )
        new_params, new_opt, opt_metrics = adamw_update(
            grads, state.opt, state.params, opt_cfg
        )
        new_state = TrainState(
            params=new_params, opt=new_opt, step=state.step + 1
        )
        return new_state, {"loss": loss, **metrics, **opt_metrics}

    return train_step
