from .data import SyntheticLM, make_batch
from .optimizer import AdamWConfig, adamw_init, adamw_update, lr_schedule
from .train_loop import TrainState, cross_entropy, init_train_state, make_train_step

__all__ = [
    "SyntheticLM",
    "make_batch",
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "lr_schedule",
    "TrainState",
    "cross_entropy",
    "init_train_state",
    "make_train_step",
]
