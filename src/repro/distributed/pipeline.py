"""GPipe-style pipeline parallelism with shard_map + ppermute.

The paper's Petals groups are pipeline stages over WAN replicas; on a TPU
mesh the same structure maps to a ``stage`` mesh axis: each device along
the axis holds one stage's weights, microbatches stream through
``lax.ppermute`` in a single fused SPMD program (n_micro + n_stages - 1
ticks), and the bubble shrinks as n_micro grows.

``stage_fn(params, x) -> y`` must be shape-preserving on the hidden
microbatch (embedding/unembedding live inside the first/last stage's
params — :mod:`repro.serving.partition` produces exactly that layout).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

__all__ = ["gpipe", "pipeline_apply"]


def gpipe(
    stage_fn: Callable,
    *,
    n_stages: int,
    n_micro: int,
    axis: str = "stage",
) -> Callable:
    """Per-device GPipe schedule (call inside shard_map over ``axis``)."""

    def run(params_local, micro_inputs):
        s = jax.lax.axis_index(axis)
        n_ticks = n_micro + n_stages - 1
        hidden_shape = micro_inputs.shape[1:]
        buf0 = jnp.zeros(hidden_shape, micro_inputs.dtype)
        outs0 = jnp.zeros((n_micro,) + hidden_shape, micro_inputs.dtype)

        def tick(carry, t):
            buf, outs = carry
            mb = t - s
            active = (mb >= 0) & (mb < n_micro)
            mb_c = jnp.clip(mb, 0, n_micro - 1)
            inp0 = jax.lax.dynamic_index_in_dim(micro_inputs, mb_c, 0, keepdims=False)
            inp = jnp.where(s == 0, inp0, buf)
            out = stage_fn(params_local, inp)
            out = jnp.where(active, out, jnp.zeros_like(out))
            updated = jax.lax.dynamic_update_index_in_dim(outs, out, mb_c, 0)
            outs = jnp.where(active & (s == n_stages - 1), updated, outs)
            if n_stages > 1:
                nxt = jax.lax.ppermute(
                    out, axis, [(i, i + 1) for i in range(n_stages - 1)]
                )
            else:
                nxt = out
            return (nxt, outs), None

        (_, outs), _ = jax.lax.scan(tick, (buf0, outs0), jnp.arange(n_ticks))
        # Only the last stage holds real outputs (zeros elsewhere): psum
        # broadcasts them to every stage device.
        return jax.lax.psum(outs, axis) if n_stages > 1 else outs

    return run


def pipeline_apply(
    mesh: Mesh,
    stage_fn: Callable,
    stacked_params,
    inputs: jax.Array,
    *,
    n_micro: int,
    axis: str = "stage",
):
    """Run the pipeline over ``inputs`` [batch, ...].

    ``stacked_params``: leaves with leading dim n_stages (stage-sharded on
    ``axis``). Returns outputs with the input batch layout.
    """
    n_stages = mesh.shape[axis]
    B = inputs.shape[0]
    if B % n_micro:
        raise ValueError("batch must divide into microbatches")
    micro = inputs.reshape(n_micro, B // n_micro, *inputs.shape[1:])

    run = gpipe(stage_fn, n_stages=n_stages, n_micro=n_micro, axis=axis)

    def body(params_local, micro_all):
        params_local = jax.tree_util.tree_map(lambda a: a[0], params_local)
        return run(params_local, micro_all)

    pspec = jax.tree_util.tree_map(lambda _: P(axis), stacked_params)
    out = shard_map(
        body,
        mesh=mesh,
        in_specs=(pspec, P()),
        out_specs=P(),
        check_rep=False,
    )(stacked_params, micro)
    return out.reshape(B, *out.shape[2:])
