"""Distribution substrate: mesh axes, logical sharding rules, pipeline
parallelism, and collective helpers."""

from .pipeline import gpipe, pipeline_apply
from .sharding import (
    AxisRules,
    DECODE_RULES,
    DEFAULT_RULES,
    PREFILL_RULES,
    SERVE_RULES,
    TRAIN_RULES,
    divisible_spec,
    logical,
    logical_sharding,
    mesh_axes,
    param_shardings,
    replica_submeshes,
    serve_cache_spec,
    use_mesh_rules,
)

__all__ = [
    "gpipe",
    "pipeline_apply",
    "AxisRules",
    "DECODE_RULES",
    "DEFAULT_RULES",
    "PREFILL_RULES",
    "SERVE_RULES",
    "TRAIN_RULES",
    "divisible_spec",
    "logical",
    "logical_sharding",
    "mesh_axes",
    "param_shardings",
    "replica_submeshes",
    "serve_cache_spec",
    "use_mesh_rules",
]
