"""Logical-axis sharding rules (MaxText-style) for pjit distribution.

Model code annotates arrays with *logical* axis names ("batch", "heads",
"ff", ...). A rule table maps logical names to physical mesh axes; rules
referencing axes absent from the active mesh are dropped, so the same
model code runs on the single-pod ``(data, model)`` mesh, the multi-pod
``(pod, data, model)`` mesh, or a single CPU device (no mesh: no-op).

Two standard rule sets:

* ``DEFAULT_RULES`` (training): batch over (pod, data); TP over model for
  heads / ff / vocab / experts; FSDP-style extra sharding of large param
  dims over data.
* ``SERVE_RULES``: TP over model only; params replicated over (pod, data)
  so each data replica serves independent requests — this is the replica
  set the paper's scheduler routes over.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisRules = Mapping[str, Any]  # logical name -> mesh axis | tuple | None

DEFAULT_RULES: AxisRules = {
    "batch": ("pod", "data"),
    "seq": None,
    "act_seq": None,  # residual-stream sequence dim (SP shards it)
    "embed": None,
    "embed_fsdp": "data",  # FSDP shard of the d_model dim of big params
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ff": "model",
    "expert_ff": None,
    "vocab": "model",
    "experts": "model",
    "ssm_inner": "model",
    "ssm_state": None,
    "conv": None,
    "layers": None,
    "cache_seq": None,
    "cache_batch": ("pod", "data"),
    "patches": None,
    "frontend": None,
}

# Training: FSDP over data + TP over model + Megatron-style sequence
# parallelism on the residual stream (the per-layer scan carry shrinks by
# the TP degree — what makes 70B-class train cells fit 16 GB chips).
TRAIN_RULES: AxisRules = {
    **DEFAULT_RULES,
    "act_seq": "model",
}

# Serving (prefill): params replicated across data replicas (each serves
# its own requests — the replica set the paper's router schedules over);
# long prompts are sequence-parallel; the produced KV cache is
# seq-sharded over model.
PREFILL_RULES: AxisRules = {
    **DEFAULT_RULES,
    "embed_fsdp": None,
    "act_seq": "model",
    "cache_seq": "model",
    "kv_heads": None,  # cache layout: shard seq, replicate (few) kv heads
}

# Serving (decode): one token, long caches — flash-decoding across chips:
# the KV cache (and its attention reduction) is sharded over model on the
# sequence dim; weights stay TP.
DECODE_RULES: AxisRules = {
    **PREFILL_RULES,
    "act_seq": None,
}

# Training variant (perf iteration C, EXPERIMENTS.md §Perf): keep the
# sequence dim sharded THROUGH attention and the MLP instead of
# head/ff-TP — the per-layer collective drops from an all-gather of the
# full residual stream (B*S*D) to an all-gather of K/V (B*S*KV*Dh,
# ~G x smaller under GQA); weights are fully sharded over (data, model)
# jointly (ZeRO-3 style) and gathered per layer.
TRAIN_RULES_SEQ: AxisRules = {
    **DEFAULT_RULES,
    "act_seq": "model",
    "seq": "model",
    "heads": None,
    "kv_heads": None,
    "ff": None,
    "expert_ff": None,
    "vocab": ("data", "model"),
    "embed_fsdp": ("data", "model"),
}

# Serving engine (PipelineServer on a real mesh): params TP over model
# and fully replicated over (pod, data) — every data replica owns a
# complete stage copy and serves its own requests, which is the replica
# set the paper's Router schedules over. KV caches and paged pools shard
# only on cache_batch (see :func:`serve_cache_spec`) so one replica's
# cache never straddles a replica boundary and failover stays local.
SERVE_RULES: AxisRules = {
    **DEFAULT_RULES,
    "embed_fsdp": None,
}

RULE_SETS = {
    "train": TRAIN_RULES,
    "train_seq": TRAIN_RULES_SEQ,
    "prefill": PREFILL_RULES,
    "decode": DECODE_RULES,
    "serve": SERVE_RULES,
}


class _Ctx(threading.local):
    def __init__(self) -> None:
        self.mesh: Mesh | None = None
        self.rules: AxisRules | None = None


_CTX = _Ctx()


@contextlib.contextmanager
def use_mesh_rules(mesh: Mesh | None, rules: AxisRules):
    """Activate (mesh, rules) for :func:`logical` annotations."""
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def mesh_axes(mesh: Mesh) -> set[str]:
    return set(mesh.axis_names)


def _resolve(rules: AxisRules, mesh: Mesh, names: Sequence[str | None]) -> P:
    """Map logical axis names to a PartitionSpec valid on ``mesh``."""
    axes = mesh_axes(mesh)
    used: set[str] = set()
    out = []
    for name in names:
        if name is None:
            out.append(None)
            continue
        rule = rules.get(name)
        if rule is None:
            out.append(None)
            continue
        parts = rule if isinstance(rule, tuple) else (rule,)
        parts = tuple(p for p in parts if p in axes and p not in used)
        used.update(parts)
        if not parts:
            out.append(None)
        elif len(parts) == 1:
            out.append(parts[0])
        else:
            out.append(parts)
    return P(*out)


def logical_sharding(
    names: Sequence[str | None],
    mesh: Mesh | None = None,
    rules: AxisRules | None = None,
) -> NamedSharding | None:
    """NamedSharding for logical ``names`` under (mesh, rules)."""
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules
    if mesh is None or rules is None:
        return None
    return NamedSharding(mesh, _resolve(rules, mesh, names))


def logical(x: jax.Array, names: Sequence[str | None]) -> jax.Array:
    """Annotate ``x`` with a sharding constraint; no-op without a mesh."""
    s = logical_sharding(names)
    if s is None:
        return x
    return jax.lax.with_sharding_constraint(x, s)


def _shard_spec_for_leaf(axes, mesh: Mesh, rules: AxisRules) -> NamedSharding:
    return NamedSharding(mesh, _resolve(rules, mesh, axes))


def divisible_spec(
    shape: Sequence[int], axes: Sequence[str | None], mesh: Mesh, rules: AxisRules
) -> P:
    """PartitionSpec for ``shape`` with divisibility enforcement.

    A mesh axis is only applied to a dim it divides evenly — otherwise the
    dim falls back to replication (heterogeneous head counts like hymba's
    25 heads replicate on that dim instead of erroring).
    """
    spec = list(_resolve(rules, mesh, axes))
    shape = tuple(shape)
    for i, part in enumerate(spec):
        if part is None:
            continue
        parts = part if isinstance(part, tuple) else (part,)
        size = int(np.prod([mesh.shape[p] for p in parts]))
        if i >= len(shape) or shape[i] % size != 0:
            spec[i] = None
    return P(*spec)


def serve_cache_spec(
    shape: Sequence[int],
    axes: Sequence[str | None],
    mesh: Mesh,
    rules: AxisRules = SERVE_RULES,
) -> P:
    """PartitionSpec for a KV cache / paged-pool leaf under serving.

    Cache leaves shard ONLY on their ``cache_batch`` dim: every other
    logical axis is masked to replication before resolving, regardless
    of what ``rules`` would map it to. On a mesh without a rule target
    for ``cache_batch`` (e.g. a model-only submesh) the whole leaf is
    replicated — a replica's cache lives entirely inside its own
    tensor-parallel device set.
    """
    masked = tuple(a if a == "cache_batch" else None for a in axes)
    return divisible_spec(shape, masked, mesh, rules)


def replica_submeshes(mesh: Mesh, n_replicas: int):
    """Carve a serving mesh into per-data-slice tensor-parallel submeshes.

    ``mesh`` must use only ``("data", "model")`` axes (either order;
    ``model`` alone is accepted). Returns ``(slices, slice_of)`` where
    ``slices[d]`` is a ``(1, model)``-shaped ``("data", "model")`` Mesh
    over data-slice ``d``'s devices and ``slice_of[r]`` maps replica
    ``r`` to its slice (round-robin when replicas outnumber slices).
    Distinct slices are disjoint device sets — the "real replica sets"
    the Router routes over; stage handoffs between them are
    device-to-device transfers.
    """
    names = tuple(mesh.axis_names)
    if "model" not in names or not set(names) <= {"data", "model"}:
        raise ValueError(
            "serving mesh must use only ('data', 'model') axes with "
            f"'model' present, got {names!r} — build one with "
            "launch.mesh.make_serving_mesh"
        )
    devs = np.asarray(mesh.devices)
    if names == ("model",):
        devs = devs.reshape(1, -1)
    elif names == ("model", "data"):
        devs = devs.T
    slices = [
        Mesh(devs[d : d + 1, :], ("data", "model")) for d in range(devs.shape[0])
    ]
    slice_of = [r % len(slices) for r in range(n_replicas)]
    return slices, slice_of


def param_shardings(template, mesh: Mesh, rules: AxisRules):
    """Map a tree whose leaves expose ``.shape`` and ``.axes`` (e.g.
    :class:`repro.models.common.ParamSpec`) to NamedShardings."""

    def one(leaf):
        return NamedSharding(mesh, divisible_spec(leaf.shape, leaf.axes, mesh, rules))

    return jax.tree_util.tree_map(one, template, is_leaf=lambda v: hasattr(v, "axes"))
