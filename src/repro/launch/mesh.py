"""Production / serving mesh construction.

FUNCTIONS, not module-level constants — importing this module never
touches jax device state (required for the dry-run's placeholder-device
environment variable to take effect first).

``make_production_mesh`` derives its shape from ``jax.device_count()``
(explicit ``shape=`` override for the classic 256/512-chip pod layouts),
so the same entry points run on a laptop CPU, a forced-host-device CI
container, or a real pod slice. ``make_serving_mesh`` builds the
``(data, model)`` mesh the serving engine carves into per-replica
tensor-parallel submeshes.
"""

from __future__ import annotations

import math

import jax

__all__ = ["make_production_mesh", "make_serving_mesh", "make_host_mesh"]


def _largest_divisor_leq_sqrt(n: int) -> int:
    for d in range(int(math.isqrt(n)), 0, -1):
        if n % d == 0:
            return d
    return 1


def make_production_mesh(*, multi_pod: bool = False, shape: tuple[int, ...] | None = None):
    """Mesh over the available devices.

    Without ``shape``, derives a balanced layout from
    ``jax.device_count()``: ``(data, model)`` single-pod with ``model``
    the largest divisor ≤ √n (256 chips → the classic (16, 16)), or
    ``(pod=2, data, model)`` with ``multi_pod=True``. With ``shape``,
    uses exactly that layout over a prefix of ``jax.devices()`` (the
    historical 256/512-chip entry points pass it explicitly). Raises a
    clear error when the devices don't factor instead of the old
    hardcoded-shape crash on non-TPU hosts.
    """
    n = jax.device_count()
    if shape is not None:
        axes = ("pod", "data", "model") if len(shape) == 3 else ("data", "model")
        if len(shape) not in (2, 3):
            raise ValueError(f"shape must be (data, model) or (pod, data, model), got {shape!r}")
        need = math.prod(shape)
        if need > n:
            raise ValueError(
                f"mesh shape {shape} needs {need} devices but only {n} are "
                f"visible — set XLA_FLAGS=--xla_force_host_platform_device_count={need} "
                "(CI / dry-run) or drop shape= to derive one from the device count"
            )
        return jax.make_mesh(shape, axes)
    if multi_pod:
        if n % 2 != 0:
            raise ValueError(
                f"multi_pod mesh needs an even device count, got {n} — "
                "pass shape=(pod, data, model) explicitly to override"
            )
        per_pod = n // 2
        model = _largest_divisor_leq_sqrt(per_pod)
        return jax.make_mesh((2, per_pod // model, model), ("pod", "data", "model"))
    model = _largest_divisor_leq_sqrt(n)
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_serving_mesh(*, model_axis: int | None = None, data_axis: int = 1, devices=None):
    """``(data, model)`` serving mesh over the real local devices.

    ``model_axis`` is the tensor-parallel width of one replica slice
    (defaults to all remaining devices after ``data_axis``); the engine
    splits the data axis into per-replica submeshes
    (:func:`repro.distributed.sharding.replica_submeshes`). Errors
    clearly when the request doesn't fit the visible devices.
    """
    devs = list(devices if devices is not None else jax.devices())
    n = len(devs)
    if data_axis < 1:
        raise ValueError(f"data_axis must be >= 1, got {data_axis}")
    if model_axis is None:
        if n % data_axis != 0:
            raise ValueError(
                f"{n} devices don't factor into data_axis={data_axis} slices — "
                "pass model_axis explicitly"
            )
        model_axis = n // data_axis
    need = data_axis * model_axis
    if need > n:
        raise ValueError(
            f"serving mesh (data={data_axis}, model={model_axis}) needs {need} "
            f"devices but only {n} are visible — set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need} or shrink the axes"
        )
    import numpy as np
    from jax.sharding import Mesh

    grid = np.array(devs[:need]).reshape(data_axis, model_axis)
    return Mesh(grid, ("data", "model"))


def make_host_mesh():
    """Degenerate 1x1 mesh for CPU tests/examples (same axis names)."""
    return jax.make_mesh((1, 1), ("data", "model"))
