"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (required for the dry-run's placeholder-device
environment variable to take effect first).

Meshes:
  * single-pod: (data=16, model=16) — 256 chips (one v5e pod)
  * multi-pod:  (pod=2, data=16, model=16) — 512 chips across 2 pods
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh for CPU tests/examples (same axis names)."""
    return jax.make_mesh((1, 1), ("data", "model"))
