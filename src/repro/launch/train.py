"""Training driver.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
        --smoke --steps 50 --batch 4 --seq 64 --ckpt-dir /tmp/ckpt

On a real TPU fleet the same driver runs under the production mesh
(``--mesh single|multi``); on CPU (tests/examples) use ``--smoke`` for the
reduced config on the host mesh. Checkpoints restore automatically on
restart (fault tolerance: kill it mid-run and relaunch).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax

from ..configs import ARCH_NAMES, get_config, get_smoke_config
from ..distributed.sharding import TRAIN_RULES, use_mesh_rules
from ..ft.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from ..models import build_model, init_from_template
from ..training import (
    AdamWConfig,
    SyntheticLM,
    init_train_state,
    make_batch,
    make_train_step,
)
from .mesh import make_host_mesh, make_production_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="stablelm-1.6b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--mesh", choices=["host", "single", "multi"], default="host")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = dataclasses.replace(cfg, dtype="float32", param_dtype="float32")
    model = build_model(cfg)

    mesh = {
        "host": make_host_mesh,
        "single": make_production_mesh,
        "multi": lambda: make_production_mesh(multi_pod=True),
    }[args.mesh]()

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=5, total_steps=args.steps)
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch)

    with use_mesh_rules(mesh, TRAIN_RULES):
        params = init_from_template(model.template, jax.random.PRNGKey(0), cfg.param_dtype)
        state = init_train_state(model, params)
        start = 0
        if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
            state, start = restore_checkpoint(args.ckpt_dir, state)
            print(f"restored checkpoint at step {start}")
        step_fn = jax.jit(make_train_step(model, opt_cfg))

        t0 = time.time()
        for i in range(start, args.steps):
            state, metrics = step_fn(state, make_batch(cfg, data, i))
            if (i + 1) % 10 == 0 or i == start:
                print(
                    f"step {i+1:4d} loss={float(metrics['loss']):.4f} "
                    f"gnorm={float(metrics['grad_norm']):.2f} "
                    f"lr={float(metrics['lr']):.2e}"
                )
            if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
                save_checkpoint(args.ckpt_dir, i + 1, state)
        dt = time.time() - t0
        print(f"done: {args.steps - start} steps in {dt:.1f}s")


if __name__ == "__main__":
    main()
