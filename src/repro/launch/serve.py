"""Serving driver: the paper's decentralized inference system.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
        --smoke --groups 3 --replicas 3 --policy adaptive --slots 60

Hosts G pipeline groups x R replicas of the (partitioned) model, routes
requests with the energy-aware scheduler, prints throughput/downtime.
"""

from __future__ import annotations

import argparse
import dataclasses

import jax

from ..configs import ARCH_NAMES, get_config, get_smoke_config
from ..models import build_model, init_from_template
from ..models.registry import default_draft_for
from ..serving import MPPipelineServer, PipelineServer
from .mesh import make_serving_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="stablelm-1.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--groups", type=int, default=3)
    ap.add_argument("--replicas", type=int, default=3)
    ap.add_argument(
        "--policy", choices=["uniform", "long_term", "adaptive"], default="adaptive"
    )
    ap.add_argument("--slots", type=int, default=60)
    ap.add_argument("--max-batch", type=int, default=4,
                    help="continuous-batching slots per (group, replica)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="pending-queue bound (backpressure); None = unbounded")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache: per-replica page pool + block tables "
                         "instead of a dense max_batch x max_len reservation")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV entries per page (paged mode)")
    ap.add_argument("--max-pages", type=int, default=None,
                    help="pool pages per (group, replica); default matches the "
                         "dense reservation (max_batch * ceil(max_len/page_size))")
    ap.add_argument("--kv-dtype", choices=["compute", "int8"], default="compute",
                    help="paged KV page dtype: 'compute' stores pages at the "
                         "model compute dtype; 'int8' quantizes at scatter "
                         "(per-row fp32 scales, dequantized in the page "
                         "gather) — 4x (fp32) / 2x (bf16) fewer KV bytes per "
                         "token, so the same pool admits more residents")
    ap.add_argument("--max-park-steps", type=int, default=32,
                    help="starvation-free aging: force-place (preempting the "
                         "youngest resident of a live sibling) any failover "
                         "victim parked slotless longer than this many slots; "
                         "<= 0 disables aging")
    ap.add_argument("--async-depth", type=int, default=2,
                    help="in-flight calls per (group, replica): the producer "
                         "dispatches up to this many jitted calls before the "
                         "committer drains results from the completion queue; "
                         "1 = commit-time readback without pipelining, "
                         "0 = legacy synchronous engine (readback at dispatch)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked prefill: split joining prompts into fixed "
                         "N-token chunks co-scheduled with decode (one compiled "
                         "prefill shape regardless of prompt lengths, bounded "
                         "per-step prefill work); None = whole-prompt prefill")
    ap.add_argument("--spec-draft", choices=ARCH_NAMES + ("auto",), default=None,
                    help="speculative decoding: draft architecture that "
                         "proposes spec-k tokens per round, verified in one "
                         "paged chunk call (bit-for-bit vs plain decode). "
                         "'auto' uses the registry pairing for --arch "
                         "(repro.models.registry.SPEC_DRAFT_PAIRS). "
                         "Requires --paged")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens proposed per speculative round")
    ap.add_argument("--mesh-model", type=int, default=None,
                    help="tensor-parallel width: shard each stage's params "
                         "over a 'model' mesh axis (SERVE_RULES), one jitted "
                         "dispatch lowering to collectives. Needs "
                         "mesh-model * mesh-data visible devices (XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N on CPU)")
    ap.add_argument("--mesh-data", type=int, default=1,
                    help="replica slices of the serving mesh: replicas are "
                         "assigned round-robin to mesh-data disjoint "
                         "(1, mesh-model) submeshes — real replica sets")
    ap.add_argument("--multiprocess", action="store_true",
                    help="one OS process per (group, replica) stage cell "
                         "(dense whole-prompt mode): handoffs cross process "
                         "boundaries, process death is a live membership "
                         "leave. --mesh-model then gives each worker its own "
                         "forced-host TP mesh")
    ap.add_argument("--arrival-p", type=float, default=0.5)
    ap.add_argument("--harvest", type=float, nargs=2, default=(6.0, 10.0))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = dataclasses.replace(cfg, dtype="float32", param_dtype="float32")
    model = build_model(cfg)
    params = init_from_template(model.template, jax.random.PRNGKey(0), cfg.param_dtype)

    spec_draft = None
    if args.spec_draft is not None:
        name = (
            default_draft_for(args.arch) if args.spec_draft == "auto"
            else args.spec_draft
        )
        dcfg = get_smoke_config(name) if args.smoke else get_config(name)
        dcfg = dataclasses.replace(dcfg, dtype="float32", param_dtype="float32")
        draft = build_model(dcfg)
        dparams = init_from_template(
            draft.template, jax.random.PRNGKey(1), dcfg.param_dtype
        )
        spec_draft = (draft, dparams)

    common = dict(
        n_groups=args.groups,
        n_replicas=args.replicas,
        policy=args.policy,
        harvest_bounds=tuple(args.harvest),
        max_len=128,
        max_batch=args.max_batch,
        max_queue=args.max_queue,
        max_park_steps=args.max_park_steps if args.max_park_steps > 0 else None,
        async_depth=args.async_depth,
        seed=args.seed,
    )
    if args.multiprocess:
        if args.paged or args.prefill_chunk or args.spec_draft:
            ap.error("--multiprocess is dense whole-prompt only "
                     "(no --paged / --prefill-chunk / --spec-draft)")
        server = MPPipelineServer(
            {
                "arch": args.arch,
                "smoke": args.smoke,
                "overrides": {"dtype": "float32", "param_dtype": "float32"},
                "seed": 0,
            },
            mesh_model=args.mesh_model or 1,
            **common,
        )
    else:
        mesh = None
        if args.mesh_model is not None:
            mesh = make_serving_mesh(
                model_axis=args.mesh_model, data_axis=args.mesh_data
            )
        server = PipelineServer(
            model,
            params,
            mesh=mesh,
            paged=args.paged,
            page_size=args.page_size,
            max_pages=args.max_pages,
            kv_dtype=None if args.kv_dtype == "compute" else args.kv_dtype,
            prefill_chunk=args.prefill_chunk,
            spec_draft=spec_draft,
            spec_k=args.spec_k,
            **common,
        )
    if args.mesh_model is not None or args.multiprocess:
        print(
            f"substrate: {'multiprocess' if args.multiprocess else 'mesh'} "
            f"model_axis={args.mesh_model or 1} data_axis={args.mesh_data} "
            f"devices={jax.device_count()}"
        )
    stats = server.run(args.slots, arrival_p=args.arrival_p)
    if args.multiprocess:
        server.close()
    paged_info = (
        f" preempted={stats.preempted_jobs} peak_active={stats.peak_active}"
        if args.paged
        else ""
    )
    if spec_draft is not None:
        paged_info += (
            f" spec_rounds={stats.spec_rounds}"
            f" acceptance={stats.acceptance_rate:.3f}"
            f" accepted_tokens={stats.accepted_tokens}"
        )
    print(
        f"policy={args.policy}: submitted={stats.submitted} "
        f"completed={stats.completed_jobs} dropped={stats.dropped_jobs} "
        f"queued={stats.queued_jobs} tokens={stats.tokens_generated} "
        f"decode_calls={stats.decode_calls} "
        f"downtime={stats.downtime_fraction:.3f} "
        f"rerouted={stats.rerouted_stages}" + paged_info
    )


if __name__ == "__main__":
    main()
