import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh)
cell on placeholder devices and record memory/cost/collective artifacts.

The two lines above MUST precede any other import (jax locks the device
count at first init). Run:

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-14b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all          # every cell
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod

Artifacts land in artifacts/dryrun/<arch>__<shape>__<mesh>.json and feed
EXPERIMENTS.md §Dry-run / §Roofline.
"""

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import gzip  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from ..analysis.memory import memory_report  # noqa: E402
from ..configs import ARCH_NAMES, SHAPES, cells_for, get_config  # noqa: E402
from ..distributed.sharding import (  # noqa: E402
    DECODE_RULES,
    PREFILL_RULES,
    RULE_SETS,
    TRAIN_RULES,
    divisible_spec,
    param_shardings,
    use_mesh_rules,
)
from ..models import abstract_params, build_model, count_params  # noqa: E402
from ..models.inputs import ENC_LEN_DECODE, input_specs  # noqa: E402
from ..models.transformer import cache_logical_axes  # noqa: E402
from ..roofline.analysis import roofline_terms  # noqa: E402
from ..training import AdamWConfig, make_train_step  # noqa: E402
from ..training.train_loop import TrainState  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun")


def _sharding(mesh, rules, shape, axes):
    from jax.sharding import NamedSharding

    return NamedSharding(mesh, divisible_spec(shape, axes, mesh, rules))


def _tree_shardings(mesh, rules, sds_tree, axes_tree):
    return jax.tree_util.tree_map(
        lambda s, a: _sharding(mesh, rules, s.shape, a),
        sds_tree,
        axes_tree,
        is_leaf=lambda v: isinstance(v, jax.ShapeDtypeStruct),
    )


def _axes_like(template):
    return jax.tree_util.tree_map(
        lambda spec: spec.axes, template, is_leaf=lambda v: hasattr(v, "axes")
    )


def model_flops(cfg, cell) -> float:
    """Analytic MODEL_FLOPS: 6*N*D (train) / 2*N*D (forward-only), with
    N = active params (MoE counts routed experts only)."""
    model = build_model(cfg)
    n = count_params(model.template)
    if cfg.is_moe:
        # Subtract inactive expert FFN params.
        plan_experts = cfg.n_experts
        active = cfg.moe_top_k
        expert_params = (
            cfg.n_layers * cfg.n_experts * (3 * cfg.d_model * cfg.d_ff_expert)
        )
        n = n - expert_params + expert_params * active / plan_experts
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    mult = 6.0 if cell.kind == "train" else 2.0
    return mult * n * tokens


def lower_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    overrides: dict | None = None,
    rules_override=None,
    hlo_path: str | None = None,
) -> dict:
    cfg = get_config(arch)
    cell = SHAPES[shape_name]
    # Pin the classic pod layouts — the dry-run forces 512 host devices
    # and its artifacts are calibrated to (16, 16) / (2, 16, 16).
    mesh = make_production_mesh(
        multi_pod=multi_pod, shape=(2, 16, 16) if multi_pod else (16, 16)
    )
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()

    if cell.kind == "train":
        rules = TRAIN_RULES
        # 70B-class models need block remat to fit the carry.
        cfg = dataclasses.replace(cfg, remat=True, remat_block=8)
    elif cell.kind == "prefill":
        rules = PREFILL_RULES
        cfg = dataclasses.replace(cfg, remat=False)
    else:
        rules = DECODE_RULES
        cfg = dataclasses.replace(cfg, remat=False)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    if rules_override is not None:
        rules = rules_override

    model = build_model(cfg)
    template = model.template
    abstract = abstract_params(template, cfg.param_dtype)
    p_shardings = param_shardings(template, mesh, rules)
    batch_specs = input_specs(cfg, cell)

    def batch_shardings(specs):
        out = {}
        for k, s in specs.items():
            if k in ("tokens", "labels", "token"):
                axes = ("batch", "seq")
            elif k == "patch_embeds":
                axes = ("batch", "patches", "frontend")
            elif k == "frames":
                axes = ("batch", "act_seq", "frontend")
            elif k == "hidden":
                axes = ("batch", "act_seq", "embed")
            else:
                axes = tuple([None] * len(s.shape))
            out[k] = _sharding(mesh, rules, s.shape, axes)
        return out

    with use_mesh_rules(mesh, rules):
        if cell.kind == "train":
            step_fn = make_train_step(model, AdamWConfig())
            opt_abs = {
                "m": jax.tree_util.tree_map(
                    lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), abstract
                ),
                "v": jax.tree_util.tree_map(
                    lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), abstract
                ),
                "count": jax.ShapeDtypeStruct((), jnp.int32),
            }
            state_abs = TrainState(
                params=abstract, opt=opt_abs, step=jax.ShapeDtypeStruct((), jnp.int32)
            )
            from jax.sharding import NamedSharding, PartitionSpec as P

            rep = NamedSharding(mesh, P())
            state_sh = TrainState(
                params=p_shardings,
                opt={"m": p_shardings, "v": p_shardings, "count": rep},
                step=rep,
            )
            lowered = jax.jit(
                step_fn,
                in_shardings=(state_sh, batch_shardings(batch_specs)),
            ).lower(state_abs, batch_specs)
        elif cell.kind == "prefill":
            fn = lambda p, b: model.prefill(p, b, cell.seq_len + 128)
            lowered = jax.jit(
                fn, in_shardings=(p_shardings, batch_shardings(batch_specs))
            ).lower(abstract, batch_specs)
        else:  # decode
            if cfg.is_encdec:
                cache_abs = model.cache_shapes(
                    cell.global_batch, cell.seq_len + 128, ENC_LEN_DECODE
                )
                from ..models.encdec import init_cache_shapes as _  # noqa: F401

                kv_axes = ("layers", "cache_batch", "cache_seq", "kv_heads", "head_dim")
                cache_axes = {
                    "len": (),
                    "k": kv_axes,
                    "v": kv_axes,
                    "ck": kv_axes,
                    "cv": kv_axes,
                }
            else:
                cache_abs = model.cache_shapes(cell.global_batch, cell.seq_len + 128)
                cache_axes = dict(cache_logical_axes(cfg))
                cache_axes = {
                    k: (
                        v
                        if k == "len"
                        else {kk: tuple(vv) for kk, vv in v.items()}
                    )
                    for k, v in cache_axes.items()
                }
            cache_sh = jax.tree_util.tree_map(
                lambda s, a: _sharding(mesh, rules, s.shape, a),
                cache_abs,
                cache_axes,
                is_leaf=lambda v: isinstance(v, jax.ShapeDtypeStruct),
            )
            # Fill len with a concrete sharding (scalar)
            fn = lambda p, t, c: model.decode_step(p, t, c)
            lowered = jax.jit(
                fn,
                in_shardings=(
                    p_shardings,
                    batch_shardings({"token": batch_specs["token"]})["token"],
                    cache_sh,
                ),
                donate_argnums=(2,),
            ).lower(abstract, batch_specs["token"], cache_abs)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    if hlo_path:
        with gzip.open(hlo_path, "wt") as f:
            f.write(hlo)
    terms, hlo_cost = roofline_terms(hlo, chips)
    mf = model_flops(get_config(arch), cell)

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "param_count": count_params(template),
        # Shared byte accounting with the analysis donation gate — one
        # implementation (repro.analysis.memory.memory_report).
        "memory_analysis": memory_report(compiled),
        # XLA cost_analysis (loop bodies counted ONCE — kept for reference;
        # the roofline uses the trip-scaled HLO walker, see roofline/analysis.py)
        "xla_cost_analysis": {
            "flops_per_device": float(cost.get("flops", 0.0)),
            "bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        },
        "collectives": hlo_cost.collectives,
        "roofline": terms.as_dict(),
        "model_flops": mf,
        "useful_flop_ratio": mf / max(terms.flops, 1.0),
    }
    return result


def cell_path(arch: str, shape: str, multi_pod: bool, tag: str = "") -> str:
    mesh = "2x16x16" if multi_pod else "16x16"
    suffix = f"__{tag}" if tag else ""
    return os.path.join(ARTIFACT_DIR, f"{arch}__{shape}__{mesh}{suffix}.json")


def run_cell(
    arch: str,
    shape: str,
    *,
    multi_pod: bool,
    force: bool = False,
    tag: str = "",
    overrides: dict | None = None,
    rules_override=None,
) -> dict:
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    path = cell_path(arch, shape, multi_pod, tag)
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    try:
        result = lower_cell(
            arch,
            shape,
            multi_pod=multi_pod,
            overrides=overrides,
            rules_override=rules_override,
            hlo_path=path.replace(".json", ".hlo.gz"),
        )
        if tag:
            result["tag"] = tag
    except Exception as e:  # record failures — they are bugs to fix
        result = {
            "arch": arch,
            "shape": shape,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        }
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="", help="artifact suffix for variants")
    ap.add_argument(
        "--rules", choices=list(RULE_SETS), default=None,
        help="override the sharding rule set (perf variants)",
    )
    ap.add_argument(
        "--set", dest="overrides", action="append", default=[],
        metavar="FIELD=VALUE", help="ModelConfig override (perf variants)",
    )
    args = ap.parse_args()

    overrides: dict = {}
    for ov in args.overrides:
        key, val = ov.split("=", 1)
        if val in ("true", "false"):
            parsed = val == "true"
        else:
            try:
                parsed = int(val)
            except ValueError:
                parsed = val
        overrides[key] = parsed
    rules_override = RULE_SETS[args.rules] if args.rules else None

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    cells: list[tuple[str, str]] = []
    if args.all:
        for arch in ARCH_NAMES:
            for shape in cells_for(arch):
                cells.append((arch, shape))
    else:
        if not (args.arch and args.shape):
            ap.error("need --arch and --shape (or --all)")
        cells = [(args.arch, args.shape)]

    n_fail = 0
    for arch, shape in cells:
        for mp in meshes:
            r = run_cell(
                arch, shape, multi_pod=mp, force=args.force,
                tag=args.tag, overrides=overrides or None,
                rules_override=rules_override,
            )
            mesh = r.get("mesh")
            if "error" in r:
                n_fail += 1
                print(f"[FAIL] {arch} {shape} {mesh}: {r['error']}", flush=True)
            else:
                rt = r["roofline"]
                print(
                    f"[ok] {arch} {shape} {mesh}: dominant={rt['dominant']} "
                    f"compute={rt['compute_s']:.4f}s memory={rt['memory_s']:.4f}s "
                    f"coll={rt['collective_s']:.4f}s compile={r['compile_s']}s",
                    flush=True,
                )
    if n_fail:
        raise SystemExit(f"{n_fail} cells failed")


if __name__ == "__main__":
    main()
