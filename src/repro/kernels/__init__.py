"""Pallas TPU kernels for the serving substrate's compute hot spots.

The paper's own contribution is scheduler-level (no custom kernel), but
the inference substrate it assumes (Petals-style transformer serving) is
kernel-bound; these four cover its hot paths. Each kernel package ships
`<name>.py` (pl.pallas_call + BlockSpec VMEM tiling), `ops.py` (jit'd
public wrapper), and `ref.py` (pure-jnp oracle). Kernels target TPU;
tests validate them in interpret mode on CPU across shape/dtype sweeps
(tests/test_kernels_*.py).
"""

__all__ = [
    "flash_attention",
    "decode_attention",
    "rmsnorm",
    "selective_scan",
]
