"""Jit'd public wrapper for flash attention.

Accepts the model-layout tensors ([B, S, H, D]) and dispatches to the
Pallas kernel (TPU) or its interpret-mode execution (CPU tests). The
pure-XLA chunked path lives in :mod:`repro.models.attention`; the jnp
oracle in :mod:`.ref`.
"""

from __future__ import annotations

import jax

from .flash_attention import flash_attention_bhsd
from .ref import attention_ref

__all__ = ["flash_attention", "flash_attention_ref"]


def _to_bhsd(x: jax.Array) -> jax.Array:
    return x.transpose(0, 2, 1, 3)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """q: [B, Sq, H, D]; k, v: [B, Skv, KV, D] -> [B, Sq, H, D]."""
    out = flash_attention_bhsd(
        _to_bhsd(q),
        _to_bhsd(k),
        _to_bhsd(v),
        causal=causal,
        window=window,
        block_q=block_q,
        block_kv=block_kv,
        interpret=interpret,
    )
    return out.transpose(0, 2, 1, 3)


def flash_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
) -> jax.Array:
    """Oracle with the same [B, S, H, D] signature."""
    return attention_ref(
        _to_bhsd(q), _to_bhsd(k), _to_bhsd(v), causal=causal, window=window
    ).transpose(0, 2, 1, 3)
