"""Pallas TPU flash-attention forward kernel.

TPU-native adaptation (DESIGN.md Sec. 7): (block_q × block_kv) tiles are
resident in VMEM, the MXU consumes (block, head_dim) matmuls, and the
online-softmax running state (m, l, acc) lives in VMEM scratch that
persists across the innermost KV grid dimension (TPU grids execute
sequentially minor-to-major, replacing the GPU warp-level loop).

Supports: causal masking, GQA (q-head grid indexes its KV head), static
sliding windows (KV block range is trimmed per q block — out-of-window
blocks are never touched), and tail padding.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_scr,
    l_scr,
    acc_scr,
    *,
    block_q: int,
    block_kv: int,
    seq_q: int,
    seq_kv: int,
    causal: bool,
    window: int | None,
    scale: float,
):
    qi = pl.program_id(2)
    kj = pl.program_id(3)
    n_kv = pl.num_programs(3)

    q_start = qi * block_q
    kv_start = kj * block_kv

    # KV block range relevant to this q block.
    if causal:
        j_last = jnp.minimum((q_start + block_q - 1) // block_kv, n_kv - 1)
    else:
        j_last = n_kv - 1
    if window is not None:
        j_first = jnp.maximum((q_start - window + 1) // block_kv, 0)
    else:
        j_first = 0

    @pl.when(kj == j_first)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when((kj >= j_first) & (kj <= j_last))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)  # [bkv, d]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # [bq, bkv]

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        kv_pos = kv_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        mask = kv_pos < seq_kv  # tail padding
        mask = mask & (q_pos < seq_q)
        if causal:
            mask = mask & (q_pos >= kv_pos)
        if window is not None:
            mask = mask & (q_pos - kv_pos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]  # [bq, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(kj == j_last)
    def _finalize():
        l = l_scr[...]
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal",
        "window",
        "block_q",
        "block_kv",
        "interpret",
    ),
)
def flash_attention_bhsd(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """q: [B, H, Sq, D]; k, v: [B, KV, Skv, D]; H = G * KV. Returns like q."""
    B, H, Sq, D = q.shape
    _, KV, Skv, _ = k.shape
    assert H % KV == 0, "GQA requires H % KV == 0"
    G = H // KV
    scale = D**-0.5

    block_q = min(block_q, max(Sq, 8))
    block_kv = min(block_kv, max(Skv, 8))
    q_pad = -Sq % block_q
    kv_pad = -Skv % block_kv
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, q_pad), (0, 0)))
    if kv_pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, kv_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, kv_pad), (0, 0)))
    nq = (Sq + q_pad) // block_q
    nkv = (Skv + kv_pad) // block_kv

    kernel = functools.partial(
        _attn_kernel,
        block_q=block_q,
        block_kv=block_kv,
        seq_q=Sq,
        seq_kv=Skv,
        causal=causal,
        window=window,
        scale=scale,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_kv, D), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_kv, D), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq + q_pad, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    if q_pad:
        out = out[:, :, :Sq]
    return out
