"""Pure-jnp oracle for the flash-attention kernel (materializes scores)."""

from __future__ import annotations

import jax.numpy as jnp
import jax

NEG_INF = -1e30


def attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
) -> jnp.ndarray:
    """q: [B, H, Sq, D]; k, v: [B, KV, Skv, D]. fp32 reference."""
    B, H, Sq, D = q.shape
    _, KV, Skv, _ = k.shape
    G = H // KV
    scale = D**-0.5

    qf = q.astype(jnp.float32).reshape(B, KV, G, Sq, D) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qf, kf)

    q_pos = jnp.arange(Sq)[:, None]
    kv_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask = mask & (q_pos >= kv_pos)
    if window is not None:
        mask = mask & (q_pos - kv_pos < window)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bkgqd", p, vf)
    return out.reshape(B, H, Sq, D).astype(q.dtype)
