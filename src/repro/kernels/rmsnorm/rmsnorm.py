"""Pallas TPU fused RMSNorm kernel.

Row-tiled: each grid cell normalizes a [block_rows, D] tile in VMEM with
fp32 accumulation and applies the scale in the same pass (one HBM
round-trip instead of XLA's normalize-then-scale pair).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # [block_rows, D]
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm_2d(
    x: jax.Array,  # [R, D]
    w: jax.Array,  # [D]
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    R, D = x.shape
    block_rows = min(block_rows, R)
    pad = -R % block_rows
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    n = (R + pad) // block_rows
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R + pad, D), x.dtype),
        interpret=interpret,
    )(x, w)
    return out[:R] if pad else out
