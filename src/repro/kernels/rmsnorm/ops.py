"""Public wrapper: RMSNorm over the trailing dim of any-rank input."""

from __future__ import annotations

import jax

from .ref import rmsnorm_ref
from .rmsnorm import rmsnorm_2d

__all__ = ["rmsnorm", "rmsnorm_ref"]


def rmsnorm(
    x: jax.Array,
    w: jax.Array,
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    shape = x.shape
    out = rmsnorm_2d(
        x.reshape(-1, shape[-1]), w, eps=eps, block_rows=block_rows, interpret=interpret
    )
    return out.reshape(shape)
