"""Pallas TPU paged prefill-attention kernel (block-table walk).

Chunked prefill scatters each chunk's K/V into the request's reserved
pages and then needs the chunk's C query positions to attend causally
over the *whole* paged prefix. The XLA fallback
(:func:`.ref.paged_prefill_attention`) materializes every lane's pages
with one gather per chunk — O(prefix) copied bytes per chunk, the
dominant per-token cost on memory-starved edge devices. This kernel is
the multi-query sibling of :func:`.paged.paged_decode_attention`: the
grid is (batch, kv_head, prefix block) and the block table is a
*scalar-prefetch* operand, so each cell's BlockSpec ``index_map``
resolves the logical block to its physical page and the DMA fetches
exactly that page — the gather happens in the memory system and the
contiguous copy never exists.

The causal mask is applied in-kernel from the per-lane ``offsets``
(query ``i`` of lane ``b`` sits at absolute position ``offsets[b] + i``
and attends positions ``<= offsets[b] + i``); per-cell partials
(m, l, acc) are merged by the same tiny XLA log-sum-exp combine as the
decode kernels. Blocks entirely beyond a lane's chunk window mask to
exp(-inf) = 0 and out-of-range logical blocks point at the pool's
reserved scratch page, so ragged lanes cost masked lanes nothing.

int8 pages (:mod:`repro.serving` ``kv_dtype="int8"``) carry one fp32
scale per page row; the kernel dequantizes each fetched page in VMEM —
quantized serving never materializes an fp copy of the cache either.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_prefill_kernel(
    bt_ref,  # [B, NB] int32 scalar-prefetch: logical block -> physical page
    off_ref,  # [B] int32 scalar-prefetch: absolute position of q[:, 0]
    q_ref,  # [1, 1, C, G, D]
    k_ref,  # [1, page, 1, D] — the physical page named by bt[b, c]
    v_ref,
    *refs,  # ([ks_ref, vs_ref] when quantized), m_out, l_out, acc_out
    page_size: int,
    scale: float,
    quantized: bool,
):
    if quantized:
        ks_ref, vs_ref, m_out, l_out, acc_out = refs
    else:
        m_out, l_out, acc_out = refs
    b = pl.program_id(0)
    ci = pl.program_id(2)
    off = off_ref[b]

    q = q_ref[0, 0].astype(jnp.float32) * scale  # [C, G, D]
    C, G, D = q.shape
    k = k_ref[0, :, 0]  # [page, D]
    v = v_ref[0, :, 0]
    if quantized:
        k = k.astype(jnp.float32) * ks_ref[0][:, None]
        v = v.astype(jnp.float32) * vs_ref[0][:, None]
    else:
        k = k.astype(jnp.float32)
        v = v.astype(jnp.float32)

    s = jax.lax.dot_general(
        q.reshape(C * G, D), k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(C, G, page_size)
    kv_pos = ci * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, 1, page_size), 2
    )
    q_pos = off + jax.lax.broadcasted_iota(jnp.int32, (C, 1, 1), 0)
    mask = kv_pos <= q_pos  # causal incl. self, [C, 1, page]
    s = jnp.where(mask, s, NEG_INF)

    m = jnp.max(s, axis=2)  # [C, G]
    p = jnp.where(mask, jnp.exp(s - m[:, :, None]), 0.0)
    l = jnp.sum(p, axis=2)
    acc = jax.lax.dot_general(
        p.reshape(C * G, page_size), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(C, G, D)
    m_out[0, 0, 0] = m
    l_out[0, 0, 0] = l
    acc_out[0, 0, 0] = acc


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_prefill_attention_pallas(
    q: jax.Array,  # [B, C, H, D] (model layout) — C new tokens per lane
    k_pages: jax.Array,  # [P, page, KV, D] — shared page pool
    v_pages: jax.Array,
    block_tables: jax.Array,  # [B, NB] int32 physical page per logical block
    offsets: jax.Array,  # [B] int32 absolute position of q[:, 0] (>= 0)
    *,
    k_scales: jax.Array | None = None,  # [P, page] fp32 per-row scales (int8)
    v_scales: jax.Array | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Chunk attention over a paged prefix, gather-free. Returns [B,C,H,D].

    Drop-in for :func:`.ref.paged_prefill_attention` (the XLA gather
    fallback, which stays as the off-TPU path and test oracle). Rows
    past the caller's valid count produce garbage the engine discards.
    """
    B, C, H, D = q.shape
    _, page, KV, _ = k_pages.shape
    NB = block_tables.shape[1]
    G = H // KV
    scale = D**-0.5
    quantized = k_scales is not None

    qg = q.reshape(B, C, KV, G, D).transpose(0, 2, 1, 3, 4)  # [B, KV, C, G, D]
    block_tables = block_tables.astype(jnp.int32)
    offsets = offsets.astype(jnp.int32)

    kernel = functools.partial(
        _paged_prefill_kernel, page_size=page, scale=scale, quantized=quantized
    )
    page_spec = pl.BlockSpec(
        (1, page, 1, D), lambda b, h, c, bt, off: (bt[b, c], 0, h, 0)
    )
    in_specs = [
        pl.BlockSpec((1, 1, C, G, D), lambda b, h, c, bt, off: (b, h, 0, 0, 0)),
        page_spec,
        page_spec,
    ]
    operands = [qg, k_pages, v_pages]
    if quantized:
        scale_spec = pl.BlockSpec(
            (1, page), lambda b, h, c, bt, off: (bt[b, c], 0)
        )
        in_specs += [scale_spec, scale_spec]
        operands += [k_scales, v_scales]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, NB),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec(
                (1, 1, 1, C, G), lambda b, h, c, bt, off: (b, h, c, 0, 0)
            ),
            pl.BlockSpec(
                (1, 1, 1, C, G), lambda b, h, c, bt, off: (b, h, c, 0, 0)
            ),
            pl.BlockSpec(
                (1, 1, 1, C, G, D), lambda b, h, c, bt, off: (b, h, c, 0, 0, 0)
            ),
        ],
    )
    m, l, acc = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, KV, NB, C, G), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, NB, C, G), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, NB, C, G, D), jnp.float32),
        ],
        interpret=interpret,
    )(block_tables, offsets, *operands)

    # Log-sum-exp merge across logical blocks (tiny XLA reduction).
    M = jnp.max(m, axis=2, keepdims=True)  # [B,KV,1,C,G]
    w = jnp.exp(m - M)  # [B,KV,NB,C,G]
    denom = jnp.sum(w * l, axis=2)  # [B,KV,C,G]
    numer = jnp.sum(w[..., None] * acc, axis=2)  # [B,KV,C,G,D]
    out = numer / jnp.maximum(denom[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3, 4).reshape(B, C, H, D).astype(q.dtype)
