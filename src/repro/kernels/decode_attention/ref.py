"""Pure-jnp oracle for flash-decode."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(
    q: jnp.ndarray,  # [B, H, 1, D]
    k_cache: jnp.ndarray,  # [B, KV, S, D]
    v_cache: jnp.ndarray,
    lengths: jnp.ndarray,  # [B]
    *,
    window: int | None = None,
) -> jnp.ndarray:
    B, H, _, D = q.shape
    _, KV, S, _ = k_cache.shape
    G = H // KV
    scale = D**-0.5

    qg = q.astype(jnp.float32).reshape(B, KV, G, D) * scale
    s = jnp.einsum("bkgd,bksd->bkgs", qg, k_cache.astype(jnp.float32))
    pos = jnp.arange(S)[None, :]
    mask = pos < lengths[:, None]
    if window is not None:
        mask = mask & (pos >= lengths[:, None] - window)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, H, 1, D).astype(q.dtype)
