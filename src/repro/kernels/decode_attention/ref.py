"""Pure-jnp oracles for flash-decode and paged flash-decode, plus the
scatter-time int8 page quantizer shared by models and engine."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def quantize_kv(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-row int8 quantization of K/V cache entries.

    ``x[..., KV, D]`` -> (int8 values, fp32 scales ``[...]``): one amax
    scale per token row (all KV heads x head_dim of one cache entry).
    Scales live per page *row*, not one scalar per page, deliberately:
    pages fill incrementally (decode writes one row per step), and a
    whole-page amax would force requantizing every previously written
    row on each scatter. All-zero rows get scale 1 so dequant stays 0.
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=(-2, -1))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xf / scale[..., None, None]), -127, 127)
    return q.astype(jnp.int8), scale


def gather_pages(
    pages: jnp.ndarray,
    block_tables: jnp.ndarray,
    scales: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Materialize a contiguous cache from a page pool.

    pages: [P, page, KV, D]; block_tables: [B, NB] -> [B, NB*page, KV, D].
    With ``scales`` ([P, page] per-row fp32, int8 pools) the gathered
    rows are dequantized: ``pages[bt] * scales[bt]``.
    """
    B, NB = block_tables.shape
    _, page, KV, D = pages.shape
    out = pages[block_tables].reshape(B, NB * page, KV, D)
    if scales is None:
        return out
    s = scales[block_tables].reshape(B, NB * page)
    return out.astype(s.dtype) * s[:, :, None, None]


def paged_decode_attention_ref(
    q: jnp.ndarray,  # [B, 1, H, D] (model layout)
    k_pages: jnp.ndarray,  # [P, page, KV, D]
    v_pages: jnp.ndarray,
    block_tables: jnp.ndarray,  # [B, NB] int32
    lengths: jnp.ndarray,  # [B] int32, valid entries incl. current token
    *,
    window: int | None = None,
    k_scales: jnp.ndarray | None = None,  # [P, page] fp32 (int8 pools)
    v_scales: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Gather-then-attend oracle for the paged kernel. Returns [B,1,H,D]."""
    B, _, H, D = q.shape
    k = gather_pages(k_pages, block_tables, k_scales)  # [B, S, KV, D]
    v = gather_pages(v_pages, block_tables, v_scales)
    return decode_attention_ref(
        q.transpose(0, 2, 1, 3),
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (B,)),
        window=window,
    ).transpose(0, 2, 1, 3)


def paged_prefill_attention(
    q: jnp.ndarray,  # [B, C, H, D] (model layout) — C new tokens per lane
    k_pages: jnp.ndarray,  # [P, page, KV, D]
    v_pages: jnp.ndarray,
    block_tables: jnp.ndarray,  # [B, NB] int32
    offsets: jnp.ndarray,  # [B] int32 absolute position of q[:, 0]
    *,
    k_scales: jnp.ndarray | None = None,  # [P, page] fp32 (int8 pools)
    v_scales: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Prefill-over-paged-prefix attention — the gather fallback.

    Chunked prefill writes each chunk's K/V into the request's reserved
    pages and then needs the chunk's queries to attend causally over the
    whole paged prefix. This fallback materializes each lane's pages
    (one gather, dequantized for int8 pools) and runs masked attention;
    the Pallas kernel that walks the block table directly —
    :func:`.paged_prefill.paged_prefill_attention_pallas`, the
    multi-query sibling of :func:`.paged.paged_decode_attention` —
    replaces it behind this signature on TPU, and this fallback stays as
    the off-TPU path and test oracle. Query ``i`` of lane ``b`` attends
    positions ``<= offsets[b] + i``; rows past the caller's valid count
    produce garbage that the engine discards. Returns [B, C, H, D].
    """
    B, C, H, D = q.shape
    k = gather_pages(k_pages, block_tables, k_scales)  # [B, S, KV, D]
    v = gather_pages(v_pages, block_tables, v_scales)
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = D**-0.5
    qg = q.astype(jnp.float32).reshape(B, C, KV, G, D) * scale
    s = jnp.einsum("bckgd,bskd->bckgs", qg, k.astype(jnp.float32))
    q_pos = offsets[:, None] + jnp.arange(C, dtype=jnp.int32)  # [B, C]
    kv_pos = jnp.arange(S, dtype=jnp.int32)
    mask = kv_pos[None, None, :] <= q_pos[:, :, None]  # causal incl. self
    s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bckgs,bskd->bckgd", p, v.astype(jnp.float32))
    return out.reshape(B, C, H, D).astype(q.dtype)


def decode_attention_ref(
    q: jnp.ndarray,  # [B, H, 1, D]
    k_cache: jnp.ndarray,  # [B, KV, S, D]
    v_cache: jnp.ndarray,
    lengths: jnp.ndarray,  # [B]
    *,
    window: int | None = None,
) -> jnp.ndarray:
    B, H, _, D = q.shape
    _, KV, S, _ = k_cache.shape
    G = H // KV
    scale = D**-0.5

    qg = q.astype(jnp.float32).reshape(B, KV, G, D) * scale
    s = jnp.einsum("bkgd,bksd->bkgs", qg, k_cache.astype(jnp.float32))
    pos = jnp.arange(S)[None, :]
    mask = pos < lengths[:, None]
    if window is not None:
        mask = mask & (pos >= lengths[:, None] - window)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, H, 1, D).astype(q.dtype)
