"""Pallas TPU paged decode-attention kernel (block-table gather).

Serving keeps each replica's KV cache as a shared pool of fixed-size
pages (``serving/cache.py``); a request's context is scattered
over non-contiguous pages named by its block table. One query token per
sequence attends to that scattered cache without ever materializing a
contiguous copy: the grid is (batch, kv_head, block) and the block
table is a *scalar-prefetch* operand, so each cell's BlockSpec
``index_map`` resolves the logical block to its physical page and the
DMA fetches exactly that page — the gather happens in the memory
system, not in registers. Per-cell partials (m, l, acc) are merged by
the same tiny XLA log-sum-exp combine as the dense flash-decode kernel
(:mod:`.decode_attention`).

Out-of-range logical blocks point at a reserved scratch page; their
positions are masked by the per-sequence length, so their garbage
contributes exp(-inf) = 0 to the merge.

int8 pools (``kv_dtype="int8"`` serving) carry one fp32 scale per page
row; passing ``k_scales``/``v_scales`` makes the kernel dequantize each
fetched page in VMEM, so quantized decode reads a quarter of the fp32
bytes and never materializes an fp copy of the cache.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(
    bt_ref,  # [B, NB] int32 scalar-prefetch: logical block -> physical page
    len_ref,  # [B] int32 scalar-prefetch: valid entries incl. current token
    q_ref,  # [1, 1, G, D]
    k_ref,  # [1, page, 1, D] — the physical page named by bt[b, c]
    v_ref,
    *refs,  # ([ks_ref, vs_ref] when quantized), m_out, l_out, acc_out
    page_size: int,
    window: int | None,
    scale: float,
    quantized: bool,
):
    if quantized:
        ks_ref, vs_ref, m_out, l_out, acc_out = refs
    else:
        m_out, l_out, acc_out = refs
    b = pl.program_id(0)
    ci = pl.program_id(2)
    cache_len = len_ref[b]

    q = q_ref[0, 0].astype(jnp.float32) * scale  # [G, D]
    k = k_ref[0, :, 0]  # [page, D]
    v = v_ref[0, :, 0]
    if quantized:
        k = k.astype(jnp.float32) * ks_ref[0][:, None]
        v = v.astype(jnp.float32) * vs_ref[0][:, None]
    else:
        k = k.astype(jnp.float32)
        v = v.astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [G, page]
    pos = ci * page_size + jax.lax.broadcasted_iota(jnp.int32, (1, page_size), 1)
    mask = pos < cache_len
    if window is not None:
        mask = mask & (pos >= cache_len - window)
    s = jnp.where(mask, s, NEG_INF)

    m = jnp.max(s, axis=1)  # [G]
    p = jnp.where(mask, jnp.exp(s - m[:, None]), 0.0)
    l = jnp.sum(p, axis=1)
    acc = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # [G, D]
    m_out[0, 0, 0] = m
    l_out[0, 0, 0] = l
    acc_out[0, 0, 0] = acc


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_decode_attention(
    q: jax.Array,  # [B, 1, H, D]
    k_pages: jax.Array,  # [P, page, KV, D] — shared page pool
    v_pages: jax.Array,
    block_tables: jax.Array,  # [B, NB] int32 physical page per logical block
    lengths: jax.Array,  # [B] int32 valid entries incl. current token
    *,
    window: int | None = None,
    k_scales: jax.Array | None = None,  # [P, page] fp32 per-row scales (int8)
    v_scales: jax.Array | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Single-token attention against a paged KV cache. Returns [B,1,H,D]."""
    B, _, H, D = q.shape
    _, page, KV, _ = k_pages.shape
    NB = block_tables.shape[1]
    G = H // KV
    scale = D**-0.5
    quantized = k_scales is not None

    qg = q.reshape(B, KV, G, D)
    block_tables = block_tables.astype(jnp.int32)
    lengths = lengths.astype(jnp.int32)

    kernel = functools.partial(
        _paged_kernel, page_size=page, window=window, scale=scale,
        quantized=quantized,
    )
    page_spec = pl.BlockSpec(
        (1, page, 1, D), lambda b, h, c, bt, ln: (bt[b, c], 0, h, 0)
    )
    in_specs = [
        pl.BlockSpec((1, 1, G, D), lambda b, h, c, bt, ln: (b, h, 0, 0)),
        page_spec,
        page_spec,
    ]
    operands = [qg, k_pages, v_pages]
    if quantized:
        scale_spec = pl.BlockSpec(
            (1, page), lambda b, h, c, bt, ln: (bt[b, c], 0)
        )
        in_specs += [scale_spec, scale_spec]
        operands += [k_scales, v_scales]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, NB),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, 1, G), lambda b, h, c, bt, ln: (b, h, c, 0)),
            pl.BlockSpec((1, 1, 1, G), lambda b, h, c, bt, ln: (b, h, c, 0)),
            pl.BlockSpec(
                (1, 1, 1, G, D), lambda b, h, c, bt, ln: (b, h, c, 0, 0)
            ),
        ],
    )
    m, l, acc = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, KV, NB, G), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, NB, G), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, NB, G, D), jnp.float32),
        ],
        interpret=interpret,
    )(block_tables, lengths, *operands)

    # Log-sum-exp merge across logical blocks (tiny XLA reduction).
    M = jnp.max(m, axis=2, keepdims=True)  # [B,KV,1,G]
    w = jnp.exp(m - M)  # [B,KV,NB,G]
    denom = jnp.sum(w * l, axis=2)  # [B,KV,G]
    numer = jnp.sum(w[..., None] * acc, axis=2)  # [B,KV,G,D]
    out = numer / jnp.maximum(denom[..., None], 1e-30)
    return out.reshape(B, 1, H, D).astype(q.dtype)
