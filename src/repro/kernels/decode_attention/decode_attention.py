"""Pallas TPU flash-decode kernel (split-KV) for single-token attention.

One query token attends to a long KV cache. GPU flash-decoding splits the
KV into chunks reduced by separate thread blocks and merges with a warp
reduction; the TPU adaptation gives each (batch, kv_head, chunk) grid cell
an independent partial (m, l, acc) written to HBM, merged afterwards by a
tiny XLA log-sum-exp combine (DESIGN.md Sec. 7). Per-sequence cache
lengths (continuous batching) mask invalid and out-of-window positions
inside the kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(
    q_ref,  # [1, 1, G, D]
    k_ref,  # [1, 1, chunk, D]
    v_ref,
    len_ref,  # [1, 1] int32
    m_out,  # [1, 1, 1, G]
    l_out,  # [1, 1, 1, G]
    acc_out,  # [1, 1, 1, G, D]
    *,
    chunk: int,
    window: int | None,
    scale: float,
):
    ci = pl.program_id(2)
    cache_len = len_ref[0, 0]

    q = q_ref[0, 0].astype(jnp.float32) * scale  # [G, D]
    k = k_ref[0, 0].astype(jnp.float32)  # [chunk, D]
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [G, chunk]
    pos = ci * chunk + jax.lax.broadcasted_iota(jnp.int32, (1, chunk), 1)
    mask = pos < cache_len
    if window is not None:
        mask = mask & (pos >= cache_len - window)
    s = jnp.where(mask, s, NEG_INF)

    m = jnp.max(s, axis=1)  # [G]
    p = jnp.where(mask, jnp.exp(s - m[:, None]), 0.0)
    l = jnp.sum(p, axis=1)
    acc = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # [G, D]
    m_out[0, 0, 0] = m
    l_out[0, 0, 0] = l
    acc_out[0, 0, 0] = acc


@functools.partial(
    jax.jit, static_argnames=("window", "chunk", "interpret")
)
def decode_attention_bhsd(
    q: jax.Array,  # [B, H, 1, D]
    k_cache: jax.Array,  # [B, KV, S, D]
    v_cache: jax.Array,
    lengths: jax.Array,  # [B] int32 (valid entries incl. current token)
    *,
    window: int | None = None,
    chunk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, H, _, D = q.shape
    _, KV, S, _ = k_cache.shape
    G = H // KV
    scale = D**-0.5

    chunk = min(chunk, max(S, 8))
    pad = -S % chunk
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
    C = (S + pad) // chunk

    qg = q.reshape(B, KV, G, D)
    lengths2d = lengths.reshape(B, 1).astype(jnp.int32)

    kernel = functools.partial(
        _decode_kernel, chunk=chunk, window=window, scale=scale
    )
    m, l, acc = pl.pallas_call(
        kernel,
        grid=(B, KV, C),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, c: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, chunk, D), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, D), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1), lambda b, h, c: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, G), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, 1, G), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, 1, G, D), lambda b, h, c: (b, h, c, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, KV, C, G), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, C, G), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, C, G, D), jnp.float32),
        ],
        interpret=interpret,
    )(qg.reshape(B, KV, G, D), k_cache, v_cache, lengths2d)

    # Log-sum-exp merge across chunks (tiny XLA reduction).
    M = jnp.max(m, axis=2, keepdims=True)  # [B,KV,1,G]
    w = jnp.exp(m - M)  # [B,KV,C,G]
    denom = jnp.sum(w * l, axis=2)  # [B,KV,G]
    numer = jnp.sum(w[..., None] * acc, axis=2)  # [B,KV,G,D]
    out = numer / jnp.maximum(denom[..., None], 1e-30)
    return out.reshape(B, H, 1, D).astype(q.dtype)
