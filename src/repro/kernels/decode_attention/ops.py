"""Public wrapper for flash-decode (model layout [B, 1, H, D])."""

from __future__ import annotations

import jax

from .decode_attention import decode_attention_bhsd
from .ref import decode_attention_ref as _ref

__all__ = ["decode_attention", "decode_attention_ref"]


def decode_attention(
    q: jax.Array,  # [B, 1, H, D]
    k_cache: jax.Array,  # [B, S, KV, D]
    v_cache: jax.Array,
    lengths: jax.Array,  # [B] or scalar
    *,
    window: int | None = None,
    chunk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    import jax.numpy as jnp

    B = q.shape[0]
    lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (B,))
    out = decode_attention_bhsd(
        q.transpose(0, 2, 1, 3),
        k_cache.transpose(0, 2, 1, 3),
        v_cache.transpose(0, 2, 1, 3),
        lengths,
        window=window,
        chunk=chunk,
        interpret=interpret,
    )
    return out.transpose(0, 2, 1, 3)


def decode_attention_ref(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lengths: jax.Array,
    *,
    window: int | None = None,
) -> jax.Array:
    import jax.numpy as jnp

    B = q.shape[0]
    lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (B,))
    return _ref(
        q.transpose(0, 2, 1, 3),
        k_cache.transpose(0, 2, 1, 3),
        v_cache.transpose(0, 2, 1, 3),
        lengths,
        window=window,
    ).transpose(0, 2, 1, 3)
