from .ops import decode_attention, decode_attention_ref
from .paged import paged_decode_attention
from .paged_prefill import paged_prefill_attention_pallas
from .ref import (
    gather_pages,
    paged_decode_attention_ref,
    paged_prefill_attention,
    quantize_kv,
)

__all__ = [
    "decode_attention",
    "decode_attention_ref",
    "paged_decode_attention",
    "paged_decode_attention_ref",
    "paged_prefill_attention",
    "paged_prefill_attention_pallas",
    "gather_pages",
    "quantize_kv",
]
