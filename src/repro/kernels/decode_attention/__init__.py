from .ops import decode_attention, decode_attention_ref
from .paged import paged_decode_attention
from .paged_prefill import paged_prefill_attention_pallas
from .ref import (
    gather_pages,
    paged_decode_attention_ref,
    paged_prefill_attention,
    quantize_kv,
)

# The Pallas kernels whose traced computation must stay free of XLA
# pool gathers (the block-table walk lives in the BlockSpec index map).
# ``repro.analysis.entry_points`` traces each standalone so the
# zero-gather budget binds at the kernel boundary.
PALLAS_PAGED_KERNELS = {
    "paged_decode_attention": paged_decode_attention,
    "paged_prefill_attention": paged_prefill_attention_pallas,
}

__all__ = [
    "PALLAS_PAGED_KERNELS",
    "decode_attention",
    "decode_attention_ref",
    "paged_decode_attention",
    "paged_decode_attention_ref",
    "paged_prefill_attention",
    "paged_prefill_attention_pallas",
    "gather_pages",
    "quantize_kv",
]
