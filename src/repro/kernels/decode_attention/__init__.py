from .ops import decode_attention, decode_attention_ref
from .paged import paged_decode_attention
from .ref import gather_pages, paged_decode_attention_ref, paged_prefill_attention

__all__ = [
    "decode_attention",
    "decode_attention_ref",
    "paged_decode_attention",
    "paged_decode_attention_ref",
    "paged_prefill_attention",
    "gather_pages",
]
