from .ops import selective_scan, selective_scan_ref

__all__ = ["selective_scan", "selective_scan_ref"]
