"""Pure-jnp oracle for the selective scan: plain sequential recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(
    x: jnp.ndarray,  # [B, S, Din]
    dt: jnp.ndarray,
    Bmat: jnp.ndarray,  # [B, S, N]
    Cmat: jnp.ndarray,
    A: jnp.ndarray,  # [Din, N]
    h0: jnp.ndarray | None = None,
):
    B, S, Din = x.shape
    N = A.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((B, Din, N), jnp.float32)

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = Bmat.astype(jnp.float32)
    Cf = Cmat.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(h, t):
        a = jnp.exp(dtf[:, t, :, None] * Af[None])
        b = (dtf[:, t] * xf[:, t])[..., None] * Bf[:, t, None, :]
        h = a * h + b
        y = jnp.einsum("bdn,bn->bd", h, Cf[:, t])
        return h, y

    h, ys = jax.lax.scan(step, h0, jnp.arange(S))
    y = ys.transpose(1, 0, 2).astype(x.dtype)
    return y, h
