"""Public wrapper for the chunked selective scan."""

from .ref import selective_scan_ref
from .selective_scan import selective_scan_pallas as selective_scan

__all__ = ["selective_scan", "selective_scan_ref"]
