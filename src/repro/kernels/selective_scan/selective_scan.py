"""Pallas TPU chunked selective-scan kernel (Mamba-1 recurrence).

TPU-native adaptation of the CUDA selective-scan (DESIGN.md Sec. 7): the
sequence is processed in chunks along the innermost (sequential) grid
dimension; within a chunk the recurrence runs as a vectorized associative
scan over a [chunk, block_d, N] VMEM tile, and the [block_d, N] state is
carried across chunks in VMEM scratch (no HBM round-trip per step, no
GPU-style per-thread serial loop).

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t
    y_t = <h_t, C_t>
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(
    x_ref,  # [1, chunk, block_d]
    dt_ref,  # [1, chunk, block_d]
    b_ref,  # [1, chunk, N]
    c_ref,  # [1, chunk, N]
    a_ref,  # [block_d, N]
    h0_ref,  # [1, block_d, N]
    y_ref,  # [1, chunk, block_d]
    hout_ref,  # [1, block_d, N]
    h_scr,  # VMEM [block_d, N] f32
):
    ci = pl.program_id(2)
    n_chunks = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)  # [chunk, block_d]
    dt = dt_ref[0].astype(jnp.float32)
    Bm = b_ref[0].astype(jnp.float32)  # [chunk, N]
    Cm = c_ref[0].astype(jnp.float32)
    A = a_ref[...].astype(jnp.float32)  # [block_d, N]

    a = jnp.exp(dt[:, :, None] * A[None])  # [chunk, block_d, N]
    b = (dt * x)[:, :, None] * Bm[:, None, :]  # [chunk, block_d, N]

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, br + ar * bl

    a_cum, b_cum = jax.lax.associative_scan(combine, (a, b), axis=0)
    h = a_cum * h_scr[...][None] + b_cum  # [chunk, block_d, N]
    y_ref[0] = jnp.einsum("cdn,cn->cd", h, Cm).astype(y_ref.dtype)
    h_scr[...] = h[-1]

    @pl.when(ci == n_chunks - 1)
    def _final():
        hout_ref[0] = h_scr[...].astype(hout_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("chunk", "block_d", "interpret")
)
def selective_scan_pallas(
    x: jax.Array,  # [B, S, Din]
    dt: jax.Array,  # [B, S, Din]
    Bmat: jax.Array,  # [B, S, N]
    Cmat: jax.Array,  # [B, S, N]
    A: jax.Array,  # [Din, N]
    h0: jax.Array | None = None,  # [B, Din, N]
    *,
    chunk: int = 128,
    block_d: int = 512,
    interpret: bool = False,
):
    """Returns (y [B, S, Din], h_final [B, Din, N])."""
    B, S, Din = x.shape
    N = A.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((B, Din, N), jnp.float32)

    chunk = min(chunk, S)
    block_d = min(block_d, Din)
    s_pad = -S % chunk
    d_pad = -Din % block_d
    if s_pad or d_pad:
        x = jnp.pad(x, ((0, 0), (0, s_pad), (0, d_pad)))
        dt = jnp.pad(dt, ((0, 0), (0, s_pad), (0, d_pad)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, s_pad), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, s_pad), (0, 0)))
    if d_pad:
        A = jnp.pad(A, ((0, d_pad), (0, 0)))
        h0 = jnp.pad(h0, ((0, 0), (0, d_pad), (0, 0)))
    Sp, Dp = S + s_pad, Din + d_pad
    n_chunks, n_d = Sp // chunk, Dp // block_d

    y, h_final = pl.pallas_call(
        _scan_kernel,
        grid=(B, n_d, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((block_d, N), lambda b, d, c: (d, 0)),
            pl.BlockSpec((1, block_d, N), lambda b, d, c: (b, d, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, block_d, N), lambda b, d, c: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Sp, Dp), x.dtype),
            jax.ShapeDtypeStruct((B, Dp, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, Bmat, Cmat, A, h0)

    return y[:, :S, :Din], h_final[:, :Din]
