from . import hw
from .analysis import (
    Computation,
    HloCost,
    Op,
    RooflineTerms,
    analyze_hlo,
    call_multipliers,
    callees,
    parse_computations,
    roofline_terms,
    top_contributors,
    trip_count,
)

__all__ = [
    "hw",
    "Computation",
    "HloCost",
    "Op",
    "RooflineTerms",
    "analyze_hlo",
    "call_multipliers",
    "callees",
    "parse_computations",
    "roofline_terms",
    "top_contributors",
    "trip_count",
]
