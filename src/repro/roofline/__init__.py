from . import hw
from .analysis import HloCost, RooflineTerms, analyze_hlo, roofline_terms

__all__ = ["hw", "HloCost", "RooflineTerms", "analyze_hlo", "roofline_terms"]
