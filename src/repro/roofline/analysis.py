"""Roofline terms from a compiled dry-run artifact.

    compute    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory     = HLO_bytes / (chips * HBM_bw)
    collective = collective_bytes / (chips * link_bw)

``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified
empirically on XLA:CPU), which silently drops ~n_layers× of the work for
scan-over-layers models. We therefore walk the post-optimization HLO text
ourselves:

* computations are parsed into blocks; call multiplicity is propagated
  from ENTRY through ``while`` bodies (trip count recovered from the loop
  condition's comparison constant), ``fusion``/``call``/``to_apply``
  edges;
* FLOPs: ``dot`` = 2 * prod(out) * prod(contracting dims) (batch dims
  included in out), ``convolution`` ~ 2 * prod(out) * prod(kernel
  spatial), plus 1 FLOP/element for top-level elementwise ops;
* HBM bytes: per *top-level* op (fusion internals excluded — a fusion is
  XLA's unit of HBM materialization): output bytes + shaped operand
  bytes;
* collective bytes: output bytes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute ops, trip-scaled.
"""

from __future__ import annotations

import dataclasses
import re

from . import hw

__all__ = [
    "Computation",
    "HloCost",
    "Op",
    "RooflineTerms",
    "analyze_hlo",
    "call_multipliers",
    "callees",
    "parse_computations",
    "roofline_terms",
    "static_memory_seconds",
    "static_roofline_terms",
    "top_contributors",
    "trip_count",
]

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "power", "compare",
    "select", "and", "or", "xor", "negate", "abs", "floor", "sign",
}
_BYTE_FREE = {
    "bitcast", "tuple", "get-tuple-element", "parameter", "constant",
    "after-all", "partition-id", "replica-id",
}


def _shape_dims(s: str) -> tuple[str, list[int]] | None:
    m = _SHAPE_RE.match(s)
    if not m:
        return None
    dtype, dims = m.group(1), m.group(2)
    sizes = [int(d) for d in dims.split(",") if d] if dims else []
    return dtype, sizes


def _all_shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class Op:
    """One HLO instruction: ``%name = <result_type> kind(operands), ...``."""

    name: str
    kind: str
    line: str
    result_type: str  # text before the op kind
    operands: tuple[str, ...] = ()  # referenced value names


@dataclasses.dataclass
class Computation:
    """One parsed HLO computation block (ENTRY is also under ``__entry__``)."""

    name: str
    ops: list
    types: dict = dataclasses.field(default_factory=dict)  # value -> type str


def parse_computations(hlo: str) -> dict[str, Computation]:
    """Parse post-optimization HLO text into named computation blocks.

    The ENTRY computation is additionally keyed ``"__entry__"``.
    """
    comps: dict[str, Computation] = {}
    current: Computation | None = None
    for raw in hlo.splitlines():
        line = raw.strip()
        if not line:
            continue
        header = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{$", line)
        if header and ("->" in line or line.startswith("ENTRY")):
            current = Computation(header.group(1), [])
            comps[current.name] = current
            if line.startswith("ENTRY"):
                comps["__entry__"] = current
            continue
        if line.startswith("}"):
            current = None
            continue
        if current is None:
            continue
        m = re.match(r"(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$", line)
        if not m:
            continue
        rhs = m.group(2)
        # Result type: scalar/array "bf16[...]{layout}" or a tuple type
        # "(s32[], f32[...], /*index=5*/ ...)" (comments may contain '=').
        km = re.match(
            r"((?:\((?:[^()]|\([^()]*\))*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+)?([\w\-]+)\(",
            rhs,
        )
        if not km:
            continue
        result_type = (km.group(1) or "").strip()
        kind = km.group(2)
        # Operand names: %refs inside the first (...) argument list.
        args = rhs.split(kind + "(", 1)[1]
        depth, end = 1, 0
        for j, ch in enumerate(args):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = j
                    break
        operands = tuple(re.findall(r"%([\w.\-]+)", args[:end]))
        op = Op(m.group(1), kind, line, result_type, operands)
        current.ops.append(op)
        current.types[op.name] = result_type
    return comps


def callees(op: Op) -> dict[str, str]:
    """callee name -> edge kind ('fusion'|'control'|'call')."""
    out = {}
    for key, val in re.findall(r"(calls|to_apply|body|condition)=%?([\w.\-]+)", op.line):
        if key == "calls" and op.kind == "fusion":
            out[val] = "fusion"
        elif key in ("body", "condition"):
            out[val] = key
        else:
            out[val] = "call"
    return out


def trip_count(comps: dict, while_op: Op, cond_name: str | None) -> int:
    """Loop trip count: backend_config known_trip_count when present,
    else the loop bound from the condition's compare constant(s)."""
    tm = re.search(r'known_trip_count[^0-9]*(\d+)', while_op.line)
    if tm:
        return int(tm.group(1))
    if cond_name is None:
        return 1
    seen, stack, consts = set(), [cond_name], []
    while stack:
        name = stack.pop()
        if name in seen or name not in comps:
            continue
        seen.add(name)
        for op in comps[name].ops:
            cm = re.search(r"[su]32\[\]\s+constant\((\d+)\)", op.line)
            if cm:
                consts.append(int(cm.group(1)))
            for callee in callees(op):
                stack.append(callee)
    return max(consts) if consts else 1


def _operand_dims(comp: Computation, op: Op, idx: int) -> list[int] | None:
    if idx >= len(op.operands):
        return None
    t = comp.types.get(op.operands[idx])
    if t is None:
        return None
    sh = _shape_dims(t)
    return sh[1] if sh else None


def _dot_flops(comp: Computation, op: Op) -> float:
    out = _shape_dims(op.result_type)
    if out is None:
        return 0.0
    _, out_dims = out
    lhs_dims = _operand_dims(comp, op, 0)
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    if lhs_dims is None or cm is None:
        return 0.0
    contract = 1
    for idx in cm.group(1).split(","):
        if idx and int(idx) < len(lhs_dims):
            contract *= lhs_dims[int(idx)]
    n_out = 1
    for d in out_dims:
        n_out *= d
    return 2.0 * n_out * contract


def _conv_flops(comp: Computation, op: Op) -> float:
    out = _shape_dims(op.result_type)
    if out is None:
        return 0.0
    _, out_dims = out
    kernel_dims = _operand_dims(comp, op, 1)
    if kernel_dims is None:
        return 0.0
    n_out = 1
    for d in out_dims:
        n_out *= d
    kernel = 1
    for d in kernel_dims:
        kernel *= d
    # kernel dims include in/groups and out channels; flops per output
    # element ~ 2 * prod(kernel)/out_channels.
    out_ch = kernel_dims[-1] if kernel_dims else 1
    return 2.0 * n_out * kernel / max(out_ch, 1)


def _op_bytes(comp: Computation, op: Op) -> float:
    """HBM traffic of a top-level op: output write + operand reads.

    Special cases:
    * dynamic-update-slice (op or fusion root): the big buffer is aliased
      in place — traffic is the updated slice (2x: read-modify-write at
      slice granularity), not the whole tensor;
    * ``convert``-rooted fusions: XLA:CPU materializes bf16->f32 weight
      conversions because the CPU backend lacks native bf16 matmul — on
      the TPU target the MXU consumes bf16 directly, so these are
      excluded from the (TPU) roofline.
    """
    root = op.name
    if op.kind in ("while", "conditional"):
        return 0.0  # carried buffers alias; bodies account for the work
    if op.kind == "convert" or (
        op.kind == "fusion" and re.match(r"(wrapped_)?convert", root)
    ):
        return 0.0
    operand_bytes = []
    for name in op.operands:
        t = comp.types.get(name)
        if t:
            operand_bytes.append(float(_all_shape_bytes(t)))
    out_bytes = float(_all_shape_bytes(op.result_type))
    if op.kind == "dynamic-update-slice" or (
        op.kind == "fusion" and "dynamic-update-slice" in root
    ):
        # In-place slice update: traffic = the small operands (the slice
        # + indices), read-modify-write. Aliased full buffers (possibly
        # several) don't move.
        big = max(operand_bytes, default=0.0)
        small = sum(b for b in operand_bytes if b < 0.5 * big)
        return 2.0 * small
    return out_bytes + sum(operand_bytes)


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives: dict = dataclasses.field(default_factory=dict)


def call_multipliers(
    comps: dict[str, Computation],
) -> tuple[dict[str, float], dict[str, bool]]:
    """Call multiplicity and fusion-internality per computation.

    Returns ``(mult, fused)``: ``mult[name]`` is the number of times the
    computation executes per ENTRY invocation (trip-scaled across
    ``while`` bodies); ``fused[name]`` is True when *every* call site is
    fusion-internal (the computation never materializes HBM traffic of
    its own). Propagated in topological order (Kahn) — a BFS can visit
    a computation before all of its callers' multipliers have
    accumulated. Shared by :func:`analyze_hlo` and
    :func:`top_contributors` (and ``scripts/hlo_top.py``).
    """
    from collections import deque

    if "__entry__" not in comps:
        return {}, {}
    entry = comps["__entry__"].name
    names = [n for n in comps if n != "__entry__"]

    # (callee, factor, fusion_edge) per caller computation.
    comp_edges: dict[str, list[tuple[str, float, bool]]] = {n: [] for n in names}
    in_deg: dict[str, int] = {n: 0 for n in names}
    for name in names:
        for op in comps[name].ops:
            edges = callees(op)
            trip = None
            if op.kind == "while":
                cond = next((c for c, k in edges.items() if k == "condition"), None)
                trip = trip_count(comps, op, cond)
            for callee, kind in edges.items():
                if callee not in in_deg:
                    continue
                if kind == "condition":
                    factor, fus = float((trip or 1) + 1), True
                elif kind == "body":
                    factor, fus = float(trip or 1), False
                elif kind == "fusion":
                    factor, fus = 1.0, True
                else:
                    factor, fus = 1.0, False
                comp_edges[name].append((callee, factor, fus))
                in_deg[callee] += 1

    mult: dict[str, float] = {n: 0.0 for n in names}
    fused: dict[str, bool | None] = {n: None for n in names}
    mult[entry] = 1.0
    fused[entry] = False
    q = deque([n for n in names if in_deg[n] == 0])
    while q:
        name = q.popleft()
        in_fusion = bool(fused[name])
        for callee, factor, fus_edge in comp_edges[name]:
            mult[callee] += mult[name] * factor
            child_fused = in_fusion or fus_edge
            # bytes-free only if EVERY call site is fusion-internal
            fused[callee] = (
                child_fused if fused[callee] is None else (fused[callee] and child_fused)
            )
            in_deg[callee] -= 1
            if in_deg[callee] == 0:
                q.append(callee)
    return mult, {n: bool(v) for n, v in fused.items()}


def analyze_hlo(hlo: str) -> HloCost:
    comps = parse_computations(hlo)
    if "__entry__" not in comps:
        return HloCost()
    mult, fused = call_multipliers(comps)

    cost = HloCost(collectives={k: {"count": 0, "bytes": 0.0} for k in _COLLECTIVES})
    for name, comp in comps.items():
        if name == "__entry__":
            continue
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        for op in comp.ops:
            if op.kind == "dot":
                cost.flops += m * _dot_flops(comp, op)
            elif op.kind == "convolution":
                cost.flops += m * _conv_flops(comp, op)
            elif op.kind in _ELEMENTWISE:
                sh = _shape_dims(op.result_type)
                if sh:
                    n = 1
                    for d in sh[1]:
                        n *= d
                    cost.flops += m * n
            # HBM bytes: top-level ops only (fusions are the HBM unit).
            if not fused.get(name, False) and op.kind not in _BYTE_FREE:
                cost.bytes += m * _op_bytes(comp, op)
            # Collectives
            base = op.kind
            if base.endswith("-start"):
                base = base[: -len("-start")]
            if base in _COLLECTIVES and not op.kind.endswith("-done"):
                b = _all_shape_bytes(op.result_type)
                cost.collective_bytes += m * b
                cost.collectives[base]["count"] += m
                cost.collectives[base]["bytes"] += m * b
    return cost


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    flops: float  # total FLOPs (all devices)
    hbm_bytes: float  # total bytes accessed
    collective_bytes: float  # total collective payload bytes
    chips: int

    @property
    def compute_s(self) -> float:
        return self.flops / (self.chips * hw.PEAK_FLOPS_BF16)

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / (self.chips * hw.HBM_BW)

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / (self.chips * hw.ICI_BW_PER_LINK)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline-optimistic step time (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "step_time_s": self.step_time_s,
        }


def roofline_terms(hlo_text: str, chips: int) -> tuple[RooflineTerms, HloCost]:
    """Trip-scaled terms from the post-SPMD HLO (per-device program);
    totals scale by ``chips``, the per-chip time terms divide them out."""
    cost = analyze_hlo(hlo_text)
    terms = RooflineTerms(
        flops=cost.flops * chips,
        hbm_bytes=cost.bytes * chips,
        collective_bytes=cost.collective_bytes * chips,
        chips=chips,
    )
    return terms, cost


def static_memory_seconds(required_bytes: float, chips: int = 1) -> float:
    """Attainable-bandwidth floor on step time from *statically* required
    bytes — the jaxpr-level memory pass (``repro.analysis.memory``) feeds
    its per-entry transfer bytes through here, so the roofline's memory
    term is available before anything compiles, not just from
    post-optimization HLO."""
    return required_bytes / (chips * hw.HBM_BW)


def static_roofline_terms(required_bytes: float, chips: int = 1) -> RooflineTerms:
    """A memory-only :class:`RooflineTerms` from static required bytes
    (FLOPs/collectives unknown before compilation → zero)."""
    return RooflineTerms(
        flops=0.0,
        hbm_bytes=float(required_bytes),
        collective_bytes=0.0,
        chips=chips,
    )


def top_contributors(
    hlo: str, mode: str = "bytes", limit: int | None = None
) -> list[tuple[float, str, str]]:
    """Trip-scaled per-op contributors, largest first.

    ``mode``: ``"bytes"`` (HBM traffic of top-level ops), ``"flops"``
    (dot/convolution FLOPs), or ``"coll"`` (collective payload bytes).
    Returns ``(value, op_kind, hlo_line)`` tuples — the drill-down view
    behind ``scripts/hlo_top.py``, sharing :func:`call_multipliers` with
    :func:`analyze_hlo` so both always agree on loop trip scaling.
    """
    if mode not in ("bytes", "flops", "coll"):
        raise ValueError(f"unknown mode {mode!r} (expected bytes|flops|coll)")
    comps = parse_computations(hlo)
    if "__entry__" not in comps:
        return []
    mult, fused = call_multipliers(comps)
    contrib: list[tuple[float, str, str]] = []
    for name, comp in comps.items():
        if name == "__entry__":
            continue
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        for op in comp.ops:
            if mode == "flops":
                if op.kind == "dot":
                    v = m * _dot_flops(comp, op)
                elif op.kind == "convolution":
                    v = m * _conv_flops(comp, op)
                else:
                    continue
            elif mode == "coll":
                base = op.kind[:-6] if op.kind.endswith("-start") else op.kind
                if base not in _COLLECTIVES or op.kind.endswith("-done"):
                    continue
                v = m * _all_shape_bytes(op.result_type)
            else:
                if fused.get(name, False) or op.kind in _BYTE_FREE:
                    continue
                v = m * _op_bytes(comp, op)
            if v > 0:
                contrib.append((v, op.kind, op.line))
    contrib.sort(key=lambda t: -t[0])
    return contrib[:limit] if limit is not None else contrib


# Back-compat aliases for the pre-public-API names.
_parse_computations = parse_computations
_callees = callees
_trip_count = trip_count
