"""TPU v5e hardware constants for the roofline model (per chip)."""

PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # bytes/s
ICI_BW_PER_LINK = 50e9  # bytes/s per link (~ per-chip effective)
HBM_BYTES = 16 * 1024**3  # 16 GiB
