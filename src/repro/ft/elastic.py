"""Elastic membership: add/remove replicas at runtime.

The paper observes that the long-term scheduling solution only needs
recomputation "when the network parameters change" — that is exactly a
membership event. ``ElasticController`` owns the mapping from the fleet's
device specs to the router's long-term rate table and refreshes it (from
the cached semi-Markov solutions) on join/leave/failure.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core.network import DeviceSpec
from ..serving.router import Router

__all__ = ["ElasticController"]


@dataclasses.dataclass
class ElasticController:
    router: Router
    specs: list[list[DeviceSpec]]  # [G][R]
    xi_lim: float = 0.01

    def refresh(self) -> list[np.ndarray]:
        """Recompute Eq.-(6) numerators for the current membership."""
        rates = [
            np.array([d.rate_limits(self.xi_lim).q_lim for d in group])
            for group in self.specs
        ]
        self.router.on_membership_change(rates)
        return rates

    def join(self, group: int, spec: DeviceSpec) -> np.ndarray:
        self.specs[group] = list(self.specs[group]) + [spec]
        return self.refresh()

    def leave(self, group: int, index: int) -> np.ndarray:
        group_specs = list(self.specs[group])
        group_specs.pop(index)
        self.specs[group] = group_specs
        return self.refresh()
