"""Elastic membership: add/remove replicas at runtime.

The paper observes that the long-term scheduling solution only needs
recomputation "when the network parameters change" — that is exactly a
membership event. ``ElasticController`` owns the mapping from the fleet's
device specs to the router's long-term rate table and refreshes it (from
the cached semi-Markov solutions) on join/leave/failure.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core.network import DeviceSpec
from ..serving.router import Router

__all__ = ["ElasticController"]


@dataclasses.dataclass
class ElasticController:
    router: Router
    specs: list[list[DeviceSpec]]  # [G][R]
    xi_lim: float = 0.01
    # Liveness overlay for fixed-width fleets (the serving engine's G x R
    # grid): a dead member keeps its index — its long-term rate is zeroed
    # so the router immediately stops sending it mass — and rejoining
    # restores the spec-derived rate. join/leave below still resize the
    # membership for genuinely elastic fleets.
    live: list[list[bool]] = dataclasses.field(default_factory=list)

    def refresh(self) -> list[np.ndarray]:
        """Recompute Eq.-(6) numerators for the current membership."""
        if len(self.live) != len(self.specs) or any(
            len(lv) != len(grp) for lv, grp in zip(self.live, self.specs)
        ):
            self.live = [[True] * len(grp) for grp in self.specs]
        rates = [
            np.array(
                [
                    d.rate_limits(self.xi_lim).q_lim if ok else 0.0
                    for d, ok in zip(group, self.live[g])
                ]
            )
            for g, group in enumerate(self.specs)
        ]
        self.router.on_membership_change(rates)
        return rates

    def fail(self, group: int, index: int) -> list[np.ndarray]:
        """Membership-leave for a fixed grid slot (process death)."""
        if not self.live:
            self.live = [[True] * len(grp) for grp in self.specs]
        self.live[group][index] = False
        return self.refresh()

    def rejoin(self, group: int, index: int) -> list[np.ndarray]:
        """The grid slot's process is back (respawn / recovery)."""
        if not self.live:
            self.live = [[True] * len(grp) for grp in self.specs]
        self.live[group][index] = True
        return self.refresh()

    def join(self, group: int, spec: DeviceSpec) -> np.ndarray:
        self.specs[group] = list(self.specs[group]) + [spec]
        self.live = []
        return self.refresh()

    def leave(self, group: int, index: int) -> np.ndarray:
        group_specs = list(self.specs[group])
        group_specs.pop(index)
        self.specs[group] = group_specs
        self.live = []
        return self.refresh()
