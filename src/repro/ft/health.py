"""Replica health: heartbeats, failure detection, straggler mitigation.

Maps cleanly onto the paper's model: a dead node is a node whose budget
is drained (power-save with no recovery); a straggler is a node stuck in
the critical power mode PM1 — exactly the set Algorithm 1's adaptive
policy down-weights. ``HedgePolicy`` adds the classic tail-latency
mitigation: if a stage call exceeds the trailing p-quantile latency,
issue a backup call on a sibling replica and take the first result.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque

__all__ = ["HeartbeatMonitor", "ProcessMonitor", "HedgePolicy"]


@dataclasses.dataclass
class HeartbeatMonitor:
    """Marks replicas dead when heartbeats go stale."""

    timeout: float = 3.0  # seconds (or slots, in simulated time)
    _last: dict = dataclasses.field(default_factory=dict)

    def beat(self, replica_id, now: float | None = None) -> None:
        self._last[replica_id] = time.monotonic() if now is None else now

    def dead(self, now: float | None = None) -> set:
        now = time.monotonic() if now is None else now
        return {
            rid for rid, t in self._last.items() if now - t > self.timeout
        }

    def alive(self, replica_id, now: float | None = None) -> bool:
        now = time.monotonic() if now is None else now
        t = self._last.get(replica_id)
        return t is not None and now - t <= self.timeout


@dataclasses.dataclass
class ProcessMonitor:
    """Liveness of *real* worker processes (multi-process serving).

    ``register`` a popen-like object (anything with ``.poll()``) per
    replica key; ``poll`` returns keys whose process has exited since
    the last sweep, each reported exactly once — the engine turns these
    into ``fail_replica`` membership-leave events. Successful RPC
    responses ``beat`` the embedded :class:`HeartbeatMonitor`, so a
    hung-but-running worker (stale heartbeat) is detectable separately
    from a dead one (process exit).
    """

    heartbeats: HeartbeatMonitor = dataclasses.field(
        default_factory=lambda: HeartbeatMonitor(timeout=60.0)
    )
    _procs: dict = dataclasses.field(default_factory=dict)
    _reported: set = dataclasses.field(default_factory=set)

    def register(self, key, proc) -> None:
        self._procs[key] = proc
        self._reported.discard(key)
        self.heartbeats.beat(key)

    def forget(self, key) -> None:
        self._procs.pop(key, None)
        self._reported.discard(key)

    def beat(self, key) -> None:
        self.heartbeats.beat(key)

    def alive(self, key) -> bool:
        proc = self._procs.get(key)
        return proc is not None and proc.poll() is None

    def poll(self) -> list:
        """Keys whose process has exited, newly dead since the last sweep."""
        dead = []
        for key, proc in self._procs.items():
            if key not in self._reported and proc.poll() is not None:
                dead.append(key)
                self._reported.add(key)
        return dead


@dataclasses.dataclass
class HedgePolicy:
    """Hedged-request straggler mitigation over a trailing latency window."""

    quantile: float = 0.95
    window: int = 128
    min_samples: int = 8
    _lat: deque = dataclasses.field(default_factory=lambda: deque(maxlen=128))

    def record(self, latency: float) -> None:
        self._lat.append(latency)

    def threshold(self) -> float | None:
        if len(self._lat) < self.min_samples:
            return None
        xs = sorted(self._lat)
        idx = min(int(self.quantile * len(xs)), len(xs) - 1)
        return xs[idx]

    def should_hedge(self, elapsed: float) -> bool:
        thr = self.threshold()
        return thr is not None and elapsed > thr
