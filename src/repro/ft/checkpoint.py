"""Sharded, atomic, manifest-driven checkpointing.

Layout::

    <dir>/step_<N>/
        manifest.json       # step, leaf paths, shapes, dtypes, tree hash
        leaf_000000.npy ... # one file per pytree leaf (process-local shard)

Writes go to ``<dir>/.tmp_step_<N>`` and are atomically renamed — a
crashed writer never corrupts the latest checkpoint. ``restore`` places
leaves with the provided shardings (multi-host: each process restores its
shard; on CPU it degenerates to plain device_put). Retention keeps the
newest ``keep`` checkpoints.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil

import jax
import ml_dtypes
import numpy as np


def _resolve_dtype(name: str) -> np.dtype:
    """Dtype from manifest string, covering ml_dtypes (bfloat16, fp8...)."""
    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(ml_dtypes, name))

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step", "list_steps"]

_MANIFEST = "manifest.json"


def _leaf_paths(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in leaves], treedef


def _tree_signature(names: list[str]) -> str:
    return hashlib.sha256("\n".join(names).encode()).hexdigest()[:16]


def save_checkpoint(directory: str, step: int, tree, *, keep: int = 3) -> str:
    """Atomically persist ``tree`` at ``step``. Returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = os.path.join(directory, f".tmp_step_{step:010d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    named, _ = _leaf_paths(tree)
    manifest = {
        "step": step,
        "signature": _tree_signature([n for n, _ in named]),
        "leaves": [],
    }
    for i, (name, leaf) in enumerate(named):
        arr = np.asarray(leaf)
        fname = f"leaf_{i:06d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"name": name, "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)

    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic publish
    _apply_retention(directory, keep)
    return final


def list_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and os.path.isfile(
            os.path.join(directory, name, _MANIFEST)
        ):
            steps.append(int(name.split("_")[1]))
    return sorted(steps)


def latest_step(directory: str) -> int | None:
    steps = list_steps(directory)
    return steps[-1] if steps else None


def restore_checkpoint(directory: str, tree_like, step: int | None = None,
                       shardings=None):
    """Restore into the structure of ``tree_like``.

    ``shardings``: optional matching tree of ``jax.sharding.Sharding`` —
    leaves are placed directly into their distributed layout.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)

    named, treedef = _leaf_paths(tree_like)
    names = [n for n, _ in named]
    if manifest["signature"] != _tree_signature(names):
        raise ValueError(
            "checkpoint tree structure does not match the target structure"
        )
    shard_leaves = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None else None
    )
    leaves = []
    for i, (entry, (name, like)) in enumerate(zip(manifest["leaves"], named)):
        arr = np.load(os.path.join(path, entry["file"]))
        if arr.dtype.kind == "V":  # ml_dtypes round-trip (e.g. bfloat16)
            arr = arr.view(_resolve_dtype(entry["dtype"]))
        if shard_leaves is not None:
            leaves.append(jax.device_put(arr, shard_leaves[i]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return treedef.unflatten(leaves), step


def _apply_retention(directory: str, keep: int) -> None:
    steps = list_steps(directory)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:010d}"), ignore_errors=True)
