from .checkpoint import latest_step, list_steps, restore_checkpoint, save_checkpoint
from .elastic import ElasticController
from .health import HeartbeatMonitor, HedgePolicy, ProcessMonitor

__all__ = [
    "latest_step",
    "list_steps",
    "restore_checkpoint",
    "save_checkpoint",
    "ElasticController",
    "HeartbeatMonitor",
    "HedgePolicy",
    "ProcessMonitor",
]
