"""granite-moe-1b-a400m [moe] — hf:ibm-granite/granite-3.0-1b-a400m-base.

24L d_model=1024 16H (GQA kv=8) per-expert d_ff=512 vocab=49155,
MoE 32 experts top-8.
"""

import dataclasses

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    act="swiglu",
    rope_theta=10_000.0,
    n_experts=32,
    moe_top_k=8,
    d_ff_expert=512,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="granite-moe-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=32,
        vocab_size=256,
        n_experts=4,
        moe_top_k=2,
        d_ff_expert=32,
    )
