"""qwen2.5-14b [dense] — hf:Qwen/Qwen2.5-14B (hf tier).

48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064 — GQA, QKV bias.
"""

import dataclasses

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=152064,
    act="swiglu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="qwen2.5-smoke",
        n_layers=2,
        d_model=80,
        n_heads=5,
        n_kv_heads=1,
        d_ff=160,
        vocab_size=256,
    )
