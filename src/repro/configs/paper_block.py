"""The paper's Sec. V case-study block (for the energy benchmarks).

"an LLM block with 100 encoder layers and 100 decoder layers, each
employing 100 attention heads", evaluated on inputs of size
(64 x 16 x 512) on a Jetson AGX Orin. We model it as an enc-dec
transformer with d_model=512 (matching the input width) and 100 heads.

This config exists so the energy/scheduling benchmarks are tied to a
concrete model whose per-power-mode (time, energy) measurements the
paper reports; the framework can also lower it like any other arch.
"""

import dataclasses

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="paper-block",
    family="audio",
    n_layers=100,
    encoder_layers=100,
    d_model=512,
    n_heads=100,  # 100 heads; head_dim padded via explicit d_head
    n_kv_heads=100,
    d_head=8,
    d_ff=2048,
    vocab_size=32000,
    act="gelu",
    frontend="frames",
    frontend_dim=512,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="paper-block-smoke",
        n_layers=2,
        encoder_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_head=16,
        d_ff=128,
        vocab_size=256,
        frontend_dim=64,
    )
