"""qwen3-moe-30b-a3b [moe] — hf:Qwen/Qwen3-30B-A3B (hf tier).

48L d_model=2048 32H (GQA kv=4) per-expert d_ff=768 vocab=151936,
MoE 128 experts top-8; head_dim=128 with q/k norm (qwen3 style).
"""

import dataclasses

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_head=128,
    d_ff=768,  # per-expert width (kept for reference)
    vocab_size=151936,
    act="swiglu",
    qk_norm=True,
    rope_theta=1_000_000.0,
    n_experts=128,
    moe_top_k=8,
    d_ff_expert=768,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="qwen3-moe-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=32,
        vocab_size=256,
        n_experts=8,
        moe_top_k=2,
        d_ff_expert=32,
    )
