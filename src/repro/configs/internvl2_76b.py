"""internvl2-76b [vlm] — arXiv:2404.16821 (unverified tier).

LLM backbone (Llama-3-70B-class): 80L d_model=8192 64H (GQA kv=8)
d_ff=28672 vocab=128256. The InternViT frontend is a STUB: input_specs
provides precomputed patch embeddings (width 3200 = InternViT-6B hidden)
projected into the backbone and occupying the first ``n_frontend_tokens``
sequence positions.
"""

import dataclasses

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    act="swiglu",
    rope_theta=500_000.0,
    frontend="patches",
    frontend_dim=3200,
    n_frontend_tokens=1024,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="internvl2-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        frontend_dim=48,
        n_frontend_tokens=4,
    )
