"""Assigned-architecture configs + input-shape cells.

``get_config(name)`` returns the exact published config; every module
also exposes ``smoke()`` — a reduced same-family config for CPU tests.

Shape cells (assigned to every LM arch):
  * ``train_4k``    seq 4096,   global batch 256  (train_step)
  * ``prefill_32k`` seq 32768,  global batch 32   (serve prefill)
  * ``decode_32k``  KV 32768,   global batch 128  (serve decode, 1 token)
  * ``long_500k``   KV 524288,  global batch 1    (sub-quadratic archs only)
"""

from __future__ import annotations

import dataclasses
import importlib

from ..models.common import ModelConfig

__all__ = ["ARCH_NAMES", "SHAPES", "ShapeCell", "get_config", "get_smoke_config", "cells_for"]

ARCH_NAMES = (
    "stablelm-1.6b",
    "phi4-mini-3.8b",
    "qwen2.5-14b",
    "granite-20b",
    "seamless-m4t-large-v2",
    "qwen3-moe-30b-a3b",
    "granite-moe-1b-a400m",
    "hymba-1.5b",
    "falcon-mamba-7b",
    "internvl2-76b",
)

_MODULES = {
    "stablelm-1.6b": "stablelm_1_6b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "qwen2.5-14b": "qwen2_5_14b",
    "granite-20b": "granite_20b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "hymba-1.5b": "hymba_1_5b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "internvl2-76b": "internvl2_76b",
    "paper-block": "paper_block",
}


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown architecture {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f".{_MODULES[name]}", __package__)


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).smoke()


def is_subquadratic(cfg: ModelConfig) -> bool:
    """Can the arch serve 500k-token contexts (SSM / sliding-window)?"""
    if cfg.block == "mamba":
        return True
    if cfg.block == "hymba":
        # Windowed layers are O(w); the few global layers hold the long
        # KV at batch 1 — feasible (see DESIGN.md Sec. 5).
        return cfg.attn_window is not None
    return cfg.attn_window is not None


def cells_for(name: str) -> list[str]:
    """Runnable shape cells for an arch (documented skips excluded)."""
    cfg = get_config(name)
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if is_subquadratic(cfg):
        cells.append("long_500k")
    return cells
