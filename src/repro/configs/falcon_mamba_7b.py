"""falcon-mamba-7b [ssm] — arXiv:2410.05355 (unverified tier).

64L d_model=4096, attention-free Mamba-1 blocks, vocab=65024,
ssm_state=16, expand=2 (d_inner=8192). Sub-quadratic => runs long_500k.
"""

import dataclasses

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,  # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab_size=65024,
    block="mamba",
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="falcon-mamba-smoke",
        n_layers=2,
        d_model=64,
        vocab_size=256,
        ssm_state=8,
    )
