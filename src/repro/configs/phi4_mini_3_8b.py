"""phi4-mini-3.8b [dense] — arXiv:2412.08905 (hf tier).

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064 — RoPE SwiGLU GQA.
"""

import dataclasses

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=200064,
    act="swiglu",
    rope_theta=10_000.0,
    tie_embeddings=True,  # phi-4-mini ties input/output embeddings
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="phi4-mini-smoke",
        n_layers=2,
        d_model=96,
        n_heads=6,
        n_kv_heads=2,
        d_ff=192,
        vocab_size=256,
    )
