"""granite-20b [dense] — arXiv:2405.04324 (hf tier).

52L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152 — llama-arch, code.
"""

import dataclasses

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    act="gelu",  # granite-20b-code uses gpt-bigcode-style MLP
    rope_theta=10_000.0,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="granite-20b-smoke",
        n_layers=2,
        d_model=96,
        n_heads=6,
        n_kv_heads=1,
        d_ff=192,
        vocab_size=256,
    )
