"""seamless-m4t-large-v2 [audio] — arXiv:2308.11596 (hf tier).

Enc-dec: 24 encoder + 24 decoder layers, d_model=1024 16H (kv=16)
d_ff=8192 vocab=256206. The speech frontend is a STUB: the encoder
consumes precomputed frame embeddings (input_specs provides them).
"""

import dataclasses

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,  # decoder layers
    encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    act="gelu",
    tie_embeddings=True,  # shared text embedding/output projection
    frontend="frames",
    frontend_dim=1024,  # stub: precomputed speech-frame embedding width
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="seamless-smoke",
        n_layers=2,
        encoder_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        frontend_dim=32,
    )
