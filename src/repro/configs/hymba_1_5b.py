"""hymba-1.5b [hybrid] — arXiv:2411.13676 (hf tier).

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16 —
parallel attention + mamba heads per layer; sliding-window attention
(window 1024) everywhere except 3 global layers {0, 15, 31}, following
the Hymba paper's SWA+global layout. Sub-quadratic => runs long_500k.
"""

import dataclasses

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    act="swiglu",
    rope_theta=10_000.0,
    block="hymba",
    attn_window=1024,
    global_attn_layers=(0, 15, 31),
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="hymba-smoke",
        n_layers=4,
        d_model=64,
        n_heads=5,
        n_kv_heads=1,
        d_ff=128,
        vocab_size=256,
        attn_window=16,
        global_attn_layers=(0, 3),
        ssm_state=8,
    )
