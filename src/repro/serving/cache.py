"""KV-cache managers: one accounting abstraction over dense and paged.

The serving control plane (:mod:`.scheduler`) never branches on cache
layout. Every (group, replica) owns one :class:`KVCacheManager` that
answers the same five questions — can this context ever fit? can it be
reserved now? grow it? release it? how much headroom is left for the
router? — and the engine keeps a single admission / failover /
preemption / queueing implementation on top.

Two implementations:

* :class:`DenseSlotCache` — the slot-stacked layout: ``max_batch``
  per-request slots, each implicitly reserving a full ``max_len``
  context. It is the one-page-per-slot special case of paging: the slot
  *is* the reservation, so ``try_extend`` never fails and preemption
  never triggers.
* :class:`PagedKVCache` — a :class:`PagePool` of fixed-size pages plus
  the per-slot block tables that name them. Reservations are
  ``ceil(context / page_size)`` pages, growth can fail (the scheduler
  then preempts the youngest resident), and the router weight is free
  pages instead of free slots.

The device-side arrays (the stacked KV cache / the page pool tensors)
stay in the engine — managers are pure host accounting, which is what
makes them cheap to fuzz (``tests/test_paged_cache.py``).

Invariants (fuzz-tested):

* conservation — ``free + allocated == capacity`` always;
* exclusivity — a page/slot has at most one owner; double-free and
  foreign-free raise instead of corrupting the pool.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "PageError",
    "PagePool",
    "KVCacheManager",
    "DenseSlotCache",
    "PagedKVCache",
    "kv_page_bytes",
]


def kv_page_bytes(
    page_size: int,
    n_kv_heads: int,
    head_dim: int,
    n_layers: int,
    kv_dtype: str = "float32",
) -> int:
    """Bytes one page costs across the K and V pools of every layer.

    The dtype-aware page math behind ``max_pages`` sizing: int8 pages
    carry one fp32 scale per page row per pool (quantized at scatter),
    so an int8 page costs ``page_size * (n_kv_heads * head_dim + 4)``
    bytes per pool per layer instead of fp32's
    ``page_size * n_kv_heads * head_dim * 4`` — ~4x more pages in the
    same byte budget at fp32 compute (~2x at bf16). Benchmarks use this
    to hold KV bytes equal across dtypes
    (``benchmarks/quant_kv_bench.py``).
    """
    itemsize = np.dtype(kv_dtype).itemsize
    per_pool = page_size * n_kv_heads * head_dim * itemsize
    if np.dtype(kv_dtype) == np.dtype(np.int8):
        per_pool += page_size * 4  # fp32 per-row scale
    return 2 * n_layers * per_pool


class PageError(RuntimeError):
    """Pool accounting violation (double free / foreign free / overdraw)."""


@dataclasses.dataclass
class PagePool:
    """Fixed-size page allocator for one replica's KV pool.

    Pages are plain indices into the device pool arrays; index
    ``n_pages`` (one past the end) is the reserved scratch page and is
    never handed out.
    """

    n_pages: int
    page_size: int

    def __post_init__(self) -> None:
        if self.n_pages <= 0 or self.page_size <= 0:
            raise ValueError("need n_pages > 0 and page_size > 0")
        # LIFO free list: lowest indices first so allocation order is
        # deterministic (seed-reproducible serving runs).
        self._free: list[int] = list(range(self.n_pages - 1, -1, -1))
        self._owner: dict[int, int] = {}  # page -> rid

    @property
    def scratch(self) -> int:
        return self.n_pages

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return len(self._owner)

    def blocks_for(self, length: int) -> int:
        """Pages needed to hold ``length`` cache entries (min 1)."""
        return max(1, -(-int(length) // self.page_size))

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int, rid: int) -> list[int]:
        if n > len(self._free):
            raise PageError(
                f"pool overdraw: want {n}, have {len(self._free)} free"
            )
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._owner[p] = rid
        return pages

    def free(self, pages: list[int], rid: int) -> None:
        for p in pages:
            owner = self._owner.get(p)
            if owner is None:
                raise PageError(f"double free of page {p} (rid {rid})")
            if owner != rid:
                raise PageError(
                    f"foreign free of page {p}: owned by {owner}, freed by {rid}"
                )
            del self._owner[p]
            self._free.append(p)

    def check_conservation(self) -> None:
        """Raise unless free + allocated is exactly the pool, disjointly."""
        free = set(self._free)
        used = set(self._owner)
        if len(free) != len(self._free):
            raise PageError("free list contains duplicates")
        if free & used:
            raise PageError(f"pages both free and owned: {sorted(free & used)}")
        if free | used != set(range(self.n_pages)):
            missing = set(range(self.n_pages)) - (free | used)
            raise PageError(f"pages leaked: {sorted(missing)}")


class KVCacheManager:
    """Slot + memory accounting for one (group, replica)'s KV cache.

    ``lengths`` mirrors each slot's context length on the host so the
    control plane and the chunked-prefill offsets never sync a device
    scalar. All methods are host-side; implementations raise
    :class:`PageError` on accounting violations.
    """

    n_slots: int
    lengths: np.ndarray  # [n_slots] int64 host context lengths

    def __init__(self, n_slots: int):
        if n_slots <= 0:
            raise ValueError("need n_slots > 0")
        self.n_slots = n_slots
        self.slots: list[int | None] = [None] * n_slots  # rid per slot
        self.lengths = np.zeros(n_slots, np.int64)

    # -- capacity queries ------------------------------------------------
    def free_slots(self) -> int:
        return self.slots.count(None)

    def capacity_weight(self) -> int:
        """Router headroom weight (zero = full, attracts no new mass)."""
        raise NotImplementedError

    def fits(self, length: int) -> bool:
        """Could a ``length``-entry context EVER fit (empty replica)?"""
        raise NotImplementedError

    def can_reserve(self, length: int) -> bool:
        """Is a slot + memory for ``length`` entries available right now?"""
        raise NotImplementedError

    # -- lifecycle -------------------------------------------------------
    def reserve(self, rid: int, length: int) -> int:
        """Claim a slot plus memory covering ``length`` context entries.

        ``length == 0`` claims the slot only (failover re-placement: the
        memory is grown lazily at call time via :meth:`try_extend`).
        Returns the slot index.
        """
        raise NotImplementedError

    def try_extend(self, rid: int, slot: int, length: int) -> bool:
        """Grow ``rid``'s claim to cover ``length`` entries.

        False = out of memory right now — the scheduler preempts the
        youngest resident and retries (never happens for dense).
        """
        raise NotImplementedError

    def rollback(self, rid: int, slot: int, n: int) -> None:
        """Un-write the last ``n`` cache entries of ``rid``'s context
        (speculative decoding: rejected draft rows are rewound).

        Pure host accounting — the device rows stay physically written
        but become stale-beyond-length, which is safe because every
        consumer derives positions from the host ``lengths`` mirror and
        causal attention never reaches past it; the next call re-writes
        those positions before attending them. Dense: length decrement.
        Paged: length decrement plus freeing any tail pages the shorter
        context no longer needs (conservation-checked).
        """
        raise NotImplementedError

    def release(self, rid: int, slot: int | None) -> None:
        """Return the slot and every page/entry owned by ``rid``."""
        raise NotImplementedError

    # -- introspection ---------------------------------------------------
    def held(self, rid: int) -> int:
        """Memory units (pages / slots) currently owned by ``rid``."""
        raise NotImplementedError

    def check_conservation(self) -> None:
        """Raise unless free + allocated is exactly the capacity."""
        raise NotImplementedError

    # shared slot bookkeeping
    def _take_slot(self, rid: int) -> int:
        idx = self.slots.index(None)
        self.slots[idx] = rid
        self.lengths[idx] = 0
        return idx

    def _drop_slot(self, rid: int, slot: int | None) -> None:
        if slot is not None and self.slots[slot] == rid:
            self.slots[slot] = None
            self.lengths[slot] = 0


class DenseSlotCache(KVCacheManager):
    """The slot-stacked dense layout as a cache manager.

    Every slot implicitly reserves a ``max_len`` context (one page of
    ``max_len`` entries per slot), so memory can never run out
    mid-decode: ``try_extend`` only asserts the submit-time bound.
    """

    def __init__(self, n_slots: int, max_len: int):
        super().__init__(n_slots)
        self.max_len = max_len

    def capacity_weight(self) -> int:
        return self.free_slots()

    def fits(self, length: int) -> bool:
        return length <= self.max_len

    def can_reserve(self, length: int) -> bool:
        return length <= self.max_len and self.free_slots() > 0

    def reserve(self, rid: int, length: int) -> int:
        if not self.can_reserve(length):
            raise PageError(f"dense reserve of {length} entries refused")
        return self._take_slot(rid)

    def try_extend(self, rid: int, slot: int, length: int) -> bool:
        if length > self.max_len:
            raise PageError(
                f"rid {rid}: context {length} exceeds max_len {self.max_len} "
                "(submit should have rejected this request)"
            )
        return True

    def rollback(self, rid: int, slot: int, n: int) -> None:
        if self.slots[slot] != rid:
            raise PageError(f"rollback of slot {slot} not owned by rid {rid}")
        if n < 0 or n > self.lengths[slot]:
            raise PageError(
                f"rid {rid}: rollback of {n} entries from a "
                f"{self.lengths[slot]}-entry context"
            )
        self.lengths[slot] -= n

    def release(self, rid: int, slot: int | None) -> None:
        self._drop_slot(rid, slot)

    def held(self, rid: int) -> int:
        return sum(1 for r in self.slots if r == rid)

    def check_conservation(self) -> None:
        if self.free_slots() + sum(r is not None for r in self.slots) != self.n_slots:
            raise PageError("dense slot table corrupted")


class PagedKVCache(KVCacheManager):
    """Page-pool accounting plus the block tables that address it.

    Owns the host block table ``[n_slots, nb_max]`` (rows of physical
    page ids, scratch-padded) and a lazily refreshed device copy —
    rows change only on page alloc/free, never per decode call, so the
    hot loop reuses one device array.
    """

    def __init__(
        self, n_slots: int, max_len: int, page_size: int, n_pages: int,
        kv_dtype: str | None = None, table_buffers: int = 2,
    ):
        super().__init__(n_slots)
        self.max_len = max_len
        # Page dtype is recorded for introspection / page math only —
        # accounting is in pages, and a page holds page_size entries
        # regardless of how many bytes each entry costs.
        self.kv_dtype = kv_dtype
        self.pool = PagePool(n_pages, page_size)
        self.page_size = page_size
        self.nb_max = -(-max_len // page_size)  # block-table row width
        self.pages: dict[int, list[int]] = {}  # rid -> physical pages
        self.block_table = np.full((n_slots, self.nb_max), n_pages, np.int32)
        # Snapshot ring for the device copies. The working table above
        # mutates on every alloc/free; each device refresh snapshots it
        # into the next host buffer so a pending async dispatch that may
        # still be reading a zero-copied earlier snapshot is never
        # written through (the engine sizes this to its in-flight ring
        # depth + 1).
        if table_buffers < 2:
            raise ValueError("table_buffers must be >= 2 (double buffering)")
        self.table_buffers = table_buffers
        self._snapshots = [
            np.full((n_slots, self.nb_max), n_pages, np.int32)
            for _ in range(table_buffers)
        ]
        self._snap_idx = 0
        self._bt_dev = None  # device copy, invalidated on row change
        # Optional jax.sharding.Sharding: under a serving mesh the engine
        # points this at the owning replica's submesh so each refresh
        # commits the table next to the pool it addresses (otherwise
        # every paged dispatch would re-transfer it to the slice).
        self.sharding = None

    # -- capacity --------------------------------------------------------
    def capacity_weight(self) -> int:
        # A replica with no free slot is full regardless of free pages.
        return 0 if self.free_slots() == 0 else self.pool.free_pages

    def fits(self, length: int) -> bool:
        return (
            length <= self.max_len
            and self.pool.blocks_for(length) <= self.pool.n_pages
        )

    def can_reserve(self, length: int) -> bool:
        return (
            self.fits(length)
            and self.free_slots() > 0
            and self.pool.can_alloc(self.pool.blocks_for(length))
        )

    # -- lifecycle -------------------------------------------------------
    def reserve(self, rid: int, length: int) -> int:
        if length > 0 and not self.pool.can_alloc(self.pool.blocks_for(length)):
            raise PageError(f"paged reserve of {length} entries refused")
        slot = self._take_slot(rid)
        self.pages[rid] = (
            self.pool.alloc(self.pool.blocks_for(length), rid) if length > 0 else []
        )
        self._set_row(slot, self.pages[rid])
        return slot

    def try_extend(self, rid: int, slot: int, length: int) -> bool:
        held = self.pages.setdefault(rid, [])
        need = self.pool.blocks_for(length)
        if need > self.nb_max:
            raise PageError(
                f"rid {rid}: context {length} exceeds the block-table row "
                f"({self.nb_max} pages)"
            )
        grown = False
        while len(held) < need:
            if not self.pool.can_alloc(1):
                if grown:
                    self._set_row(slot, held)
                return False
            held.extend(self.pool.alloc(1, rid))
            grown = True
        if grown:
            self._set_row(slot, held)
        return True

    def rollback(self, rid: int, slot: int, n: int) -> None:
        if self.slots[slot] != rid:
            raise PageError(f"rollback of slot {slot} not owned by rid {rid}")
        if n < 0 or n > self.lengths[slot]:
            raise PageError(
                f"rid {rid}: rollback of {n} entries from a "
                f"{self.lengths[slot]}-entry context"
            )
        new_len = int(self.lengths[slot]) - n
        self.lengths[slot] = new_len
        if n == 0:
            return
        held = self.pages.get(rid, [])
        # A zero-length context keeps zero pages (mirrors reserve(0));
        # otherwise the tail pages the shorter context no longer touches
        # go back to the pool and the block-table row is re-scratched.
        need = self.pool.blocks_for(new_len) if new_len > 0 else 0
        if len(held) > need:
            tail = held[need:]
            del held[need:]
            self.pool.free(tail, rid)
            self._set_row(slot, held)

    def release(self, rid: int, slot: int | None) -> None:
        held = self.pages.pop(rid, [])
        if held:
            self.pool.free(held, rid)
        if slot is not None and self.slots[slot] == rid:
            # Freed lanes must never alias live pages: scratch the row.
            self._set_row(slot, [])
        self._drop_slot(rid, slot)

    # -- block tables ----------------------------------------------------
    def _set_row(self, slot: int, pages: list[int]) -> None:
        row = self.block_table[slot]
        row[:] = self.pool.scratch
        row[: len(pages)] = pages
        self._bt_dev = None

    def device_block_table(self):
        """Cached device block table; refreshed only on page alloc/free.

        Each refresh rotates to the next snapshot buffer before copying
        the working table, so an in-flight dispatch holding the previous
        device array never sees its backing host buffer mutate."""
        if self._bt_dev is None:
            import jax
            import jax.numpy as jnp

            self._snap_idx = (self._snap_idx + 1) % self.table_buffers
            buf = self._snapshots[self._snap_idx]
            np.copyto(buf, self.block_table)
            if self.sharding is not None:
                self._bt_dev = jax.device_put(buf, self.sharding)
            else:
                self._bt_dev = jnp.asarray(buf)
        return self._bt_dev

    # -- introspection ---------------------------------------------------
    def held(self, rid: int) -> int:
        return len(self.pages.get(rid, ()))

    def check_conservation(self) -> None:
        self.pool.check_conservation()
        held = [p for pages in self.pages.values() for p in pages]
        if len(held) != len(set(held)):
            raise PageError("page owned by two requests")
        if self.pool.used_pages != len(held):
            raise PageError(
                f"pool accounts {self.pool.used_pages} pages but managers "
                f"hold {len(held)}"
            )
        # Working block table rows must name exactly the pages their
        # slot's rid holds (scratch-padded), and the snapshot backing
        # the live device copy must match the working table — a stale
        # live snapshot would let a *future* dispatch read freed pages.
        for slot, rid in enumerate(self.slots):
            row = self.block_table[slot]
            pages = self.pages.get(rid, []) if rid is not None else []
            if list(row[: len(pages)]) != pages or not (
                row[len(pages):] == self.pool.scratch
            ).all():
                raise PageError(
                    f"block-table row {slot} does not match rid {rid}'s pages"
                )
        if self._bt_dev is not None:
            live = self._snapshots[self._snap_idx]
            if not np.array_equal(live, self.block_table):
                raise PageError(
                    "live device block-table snapshot is stale "
                    "(working table changed without invalidation)"
                )
