"""Petals-style pipeline partitioning: split a decoder-only model into G
contiguous layer groups (stages). Each group is itself a full ``Model``
whose first/last stages keep the embedding/unembedding; middle stages
exchange hidden states — exactly the paper's "groups of devices, identical
portions of the LLM layers replicated within a group".
"""

from __future__ import annotations

import dataclasses

import jax

from ..models.common import ModelConfig
from ..models.registry import Model, build_model
from ..models.transformer import layer_plan

__all__ = ["stage_configs", "slice_stage_params", "partition_model"]


def _stage_ranges(n_layers: int, n_stages: int) -> list[tuple[int, int]]:
    base, rem = divmod(n_layers, n_stages)
    ranges = []
    start = 0
    for g in range(n_stages):
        size = base + (1 if g < rem else 0)
        ranges.append((start, start + size))
        start += size
    return ranges


def stage_configs(cfg: ModelConfig, n_stages: int) -> list[ModelConfig]:
    """Per-stage configs with remapped window-class layer ids."""
    if cfg.is_encdec:
        raise NotImplementedError("pipeline partitioning targets decoder-only archs")
    out = []
    for g, (start, end) in enumerate(_stage_ranges(cfg.n_layers, n_stages)):
        globals_in_range = tuple(
            l - start for l in cfg.global_attn_layers if start <= l < end
        )
        out.append(
            dataclasses.replace(
                cfg,
                name=f"{cfg.name}/stage{g}",
                n_layers=end - start,
                global_attn_layers=globals_in_range,
                stage_embed=(g == 0),
                stage_unembed=(g == n_stages - 1),
                tie_embeddings=cfg.tie_embeddings,
            )
        )
    return out


def slice_stage_params(cfg: ModelConfig, params, n_stages: int) -> list:
    """Slice the full model's parameters into per-stage trees.

    Class stacks are sliced along the leading layer axis; the embedding
    goes to stage 0 (and, when tied, to the last stage too), final norm /
    lm_head to the last stage.
    """
    full_plan = layer_plan(cfg)
    stage_cfgs = stage_configs(cfg, n_stages)
    ranges = _stage_ranges(cfg.n_layers, n_stages)
    out = []
    for g, ((start, end), s_cfg) in enumerate(zip(ranges, stage_cfgs)):
        s_plan = layer_plan(s_cfg)
        classes = {}
        for si, s_cls in enumerate(s_plan.classes):
            # Find the matching full-model class (same window).
            fi = next(
                i for i, c in enumerate(full_plan.classes) if c.window == s_cls.window
            )
            f_cls = full_plan.classes[fi]
            # Positions of this stage's layers inside the full class stack.
            keep = [
                pos
                for pos, l in enumerate(f_cls.layer_ids)
                if start <= l < end
            ]
            lo, hi = keep[0], keep[-1] + 1
            assert keep == list(range(lo, hi)), "class rows must be contiguous"
            classes[f"c{si}"] = jax.tree_util.tree_map(
                lambda a: a[lo:hi], params["classes"][f"c{fi}"]
            )
        tree = {"classes": classes}
        emb: dict = {}
        if s_cfg.stage_embed or (s_cfg.stage_unembed and s_cfg.tie_embeddings):
            emb["tok"] = params["embed"]["tok"]
        if s_cfg.stage_unembed and not s_cfg.tie_embeddings:
            emb["lm_head"] = params["embed"]["lm_head"]
        if emb:
            tree["embed"] = emb
        if s_cfg.stage_unembed:
            tree["final_norm"] = params["final_norm"]
        if s_cfg.stage_embed and cfg.frontend == "patches":
            tree["vision_proj"] = params["vision_proj"]
        out.append(tree)
    return out


def partition_model(
    cfg: ModelConfig, params, n_stages: int
) -> list[tuple[Model, dict]]:
    """(stage model, stage params) per pipeline group."""
    cfgs = stage_configs(cfg, n_stages)
    trees = slice_stage_params(cfg, params, n_stages)
    return [(build_model(c), p) for c, p in zip(cfgs, trees)]
