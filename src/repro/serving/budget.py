"""Replica budget state — the paper's battery/energy model in production.

Each replica carries a replenishable budget (paper: battery kJ; fleet:
power-cap credits / thermal headroom). The hysteresis power-save flag and
the PM lookup reuse :mod:`repro.core.power` verbatim; the serving engine
charges ``CE(PM)/kappa`` per slot of stage work exactly like the
simulator, so the semi-Markov analysis (q_lim, long-term rates) applies
unchanged to the serving fleet.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core.power import PowerModePolicy

__all__ = ["ReplicaBudget"]


@dataclasses.dataclass
class ReplicaBudget:
    policy: PowerModePolicy
    e_max: float = 100.0
    e_th: float = 10.0
    e_th_hi: float = 25.0
    level: float | None = None  # None -> full
    active: bool = True
    alive: bool = True  # False = failed node (budget semantics: drained)

    def __post_init__(self) -> None:
        # Mirrors the SimConfig / DeviceModel hysteresis validation.
        if not (0 <= self.e_th < self.e_th_hi <= self.e_max):
            raise ValueError("need 0 <= e_th < e_th_hi <= e_max (hysteresis)")
        if self.level is None:
            self.level = self.e_max
        if not (0 <= self.level <= self.e_max):
            raise ValueError("need 0 <= level <= e_max")

    @property
    def pm(self) -> int:
        return int(self.policy.pm_for_energy(self.level))

    @property
    def available(self) -> bool:
        return self.alive and self.active

    def harvest(self, units: float) -> None:
        self.level = min(self.level + units, self.e_max)
        self._hysteresis()

    def charge(self, units: float) -> None:
        self.level = max(self.level - units, 0.0)
        self._hysteresis()

    def can_start(self) -> bool:
        """Energy gate (paper: CE(PM) <= E)."""
        return self.available and self.level >= self.policy.mode(self.pm).ce

    def fail(self) -> None:
        self.alive = False
        self.active = False

    def recover(self, level: float | None = None) -> None:
        self.alive = True
        target = self.e_th_hi + 1 if level is None else level
        self.level = min(max(float(target), 0.0), self.e_max)
        self._hysteresis()

    def _hysteresis(self) -> None:
        if not self.alive:
            self.active = False
            return
        if self.level < self.e_th:
            self.active = False
        elif self.level > self.e_th_hi:
            self.active = True
