"""Serving control plane: admission, queueing, failover, preemption.

:class:`StepScheduler` is the per-step decision maker extracted from the
engine so that *policy* (who runs where, who waits, who is evicted) is
written once against the :class:`~repro.serving.cache.KVCacheManager`
abstraction, while the engine keeps only *execution* (building inputs,
issuing the jitted calls, committing results). Both cache layouts —
dense slot-stacked and paged — and every driver (``PipelineServer.run``,
``benchmarks/serve_bench``, ``benchmarks/chunked_bench``) share this one
implementation.

Responsibilities:

* **Admission** (the paper's Alg. 1): route one replica per group via
  the energy-aware :class:`~repro.serving.router.Router`, reserve a slot
  + memory on each, or backpressure into the FIFO pending queue.
* **Queueing**: new arrivals never jump requests already waiting; a
  fully dead group drains the queue (nothing to wait for).
* **Failover re-placement**: an in-flight stage whose replica died is
  re-routed to a sibling (slot-only reservation, memory grows lazily at
  call time) or parked slotless and retried every slot. Parked requests
  re-place BEFORE queue admission so fresh traffic cannot starve them.
* **Starvation-free aging**: re-placement alone cannot help when live
  siblings stay saturated — sustained traffic refills every freed slot
  and a parked victim waits forever. Each parked slot-step increments
  ``Request.park_steps``; past ``max_park_steps`` the scheduler stops
  waiting and *force-places*: it preempts the youngest resident of the
  best live sibling (requeued loss-free, like page-exhaustion
  preemption) and hands the freed slot to the victim.
* **Preemption**: when a paged replica runs out of pages mid-step, the
  youngest resident not in a call is evicted fleet-wide and requeued;
  its prompt + generated tokens re-prefill on re-admission, so
  preemption loses work, not tokens. Dense reservations cannot run out
  (``try_extend`` always succeeds), so the same code path simply never
  preempts.
* **Energy gating**: a replica only opens a call when its budget clears
  ``ReplicaBudget.can_start`` (paper: CE(PM) <= E).
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Any

import numpy as np

from .budget import ReplicaBudget
from .cache import KVCacheManager
from .router import RouteError, Router

__all__ = ["Request", "StepScheduler"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # immutable prompt [S] — never mutated after submit
    n_tokens: int  # tokens to generate
    # runtime state
    stage: int = 0
    replicas: list[int] | None = None  # designated replica per group
    slot_ids: list[int] | None = None  # batch slot per group
    cache_ready: list[bool] | None = None  # per-group: slot cache prefilled
    chunk_pos: int = 0  # chunked prefill: tokens consumed at the current stage
    chunk_outs: list = dataclasses.field(default_factory=list)  # per-chunk hidden
    chunk_seq: Any = None  # cached stage input for the in-progress prefill
    generated: list[int] = dataclasses.field(default_factory=list)
    hidden: Any = None  # inter-stage activation
    # Speculative decoding round state (engine-managed). ``spec_drafts``
    # holds the host copies of the round's draft tokens once the stage-0
    # call commits; ``spec_adv[g]`` counts the KV rows stage ``g``
    # optimistically wrote for the round still in flight (rewound by the
    # accept finalizer, or by :meth:`StepScheduler.rewind_spec` when the
    # round aborts before its final-stage commit).
    spec_drafts: list[int] | None = None
    spec_adv: list[int] | None = None
    in_call: bool = False  # member of the current stage call
    park_steps: int = 0  # consecutive slots parked slotless (aging)
    queued: bool = False  # waiting for admission (backpressure)
    done: bool = False
    dropped: bool = False
    t_submit: float = 0.0  # wall clock at submit (TTFT accounting)
    t_first_token: float | None = None  # wall clock of the first generated token
    submit_slot: int = 0  # engine slot counter at submit
    slot_first_token: int | None = None  # slot the first token's call completed

    @property
    def ttft(self) -> float | None:
        """Wall-clock time-to-first-token, once the first token lands.

        Stamped at dispatch-observable time — the moment the producing
        call's device slots complete — not when the async completion
        queue drains it, so a deep in-flight ring cannot inflate TTFT.
        """
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.t_submit

    @property
    def ttft_slots(self) -> int | None:
        """TTFT in whole engine slots (deterministic, wall-clock-free):
        slots elapsed from submit until the call that produced the first
        token completed its device work."""
        if self.slot_first_token is None:
            return None
        return self.slot_first_token - self.submit_slot

    def context_len(self) -> int:
        """Current full context: prompt plus every generated token."""
        return len(self.prompt) + len(self.generated)


class StepScheduler:
    """Shared per-step control plane over :class:`KVCacheManager`.

    Owns the resident set (``active``), the FIFO backpressure queue
    (``pending``) and the router; mutates only host accounting and the
    shared ``stats`` object — never device state.
    """

    def __init__(
        self,
        *,
        budgets: list[list[ReplicaBudget]],
        managers: dict[tuple[int, int], KVCacheManager],
        router: Router,
        stats,
        max_queue: int | None = None,
        max_park_steps: int | None = 32,
    ):
        self.budgets = budgets
        self.managers = managers
        self.router = router
        self.stats = stats
        self.max_queue = max_queue
        self.max_park_steps = max_park_steps
        self.G = len(budgets)
        self.R = len(budgets[0]) if budgets else 0
        self.active: list[Request] = []
        self.pending: collections.deque[Request] = collections.deque()
        # Optional supplier of per-(group, replica) in-flight ring depths
        # (wired by the engine); routing de-weights replicas with deeper
        # completion queues so admissions spread across the ring.
        self.inflight = None

    # ------------------------------------------------------------------
    # Capacity / gating
    # ------------------------------------------------------------------
    def free_counts(self) -> list[list[int]]:
        """Router headroom weights per (group, replica)."""
        return [
            [self.managers[(g, r)].capacity_weight() for r in range(self.R)]
            for g in range(self.G)
        ]

    def fits(self, length: int) -> bool:
        """Could a ``length`` context ever fit a replica's cache?"""
        return self.managers[(0, 0)].fits(length)

    def any_group_dead(self) -> bool:
        return any(not any(b.alive for b in group) for group in self.budgets)

    def can_start(self, g: int, r: int) -> bool:
        """Energy gate: power-saving / drained replicas hold their jobs."""
        b = self.budgets[g][r]
        return b.available and b.can_start()

    # ------------------------------------------------------------------
    # Admission & queueing
    # ------------------------------------------------------------------
    def submit(self, req: Request) -> Request | None:
        """Admit ``req`` (one replica + slot per group) or queue it.

        Returns None when the request is rejected outright: a final
        context that can never fit any replica, a fully dead group, or a
        full bounded queue.
        """
        final_ctx = len(req.prompt) + req.n_tokens
        if not self.fits(final_ctx):
            # The final context cannot fit a slot's cache / block-table
            # row / page pool, so the request can never complete: reject
            # up front rather than corrupt the cache tail, overflow the
            # table mid-decode, park an unadmittable request at the
            # queue head forever, or preempt healthy residents while
            # growing toward an inevitable drop.
            req.dropped = True
            self.stats.dropped_jobs += 1
            return None
        if self.any_group_dead():
            # A whole group is dead: nothing to wait for.
            req.dropped = True
            self.stats.dropped_jobs += 1
            return None
        # FIFO fairness: a new arrival never jumps requests already
        # waiting in the queue (capacity freed since the last drain goes
        # to the queue head on the next step, not to the newest submit).
        if not self.pending and self.try_admit(req):
            return req
        if self.max_queue is not None and len(self.pending) >= self.max_queue:
            req.dropped = True
            self.stats.dropped_jobs += 1
            return None
        req.queued = True
        self.pending.append(req)
        self.stats.queued_jobs += 1
        return req

    def try_admit(self, req: Request) -> bool:
        """Alg. 1: pick one replica per group and reserve slot + memory
        for the full current context — prompt plus any tokens already
        generated (a preempted request re-admits with its whole prefix
        to re-prefill), so admissions within a slot see each other's
        claims and an under-reserved re-admit cannot immediately preempt
        healthy residents. Decode growth still allocates lazily."""
        try:
            replicas = self.router.route(
                self.budgets,
                free_slots=self.free_counts(),
                inflight=self.inflight() if self.inflight is not None else None,
            )
        except RouteError:
            return False
        ctx = req.context_len()
        mgrs = [self.managers[(g, replicas[g])] for g in range(self.G)]
        if any(not m.can_reserve(ctx) for m in mgrs):
            return False
        req.replicas = replicas
        req.slot_ids = [m.reserve(req.rid, ctx) for m in mgrs]
        req.cache_ready = [False] * self.G
        req.chunk_pos = 0
        req.chunk_outs = []
        req.park_steps = 0
        req.queued = False
        self.active.append(req)
        self.stats.peak_active = max(self.stats.peak_active, len(self.active))
        return True

    def admit_pending(self) -> None:
        """Drain the FIFO queue into freed capacity; a fully dead group
        means queued requests have nothing to wait for (mirrors the
        submit-time drop)."""
        if self.pending and self.any_group_dead():
            while self.pending:
                req = self.pending.popleft()
                req.dropped = True
                req.queued = False
                self.stats.dropped_jobs += 1
        while self.pending and self.try_admit(self.pending[0]):
            self.pending.popleft()

    # ------------------------------------------------------------------
    # Failure handling
    # ------------------------------------------------------------------
    def replace_parked(self) -> None:
        """Re-place idle requests whose current-stage replica died, and
        parked ones (slotless after a failed failover — their old
        replica may have recovered or a sibling freed up). Runs BEFORE
        queue admission: in-flight work already holds slots and pages on
        its other groups, so freed capacity goes to it first — fresh
        admissions must not starve a parked request.

        Re-placement alone is not starvation-free: while siblings stay
        saturated the victim parks forever. Every slot a request stays
        parked ages it one ``park_steps``; past ``max_park_steps`` the
        scheduler force-places it (:meth:`force_place`)."""
        for req in list(self.active):
            if req.in_call or req.replicas is None:
                continue  # replicas None: preempted by an earlier
                # member's force_place within this very sweep (requeued)
            g = req.stage
            if self.budgets[g][req.replicas[g]].alive and req.slot_ids[g] is not None:
                continue
            self.reroute_or_drop(req)
            if req.dropped or req.queued or req.slot_ids[g] is not None:
                req.park_steps = 0  # placed (or no longer waiting)
                continue
            req.park_steps += 1
            if (
                self.max_park_steps is not None
                and req.park_steps > self.max_park_steps
                and self.force_place(req)
            ):
                req.park_steps = 0

    def rewind_spec(self, req: Request) -> None:
        """Abort an in-flight speculative round: rewind every stage's
        optimistic KV advance back to the committed stream.

        A stage that already committed its verify this round keeps ONE
        row — the KV of ``generated[-1]``, the round's first (true)
        input, exactly the row a plain decode round would have left
        behind — so an abandoned round degrades to plain-decode state.
        The current stage (dispatched but never committed) rewinds
        fully; the round's drafts are discarded. No-op outside a round.
        """
        if req.spec_adv is None:
            return
        for g in range(self.G):
            n = req.spec_adv[g]
            req.spec_adv[g] = 0
            if not n:
                continue
            keep = 1 if g < req.stage else 0
            slot = req.slot_ids[g] if req.slot_ids is not None else None
            if slot is None or req.replicas is None:
                continue
            mgr = self.managers[(g, req.replicas[g])]
            if mgr.slots[slot] == req.rid:
                mgr.rollback(req.rid, slot, n - keep)
        req.spec_drafts = None

    def reroute_or_drop(self, req: Request) -> None:
        """Failure handling: shift the in-flight stage to a sibling.

        The failed replica held this stage's slot and KV memory: both
        are released (the cache on the dead node is lost) and the
        sibling re-prefills. Stage 0 reconstructs its full context from
        the immutable prompt + generated tokens; deeper stages restart
        from the latest hidden handoff (documented context loss under
        failure). An in-flight speculative round is rewound first
        (:meth:`rewind_spec`) — its uncommitted draft rows must not
        survive as phantom context on the stages that stay placed."""
        self.rewind_spec(req)
        g = req.stage
        self.managers[(g, req.replicas[g])].release(req.rid, req.slot_ids[g])
        req.slot_ids[g] = None
        req.cache_ready[g] = False
        req.chunk_pos = 0
        req.chunk_outs = []
        req.chunk_seq = None
        if not any(b.alive for b in self.budgets[g]):
            # The whole group is gone: nothing to fail over to.
            self.drop_resident(req)
            return
        try:
            new_r = self.router.reroute(
                self.budgets,
                g,
                free_slots=self.free_counts(),
                inflight=self.inflight() if self.inflight is not None else None,
            )
        except RouteError:
            # Live siblings exist but are momentarily full / power-saving:
            # the request stays parked (slotless) and the re-place is
            # retried every slot until a sibling slot frees up.
            return
        req.replicas[g] = new_r
        # Slot-only reservation: the sibling's memory grows lazily at
        # call time (ensure_capacity), chunk by chunk in chunked mode.
        req.slot_ids[g] = self.managers[(g, new_r)].reserve(req.rid, 0)
        self.stats.rerouted_stages += 1

    def evict_stage_residents(self, g: int, r: int) -> None:
        """Replica ``(g, r)``'s device state was wiped out from under
        its residents — e.g. a respawned worker process starts with an
        empty cache, unlike a simulated in-process failure where the
        device arrays survive. Release every non-in-call resident's
        stage-``g`` claim so the normal re-place machinery
        (:meth:`replace_parked`) re-prefills them against the fresh
        state instead of decoding over zeros."""
        for req in self.active:
            if (
                req.replicas is not None
                and req.replicas[g] == r
                and req.slot_ids[g] is not None
                and not req.in_call
            ):
                self.managers[(g, r)].release(req.rid, req.slot_ids[g])
                req.slot_ids[g] = None
                req.cache_ready[g] = False

    def force_place(self, req: Request) -> bool:
        """Starvation-free aging: give a long-parked request a slot NOW.

        A request parked longer than ``max_park_steps`` stops waiting
        for capacity to free naturally: the youngest resident of the
        live sibling with the most headroom is preempted (requeued
        loss-free, exactly like page-exhaustion preemption) and the
        parked request takes the freed slot (slot-only reservation —
        memory grows lazily at call time). False = no live sibling had
        a preemptable resident this slot; aging retries next slot."""
        g = req.stage
        live = [r for r in range(self.R) if self.budgets[g][r].alive]
        live.sort(
            key=lambda r: self.managers[(g, r)].capacity_weight(), reverse=True
        )
        for r in live:
            mgr = self.managers[(g, r)]
            while mgr.free_slots() == 0:
                victim = self.youngest_preemptable(g, r, {req.rid})
                if victim is None:
                    break
                self.preempt(victim)
            if mgr.free_slots() > 0:
                req.replicas[g] = r
                req.slot_ids[g] = mgr.reserve(req.rid, 0)
                self.stats.rerouted_stages += 1
                self.stats.aged_placements += 1
                return True
        return False

    def drop_resident(self, req: Request) -> None:
        """Release every group's claim and drop the request."""
        for g in range(self.G):
            self.managers[(g, req.replicas[g])].release(req.rid, req.slot_ids[g])
        self.active.remove(req)
        req.dropped = True
        self.stats.dropped_jobs += 1

    def release_all(self, req: Request) -> None:
        """Completion: return every slot and page to the fleet."""
        for g in range(self.G):
            self.managers[(g, req.replicas[g])].release(req.rid, req.slot_ids[g])
        self.active.remove(req)

    # ------------------------------------------------------------------
    # Preemption
    # ------------------------------------------------------------------
    def youngest_preemptable(
        self, g: int, r: int, protected: set[int]
    ) -> Request | None:
        """Newest resident holding memory on (g, r) that can be evicted:
        not mid-call anywhere, not already part of the call being built."""
        mgr = self.managers[(g, r)]
        victims = [
            req
            for req in self.active
            if req.rid not in protected
            and not req.in_call
            and req.replicas[g] == r
            and mgr.held(req.rid) > 0
        ]
        return max(victims, key=lambda q: q.rid, default=None)

    def preempt(self, victim: Request) -> None:
        """Evict a resident fleet-wide and requeue it. Its prompt and
        generated tokens are intact, so re-admission re-prefills the
        exact context at stage 0 — preemption loses work, not tokens.

        The victim joins the FIFO *tail* deliberately: re-admission must
        reserve its grown prompt+generated context, so putting it at the
        head would let it re-claim the pages its preemptor just took and
        ping-pong the pool under pressure. The latency cost of waiting
        behind fresh arrivals is the trade-off (a starvation-free aging
        policy is an open item in ROADMAP.md)."""
        for g in range(self.G):
            self.managers[(g, victim.replicas[g])].release(
                victim.rid, victim.slot_ids[g]
            )
        self.active.remove(victim)
        victim.replicas = None
        victim.slot_ids = None
        victim.cache_ready = None
        victim.stage = 0
        victim.hidden = None
        victim.chunk_pos = 0
        victim.chunk_outs = []
        victim.chunk_seq = None
        victim.park_steps = 0
        # A preempted mid-round speculative request starts over: every
        # slot and page was just released (lengths zeroed with them), so
        # no rollback is needed — just forget the round.
        victim.spec_drafts = None
        victim.spec_adv = None
        victim.queued = True
        self.pending.append(victim)
        self.stats.preempted_jobs += 1

    def ensure_capacity(
        self, g: int, r: int, req: Request, need_len: int, protected: set[int]
    ) -> bool:
        """Grow ``req``'s memory claim on (g, r) to cover ``need_len``
        entries, preempting the youngest resident on exhaustion. False =
        defer this member to a later slot (no preemptable victim now).
        Dense managers always extend, so this is a no-op there."""
        mgr = self.managers[(g, r)]
        if not mgr.fits(need_len):
            # Can never fit, even with the replica to itself: drop.
            self.drop_resident(req)
            return False
        while not mgr.try_extend(req.rid, req.slot_ids[g], need_len):
            victim = self.youngest_preemptable(g, r, protected)
            if victim is None:
                return False
            self.preempt(victim)
        return True

    # ------------------------------------------------------------------
    # Member selection
    # ------------------------------------------------------------------
    def select_members(self, g: int, r: int) -> list[Request]:
        """Residents ready to join (g, r)'s next batched call."""
        return [
            req
            for req in self.active
            if req.stage == g
            and req.replicas[g] == r
            and not req.in_call
            and req.slot_ids[g] is not None  # parked: awaiting re-place
        ]
