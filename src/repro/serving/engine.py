"""Decentralized serving engine: the paper's system with real compute.

``PipelineServer`` hosts G pipeline groups × R replicas of a partitioned
model (:mod:`.partition`). Time advances in slots (the paper's delta);
per slot every replica harvests budget, resident requests execute real
JAX decode compute on their designated replicas, and new requests are
admitted by the energy-aware :class:`Router` (Alg. 1) or held in a
pending queue when the fleet is full (backpressure). Replica failure
(ft/health) is just a drained budget — the router's mass shifts
instantly and in-flight stage work is re-routed to a sibling replica.

Continuous batching
-------------------
Each (group, replica) owns one static-shaped batched KV cache with
``max_batch`` per-request slots: every per-request cache (inner batch
dim 1, per-slot context length in the stacked ``cache["len"]`` vector)
is stacked on a leading slot axis. Per simulation slot a replica issues
**one** jitted stage call covering every resident request at that stage
— a masked ``decode_batch`` over the full slot width (non-participating
slots keep their cache via a select) plus one vmapped ``prefill_batch``
per distinct joining prompt length — instead of one Python-level JAX
dispatch per request. Requests join and leave the batch mid-flight:
slots are allocated on admission, freed on completion/drop, and
re-allocated on a sibling after failover (the dead replica's slot is
lost and the stage re-prefills).

Execution model per request = generate ``n_tokens`` autoregressively:
each token passes stages 0..G-1. A stage call occupies its replica for
``kappa(PM)`` slots (the paper's measured per-mode latency) and charges
``CE(PM)/kappa`` per slot *per call* — the paper's device-level job
cost, now amortized over every request in the batch. Call results
(tokens / hidden handoffs) are committed when the call completes, so an
aborted call (replica death mid-call) never corrupts request state.

Paged KV cache (``paged=True``)
-------------------------------
The dense layout above reserves ``max_batch x max_len`` KV entries per
replica — worst-case memory for every slot. In paged mode each replica
instead owns a shared pool of fixed-size pages
(:mod:`.paged_cache`): a request holds ``ceil(context/page_size)``
pages per group, named by its block table, and ``decode_paged`` (one
natively-batched call, Pallas block-table gather on TPU) reads the
scattered cache directly. Admission checks free *pages*, the router
weighs replicas by free pages, failover re-allocates pages on the
sibling, and page exhaustion mid-decode preempts the youngest resident
back to the pending queue (prompt + generated tokens re-prefill on
re-admission, so preemption is loss-free) instead of crashing.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..core.power import PowerModePolicy, dynamic_policy
from ..models.registry import Model
from .budget import ReplicaBudget
from .paged_cache import PagePool
from .partition import partition_model
from .router import RouteError, Router

__all__ = ["Request", "PipelineServer", "ServerStats"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # immutable prompt [S] — never mutated after submit
    n_tokens: int  # tokens to generate
    # runtime state
    stage: int = 0
    replicas: list[int] | None = None  # designated replica per group
    slot_ids: list[int] | None = None  # batch slot per group
    cache_ready: list[bool] | None = None  # per-group: slot cache prefilled
    pages: list[list[int]] | None = None  # per-group physical pages (paged mode)
    generated: list[int] = dataclasses.field(default_factory=list)
    hidden: Any = None  # inter-stage activation
    in_call: bool = False  # member of the current stage call
    queued: bool = False  # waiting for admission (backpressure)
    done: bool = False
    dropped: bool = False

    @property
    def tokens(self) -> np.ndarray:
        """Back-compat alias: the immutable prompt."""
        return self.prompt


@dataclasses.dataclass
class _StageCall:
    """One in-flight batched stage execution on a (group, replica)."""

    members: list[Request]
    outputs: list[Any]  # per-member logits/hidden, committed on completion
    pm: int
    slots_left: int


@dataclasses.dataclass
class ServerStats:
    submitted: int = 0
    completed_jobs: int = 0
    dropped_jobs: int = 0
    queued_jobs: int = 0  # submissions that waited in the pending queue
    tokens_generated: int = 0
    stage_executions: int = 0  # per-request stage work units
    prefill_calls: int = 0  # batched JAX dispatches (prefill)
    decode_calls: int = 0  # batched JAX dispatches (decode)
    rerouted_stages: int = 0
    preempted_jobs: int = 0  # paged: evicted on page exhaustion, requeued
    peak_active: int = 0  # max concurrently resident requests
    slots: int = 0
    downtime_replica_slots: int = 0  # whole (replica, slot) pairs down
    n_groups: int = 1
    n_replicas: int = 1

    @property
    def downtime_fraction(self) -> float:
        denom = self.slots * self.n_groups * self.n_replicas
        return self.downtime_replica_slots / max(denom, 1)


class PipelineServer:
    def __init__(
        self,
        model: Model,
        params,
        *,
        n_groups: int = 3,
        n_replicas: int = 3,
        policy: str = "adaptive",
        pm_policy: PowerModePolicy | None = None,
        harvest_bounds: tuple[float, float] = (6.0, 10.0),
        long_term_rates: np.ndarray | None = None,
        max_len: int = 256,
        max_batch: int = 4,
        max_queue: int | None = None,
        paged: bool = False,
        page_size: int = 16,
        max_pages: int | None = None,
        seed: int = 0,
    ):
        self.cfg = model.cfg
        self.stages = partition_model(model.cfg, params, n_groups)
        self.G, self.R = n_groups, n_replicas
        self.max_len = max_len
        self.max_batch = max_batch
        self.max_queue = max_queue
        self.paged = paged
        self.page_size = page_size
        # Block-table width: max context per request, in pages.
        self._nb_max = -(-max_len // page_size)
        # Default pool = dense capacity (max_batch full-length contexts);
        # the paged win comes from setting max_pages *below* this while
        # raising max_batch — short requests then pack the same memory.
        self.max_pages = (
            max_pages if max_pages is not None else max_batch * self._nb_max
        )
        if paged and any(m.decode_paged is None for m, _ in self.stages):
            raise ValueError(
                f"{model.cfg.name}: paged serving needs uniform full "
                "attention (see repro.models.transformer.supports_paged)"
            )
        self.pm_policy = pm_policy or dynamic_policy(100)
        # Independent RNG streams: harvest/arrival draws and routing draws
        # must not be correlated (same-integer seeding would lockstep them).
        engine_seq, router_seq = np.random.SeedSequence(seed).spawn(2)
        self._rng = np.random.default_rng(engine_seq)
        # Replicas share stage weights (replication within a group) but
        # have independent budgets/harvests (heterogeneous nodes).
        lo, hi = harvest_bounds
        centers = self._rng.uniform(lo, hi, size=(self.G, self.R))
        self.harvest = np.stack([centers - 2.0, centers + 2.0], axis=-1).clip(0.0)
        self.budgets = [
            [ReplicaBudget(policy=self.pm_policy) for _ in range(n_replicas)]
            for _ in range(n_groups)
        ]
        self.router = Router(
            policy=policy, long_term_rates=long_term_rates, seed=router_seq
        )
        self.stats = ServerStats(n_groups=n_groups, n_replicas=n_replicas)
        self._active: list[Request] = []
        self._pending: collections.deque[Request] = collections.deque()
        self._next_rid = 0
        # Continuous-batching state: per (g, r) slot table, stacked cache,
        # in-flight call, and the per-stage jitted batched entry points.
        self._slot_map: dict[tuple[int, int], list[int | None]] = {
            (g, r): [None] * max_batch
            for g in range(n_groups)
            for r in range(n_replicas)
        }
        if paged:
            self._pools = {
                (g, r): PagePool(self.max_pages, page_size)
                for g in range(n_groups)
                for r in range(n_replicas)
            }
            self._lens = {
                (g, r): np.zeros(max_batch, np.int64)
                for g in range(n_groups)
                for r in range(n_replicas)
            }
            self._caches = {
                (g, r): self._init_paged_cache(g)
                for g in range(n_groups)
                for r in range(n_replicas)
            }
            # Host block tables (+ lazily refreshed device copies): rows
            # change only on page alloc/free, not per decode call.
            self._bt = {
                (g, r): np.full(
                    (max_batch, self._nb_max), self.max_pages, np.int32
                )
                for g in range(n_groups)
                for r in range(n_replicas)
            }
            self._bt_dev: dict[tuple[int, int], Any] = {}
            self._fns = [self._build_paged_fns(g) for g in range(n_groups)]
        else:
            self._caches = {
                (g, r): self._init_cache(g)
                for g in range(n_groups)
                for r in range(n_replicas)
            }
            self._fns = [self._build_stage_fns(g) for g in range(n_groups)]
        self._calls: dict[tuple[int, int], _StageCall] = {}

    # ------------------------------------------------------------------
    # Batched cache plumbing
    # ------------------------------------------------------------------
    def _init_cache(self, g: int):
        """Zeroed slot-stacked cache for stage g: [max_batch, <B=1 cache>]."""
        model_g, _ = self.stages[g]
        shapes = model_g.cache_shapes(1, self.max_len)
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros((self.max_batch,) + tuple(s.shape), s.dtype), shapes
        )

    def _build_stage_fns(self, g: int):
        """Jitted batched stage entry points (one pair per stage, built
        once so jit caches by shape, not by call site)."""
        model_g, _ = self.stages[g]
        max_len = self.max_len

        @jax.jit
        def prefill_into(params, batch, cache, slot_idx):
            # batch leaves: [N, 1, S(, D)] — N joining requests, same S.
            out, new = model_g.prefill_batch(params, batch, max_len)
            cache = jax.tree_util.tree_map(
                lambda big, small: big.at[slot_idx].set(small), cache, new
            )
            return out, cache

        @jax.jit
        def decode_masked(params, inp, cache, mask):
            # inp: [W, 1, 1(, D)] over the full slot width W = max_batch;
            # mask selects participating slots — the others' caches are
            # preserved by the select (their computed garbage is dropped).
            out, new = model_g.decode_batch(params, inp, cache)
            merged = jax.tree_util.tree_map(
                lambda n, o: jnp.where(
                    mask.reshape((mask.shape[0],) + (1,) * (n.ndim - 1)), n, o
                ),
                new,
                cache,
            )
            return out, merged

        return prefill_into, decode_masked

    # ------------------------------------------------------------------
    # Paged cache plumbing
    # ------------------------------------------------------------------
    def _init_paged_cache(self, g: int):
        """Shared page pool for stage g: [n_layers, P+1, page, KV, Dh]
        (page index P is the scratch page for masked lanes)."""
        c = self.stages[g][0].cfg
        shape = (
            c.n_layers, self.max_pages + 1, self.page_size,
            c.n_kv_heads, c.head_dim,
        )
        return {
            "k": jnp.zeros(shape, c.compute_dtype),
            "v": jnp.zeros(shape, c.compute_dtype),
        }

    def _build_paged_fns(self, g: int):
        """Jitted paged stage entry points: prefill-and-scatter (dense
        prefill compute, then one scatter writes the K/V into the
        request's pages) and the natively-batched paged decode."""
        model_g, _ = self.stages[g]
        ps = self.page_size

        @jax.jit
        def prefill_pages(params, batch, kp, vp, page_ids):
            # batch leaves: [N, 1, S(, D)]; page_ids: [N, NBs] with
            # NBs * ps >= S. The transient dense cache is per-call only.
            N, NBs = page_ids.shape
            out, cache = model_g.prefill_batch(params, batch, NBs * ps)
            flat = page_ids.reshape(-1)

            def scatter(pool, leaf):
                # leaf: [N, n_layers, 1, NBs*ps, KV, Dh] -> page blocks
                n = leaf.shape[1]
                x = leaf[:, :, 0].reshape(N, n, NBs, ps, *leaf.shape[4:])
                x = x.transpose(1, 0, 2, 3, 4, 5).reshape(
                    n, N * NBs, ps, *leaf.shape[4:]
                )
                return pool.at[:, flat].set(x.astype(pool.dtype))

            kp = scatter(kp, cache["c0"]["k"])
            vp = scatter(vp, cache["c0"]["v"])
            return out, kp, vp

        decode_paged = jax.jit(model_g.decode_paged)
        return prefill_pages, decode_paged

    def _free_pages(self, g: int, r: int, req: Request) -> None:
        if not self.paged or req.pages is None:
            return
        if req.pages[g]:
            self._pools[(g, r)].free(req.pages[g], req.rid)
            req.pages[g] = []

    def _bt_set_row(self, g: int, r: int, slot: int, pages: list[int]) -> None:
        row = self._bt[(g, r)][slot]
        row[:] = self.max_pages  # scratch
        row[: len(pages)] = pages
        self._bt_dev.pop((g, r), None)

    def _alloc_slot(self, g: int, r: int, rid: int) -> int:
        table = self._slot_map[(g, r)]
        idx = table.index(None)
        table[idx] = rid
        return idx

    def _free_slot(self, g: int, r: int, req: Request) -> None:
        table = self._slot_map[(g, r)]
        slot = req.slot_ids[g]
        if slot is not None and table[slot] == req.rid:
            table[slot] = None
            if self.paged:
                # Freed lanes must never alias live pages: scratch the row.
                self._bt_set_row(g, r, slot, [])
                self._lens[(g, r)][slot] = 0

    def _free_counts(self) -> list[list[int]]:
        """Router capacity weights: free batch slots (dense) or free
        pages (paged; a replica with no free slot is full either way)."""
        if self.paged:
            return [
                [
                    0
                    if self._slot_map[(g, r)].count(None) == 0
                    else self._pools[(g, r)].free_pages
                    for r in range(self.R)
                ]
                for g in range(self.G)
            ]
        return [
            [self._slot_map[(g, r)].count(None) for r in range(self.R)]
            for g in range(self.G)
        ]

    # ------------------------------------------------------------------
    # Admission
    # ------------------------------------------------------------------
    def submit(self, tokens: np.ndarray, n_tokens: int = 8) -> Request | None:
        """Admit a new request (one replica + batch slot per group, Alg. 1)
        or hold it in the pending queue when the fleet is full."""
        self.stats.submitted += 1
        req = Request(
            rid=self._next_rid, prompt=np.asarray(tokens), n_tokens=n_tokens
        )
        self._next_rid += 1
        final_ctx = len(req.prompt) + n_tokens
        if final_ctx > self.max_len or (
            self.paged and -(-final_ctx // self.page_size) > self.max_pages
        ):
            # The final context cannot fit a slot's cache / block-table
            # row / page pool, so the request can never complete: reject
            # up front rather than corrupt the cache tail, overflow the
            # table mid-decode, park an unadmittable request at the
            # queue head forever, or preempt healthy residents while
            # growing toward an inevitable drop.
            req.dropped = True
            self.stats.dropped_jobs += 1
            return None
        if any(not any(b.alive for b in group) for group in self.budgets):
            # A whole group is dead: nothing to wait for.
            req.dropped = True
            self.stats.dropped_jobs += 1
            return None
        # FIFO fairness: a new arrival never jumps requests already
        # waiting in the queue (capacity freed since the last drain goes
        # to the queue head on the next step, not to the newest submit).
        if not self._pending and self._try_admit(req):
            return req
        if self.max_queue is not None and len(self._pending) >= self.max_queue:
            req.dropped = True
            self.stats.dropped_jobs += 1
            return None
        req.queued = True
        self._pending.append(req)
        self.stats.queued_jobs += 1
        return req

    def _try_admit(self, req: Request) -> bool:
        try:
            replicas = self.router.route(self.budgets, free_slots=self._free_counts())
        except RouteError:
            return False
        if self.paged:
            # Reserve the full current context up front — prompt plus any
            # tokens already generated (a preempted request re-admits with
            # its whole prefix to re-prefill) — so admissions within a
            # slot see each other's claims and an under-reserved re-admit
            # cannot immediately preempt healthy residents. Decode growth
            # still allocates lazily (see _ensure_pages).
            blocks = self._pools[(0, replicas[0])].blocks_for(
                len(req.prompt) + len(req.generated)
            )
            pools = [self._pools[(g, replicas[g])] for g in range(self.G)]
            if any(not p.can_alloc(blocks) for p in pools):
                return False
            req.pages = [p.alloc(blocks, req.rid) for p in pools]
        req.replicas = replicas
        req.slot_ids = [self._alloc_slot(g, replicas[g], req.rid) for g in range(self.G)]
        if self.paged:
            for g in range(self.G):
                self._bt_set_row(g, replicas[g], req.slot_ids[g], req.pages[g])
        req.cache_ready = [False] * self.G
        req.queued = False
        self._active.append(req)
        self.stats.peak_active = max(self.stats.peak_active, len(self._active))
        return True

    # ------------------------------------------------------------------
    # Batched stage execution
    # ------------------------------------------------------------------
    def _start_call(self, g: int, r: int, members: list[Request]) -> _StageCall | None:
        """Issue the batched JAX work for every member and open the call.
        Paged mode may defer members (page exhaustion) and returns None
        when nothing could be served this slot."""
        if self.paged:
            return self._start_call_paged(g, r, members)
        return self._start_call_dense(g, r, members)

    def _start_call_dense(self, g: int, r: int, members: list[Request]) -> _StageCall:
        _, params_g = self.stages[g]
        b = self.budgets[g][r]
        pm = b.pm
        prefill_into, decode_masked = self._fns[g]
        outputs: list[Any] = [None] * len(members)
        cache = self._caches[(g, r)]

        pre = [i for i, m in enumerate(members) if not m.cache_ready[g]]
        dec = [i for i, m in enumerate(members) if m.cache_ready[g]]

        # Prefills, grouped by prompt/handoff length (one dispatch each).
        by_len: dict[int, list[tuple[int, Any]]] = {}
        for i in pre:
            m = members[i]
            if g == 0:
                ids = np.asarray(m.prompt, np.int32)
                if m.generated:
                    # Failover re-prefill: rebuild the full prefix — prompt
                    # plus every generated token, the current round's input
                    # included — from the immutable prompt. The last
                    # position's hidden/logits then replace the decode step
                    # the dead replica lost, so decoding stays token-exact
                    # across any number of failovers.
                    ids = np.concatenate([ids, np.asarray(m.generated, np.int32)])
                inp = jnp.asarray(ids)[None, :]
            else:
                inp = m.hidden  # [1, S, D] handoff from the upstream stage
            by_len.setdefault(int(inp.shape[1]), []).append((i, inp))
        last = g == self.G - 1
        key = "tokens" if g == 0 else "hidden"
        for _length, grp in sorted(by_len.items()):
            idxs = [i for i, _ in grp]
            stacked = jnp.stack([x for _, x in grp])
            slots = jnp.asarray([members[i].slot_ids[g] for i in idxs], jnp.int32)
            out, cache = prefill_into(params_g, {key: stacked}, cache, slots)
            self.stats.prefill_calls += 1
            if last:
                # One batched argmax + one host sync for the whole group
                # (a per-request int() would cost one sync per token).
                toks = np.asarray(jnp.argmax(out[:, 0, -1], axis=-1))
                for j, i in enumerate(idxs):
                    outputs[i] = int(toks[j])
            else:
                for j, i in enumerate(idxs):
                    outputs[i] = out[j]

        # Decode: one masked dispatch over the full static slot width.
        if dec:
            W = self.max_batch
            mask = np.zeros((W,), bool)
            slots = np.asarray([members[i].slot_ids[g] for i in dec], np.int32)
            mask[slots] = True
            if g == 0:
                buf = np.zeros((W, 1, 1), np.int32)
                for i in dec:
                    buf[members[i].slot_ids[g], 0, 0] = members[i].generated[-1]
                inp = jnp.asarray(buf)
            else:
                # Assemble on device: the handoffs are device arrays and a
                # host round-trip per member would not amortize. After an
                # upstream re-prefill the handoff carries the whole
                # prefix; a caching stage only consumes the newest position.
                hs = jnp.stack(
                    [
                        m.hidden if m.hidden.shape[1] == 1 else m.hidden[:, -1:]
                        for m in (members[i] for i in dec)
                    ]
                )
                inp = (
                    jnp.zeros((W, 1, 1, self.cfg.d_model), hs.dtype)
                    .at[jnp.asarray(slots)]
                    .set(hs)
                )
            out, cache = decode_masked(params_g, inp, cache, jnp.asarray(mask))
            self.stats.decode_calls += 1
            if last:
                toks = np.asarray(jnp.argmax(out[:, 0, -1], axis=-1))
                for i in dec:
                    outputs[i] = int(toks[members[i].slot_ids[g]])
            else:
                for i in dec:
                    outputs[i] = out[members[i].slot_ids[g]]

        self._caches[(g, r)] = cache
        self.stats.stage_executions += len(members)
        for m in members:
            m.in_call = True
        kappa = self.pm_policy.mode(pm).kappa
        return _StageCall(
            members=list(members), outputs=outputs, pm=pm, slots_left=kappa
        )

    # ------------------------------------------------------------------
    # Paged stage execution
    # ------------------------------------------------------------------
    def _youngest_preemptable(
        self, g: int, r: int, protected: set[int]
    ) -> Request | None:
        """Newest resident holding pages on (g, r) that can be evicted:
        not mid-call anywhere, not already part of the call being built."""
        victims = [
            req
            for req in self._active
            if req.rid not in protected
            and not req.in_call
            and req.replicas[g] == r
            and req.pages[g]
        ]
        return max(victims, key=lambda q: q.rid, default=None)

    def _preempt(self, victim: Request) -> None:
        """Evict a resident fleet-wide and requeue it. Its prompt and
        generated tokens are intact, so re-admission re-prefills the
        exact context at stage 0 — preemption loses work, not tokens."""
        for g in range(self.G):
            self._free_slot(g, victim.replicas[g], victim)
            self._free_pages(g, victim.replicas[g], victim)
        self._active.remove(victim)
        victim.replicas = None
        victim.slot_ids = None
        victim.cache_ready = None
        victim.pages = None
        victim.stage = 0
        victim.hidden = None
        victim.queued = True
        self._pending.append(victim)
        self.stats.preempted_jobs += 1

    def _ensure_pages(
        self, g: int, r: int, req: Request, need_len: int, protected: set[int]
    ) -> bool:
        """Grow ``req``'s page list on (g, r) to cover ``need_len``
        entries, preempting the youngest resident on exhaustion. False =
        defer this member to a later slot (no preemptable victim now)."""
        pool = self._pools[(g, r)]
        need = pool.blocks_for(need_len)
        if need > pool.n_pages:
            # Can never fit, even with the pool to itself: drop.
            for gg in range(self.G):
                self._free_slot(gg, req.replicas[gg], req)
                self._free_pages(gg, req.replicas[gg], req)
            self._active.remove(req)
            req.dropped = True
            self.stats.dropped_jobs += 1
            return False
        grown = False
        while len(req.pages[g]) < need:
            if pool.can_alloc(1):
                req.pages[g].extend(pool.alloc(1, req.rid))
                grown = True
                continue
            victim = self._youngest_preemptable(g, r, protected)
            if victim is None:
                return False
            self._preempt(victim)
        if grown:
            self._bt_set_row(g, r, req.slot_ids[g], req.pages[g])
        return True

    def _start_call_paged(
        self, g: int, r: int, members: list[Request]
    ) -> _StageCall | None:
        _, params_g = self.stages[g]
        b = self.budgets[g][r]
        pm = b.pm
        prefill_pages, decode_fn = self._fns[g]
        pool = self._pools[(g, r)]
        lens_host = self._lens[(g, r)]
        cache = self._caches[(g, r)]
        last = g == self.G - 1
        key = "tokens" if g == 0 else "hidden"

        # Build prefill inputs first (their length drives page demand),
        # then secure pages oldest-first; members that cannot get pages
        # this slot are deferred, and _ensure_pages may preempt younger
        # members — skip those when reached (queued/dropped flips).
        pre_inp: dict[int, Any] = {}
        for m in members:
            if m.cache_ready[g]:
                continue
            if g == 0:
                ids = np.asarray(m.prompt, np.int32)
                if m.generated:
                    # Failover/preemption re-prefill: full prefix from the
                    # immutable prompt + every generated token (see the
                    # dense path for why this keeps decoding token-exact).
                    ids = np.concatenate([ids, np.asarray(m.generated, np.int32)])
                pre_inp[m.rid] = jnp.asarray(ids)[None, :]
            else:
                # Paged decode hand-offs are [1, D] (see below); prefill
                # inputs are [1, S, D].
                pre_inp[m.rid] = (
                    m.hidden if m.hidden.ndim == 3 else m.hidden[:, None]
                )
        served: list[Request] = []
        protected: set[int] = set()
        for m in sorted(members, key=lambda q: q.rid):
            if m.queued or m.dropped:
                continue  # preempted/dropped by an earlier member's ensure
            if m.cache_ready[g]:
                need = int(lens_host[m.slot_ids[g]]) + 1
            else:
                need = int(pre_inp[m.rid].shape[1])
            if self._ensure_pages(g, r, m, need, protected | {m.rid}):
                served.append(m)
                protected.add(m.rid)
        if not served:
            return None

        outputs: list[Any] = [None] * len(served)
        pre = [i for i, m in enumerate(served) if not m.cache_ready[g]]
        dec = [i for i, m in enumerate(served) if m.cache_ready[g]]

        # Prefills, grouped by prompt/handoff length (one dispatch each);
        # the scatter lands each request's K/V in its own pages.
        by_len: dict[int, list[int]] = {}
        for i in pre:
            by_len.setdefault(int(pre_inp[served[i].rid].shape[1]), []).append(i)
        for length, idxs in sorted(by_len.items()):
            stacked = jnp.stack([pre_inp[served[i].rid] for i in idxs])
            nbs = pool.blocks_for(length)
            page_ids = np.asarray(
                [served[i].pages[g][:nbs] for i in idxs], np.int32
            )
            out, kp, vp = prefill_pages(
                params_g, {key: stacked}, cache["k"], cache["v"],
                jnp.asarray(page_ids),
            )
            cache = {"k": kp, "v": vp}
            self.stats.prefill_calls += 1
            for i in idxs:
                lens_host[served[i].slot_ids[g]] = length
            if last:
                toks = np.asarray(jnp.argmax(out[:, 0, -1], axis=-1))
                for j, i in enumerate(idxs):
                    outputs[i] = int(toks[j])
            else:
                for j, i in enumerate(idxs):
                    outputs[i] = out[j]

        # Decode: one natively-batched paged dispatch over the slot
        # width. Lanes marked -1 write to the scratch page and attend
        # one masked position; their outputs are never read. The device
        # block table is cached and refreshed only on page alloc/free.
        if dec:
            W = self.max_batch
            lens_arr = np.full((W,), -1, np.int32)
            for i in dec:
                s = served[i].slot_ids[g]
                lens_arr[s] = lens_host[s]
            if (g, r) not in self._bt_dev:
                self._bt_dev[(g, r)] = jnp.asarray(self._bt[(g, r)])
            if g == 0:
                buf = np.zeros((W, 1), np.int32)
                for i in dec:
                    buf[served[i].slot_ids[g], 0] = served[i].generated[-1]
                inp = jnp.asarray(buf)
            else:
                slots = np.asarray([served[i].slot_ids[g] for i in dec], np.int32)
                # Hand-offs: [1, D] from an upstream decode, [1, S, D]
                # after an upstream re-prefill (consume the last position).
                hs = jnp.stack(
                    [
                        m.hidden if m.hidden.ndim == 2 else m.hidden[:, -1]
                        for m in (served[i] for i in dec)
                    ]
                )  # [N, 1, D]
                inp = (
                    jnp.zeros((W, 1, self.cfg.d_model), hs.dtype)
                    .at[jnp.asarray(slots)]
                    .set(hs)
                )
            out, cache = decode_fn(
                params_g, inp, {"k": cache["k"], "v": cache["v"]},
                jnp.asarray(lens_arr), self._bt_dev[(g, r)],
            )
            self.stats.decode_calls += 1
            for i in dec:
                lens_host[served[i].slot_ids[g]] += 1
            if last:
                toks = np.asarray(jnp.argmax(out[:, 0], axis=-1))
                for i in dec:
                    outputs[i] = int(toks[served[i].slot_ids[g]])
            else:
                # Hand-offs stay [1, D] (not dense's [1, 1, D]): the
                # per-member [None] here costs one eagerly-dispatched
                # expand_dims per request per stage round, which measured
                # as a whole-percent tokens/s hit; both consumers branch
                # on ndim instead.
                for i in dec:
                    outputs[i] = out[served[i].slot_ids[g]]  # [1, D]

        self._caches[(g, r)] = cache
        self.stats.stage_executions += len(served)
        for m in served:
            m.in_call = True
        kappa = self.pm_policy.mode(pm).kappa
        return _StageCall(members=served, outputs=outputs, pm=pm, slots_left=kappa)

    def _commit(self, req: Request, out: Any, g: int) -> None:
        """Apply a completed stage call's result to the request."""
        req.in_call = False
        req.cache_ready[g] = True
        if g == self.G - 1:
            req.generated.append(out)  # already an int (batched argmax)
            self.stats.tokens_generated += 1
        else:
            req.hidden = out
        self._advance(req)

    # ------------------------------------------------------------------
    # Slot loop
    # ------------------------------------------------------------------
    def step(self) -> None:
        """Advance one slot (the paper's Algorithm 1 outer loop)."""
        self.stats.slots += 1
        # 1) harvest + hysteresis + downtime telemetry (whole replica-slots)
        for g in range(self.G):
            for r in range(self.R):
                b = self.budgets[g][r]
                lo, hi = self.harvest[g, r]
                b.harvest(self._rng.uniform(lo, hi))
                if not b.available:
                    self.stats.downtime_replica_slots += 1

        # 2) abort calls on dead replicas; reroute their members
        for (g, r), call in list(self._calls.items()):
            if not self.budgets[g][r].alive:
                del self._calls[(g, r)]
                for m in call.members:
                    m.in_call = False
                    self._reroute_or_drop(m)

        # 3) re-place idle requests whose current-stage replica died, and
        #    parked ones (slotless after a failed failover — their old
        #    replica may have recovered or a sibling freed up). Runs
        #    BEFORE queue admission: in-flight work already holds slots
        #    and pages on its other groups, so freed capacity goes to it
        #    first — fresh admissions must not starve a parked request.
        for req in list(self._active):
            if req.in_call:
                continue
            g = req.stage
            if not self.budgets[g][req.replicas[g]].alive or req.slot_ids[g] is None:
                self._reroute_or_drop(req)

        # 4) backpressure queue: admit while capacity allows (FIFO); a
        #    fully dead group means queued requests have nothing to wait
        #    for (mirrors the submit-time drop)
        if self._pending and any(
            not any(b.alive for b in group) for group in self.budgets
        ):
            while self._pending:
                req = self._pending.popleft()
                req.dropped = True
                req.queued = False
                self.stats.dropped_jobs += 1
        while self._pending and self._try_admit(self._pending[0]):
            self._pending.popleft()

        # 5) start one batched call per idle, energy-ready replica
        for g in range(self.G):
            for r in range(self.R):
                if (g, r) in self._calls:
                    continue
                b = self.budgets[g][r]
                if not b.available or not b.can_start():
                    continue  # power saving / energy gate: jobs held
                members = [
                    req
                    for req in self._active
                    if req.stage == g
                    and req.replicas[g] == r
                    and not req.in_call
                    and req.slot_ids[g] is not None  # parked: awaiting re-place
                ]
                if members:
                    call = self._start_call(g, r, members)
                    if call is not None:  # paged: every member deferred
                        self._calls[(g, r)] = call

        # 6) advance calls: charge CE(PM)/kappa per slot (device-level,
        #    amortized over the batch), commit results on completion
        for (g, r), call in list(self._calls.items()):
            b = self.budgets[g][r]
            if not b.available:
                continue  # power saving: stage paused (jobs held, Sec. III)
            mode = self.pm_policy.mode(call.pm)
            b.charge(mode.ce / mode.kappa)
            call.slots_left -= 1
            if call.slots_left <= 0:
                del self._calls[(g, r)]
                for m, out in zip(call.members, call.outputs):
                    self._commit(m, out, g)

    def _reroute_or_drop(self, req: Request) -> None:
        """Failure handling: shift the in-flight stage to a sibling.

        The failed replica held this stage's slot and KV cache: both are
        lost and the sibling re-prefills. Stage 0 reconstructs its full
        context from the immutable prompt + generated tokens; deeper
        stages would need the prefix re-driven through the pipeline — the
        engine approximates by restarting them from the latest hidden
        handoff (documented context loss under failure).
        """
        g = req.stage
        self._free_slot(g, req.replicas[g], req)
        self._free_pages(g, req.replicas[g], req)  # cache on the dead node is lost
        req.slot_ids[g] = None
        if not any(b.alive for b in self.budgets[g]):
            # The whole group is gone: nothing to fail over to.
            req.dropped = True
            for gg in range(self.G):
                self._free_slot(gg, req.replicas[gg], req)
                self._free_pages(gg, req.replicas[gg], req)
            self._active.remove(req)
            self.stats.dropped_jobs += 1
            return
        try:
            new_r = self.router.reroute(self.budgets, g, free_slots=self._free_counts())
        except RouteError:
            # Live siblings exist but are momentarily full / power-saving:
            # the request stays parked (slotless) and the re-place is
            # retried every slot until a sibling slot frees up. Its old
            # slot was released above, so the stage cache is gone.
            req.cache_ready[g] = False
            return
        req.replicas[g] = new_r
        req.slot_ids[g] = self._alloc_slot(g, new_r, req.rid)
        req.cache_ready[g] = False
        self.stats.rerouted_stages += 1

    def _advance(self, req: Request) -> None:
        req.stage += 1
        if req.stage >= self.G:
            if len(req.generated) >= req.n_tokens:
                req.done = True
                for g in range(self.G):
                    self._free_slot(g, req.replicas[g], req)
                    self._free_pages(g, req.replicas[g], req)
                self._active.remove(req)
                self.stats.completed_jobs += 1
                return
            req.stage = 0

    # ------------------------------------------------------------------
    def fail_replica(self, g: int, r: int) -> None:
        self.budgets[g][r].fail()

    def recover_replica(self, g: int, r: int) -> None:
        self.budgets[g][r].recover()

    @property
    def queue_depth(self) -> int:
        return len(self._pending)

    def run(
        self,
        n_slots: int,
        arrival_p: float = 0.4,
        prompt_len: int = 8,
        n_tokens: int = 4,
        vocab: int | None = None,
    ) -> ServerStats:
        vocab = vocab or self.cfg.vocab_size
        for _ in range(n_slots):
            if self._rng.uniform() < arrival_p:
                prompt = self._rng.integers(0, vocab, size=prompt_len)
                self.submit(prompt, n_tokens=n_tokens)
            self.step()
        return self.stats
