"""Decentralized serving engine: the paper's system with real compute.

``PipelineServer`` hosts G pipeline groups × R replicas of a partitioned
model (:mod:`.partition`). Time advances in slots (the paper's delta);
per slot every replica harvests budget, resident requests execute real
JAX decode compute on their designated replicas, and the control plane
decides everything else. The engine is the *execution* third of a
three-way split:

* :mod:`.cache` — ``KVCacheManager``: slot + memory accounting, one
  abstraction over the dense slot-stacked layout (``DenseSlotCache``)
  and the paged pool (``PagedKVCache``). The engine and scheduler never
  branch on cache layout.
* :mod:`.scheduler` — ``StepScheduler``: admission (Alg. 1 routing),
  FIFO backpressure queueing, failover re-placement, youngest-resident
  preemption, and energy gating — one implementation for both layouts.
* this module — building the jitted stage entry points, assembling
  batched inputs, issuing the calls, and committing their results.

Continuous batching
-------------------
Each (group, replica) owns one static-shaped batched KV cache with
``max_batch`` per-request slots. Per simulation slot a replica issues
**one** batched stage call covering every resident request at that stage
— a masked decode over the full slot width plus the prefill work of any
joining requests — and charges ``CE(PM)/kappa`` per slot per call (the
paper's device-level job cost amortized over the batch). Call results
are committed when the call completes, so an aborted call (replica
death mid-call) never corrupts request state.

Chunked prefill (``prefill_chunk=N``)
-------------------------------------
Whole-prompt prefill issues one vmapped dispatch *per distinct prompt
length*, so realistic mixed traffic re-jits continuously and long
prompts head-of-line block resident decodes. With ``prefill_chunk``
set, each joining prompt is split into fixed ``N``-token chunks that
ride one static call shape — prefill chunks and decode tokens are
co-scheduled in the same per-step call, per-slot offsets advancing
through the chunk — so the number of compiled prefill computations is
independent of the workload's prompt lengths (observable via
:func:`trace_counts`) and per-step prefill work is bounded by ``N``.
Uniform full-attention architectures only (the ``supports_paged``
coverage); paged mode writes each chunk's K/V into the request's
reserved pages incrementally.

Paged KV cache (``paged=True``)
-------------------------------
The dense layout reserves ``max_batch x max_len`` KV entries per
replica — worst-case memory for every slot. In paged mode each replica
instead owns a shared pool of fixed-size pages: a request holds
``ceil(context/page_size)`` pages per group named by its block table,
``decode_paged`` reads the scattered cache directly, the router weighs
replicas by free pages, and page exhaustion preempts the youngest
resident back to the queue (loss-free: prompt + generated re-prefill).

Quantized KV pages (``kv_dtype="int8"``)
----------------------------------------
Pages default to the model's compute dtype; ``kv_dtype="int8"`` stores
int8 entries with one fp32 amax scale per page row, quantized at
scatter time (decode, chunked and whole prefill write bit-identical
pages) and dequantized inside the page gather — Pallas kernels and the
XLA fallback alike. KV bytes per token drop 4x (fp32 compute) / 2x
(bf16), so the same pool admits proportionally more residents
(``benchmarks/quant_kv_bench.py``; accuracy swept in
``tests/test_quant_kv.py``).

Mesh-sharded execution (``mesh=...``)
-------------------------------------
With a ``(data, model)`` serving mesh (``launch.mesh.make_serving_mesh``)
each replica owns a tensor-parallel **submesh**: the mesh's data axis is
carved into per-replica device slices
(:func:`repro.distributed.sharding.replica_submeshes`, round-robin when
replicas outnumber slices) and every stage's params are placed once per
slice under ``SERVE_RULES`` NamedShardings — TP over ``model``,
replicated over ``data`` — so one jitted dispatch per replica step
lowers to collectives over the slice's devices, with no per-device
Python loop. KV caches and paged pools are committed to the owning
replica's submesh (sharded only on ``cache_batch``, which is the data
axis — i.e. fully replicated *within* a tensor-parallel slice), so a
replica's cache never straddles replica boundaries and the Router
routes over real disjoint device sets. Stage handoffs between replicas
on different slices are placed onto the consuming replica's submesh at
assembly time — a device-to-device transfer, dispatched inside the
async ring's dispatch phase (no host sync: d2h stays commit-only under
the sanitizer contract). Token streams are bit-for-bit identical to the
single-device engine (``tests/test_mesh_serving.py``,
``benchmarks/mesh_bench.py``).

Async engine core (``async_depth=K``)
-------------------------------------
The step loop is split into a **producer** (scheduler decisions + call
assembly + jitted dispatch) and a **consumer** (the committer: batched
argmax readback through ``host_readback``, slot/page release, failover
re-queue). A dispatch no longer blocks on its own results: each
``_StageCall`` carries *deferred readbacks* — the device argmax arrays
plus finalizer closures — and the host sync happens only when the call
is committed from the per-replica completion queue, never at dispatch.
Each (group, replica) owns an in-flight ring of up to ``async_depth``
calls, so a replica dispatches its next call (over members not already
in flight) while previous ones are still executing; JAX async dispatch
overlaps the device work with all host-side scheduling in between.

* ``async_depth=0`` — legacy synchronous engine: ring depth 1 and the
  readback happens eagerly at dispatch (the pre-async behavior, kept as
  the differential baseline).
* ``async_depth=1`` — ring depth 1, commit-time readback. Scheduling,
  token streams and ``ServerStats`` are *identical* to depth 0; only
  the host no longer stalls inside the dispatch phase.
* ``async_depth>=2`` — true in-flight pipelining: queued calls charge
  energy and advance every slot and commit in dispatch order.

Abort-safety contract: a replica death mid-flight discards every ring
entry *without finalizing its readbacks* — deferred results are
dropped on the floor, members are re-queued by the scheduler, and no
request state is ever mutated from a call that did not commit. Token
streams are therefore bit-for-bit identical across every depth
(``tests/test_async_engine.py`` proves this differentially under
admission, chunked prefill, preemption and double failover).

Speculative draft-verify decoding (``spec_draft=(model, params)``)
------------------------------------------------------------------
Plain decode pays one full pipeline dispatch per token. With a draft
model attached, each decode round instead (1) runs the draft
autoregressively for ``spec_k`` greedy tokens in ONE scanned dispatch
on the stage-0 replica (argmax chained on device — no host sync), then
(2) verifies all ``spec_k + 1`` positions in ONE
``verify_step_paged`` chunk call per stage — the existing paged
chunk-prefill computation, no new kernel. The accept rule is greedy
prefix match on the verify argmaxes, so committed streams are
**bit-for-bit identical** to plain paged decode (the paged chunk and
decode paths share one attention reduction order — proven in
``tests/test_spec_decode.py``); a round commits between 1 (all drafts
rejected: the verify's own argmax) and ``spec_k + 1`` tokens per
pipeline pass. Rejected rows are rewound through
``KVCacheManager.rollback`` (pure host accounting: stale rows past the
length mirror are never attended and are re-written before any later
read). The commit finalizer is deferred-readback compatible with the
async ring at any depth; a round broken by replica death or preemption
is rewound by ``StepScheduler.rewind_spec`` to exactly the state plain
decode would have left. Energy is charged per *call*; throughput is
reported per *accepted token* (``ServerStats.accepted_tokens``,
``acceptance_rate``).
"""

from __future__ import annotations

import dataclasses
import time
from collections import Counter, deque
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import NamedSharding, PartitionSpec

from ..analysis.sanitizer import host_readback, mark_engine_phase, mark_engine_step
from ..core.power import PowerModePolicy, dynamic_policy
from ..distributed.sharding import (
    SERVE_RULES,
    param_shardings,
    replica_submeshes,
    serve_cache_spec,
)
from ..models.registry import Model
from .budget import ReplicaBudget
from .cache import DenseSlotCache, KVCacheManager, PagedKVCache
from .partition import partition_model
from .router import Router
from .scheduler import Request, StepScheduler

__all__ = [
    "Request",
    "PipelineServer",
    "ServerStats",
    "trace_counts",
    "reset_trace_counts",
]


# --- compile accounting ---------------------------------------------------
# Incremented inside the traced stage entry points, so it counts actual jit
# cache misses (= XLA compiles) per (kind, stage, shape). Used by the
# chunked-prefill compile-count regression test and benchmarks/chunked_bench.
_TRACE_COUNTS: Counter = Counter()


def trace_counts() -> dict[tuple, int]:
    """jit trace (cache-miss) count per ``(kind, stage, *shape)``."""
    return dict(_TRACE_COUNTS)


def reset_trace_counts() -> None:
    _TRACE_COUNTS.clear()


def _count_trace(kind: str, g: int, *shape: int) -> None:
    _TRACE_COUNTS[(kind, g) + tuple(shape)] += 1


@dataclasses.dataclass
class _StageCall:
    """One in-flight batched stage execution on a (group, replica).

    ``outputs[i]`` is a ``(kind, value, advance)`` tuple per member:
    ``("token", t, 0)`` — final-stage token; ``("hidden", h, 0)`` —
    handoff to the next stage; ``("chunk_part", h|None, n)`` — ``n``
    more prompt tokens consumed, prefill continues next step;
    ``("chunk_done", t|h, n)`` — the chunk that completed the stage's
    prefill.

    Token-valued entries are *deferred*: at dispatch they hold ``None``
    and ``readbacks`` carries ``(device_array, finalize)`` pairs — the
    batched argmax outputs still in flight plus the closures that patch
    the host integers into ``outputs``. The committer drains them
    through :func:`host_readback` when the call completes; an aborted
    call (replica death mid-flight) is discarded with its readbacks
    unfinalized, so a dead dispatch can never mutate request state.
    """

    members: list[Request]
    outputs: list[tuple]
    readbacks: list[tuple]
    pm: int
    slots_left: int
    t_dispatch: float = 0.0
    # Stamped the moment the call's device slots complete (dispatch-
    # observable time) — NOT when the completion queue finally drains
    # it; TTFT accounting reads these, so a deep ring cannot inflate it.
    t_ready: float | None = None
    ready_slot: int | None = None


@dataclasses.dataclass
class ServerStats:
    submitted: int = 0
    completed_jobs: int = 0
    dropped_jobs: int = 0
    queued_jobs: int = 0  # submissions that waited in the pending queue
    tokens_generated: int = 0
    accepted_tokens: int = 0  # committed tokens, dispatch-observable —
    # identical to tokens_generated for the plain engine; the shared
    # metric spec and plain engines are compared on (a speculative round
    # commits a variable number of accepted tokens per verify call)
    stage_executions: int = 0  # per-request stage work units
    prefill_calls: int = 0  # batched JAX dispatches (whole-prompt prefill)
    chunk_prefill_calls: int = 0  # batched JAX dispatches (chunked prefill)
    decode_calls: int = 0  # batched JAX dispatches (decode)
    draft_calls: int = 0  # speculative: draft-model scan dispatches
    verify_calls: int = 0  # speculative: target verify chunk dispatches
    spec_rounds: int = 0  # speculative rounds committed
    spec_proposed: int = 0  # draft tokens proposed to verification
    spec_accepted: int = 0  # draft tokens accepted (excl. bonus tokens)
    energy_charged: float = 0.0  # total CE(PM)/kappa charged across calls
    rerouted_stages: int = 0
    preempted_jobs: int = 0  # paged: evicted on page exhaustion, requeued
    aged_placements: int = 0  # parked > max_park_steps: force-placed
    peak_active: int = 0  # max concurrently resident requests
    inflight_peak: int = 0  # max calls in one replica's in-flight ring
    slots: int = 0
    downtime_replica_slots: int = 0  # whole (replica, slot) pairs down
    n_groups: int = 1
    n_replicas: int = 1

    @property
    def downtime_fraction(self) -> float:
        denom = self.slots * self.n_groups * self.n_replicas
        return self.downtime_replica_slots / max(denom, 1)

    @property
    def acceptance_rate(self) -> float:
        """Fraction of proposed draft tokens the target accepted."""
        return self.spec_accepted / max(self.spec_proposed, 1)


def _pad_tail(x, C: int):
    """Pad a [1, c, ...] chunk slice to width ``C`` along axis 1."""
    c = x.shape[1]
    if c == C:
        return x
    pad = [(0, 0), (0, C - c)] + [(0, 0)] * (x.ndim - 2)
    return jnp.pad(x, pad)


def _seq_len(seq) -> int:
    """Length of a stage input: [S] token ids or [1, S, D] hidden."""
    return seq.shape[1] if seq.ndim >= 2 else len(seq)


def _group_by_len(jobs) -> dict[int, list]:
    """Whole-prompt prefill pays one dispatch per distinct input length."""
    by_len: dict[int, list] = {}
    for i, m, inp in jobs:
        by_len.setdefault(int(inp.shape[1]), []).append((i, m, inp))
    return by_len


def _emit_whole_outputs(server, g, grp, out, outputs, mgr, length, readbacks):
    """Shared whole-prefill tail for both backends: record the host
    length mirror and emit one deferred token readback (batched argmax,
    one host sync at commit) or hidden handoff per member of a
    same-length dispatch group."""
    for _, m, _ in grp:
        mgr.lengths[m.slot_ids[g]] = length
    if g == server.G - 1:
        idxs = [i for i, _, _ in grp]

        def fin(toks, idxs=idxs):
            for j, i in enumerate(idxs):
                outputs[i] = ("token", int(toks[j]), 0)

        readbacks.append((jnp.argmax(out[:, 0, -1], axis=-1), fin))
    else:
        for j, (i, _, _) in enumerate(grp):
            outputs[i] = ("hidden", out[j], 0)


def _emit_chunk_outputs(server, g, jobs, outputs, mgr, argmax, hidden_at, readbacks):
    """Shared chunk-job tail for both backends: advance the host length
    mirror, decide per-lane completion, and emit ``chunk_part`` /
    ``chunk_done`` results. ``argmax`` is the batched [W, C] argmax
    device array (last stage only — its readback is deferred to
    commit); ``hidden_at(slot, valid)`` slices a lane's [1, valid, D]
    hidden from the dispatch output (mid stages only)."""
    last = g == server.G - 1
    finals: list[tuple[int, int, int]] = []
    for i, m, seq, pos, valid in jobs:
        slot = m.slot_ids[g]
        mgr.lengths[slot] = pos + valid
        done = pos + valid == _seq_len(seq)
        if last:
            if done:
                finals.append((i, slot, valid))
            outputs[i] = ("chunk_done" if done else "chunk_part", None, valid)
        else:
            value = hidden_at(slot, valid)
            outputs[i] = ("chunk_done" if done else "chunk_part", value, valid)
    if last:
        # One deferred readback per chunk dispatch (sync-count parity
        # with the pre-async engine even when no lane completed).
        def fin(toks, finals=finals):
            for i, slot, valid in finals:
                outputs[i] = ("chunk_done", int(toks[slot, valid - 1]), valid)

        readbacks.append((argmax, fin))


class _SpecState:
    """Speculative-decoding state: the draft model, its per-stage-0-replica
    slot-stacked dense caches, the host lockstep mirrors, and the two
    jitted draft entry points.

    The draft runs *unpartitioned* on each stage-0 replica: one dense
    cache of ``max_batch`` lanes keyed by the replica's stage-0 slot ids.
    ``rid``/``lens`` are host mirrors of which request owns each draft
    lane and how many rows of its true stream (prompt + committed
    tokens) are valid — a mismatched rid (lane reuse, failover) rebuilds
    the lane from position 0 via fixed-width catch-up ingests, so draft
    state needs no abort protocol of its own: it is *advisory* and every
    committed token comes from the target's verify.
    """

    def __init__(self, server: "PipelineServer", draft: Model, draft_params, k: int):
        self.model = draft
        self.params = draft_params
        self.k = k
        W = server.max_batch
        # Draft rows past the target's max_len are never *read* (requests
        # complete within max_len) but the fixed-width ingest and the
        # k-step scan may *write* up to k positions past the committed
        # context; the headroom keeps every dynamic-slice write in bounds
        # (a clamped start would silently overwrite live rows).
        shapes = draft.cache_shapes(1, server.max_len + k + 1)
        self.caches = {
            r: server._place(
                r,
                jax.tree_util.tree_map(
                    lambda sh: jnp.zeros((W,) + tuple(sh.shape), sh.dtype), shapes
                ),
            )
            for r in range(server.R)
        }
        # The draft runs unpartitioned, so under a mesh its params are
        # simply replicated onto each stage-0 replica's submesh (one
        # copy per distinct data slice).
        self._placed_params = None
        self._slice_of = server._slice_of
        if server._repl_shardings is not None:
            self._placed_params = {}
            for r in range(server.R):
                d = self._slice_of[r]
                if d not in self._placed_params:
                    self._placed_params[d] = jax.device_put(
                        draft_params, server._repl_shardings[r]
                    )
        self.rid = {r: np.full((W,), -1, np.int64) for r in range(server.R)}
        self.lens = {r: np.zeros((W,), np.int64) for r in range(server.R)}

        model = draft

        def merge(mask, new, old):
            return jax.tree_util.tree_map(
                lambda n, o: jnp.where(
                    mask.reshape((mask.shape[0],) + (1,) * (n.ndim - 1)), n, o
                ),
                new,
                old,
            )

        @partial(jax.jit, donate_argnums=(2,))
        def draft_ingest(params, buf, cache, offs, valids, mask):
            # buf: [W, 1, C] catch-up token chunks (lane rebuilds after
            # failover / reuse); masked-out lanes keep their cache.
            _count_trace("draft_ingest", 0, buf.shape[0], buf.shape[2])
            _, new = model.prefill_chunk_batch(
                params, {"tokens": buf}, cache, offs, valids
            )
            return merge(mask, new, cache)

        @partial(jax.jit, donate_argnums=(2,))
        def draft_round(params, buf, cache, offs, valids, tok0, mask):
            # ONE dispatch per round: ingest the <= C tokens the draft has
            # not seen yet (usually the previous round's accepted tail),
            # then scan k greedy steps chaining the argmax on device —
            # the k draft tokens never touch the host inside the round.
            _count_trace("draft_round", 0, buf.shape[0], buf.shape[2])
            _, c = model.prefill_chunk_batch(
                params, {"tokens": buf}, cache, offs, valids
            )

            def step(carry, _):
                tok, c = carry
                logits, c = model.decode_batch(params, tok[:, None, None], c)
                nxt = jnp.argmax(logits[:, 0, -1], axis=-1).astype(jnp.int32)
                return (nxt, c), nxt

            (_, c), drafts = jax.lax.scan(step, (tok0, c), None, length=k)
            return drafts.T, merge(mask, c, cache)  # [W, k], merged cache

        self.draft_ingest = draft_ingest
        self.draft_round = draft_round

    def params_for(self, r: int):
        if self._placed_params is None:
            return self.params
        return self._placed_params[self._slice_of[r]]


class _DenseExec:
    """Dense execution backend for one stage: slot-stacked cache, vmapped
    entry points, masked full-width decode/chunk dispatches."""

    def __init__(self, server: "PipelineServer", g: int):
        self.server = server
        self.g = g
        model_g, _ = server.stages[g]
        self.model_g = model_g
        max_len = server.max_len

        @partial(jax.jit, donate_argnums=(2,))
        def prefill_into(params, batch, cache, slot_idx):
            # batch leaves: [N, 1, S(, D)] — N joining requests, same S.
            leaf = jax.tree_util.tree_leaves(batch)[0]
            _count_trace("prefill", g, leaf.shape[0], leaf.shape[2])
            out, new = model_g.prefill_batch(params, batch, max_len)
            cache = jax.tree_util.tree_map(
                lambda big, small: big.at[slot_idx].set(small), cache, new
            )
            return out, cache

        @partial(jax.jit, donate_argnums=(2,))
        def decode_masked(params, inp, cache, mask):
            # inp: [W, 1, 1(, D)] over the full slot width W = max_batch;
            # mask selects participating slots — the others' caches are
            # preserved by the select (their computed garbage is dropped).
            _count_trace("decode", g, mask.shape[0])
            out, new = model_g.decode_batch(params, inp, cache)
            merged = jax.tree_util.tree_map(
                lambda n, o: jnp.where(
                    mask.reshape((mask.shape[0],) + (1,) * (n.ndim - 1)), n, o
                ),
                new,
                cache,
            )
            return out, merged

        self.prefill_into = prefill_into
        self.decode_masked = decode_masked
        self.chunk_masked = None
        if server.prefill_chunk is not None:

            @partial(jax.jit, donate_argnums=(2,))
            def chunk_masked(params, inp, cache, offs, valids, mask):
                # inp leaves: [W, 1, C(, D)] — one fixed chunk width for
                # every prompt length in the workload.
                leaf = jax.tree_util.tree_leaves(inp)[0]
                _count_trace("chunk", g, leaf.shape[0], leaf.shape[2])
                out, new = model_g.prefill_chunk_batch(
                    params, inp, cache, offs, valids
                )
                merged = jax.tree_util.tree_map(
                    lambda n, o: jnp.where(
                        mask.reshape((mask.shape[0],) + (1,) * (n.ndim - 1)), n, o
                    ),
                    new,
                    cache,
                )
                return out, merged

            self.chunk_masked = chunk_masked

    def init_cache(self, r):
        """Zeroed slot-stacked cache: [max_batch, <B=1 cache>],
        committed to replica ``r``'s submesh under a serving mesh
        (sharded only on the leading slot axis = ``cache_batch``)."""
        s = self.server
        shapes = self.model_g.cache_shapes(1, s.max_len)
        cache = jax.tree_util.tree_map(
            lambda sh: jnp.zeros((s.max_batch,) + tuple(sh.shape), sh.dtype), shapes
        )
        return s._place_cache(self.g, r, cache)

    # -- dispatches ------------------------------------------------------
    def run_prefill_whole(self, r, jobs, outputs, mgr: KVCacheManager, readbacks):
        """jobs: [(out_idx, member, inp [1,S(,D)])], grouped by length."""
        s, g = self.server, self.g
        params_g = s._params_for(g, r)
        cache = s._caches[(g, r)]
        key = "tokens" if g == 0 else "hidden"
        for length, grp in sorted(_group_by_len(jobs).items()):
            stacked = jnp.stack([s._place(r, inp) for _, _, inp in grp])
            slots = jnp.asarray([m.slot_ids[g] for _, m, _ in grp], jnp.int32)
            out, cache = self.prefill_into(params_g, {key: stacked}, cache, slots)
            s.stats.prefill_calls += 1
            _emit_whole_outputs(s, g, grp, out, outputs, mgr, length, readbacks)
        s._caches[(g, r)] = cache

    def run_chunks(self, r, jobs, outputs, mgr: KVCacheManager, readbacks):
        """jobs: [(out_idx, member, seq, pos, valid)] — one fixed-shape
        masked dispatch advances every joining prompt by <= C tokens."""
        s, g = self.server, self.g
        params_g = s._params_for(g, r)
        C = s.prefill_chunk
        W = s.max_batch
        cache = s._caches[(g, r)]
        last = g == s.G - 1
        mask = np.zeros((W,), bool)
        offs = np.zeros((W,), np.int32)
        valids = np.zeros((W,), np.int32)
        for _, m, _, pos, valid in jobs:
            slot = m.slot_ids[g]
            mask[slot] = True
            offs[slot] = pos
            valids[slot] = valid
        if g == 0:
            buf = np.zeros((W, 1, C), np.int32)
            for _, m, seq, pos, valid in jobs:
                buf[m.slot_ids[g], 0, :valid] = seq[pos : pos + valid]
            inp = {"tokens": jnp.asarray(buf)}
        else:
            slots = np.asarray([m.slot_ids[g] for _, m, _, _, _ in jobs], np.int32)
            hs = jnp.stack(
                [
                    s._place(r, _pad_tail(seq[:, pos : pos + valid], C))
                    for _, _, seq, pos, valid in jobs
                ]
            )  # [N, 1, C, D]
            inp = {
                "hidden": jnp.zeros((W, 1, C, s.cfg.d_model), hs.dtype)
                .at[jnp.asarray(slots)]
                .set(hs)
            }
        out, cache = self.chunk_masked(
            params_g, inp, cache, jnp.asarray(offs), jnp.asarray(valids),
            jnp.asarray(mask),
        )
        s._caches[(g, r)] = cache
        s.stats.chunk_prefill_calls += 1
        argmax = jnp.argmax(out[:, 0], axis=-1) if last else None
        _emit_chunk_outputs(
            s, g, jobs, outputs, mgr, argmax,
            lambda slot, valid: out[slot, :, :valid],  # [1, valid, D]
            readbacks,
        )

    def run_decode(self, r, jobs, outputs, mgr: KVCacheManager, readbacks):
        """jobs: [(out_idx, member)] — one masked dispatch over the full
        static slot width."""
        s, g = self.server, self.g
        params_g = s._params_for(g, r)
        cache = s._caches[(g, r)]
        last = g == s.G - 1
        W = s.max_batch
        mask = np.zeros((W,), bool)
        slots = np.asarray([m.slot_ids[g] for _, m in jobs], np.int32)
        mask[slots] = True
        if g == 0:
            buf = np.zeros((W, 1, 1), np.int32)
            for _, m in jobs:
                buf[m.slot_ids[g], 0, 0] = m.generated[-1]
            inp = jnp.asarray(buf)
        else:
            # Assemble on device: the handoffs are device arrays and a
            # host round-trip per member would not amortize. After an
            # upstream re-prefill the handoff carries the whole
            # prefix; a caching stage only consumes the newest position.
            hs = jnp.stack(
                [
                    s._place(r, m.hidden if m.hidden.shape[1] == 1 else m.hidden[:, -1:])
                    for _, m in jobs
                ]
            )
            inp = (
                jnp.zeros((W, 1, 1, s.cfg.d_model), hs.dtype)
                .at[jnp.asarray(slots)]
                .set(hs)
            )
        out, cache = self.decode_masked(params_g, inp, cache, jnp.asarray(mask))
        s._caches[(g, r)] = cache
        s.stats.decode_calls += 1
        for _, m in jobs:
            mgr.lengths[m.slot_ids[g]] += 1
        if last:
            # Capture concrete slot ints now: by commit time a member's
            # slot_ids could be rewritten by a later placement.
            pairs = [(i, m.slot_ids[g]) for i, m in jobs]

            def fin(toks, pairs=pairs):
                for i, slot in pairs:
                    outputs[i] = ("token", int(toks[slot]), 0)

            readbacks.append((jnp.argmax(out[:, 0, -1], axis=-1), fin))
        else:
            for i, m in jobs:
                outputs[i] = ("hidden", out[m.slot_ids[g]], 0)


class _PagedExec:
    """Paged execution backend for one stage: shared page pool, block
    tables from the manager, natively batched decode/chunk dispatches."""

    def __init__(self, server: "PipelineServer", g: int):
        self.server = server
        self.g = g
        model_g, _ = server.stages[g]
        self.model_g = model_g
        ps = server.page_size

        @partial(jax.jit, donate_argnums=(2,))
        def prefill_pages(params, batch, pools, page_ids):
            # batch leaves: [N, 1, S(, D)]; page_ids: [N, NBs] with
            # NBs * ps >= S. The transient dense cache is per-call only.
            # Compute-dtype pools only — int8 whole prefill goes through
            # prefill_whole_quant instead (see _run_prefill_whole_quant).
            leaf = jax.tree_util.tree_leaves(batch)[0]
            _count_trace("prefill_pages", g, leaf.shape[0], leaf.shape[2])
            N, NBs = page_ids.shape
            out, cache = model_g.prefill_batch(params, batch, NBs * ps)
            flat = page_ids.reshape(-1)

            def rows(leaf):
                # leaf: [N, n_layers, 1, NBs*ps, KV, Dh] -> page rows
                # [n_layers, N*NBs, ps, KV, Dh]
                n = leaf.shape[1]
                x = leaf[:, :, 0].reshape(N, n, NBs, ps, *leaf.shape[4:])
                return x.transpose(1, 0, 2, 3, 4, 5).reshape(
                    n, N * NBs, ps, *leaf.shape[4:]
                )

            new = dict(pools)
            new["k"] = pools["k"].at[:, flat].set(
                rows(cache["c0"]["k"]).astype(pools["k"].dtype)
            )
            new["v"] = pools["v"].at[:, flat].set(
                rows(cache["c0"]["v"]).astype(pools["v"].dtype)
            )
            return out, new

        @partial(jax.jit, donate_argnums=(2,))
        def decode_fn(params, inp, pools, lens, bt):
            _count_trace("decode_paged", g, lens.shape[0])
            return model_g.decode_paged(params, inp, pools, lens, bt)

        @partial(jax.jit, donate_argnums=(2,))
        def prefill_whole_quant(params, inp, pools, offs, valids, bt):
            # int8 pools only: whole-prompt prefill runs as ONE
            # whole-length chunk, so its logits come from the same
            # quantized pages every later read sees — chunked and
            # whole-prompt prefill stay token-exact at int8 (the
            # fp-exact prefill_pages path would emit its first token
            # from pre-quantization K/V the pool no longer holds).
            _count_trace("prefill_pages", g, inp.shape[0], inp.shape[1])
            return model_g.prefill_chunk_paged(
                params, inp, pools, offs, valids, bt
            )

        self.prefill_pages = prefill_pages
        self.prefill_whole_quant = prefill_whole_quant
        self.decode_fn = decode_fn
        self.chunk_pages = None
        if server.prefill_chunk is not None:

            @partial(jax.jit, donate_argnums=(2,))
            def chunk_pages(params, inp, pools, offs, valids, bt):
                # inp: [W, C(, D)] — one fixed chunk width; each lane's
                # K/V scatter into its reserved pages incrementally.
                _count_trace("chunk_paged", g, inp.shape[0], inp.shape[1])
                return model_g.prefill_chunk_paged(
                    params, inp, pools, offs, valids, bt
                )

            self.chunk_pages = chunk_pages
        self.verify_fn = None
        if server._spec is not None:

            @partial(jax.jit, donate_argnums=(2,))
            def verify_fn(params, inp, pools, offs, valids, bt):
                # inp: [W, k+1] — lane w holds [gen[-1], d_1..d_k] (stage
                # 0) or the upstream verify hidden (mid stages); one
                # chunk-shaped call verifies all k+1 positions, bit-exact
                # against sequential paged decode (no new kernel).
                _count_trace("verify_paged", g, inp.shape[0], inp.shape[1])
                return model_g.verify_step_paged(
                    params, inp, pools, offs, valids, bt
                )

            self.verify_fn = verify_fn

    def init_cache(self, r):
        """Shared page pool: [n_layers, P+1, page, KV, Dh] (page index P
        is the scratch page for masked lanes). ``kv_dtype="int8"`` pools
        store int8 entries plus one fp32 scale per page row (init 1.0 so
        untouched rows dequantize to 0)."""
        s = self.server
        c = self.model_g.cfg
        shape = (
            c.n_layers, s.max_pages + 1, s.page_size,
            c.n_kv_heads, c.head_dim,
        )
        pools = {
            "k": jnp.zeros(shape, s.kv_dtype),
            "v": jnp.zeros(shape, s.kv_dtype),
        }
        if s.kv_dtype == jnp.int8:
            # Distinct buffers: the dispatches donate the pool tree, and
            # XLA rejects donating one buffer at two argument positions.
            pools["k_scale"] = jnp.ones(shape[:3], jnp.float32)
            pools["v_scale"] = jnp.ones(shape[:3], jnp.float32)
        # The shared pool is addressed by page id, not by slot: no
        # ``cache_batch`` dim exists, so ``serve_cache_spec`` degenerates
        # to replication within the slice — which is exactly ``_place``.
        return s._place(r, pools)

    # -- dispatches ------------------------------------------------------
    def run_prefill_whole(self, r, jobs, outputs, mgr: PagedKVCache, readbacks):
        s, g = self.server, self.g
        params_g = s._params_for(g, r)
        cache = s._caches[(g, r)]
        if "k_scale" in cache:
            return self._run_prefill_whole_quant(r, jobs, outputs, mgr, readbacks)
        key = "tokens" if g == 0 else "hidden"
        for length, grp in sorted(_group_by_len(jobs).items()):
            stacked = jnp.stack([s._place(r, inp) for _, _, inp in grp])
            nbs = mgr.pool.blocks_for(length)
            page_ids = np.asarray(
                [mgr.pages[m.rid][:nbs] for _, m, _ in grp], np.int32
            )
            out, cache = self.prefill_pages(
                params_g, {key: stacked}, cache, jnp.asarray(page_ids)
            )
            s.stats.prefill_calls += 1
            _emit_whole_outputs(s, g, grp, out, outputs, mgr, length, readbacks)
        s._caches[(g, r)] = cache

    def _run_prefill_whole_quant(self, r, jobs, outputs, mgr: PagedKVCache, readbacks):
        """int8 pools: one whole-length chunk dispatch per distinct
        prompt length, over ONLY the joining lanes with a compact
        [N, nbs] block table (same work profile as the fp32
        prefill_pages path — dispatching the full slot width against
        the full-width table measured as a whole-percent tokens/s
        hit). The extra masked positions a full-width table would
        gather contribute exp(-inf) = 0, so the compact call is
        bit-identical to what the chunked path later reads."""
        s, g = self.server, self.g
        params_g = s._params_for(g, r)
        cache = s._caches[(g, r)]
        last = g == s.G - 1
        for length, grp in sorted(_group_by_len(jobs).items()):
            N = len(grp)
            nbs = mgr.pool.blocks_for(length)
            page_ids = np.asarray(
                [mgr.pages[m.rid][:nbs] for _, m, _ in grp], np.int32
            )
            offs = jnp.zeros((N,), jnp.int32)
            valids = jnp.full((N,), length, jnp.int32)
            if g == 0:
                inp_w = jnp.stack([jnp.asarray(inp[0]) for _, _, inp in grp])
            else:
                inp_w = jnp.stack([s._place(r, inp[0]) for _, _, inp in grp])  # [N, S, D]
            out, cache = self.prefill_whole_quant(
                params_g, inp_w, cache, offs, valids, jnp.asarray(page_ids)
            )
            s.stats.prefill_calls += 1
            for _, m, _ in grp:
                mgr.lengths[m.slot_ids[g]] = length
            if last:
                idxs = [i for i, _, _ in grp]

                def fin(toks, idxs=idxs):
                    for j, i in enumerate(idxs):
                        outputs[i] = ("token", int(toks[j]), 0)

                readbacks.append((jnp.argmax(out[:, length - 1], axis=-1), fin))
            else:
                for j, (i, _, _) in enumerate(grp):
                    outputs[i] = ("hidden", out[j, :length][None], 0)
        s._caches[(g, r)] = cache

    def run_chunks(self, r, jobs, outputs, mgr: PagedKVCache, readbacks):
        s, g = self.server, self.g
        params_g = s._params_for(g, r)
        C = s.prefill_chunk
        W = s.max_batch
        cache = s._caches[(g, r)]
        last = g == s.G - 1
        offs = np.full((W,), -1, np.int32)  # -1 = masked lane
        valids = np.zeros((W,), np.int32)
        for _, m, _, pos, valid in jobs:
            slot = m.slot_ids[g]
            offs[slot] = pos
            valids[slot] = valid
        if g == 0:
            buf = np.zeros((W, C), np.int32)
            for _, m, seq, pos, valid in jobs:
                buf[m.slot_ids[g], :valid] = seq[pos : pos + valid]
            inp = jnp.asarray(buf)
        else:
            slots = np.asarray([m.slot_ids[g] for _, m, _, _, _ in jobs], np.int32)
            hs = jnp.stack(
                [
                    s._place(r, _pad_tail(seq[:, pos : pos + valid], C)[0])
                    for _, _, seq, pos, valid in jobs
                ]
            )  # [N, C, D]
            inp = (
                jnp.zeros((W, C, s.cfg.d_model), hs.dtype)
                .at[jnp.asarray(slots)]
                .set(hs)
            )
        out, cache = self.chunk_pages(
            params_g, inp, cache,
            jnp.asarray(offs), jnp.asarray(valids), mgr.device_block_table(),
        )
        s._caches[(g, r)] = cache
        s.stats.chunk_prefill_calls += 1
        argmax = jnp.argmax(out, axis=-1) if last else None
        _emit_chunk_outputs(
            s, g, jobs, outputs, mgr, argmax,
            lambda slot, valid: out[slot, :valid][None],  # [1, valid, D]
            readbacks,
        )

    def run_decode(self, r, jobs, outputs, mgr: PagedKVCache, readbacks):
        """One natively-batched paged dispatch over the slot width.
        Lanes marked -1 write to the scratch page and attend one masked
        position; their outputs are never read. The device block table
        is cached by the manager and refreshed only on page alloc/free."""
        s, g = self.server, self.g
        params_g = s._params_for(g, r)
        cache = s._caches[(g, r)]
        last = g == s.G - 1
        W = s.max_batch
        lens_arr = np.full((W,), -1, np.int32)
        for _, m in jobs:
            slot = m.slot_ids[g]
            lens_arr[slot] = mgr.lengths[slot]
        if g == 0:
            buf = np.zeros((W, 1), np.int32)
            for _, m in jobs:
                buf[m.slot_ids[g], 0] = m.generated[-1]
            inp = jnp.asarray(buf)
        else:
            slots = np.asarray([m.slot_ids[g] for _, m in jobs], np.int32)
            # Hand-offs: [1, D] from an upstream decode, [1, S, D]
            # after an upstream re-prefill (consume the last position).
            hs = jnp.stack(
                [
                    s._place(r, m.hidden if m.hidden.ndim == 2 else m.hidden[:, -1])
                    for _, m in jobs
                ]
            )  # [N, 1, D]
            inp = (
                jnp.zeros((W, 1, s.cfg.d_model), hs.dtype)
                .at[jnp.asarray(slots)]
                .set(hs)
            )
        out, cache = self.decode_fn(
            params_g, inp, cache,
            jnp.asarray(lens_arr), mgr.device_block_table(),
        )
        s._caches[(g, r)] = cache
        s.stats.decode_calls += 1
        for _, m in jobs:
            mgr.lengths[m.slot_ids[g]] += 1
        if last:
            pairs = [(i, m.slot_ids[g]) for i, m in jobs]

            def fin(toks, pairs=pairs):
                for i, slot in pairs:
                    outputs[i] = ("token", int(toks[slot]), 0)

            readbacks.append((jnp.argmax(out[:, 0], axis=-1), fin))
        else:
            # Hand-offs stay [1, D] (not dense's [1, 1, D]): the
            # per-member [None] here costs one eagerly-dispatched
            # expand_dims per request per stage round, which measured
            # as a whole-percent tokens/s hit; both consumers branch
            # on ndim instead.
            for i, m in jobs:
                outputs[i] = ("hidden", out[m.slot_ids[g]], 0)

    def run_verify(self, r, jobs, outputs, mgr: PagedKVCache, readbacks, tok_dev):
        """jobs: [(out_idx, member, seq, pos, valid)] — ONE fixed-shape
        verify chunk covers every speculating lane's ``valid`` = k+1 (or
        fewer, near completion) positions. Stage 0 consumes the on-device
        token assembly built by the engine's draft runner; mid stages
        consume the upstream verify hidden. The host length mirror
        advances optimistically by ``valid`` — the accept finalizer (or
        an abort's ``rewind_spec``) rolls the rejected tail back."""
        s, g = self.server, self.g
        params_g = s._params_for(g, r)
        C = s._spec.k + 1
        W = s.max_batch
        cache = s._caches[(g, r)]
        last = g == s.G - 1
        offs = np.full((W,), -1, np.int32)  # -1 = masked lane
        valids = np.zeros((W,), np.int32)
        for _, m, _, pos, valid in jobs:
            slot = m.slot_ids[g]
            offs[slot] = pos
            valids[slot] = valid
        if g == 0:
            inp = tok_dev  # [W, C], assembled on device from the drafts
        else:
            slots = np.asarray([m.slot_ids[g] for _, m, _, _, _ in jobs], np.int32)
            hs = jnp.stack(
                [s._place(r, _pad_tail(seq, C)[0]) for _, _, seq, _, _ in jobs]
            )  # [N, C, D]
            inp = (
                jnp.zeros((W, C, s.cfg.d_model), hs.dtype)
                .at[jnp.asarray(slots)]
                .set(hs)
            )
        out, cache = self.verify_fn(
            params_g, inp, cache,
            jnp.asarray(offs), jnp.asarray(valids), mgr.device_block_table(),
        )
        s._caches[(g, r)] = cache
        s.stats.verify_calls += 1
        for _, m, _, pos, valid in jobs:
            mgr.lengths[m.slot_ids[g]] = pos + valid
            if m.spec_adv is None:
                m.spec_adv = [0] * s.G
            m.spec_adv[g] = valid
        if last:
            entries = [(i, m, m.slot_ids[g], valid) for i, m, _, _, valid in jobs]

            def fin(toks, entries=entries):
                for i, m, slot, v in entries:
                    # Greedy accept: row j predicts the token after input
                    # j, so drafts[a] is accepted while it matches row
                    # a's argmax; row a then donates the bonus token.
                    tgt = [int(toks[slot, j]) for j in range(v)]
                    drafts = m.spec_drafts or []
                    a = 0
                    while a < v - 1 and drafts[a] == tgt[a]:
                        a += 1
                    outputs[i] = ("spec_done", tgt[: a + 1], v)

            readbacks.append((jnp.argmax(out, axis=-1), fin))
        else:
            for i, m, _, _, valid in jobs:
                outputs[i] = ("spec_hidden", out[m.slot_ids[g], :valid][None], valid)


class PipelineServer:
    def __init__(
        self,
        model: Model,
        params,
        *,
        n_groups: int = 3,
        n_replicas: int = 3,
        policy: str = "adaptive",
        pm_policy: PowerModePolicy | None = None,
        harvest_bounds: tuple[float, float] = (6.0, 10.0),
        long_term_rates: np.ndarray | None = None,
        max_len: int = 256,
        max_batch: int = 4,
        max_queue: int | None = None,
        paged: bool = False,
        page_size: int = 16,
        max_pages: int | None = None,
        kv_dtype: str | None = None,
        prefill_chunk: int | None = None,
        max_park_steps: int | None = 32,
        async_depth: int = 2,
        spec_draft: tuple[Model, Any] | None = None,
        spec_k: int = 4,
        mesh=None,
        elastic=None,
        seed: int = 0,
    ):
        self.cfg = model.cfg
        self.stages = partition_model(model.cfg, params, n_groups)
        self.G, self.R = n_groups, n_replicas
        # Mesh-sharded execution: params TP over the model axis per
        # replica slice, caches committed to the owning slice. All state
        # is None without a mesh — every placement helper degrades to
        # identity and the engine is byte-for-byte the single-device one.
        self.mesh = mesh
        self.elastic = elastic
        self._slice_of: list[int] | None = None
        self._replica_meshes = None
        self._repl_shardings: list[NamedSharding] | None = None
        self._placed_params: dict[tuple[int, int], Any] | None = None
        if mesh is not None:
            slices, self._slice_of = replica_submeshes(mesh, n_replicas)
            self._replica_meshes = [slices[d] for d in self._slice_of]
            self._repl_shardings = [
                NamedSharding(m, PartitionSpec()) for m in self._replica_meshes
            ]
            self._placed_params = {}
            for g, (model_g, params_g) in enumerate(self.stages):
                for d, sub in enumerate(slices):
                    self._placed_params[(g, d)] = jax.device_put(
                        params_g,
                        param_shardings(model_g.template, sub, SERVE_RULES),
                    )
        self.max_len = max_len
        self.max_batch = max_batch
        self.paged = paged
        self.page_size = page_size
        self.prefill_chunk = prefill_chunk
        # KV page dtype: None keeps pages at the model's compute dtype;
        # "int8" quantizes at scatter (per-row fp32 scales ride along),
        # so the same pool bytes hold ~4x (fp32) / ~2x (bf16) the pages.
        if kv_dtype is not None and not paged:
            raise ValueError("kv_dtype applies to the paged KV cache only")
        self.kv_dtype = (
            jnp.dtype(model.cfg.compute_dtype)
            if kv_dtype is None
            else jnp.dtype(kv_dtype)
        )
        if self.kv_dtype not in (jnp.dtype(model.cfg.compute_dtype), jnp.int8):
            raise ValueError(
                f"kv_dtype must be the compute dtype or int8, got {kv_dtype}"
            )
        # Default pool = dense capacity (max_batch full-length contexts);
        # the paged win comes from setting max_pages *below* this while
        # raising max_batch — short requests then pack the same memory.
        nb_max = -(-max_len // page_size)
        self.max_pages = max_pages if max_pages is not None else max_batch * nb_max
        if paged and any(m.decode_paged is None for m, _ in self.stages):
            raise ValueError(
                f"{model.cfg.name}: paged serving needs uniform full "
                "attention (see repro.models.transformer.supports_paged)"
            )
        if prefill_chunk is not None:
            if prefill_chunk <= 0:
                raise ValueError("prefill_chunk must be a positive token count")
            if any(m.prefill_chunk is None for m, _ in self.stages):
                raise ValueError(
                    f"{model.cfg.name}: chunked prefill needs uniform full "
                    "attention (see repro.models.transformer.supports_paged)"
                )
        # Speculative draft-verify decoding: a (draft Model, draft params)
        # pair turns every decode round into k draft steps (one scanned
        # dispatch on the stage-0 replica) plus ONE k+1-wide verify chunk
        # on the target. Paged substrate only: the paged chunk and decode
        # paths share one attention reduction order, so greedy accept is
        # bit-for-bit against plain decode — the dense chunk path is not.
        self._spec = None
        if spec_draft is not None:
            if not paged:
                raise ValueError(
                    "speculative decoding runs on the paged substrate only "
                    "(the dense chunk path is not bit-exact vs decode)"
                )
            if spec_k < 1:
                raise ValueError("spec_k must be >= 1")
            draft_model, draft_params = spec_draft
            if any(m.verify_step_paged is None for m, _ in self.stages):
                raise ValueError(
                    f"{model.cfg.name}: speculative verify needs uniform full "
                    "attention (see repro.models.transformer.supports_paged)"
                )
            if (
                draft_model.prefill_chunk_batch is None
                or draft_model.decode_batch is None
            ):
                raise ValueError(
                    f"{draft_model.cfg.name}: a draft model needs chunked "
                    "prefill + batched decode (uniform full attention)"
                )
            if draft_model.cfg.vocab_size != model.cfg.vocab_size:
                raise ValueError(
                    "draft and target must share a vocabulary: "
                    f"{draft_model.cfg.vocab_size} vs {model.cfg.vocab_size}"
                )
        if async_depth < 0:
            raise ValueError("async_depth must be >= 0 (0 = legacy sync)")
        self.async_depth = async_depth
        # Ring capacity: depth 0 (legacy sync) still needs one open call.
        self._depth = max(1, async_depth)
        self.pm_policy = pm_policy or dynamic_policy(100)
        # Independent RNG streams: harvest/arrival draws and routing draws
        # must not be correlated (same-integer seeding would lockstep them).
        engine_seq, router_seq = np.random.SeedSequence(seed).spawn(2)
        self._rng = np.random.default_rng(engine_seq)
        # Replicas share stage weights (replication within a group) but
        # have independent budgets/harvests (heterogeneous nodes).
        lo, hi = harvest_bounds
        centers = self._rng.uniform(lo, hi, size=(self.G, self.R))
        self.harvest = np.stack([centers - 2.0, centers + 2.0], axis=-1).clip(0.0)
        self.budgets = [
            [ReplicaBudget(policy=self.pm_policy) for _ in range(n_replicas)]
            for _ in range(n_groups)
        ]
        self.router = Router(
            policy=policy, long_term_rates=long_term_rates, seed=router_seq
        )
        self.stats = ServerStats(n_groups=n_groups, n_replicas=n_replicas)
        self._next_rid = 0
        # One cache manager per (group, replica): the scheduler and the
        # single _start_call below talk only to this interface.
        if paged:
            self.managers: dict[tuple[int, int], KVCacheManager] = {
                (g, r): PagedKVCache(
                    max_batch, max_len, page_size, self.max_pages,
                    kv_dtype=str(self.kv_dtype),
                    # One snapshot buffer per possible in-flight call plus
                    # the one being built: a block-table refresh never
                    # touches a buffer a pending dispatch may still read.
                    table_buffers=self._depth + 1,
                )
                for g in range(n_groups)
                for r in range(n_replicas)
            }
        else:
            self.managers = {
                (g, r): DenseSlotCache(max_batch, max_len)
                for g in range(n_groups)
                for r in range(n_replicas)
            }
        self.scheduler = StepScheduler(
            budgets=self.budgets,
            managers=self.managers,
            router=self.router,
            stats=self.stats,
            max_queue=max_queue,
            max_park_steps=max_park_steps,
        )
        if self._repl_shardings is not None and paged:
            # Block-table snapshots must live where the pool lives, or
            # every paged dispatch re-transfers the table to the slice.
            for (g, r), mgr in self.managers.items():
                mgr.sharding = self._repl_shardings[r]
        if spec_draft is not None:
            # Built before _exec: the paged backend compiles its verify
            # entry point only when speculation is on.
            self._spec = _SpecState(self, spec_draft[0], spec_draft[1], spec_k)
        self._exec = self._build_exec()
        self._caches = {
            (g, r): self._exec[g].init_cache(r)
            for g in range(n_groups)
            for r in range(n_replicas)
        }
        # Per-replica in-flight rings (completion queues): producer
        # appends at dispatch, consumer drains committed heads in order.
        self._calls: dict[tuple[int, int], deque[_StageCall]] = {
            (g, r): deque() for g in range(n_groups) for r in range(n_replicas)
        }
        # (group, replica, perf_counter) per dispatch — async_bench reads
        # inter-dispatch gaps from this.
        self.dispatch_log: list[tuple[int, int, float]] = []
        self.scheduler.inflight = lambda: [
            [len(self._calls[(g, r)]) for r in range(self.R)]
            for g in range(self.G)
        ]

    # ------------------------------------------------------------------
    # Execution substrate (overridable: mpserve proxies these to worker
    # processes)
    # ------------------------------------------------------------------
    def _build_exec(self):
        return [
            (_PagedExec if self.paged else _DenseExec)(self, g)
            for g in range(self.G)
        ]

    def _params_for(self, g: int, r: int):
        """Stage ``g``'s params as replica ``r``'s dispatch should see
        them: the raw tree without a mesh, the slice-placed TP copy with
        one."""
        if self._placed_params is None:
            return self.stages[g][1]
        return self._placed_params[(g, self._slice_of[r])]

    def _place(self, r: int, x):
        """Commit an array (or tree) to replica ``r``'s submesh, replicated.

        Identity without a mesh. A handoff produced on another replica's
        slice becomes a real device-to-device transfer here — issued in
        the dispatch phase with no host sync; placing an array already
        on the slice is a no-op.
        """
        if self._repl_shardings is None:
            return x
        return jax.device_put(x, self._repl_shardings[r])

    def _place_cache(self, g: int, r: int, cache):
        """Commit stage ``g``'s slot-stacked cache to replica ``r``'s
        submesh under :func:`serve_cache_spec`: each leaf shards only on
        its ``cache_batch`` (slot) dim — the data axis, size 1 inside a
        tensor-parallel slice — and replicates everywhere else, so a
        replica's cache never straddles a slice boundary. Identity
        without a mesh; models that declare no cache axes fall back to
        plain replication."""
        if self._repl_shardings is None:
            return cache
        model_g = self.stages[g][0]
        if model_g.cache_axes is None:
            return jax.device_put(cache, self._repl_shardings[r])
        mesh = self._replica_meshes[r]
        leaves, treedef = jax.tree_util.tree_flatten(cache)
        names = treedef.flatten_up_to(model_g.cache_axes())
        return jax.tree_util.tree_unflatten(
            treedef,
            [
                jax.device_put(
                    leaf,
                    NamedSharding(mesh, serve_cache_spec(leaf.shape, n, mesh)),
                )
                for leaf, n in zip(leaves, names)
            ],
        )

    def _on_ring_abort(self, g: int, r: int) -> None:
        """Hook: a dead replica's in-flight ring was just discarded.
        The multi-process engine drains the worker's now-orphaned RPC
        responses here; in-process execution has nothing to clean up."""

    # ------------------------------------------------------------------
    # Admission
    # ------------------------------------------------------------------
    def submit(self, tokens: np.ndarray, n_tokens: int = 8) -> Request | None:
        """Admit a new request (one replica + batch slot per group, Alg. 1)
        or hold it in the pending queue when the fleet is full."""
        self.stats.submitted += 1
        req = Request(
            rid=self._next_rid,
            prompt=np.asarray(tokens),
            n_tokens=n_tokens,
            t_submit=time.perf_counter(),
            submit_slot=self.stats.slots,
        )
        self._next_rid += 1
        return self.scheduler.submit(req)

    # ------------------------------------------------------------------
    # Batched stage execution (single path over KVCacheManager)
    # ------------------------------------------------------------------
    def _stage_input(self, req: Request, g: int):
        """The sequence this request still has to prefill at stage g."""
        if g == 0:
            ids = np.asarray(req.prompt, np.int32)
            if req.generated:
                # Failover/preemption re-prefill: rebuild the full prefix
                # — prompt plus every generated token, the current round's
                # input included — from the immutable prompt. The last
                # position's output then replaces the decode step the dead
                # replica lost, so decoding stays token-exact across any
                # number of failovers.
                ids = np.concatenate([ids, np.asarray(req.generated, np.int32)])
            return ids
        # Upstream handoff: [1, S, D] after a prefill/chunk assembly,
        # [1, D] ([1, 1, D] dense) after an upstream decode.
        h = req.hidden
        return h[:, None] if h.ndim == 2 else h

    def _run_draft(self, r: int, jobs, readbacks):
        """Draft work for a stage-0 verify call: catch each lane's draft
        cache up to the committed stream (usually just the previous
        round's accepted tail), then scan ``k`` greedy draft steps in ONE
        dispatch, chaining the argmax on device. Returns the [W, k+1]
        on-device verify input — lane w = [gen[-1], d_1..d_k] — with no
        host sync in the dispatch phase; the drafts' host copies ride the
        call's deferred readbacks (needed only by the accept finalizer).
        """
        spec = self._spec
        k = spec.k
        C = k + 1
        W = self.max_batch
        cache = spec.caches[r]
        tok0 = np.zeros((W,), np.int32)
        entries = []  # [member, slot, ctx, draft_len, L] — lanes that draft
        dr_entries = []
        for _, m, _, pos, valid in jobs:
            slot = m.slot_ids[0]
            ctx = np.concatenate(
                [np.asarray(m.prompt, np.int64), np.asarray(m.generated, np.int64)]
            )
            L = len(ctx) - 1  # committed rows; ctx[L] = the round's true input
            tok0[slot] = ctx[L]
            if valid < 2:
                continue  # request's last token: nothing to draft
            if spec.rid[r][slot] != m.rid:
                # First round on this lane (or the lane was reused): the
                # draft knows nothing of the stream — rebuild from 0.
                spec.rid[r][slot] = m.rid
                spec.lens[r][slot] = 0
            entries.append([m, slot, ctx, int(spec.lens[r][slot]), L])
            dr_entries.append((m, slot, valid - 1))
        if not entries:
            drafts = jnp.zeros((W, k), jnp.int32)
        else:
            # Catch-up: a rebuilt lane may be arbitrarily far behind; feed
            # fixed C-wide chunks until one round's ingest suffices.
            while any(e[4] - e[3] > C for e in entries):
                offs = np.zeros((W,), np.int32)
                valids = np.zeros((W,), np.int32)
                mask = np.zeros((W,), bool)
                buf = np.zeros((W, 1, C), np.int32)
                for e in entries:
                    _, slot, ctx, dl, L = e
                    if L - dl > C:
                        mask[slot] = True
                        offs[slot] = dl
                        valids[slot] = C
                        buf[slot, 0, :] = ctx[dl : dl + C]
                        e[3] = dl + C
                cache = spec.draft_ingest(
                    spec.params_for(r), jnp.asarray(buf), cache,
                    jnp.asarray(offs), jnp.asarray(valids), jnp.asarray(mask),
                )
                self.stats.draft_calls += 1
            offs = np.zeros((W,), np.int32)
            valids = np.zeros((W,), np.int32)
            mask = np.zeros((W,), bool)
            buf = np.zeros((W, 1, C), np.int32)
            for _, slot, ctx, dl, L in entries:
                mask[slot] = True
                gap = L - dl
                if gap > 0:
                    offs[slot] = dl
                    valids[slot] = gap
                    buf[slot, 0, :gap] = ctx[dl:L]
                else:
                    # Caught up (an abandoned round can even leave the
                    # draft one speculative row ahead): ingest nothing,
                    # just pin the draft context length back to L.
                    offs[slot] = L
                    valids[slot] = 0
                spec.lens[r][slot] = L + 1  # the scan writes ctx[L]'s row
            drafts, cache = spec.draft_round(
                spec.params_for(r), jnp.asarray(buf), cache,
                jnp.asarray(offs), jnp.asarray(valids),
                jnp.asarray(tok0), jnp.asarray(mask),
            )
            self.stats.draft_calls += 1

            def fin(d, dr=dr_entries):
                for m, slot, ke in dr:
                    m.spec_drafts = [int(x) for x in d[slot, :ke]]

            readbacks.append((drafts, fin))
        spec.caches[r] = cache
        return jnp.concatenate([jnp.asarray(tok0)[:, None], drafts], axis=1)

    def _start_call(self, g: int, r: int, members: list[Request]) -> _StageCall | None:
        """Issue the batched JAX work for every member and open the call.

        One implementation for both cache layouts: members secure memory
        through the manager oldest-first (the scheduler preempts the
        youngest resident on paged exhaustion — members that cannot get
        memory this slot are deferred), then at most three fixed-shape
        dispatches run — whole-prompt prefills (per distinct length,
        legacy path), ONE chunked-prefill call, and ONE masked decode —
        so prefill chunks and decode tokens are co-scheduled per step.
        """
        mgr = self.managers[(g, r)]
        sched = self.scheduler
        chunk = self.prefill_chunk
        t_dispatch = time.perf_counter()

        # Build each member's work item first (prefill length drives page
        # demand), then secure memory oldest-first; _ensure may preempt
        # younger members — skip those when reached (queued/dropped flips).
        plan: dict[int, tuple] = {}
        need: dict[int, int] = {}
        spec = self._spec
        for m in members:
            if m.cache_ready[g]:
                # Speculative rounds start at stage 0; a mid stage joins
                # one only while the round is live (spec_adv[0] set by the
                # stage-0 verify dispatch) — after a mid-round failover
                # re-prefill the handoff is a plain prefix and downstream
                # stages fall back to plain decode for the pass.
                if spec is not None and (
                    g == 0 or (m.spec_adv is not None and m.spec_adv[0] > 0)
                ):
                    if g == 0:
                        v = min(spec.k + 1, m.n_tokens - len(m.generated))
                    else:
                        v = m.spec_adv[0]
                    plan[m.rid] = ("spec", v)
                    need[m.rid] = int(mgr.lengths[m.slot_ids[g]]) + v
                else:
                    plan[m.rid] = ("decode",)
                    need[m.rid] = int(mgr.lengths[m.slot_ids[g]]) + 1
            else:
                if chunk is not None:
                    # Cache the assembled stage input across chunk steps
                    # (stage 0 re-prefill would otherwise re-concatenate
                    # prompt + generated once per chunk — O(S^2/C) host
                    # copying). Reset on failover/preemption via chunk_seq.
                    if m.chunk_seq is None:
                        m.chunk_seq = self._stage_input(m, g)
                    seq = m.chunk_seq
                    pos = m.chunk_pos
                    valid = min(chunk, _seq_len(seq) - pos)
                    plan[m.rid] = ("chunk", seq, pos, valid)
                    need[m.rid] = pos + valid
                else:
                    seq = self._stage_input(m, g)
                    # Host-side [1, S] here; the exec backend's jnp.stack
                    # moves it to the device (or the remote backend ships
                    # it as-is — no device array ever enters MP dispatch).
                    inp = np.asarray(seq)[None, :] if g == 0 else seq
                    plan[m.rid] = ("whole", inp)
                    need[m.rid] = _seq_len(seq)
        served: list[Request] = []
        protected: set[int] = set()
        for m in sorted(members, key=lambda q: q.rid):
            if m.queued or m.dropped:
                continue  # preempted/dropped by an earlier member's ensure
            if sched.ensure_capacity(g, r, m, need[m.rid], protected | {m.rid}):
                served.append(m)
                protected.add(m.rid)
        if not served:
            return None

        outputs: list[tuple] = [None] * len(served)
        whole_jobs, chunk_jobs, decode_jobs, spec_jobs = [], [], [], []
        for i, m in enumerate(served):
            item = plan[m.rid]
            if item[0] == "decode":
                decode_jobs.append((i, m))
            elif item[0] == "spec":
                seq = None if g == 0 else m.hidden
                spec_jobs.append(
                    (i, m, seq, int(mgr.lengths[m.slot_ids[g]]), item[1])
                )
            elif item[0] == "chunk":
                chunk_jobs.append((i, m, item[1], item[2], item[3]))
            else:
                whole_jobs.append((i, m, item[1]))

        readbacks: list[tuple] = []
        ex = self._exec[g]
        if whole_jobs:
            ex.run_prefill_whole(r, whole_jobs, outputs, mgr, readbacks)
        if chunk_jobs:
            ex.run_chunks(r, chunk_jobs, outputs, mgr, readbacks)
        if spec_jobs:
            # Stage 0 drafts first (its readback precedes the verify's in
            # the call's drain order — the accept finalizer needs the
            # round's drafts already patched in).
            tok_dev = self._run_draft(r, spec_jobs, readbacks) if g == 0 else None
            ex.run_verify(r, spec_jobs, outputs, mgr, readbacks, tok_dev)
        if decode_jobs:
            ex.run_decode(r, decode_jobs, outputs, mgr, readbacks)

        self.stats.stage_executions += len(served)
        for m in served:
            m.in_call = True
        pm = self.budgets[g][r].pm
        kappa = self.pm_policy.mode(pm).kappa
        self.dispatch_log.append((g, r, t_dispatch))
        call = _StageCall(
            members=served,
            outputs=outputs,
            readbacks=readbacks,
            pm=pm,
            slots_left=kappa,
            t_dispatch=t_dispatch,
        )
        if self.async_depth == 0:
            # Legacy synchronous engine: block on the results right here,
            # inside the dispatch phase (the differential baseline).
            self._finalize(call)
        return call

    # ------------------------------------------------------------------
    # Commit
    # ------------------------------------------------------------------
    def _finalize(self, call: _StageCall) -> None:
        """Drain the call's deferred readbacks (the only host syncs)."""
        for dev, fin in call.readbacks:
            fin(host_readback(dev))
        call.readbacks = []

    def _commit_call(self, g: int, call: _StageCall) -> None:
        self._finalize(call)
        for m, out in zip(call.members, call.outputs):
            self._commit(m, out, g, call.t_ready, call.ready_slot)

    def _emit_token(
        self,
        req: Request,
        token: int,
        t_ready: float | None = None,
        ready_slot: int | None = None,
    ) -> None:
        req.generated.append(token)
        if req.t_first_token is None:
            # Dispatch-observable time: the slot the device work finished,
            # not the (possibly later) slot the completion queue drained.
            req.t_first_token = t_ready if t_ready is not None else time.perf_counter()
            req.slot_first_token = ready_slot
        self.stats.tokens_generated += 1
        self.stats.accepted_tokens += 1

    def _commit(
        self,
        req: Request,
        out: tuple,
        g: int,
        t_ready: float | None = None,
        ready_slot: int | None = None,
    ) -> None:
        """Apply a completed stage call's result to the request."""
        req.in_call = False
        kind, value, advance = out
        if kind == "spec_hidden":
            # Mid-stage verify handoff: the [1, v, D] hidden feeds the
            # next stage's verify; the round stays in flight.
            req.cache_ready[g] = True
            req.hidden = value
            self._advance(req)
            return
        if kind == "spec_done":
            req.cache_ready[g] = True
            self._finish_spec_round(req, value, advance, t_ready, ready_slot)
            self._advance(req)
            return
        if req.spec_adv is not None and any(req.spec_adv):
            # A plain-path result landing mid-round means the round was
            # broken (a mid-pipeline failover re-prefill replaced it):
            # rewind the optimistic rows before committing plain state.
            self.scheduler.rewind_spec(req)
        if kind == "chunk_part":
            # Prefill continues at this stage next step; mid-pipeline
            # chunks accumulate for the downstream handoff.
            req.chunk_pos += advance
            if value is not None:
                req.chunk_outs.append(value)
            return
        if kind == "chunk_done":
            req.chunk_pos = 0
            req.chunk_seq = None
            req.cache_ready[g] = True
            if g == self.G - 1:
                self._emit_token(req, value, t_ready, ready_slot)
            else:
                parts = req.chunk_outs + [value]
                req.hidden = (
                    parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
                )
            req.chunk_outs = []
            self._advance(req)
            return
        req.cache_ready[g] = True
        if kind == "token":
            self._emit_token(req, value, t_ready, ready_slot)
        else:
            req.hidden = value
        self._advance(req)

    def _finish_spec_round(self, req, emit, v, t_ready, ready_slot) -> None:
        """Commit a speculative round: accept the emitted prefix, rewind
        every stage's rejected tail, validate the draft mirror's accepted
        rows, update acceptance stats, and stream the tokens."""
        e = len(emit)
        self.stats.spec_rounds += 1
        self.stats.spec_proposed += v - 1
        self.stats.spec_accepted += e - 1
        for g in range(self.G):
            adv = req.spec_adv[g] if req.spec_adv is not None else 0
            if req.spec_adv is not None:
                req.spec_adv[g] = 0
            if not adv:
                continue
            slot = req.slot_ids[g] if req.slot_ids is not None else None
            if slot is None or req.replicas is None:
                continue
            mgr = self.managers[(g, req.replicas[g])]
            if mgr.slots[slot] == req.rid:
                mgr.rollback(req.rid, slot, adv - e)
        spec = self._spec
        if req.spec_drafts is not None and req.slot_ids is not None:
            # Draft rows are valid through the accepted prefix: the scan
            # wrote rows for [gen[-1], d_1..d_{k-1}] and d_j == t_j for
            # j < e, so next round's ingest starts after them.
            r0, slot0 = req.replicas[0], req.slot_ids[0]
            L = len(req.prompt) + len(req.generated) - 1
            if slot0 is not None and spec.rid[r0][slot0] == req.rid:
                spec.lens[r0][slot0] = L + min(e, spec.k)
        req.spec_drafts = None
        for t in emit:
            self._emit_token(req, t, t_ready, ready_slot)

    def _advance(self, req: Request) -> None:
        req.stage += 1
        if req.stage >= self.G:
            if len(req.generated) >= req.n_tokens:
                req.done = True
                self.scheduler.release_all(req)
                self.stats.completed_jobs += 1
                return
            req.stage = 0

    # ------------------------------------------------------------------
    # Slot loop
    # ------------------------------------------------------------------
    def step(self) -> None:
        """Advance one slot (the paper's Algorithm 1 outer loop),
        producer (dispatch) before consumer (commit)."""
        self.stats.slots += 1
        sched = self.scheduler
        # 1) harvest + hysteresis + downtime telemetry (whole replica-slots)
        for g in range(self.G):
            for r in range(self.R):
                b = self.budgets[g][r]
                lo, hi = self.harvest[g, r]
                b.harvest(self._rng.uniform(lo, hi))
                if not b.available:
                    self.stats.downtime_replica_slots += 1

        # 2) abort in-flight rings on dead replicas; reroute their
        #    members. The ring entries' readbacks are never finalized —
        #    a dead dispatch's results are dropped, not committed.
        for (g, r), ring in self._calls.items():
            if ring and not self.budgets[g][r].alive:
                self._abort_ring(g, r)

        # 3) re-place parked / dead-replica requests, BEFORE queue
        #    admission (in-flight work must not be starved by fresh
        #    arrivals), then 4) drain the backpressure queue (FIFO).
        sched.replace_parked()
        sched.admit_pending()

        # 5) producer: fill each energy-ready replica's in-flight ring.
        #    Members already in flight are excluded by select_members
        #    (in_call), so queued calls cover disjoint request sets.
        mark_engine_phase("dispatch")
        for g in range(self.G):
            for r in range(self.R):
                ring = self._calls[(g, r)]
                while len(ring) < self._depth:
                    if not sched.can_start(g, r):
                        break  # power saving / energy gate: jobs held
                    members = sched.select_members(g, r)
                    if not members:
                        break
                    call = self._start_call(g, r, members)
                    if call is None:  # paged: every member deferred
                        break
                    ring.append(call)
                    self.stats.inflight_peak = max(
                        self.stats.inflight_peak, len(ring)
                    )

        # 6) consumer: charge CE(PM)/kappa per slot per in-flight call
        #    (device-level, amortized over the batch), stamp readiness at
        #    the slot the device work completes, then drain the
        #    completion queue head-first in dispatch order.
        mark_engine_phase("commit")
        for (g, r), ring in self._calls.items():
            b = self.budgets[g][r]
            if not b.available:
                continue  # power saving: stage paused (jobs held, Sec. III)
            for call in ring:
                mode = self.pm_policy.mode(call.pm)
                b.charge(mode.ce / mode.kappa)
                # Energy is charged per *call* (a speculative verify costs
                # one call no matter how many tokens it commits) — the
                # per-accepted-token figure divides this by accepted_tokens.
                self.stats.energy_charged += mode.ce / mode.kappa
                call.slots_left -= 1
                if call.slots_left <= 0 and call.t_ready is None:
                    call.t_ready = time.perf_counter()
                    call.ready_slot = self.stats.slots
            while ring and ring[0].slots_left <= 0:
                self._commit_call(g, ring.popleft())
        mark_engine_phase("other")

        # 7) close this slot's device->host sync bucket (no-op unless a
        #    repro.analysis TransferSanitizer is active)
        mark_engine_step()

    def _abort_ring(self, g: int, r: int) -> None:
        """Discard (g, r)'s in-flight ring: members reroute loss-free
        (re-prefill on a sibling), readbacks are never finalized, and
        the :meth:`_on_ring_abort` hook cleans up backend state."""
        ring = self._calls[(g, r)]
        for call in ring:
            for m in call.members:
                m.in_call = False
                self.scheduler.reroute_or_drop(m)
        ring.clear()
        self._on_ring_abort(g, r)

    # ------------------------------------------------------------------
    def fail_replica(self, g: int, r: int) -> None:
        self.budgets[g][r].fail()
        if self.elastic is not None:
            self.elastic.fail(g, r)

    def recover_replica(self, g: int, r: int) -> None:
        self.budgets[g][r].recover()
        if self.elastic is not None:
            self.elastic.rejoin(g, r)

    @property
    def queue_depth(self) -> int:
        return len(self.scheduler.pending)

    @property
    def _active(self) -> list[Request]:
        """The scheduler's resident set (shared reference)."""
        return self.scheduler.active

    @property
    def _pending(self):
        return self.scheduler.pending

    def run(
        self,
        n_slots: int,
        arrival_p: float = 0.4,
        prompt_len: int = 8,
        n_tokens: int = 4,
        vocab: int | None = None,
    ) -> ServerStats:
        vocab = vocab or self.cfg.vocab_size
        for _ in range(n_slots):
            if self._rng.uniform() < arrival_p:
                prompt = self._rng.integers(0, vocab, size=prompt_len)
                self.submit(prompt, n_tokens=n_tokens)
            self.step()
        return self.stats
