"""Decentralized serving engine: the paper's system with real compute.

``PipelineServer`` hosts G pipeline groups × R replicas of a partitioned
model (:mod:`.partition`). Time advances in slots (the paper's delta);
per slot every replica harvests budget, jobs execute one stage-slot of
*real* JAX decode compute on their designated replicas, and new requests
are routed by the energy-aware :class:`Router` (Alg. 1). Replica failure
(ft/health) is just a drained budget — the router's mass shifts instantly
and the job's in-flight stage is re-routed to a sibling replica.

Execution model per job = generate ``n_tokens`` autoregressively: each
token passes stages 0..G-1. A stage occupies its replica exclusively for
``kappa(PM)`` slots (the paper's measured per-mode latency) and charges
``CE(PM)/kappa`` per slot; the stage's JAX call happens on its first slot
(hidden states are handed between groups; each stage keeps its own KV
cache — Petals semantics).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp
import numpy as np

from ..core.power import PowerModePolicy, dynamic_policy
from ..models.registry import Model
from .budget import ReplicaBudget
from .partition import partition_model
from .router import RouteError, Router

__all__ = ["Request", "PipelineServer", "ServerStats"]


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray  # prompt [S]
    n_tokens: int  # tokens to generate
    # runtime state
    stage: int = 0
    replicas: list[int] | None = None
    generated: list[int] = dataclasses.field(default_factory=list)
    caches: list[Any] | None = None  # per-stage caches
    hidden: Any = None  # inter-stage activation
    stage_started: bool = False
    stage_pm: int = 1
    slots_left: int = 0
    done: bool = False
    dropped: bool = False


@dataclasses.dataclass
class ServerStats:
    submitted: int = 0
    completed_jobs: int = 0
    dropped_jobs: int = 0
    tokens_generated: int = 0
    stage_executions: int = 0
    rerouted_stages: int = 0
    slots: int = 0
    downtime_replica_slots: int = 0

    @property
    def downtime_fraction(self) -> float:
        return self.downtime_replica_slots / max(self.slots, 1)


class PipelineServer:
    def __init__(
        self,
        model: Model,
        params,
        *,
        n_groups: int = 3,
        n_replicas: int = 3,
        policy: str = "adaptive",
        pm_policy: PowerModePolicy | None = None,
        harvest_bounds: tuple[float, float] = (6.0, 10.0),
        long_term_rates: np.ndarray | None = None,
        max_len: int = 256,
        seed: int = 0,
    ):
        self.cfg = model.cfg
        self.stages = partition_model(model.cfg, params, n_groups)
        self.G, self.R = n_groups, n_replicas
        self.max_len = max_len
        self.pm_policy = pm_policy or dynamic_policy(100)
        # Replicas share stage weights (replication within a group) but
        # have independent budgets/harvests (heterogeneous nodes).
        rng = np.random.default_rng(seed)
        lo, hi = harvest_bounds
        centers = rng.uniform(lo, hi, size=(self.G, self.R))
        self.harvest = np.stack([centers - 2.0, centers + 2.0], axis=-1).clip(0.0)
        self.budgets = [
            [ReplicaBudget(policy=self.pm_policy) for _ in range(n_replicas)]
            for _ in range(n_groups)
        ]
        self.router = Router(policy=policy, long_term_rates=long_term_rates, seed=seed)
        self._rng = rng
        self.stats = ServerStats()
        self._active: list[Request] = []
        self._next_rid = 0
        self._busy: dict[tuple[int, int], int] = {}  # (g, r) -> rid holding it

    # ------------------------------------------------------------------
    def submit(self, tokens: np.ndarray, n_tokens: int = 8) -> Request | None:
        """Route a new request (one replica designated per group, Alg. 1)."""
        self.stats.submitted += 1
        req = Request(
            rid=self._next_rid, tokens=np.asarray(tokens), n_tokens=n_tokens
        )
        self._next_rid += 1
        try:
            req.replicas = self.router.route(self.budgets)
        except RouteError:
            req.dropped = True
            self.stats.dropped_jobs += 1
            return None
        req.caches = [None] * self.G
        self._active.append(req)
        return req

    # ------------------------------------------------------------------
    def _exec_stage(self, req: Request) -> None:
        """Run the real JAX compute for the current (token, stage)."""
        g = req.stage
        model_g, params_g = self.stages[g]
        self.stats.stage_executions += 1
        if req.caches[g] is None:
            batch = (
                {"tokens": jnp.asarray(req.tokens)[None, :]}
                if g == 0
                else {"hidden": req.hidden}
            )
            out, req.caches[g] = model_g.prefill(params_g, batch, self.max_len)
        else:
            if g == 0:
                token_or_hidden = jnp.asarray([[req.generated[-1]]])
            else:
                # After an upstream re-prefill (failover) the handoff may
                # carry the whole prefix; a caching stage only consumes
                # the newest position.
                token_or_hidden = (
                    req.hidden if req.hidden.shape[1] == 1 else req.hidden[:, -1:]
                )
            out, req.caches[g] = model_g.decode_step(
                params_g, token_or_hidden, req.caches[g]
            )
        if g == self.G - 1:
            tok = int(jnp.argmax(out[0, -1]))
            req.generated.append(tok)
            self.stats.tokens_generated += 1
        else:
            req.hidden = out

    # ------------------------------------------------------------------
    def step(self) -> None:
        """Advance one slot (the paper's Algorithm 1 outer loop)."""
        self.stats.slots += 1
        # 1) harvest + hysteresis + downtime telemetry
        for g in range(self.G):
            for r in range(self.R):
                b = self.budgets[g][r]
                lo, hi = self.harvest[g, r]
                b.harvest(self._rng.uniform(lo, hi))
                if not b.available:
                    self.stats.downtime_replica_slots += 1 / (self.G * self.R)

        # 2) progress jobs
        for req in list(self._active):
            g = req.stage
            r = req.replicas[g]
            b = self.budgets[g][r]

            if not b.alive:
                self._reroute_or_drop(req)
                continue
            if not b.available:
                continue  # power saving: stage paused (job held, Sec. III)

            if not req.stage_started:
                holder = self._busy.get((g, r))
                if holder is not None and holder != req.rid:
                    continue  # replica busy with another job's stage
                if not b.can_start():
                    continue  # energy gate: CE(PM) <= E
                req.stage_pm = b.pm
                req.slots_left = self.pm_policy.mode(b.pm).kappa
                self._busy[(g, r)] = req.rid
                self._exec_stage(req)
                req.stage_started = True

            mode = self.pm_policy.mode(req.stage_pm)
            b.charge(mode.ce / mode.kappa)
            req.slots_left -= 1
            if req.slots_left <= 0:
                self._busy.pop((g, r), None)
                req.stage_started = False
                self._advance(req)

    def _reroute_or_drop(self, req: Request) -> None:
        """Failure handling: shift the in-flight stage to a sibling."""
        g = req.stage
        self._busy.pop((g, req.replicas[g]), None)
        req.stage_started = False
        try:
            probs = self.router.probabilities(self.budgets)[g]
            if probs.sum() <= 0:
                raise RouteError(f"group {g} empty")
            req.replicas[g] = int(self._rng.choice(len(probs), p=probs / probs.sum()))
            # The failed replica held this stage's KV cache: it is lost and
            # the sibling re-prefills. Stage 0 can reconstruct its full
            # context (prompt + tokens generated so far); deeper stages
            # would need the prefix re-driven through the pipeline — the
            # engine approximates by restarting them from the latest
            # hidden handoff (documented context loss under failure).
            req.caches[g] = None
            if g == 0 and req.generated:
                req.tokens = np.concatenate(
                    [req.tokens, np.asarray(req.generated[:-1], req.tokens.dtype)]
                )
            self.stats.rerouted_stages += 1
        except RouteError:
            req.dropped = True
            self._active.remove(req)
            self.stats.dropped_jobs += 1

    def _advance(self, req: Request) -> None:
        req.stage += 1
        if req.stage >= self.G:
            if len(req.generated) >= req.n_tokens:
                req.done = True
                self._active.remove(req)
                self.stats.completed_jobs += 1
                return
            req.stage = 0

    # ------------------------------------------------------------------
    def fail_replica(self, g: int, r: int) -> None:
        self.budgets[g][r].fail()

    def recover_replica(self, g: int, r: int) -> None:
        self.budgets[g][r].recover()

    def run(
        self,
        n_slots: int,
        arrival_p: float = 0.4,
        prompt_len: int = 8,
        n_tokens: int = 4,
        vocab: int | None = None,
    ) -> ServerStats:
        vocab = vocab or self.cfg.vocab_size
        for _ in range(n_slots):
            if self._rng.uniform() < arrival_p:
                prompt = self._rng.integers(0, vocab, size=prompt_len)
                self.submit(prompt, n_tokens=n_tokens)
            self.step()
        return self.stats
