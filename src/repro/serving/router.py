"""Energy-aware replica router — the paper's Algorithm 1 as the serving
fleet's request router.

Given the per-replica budgets of each pipeline group, the router returns
which replica serves each stage of a new request, using uniform /
long-term / adaptive scheduling (:mod:`repro.core.policies`). With the
continuous-batching engine the router is also capacity aware: callers
pass per-replica headroom weights through ``free_slots`` — each cache
manager's ``capacity_weight`` (free batch slots for ``DenseSlotCache``,
free KV-cache *pages* for ``PagedKVCache``), collected by
``StepScheduler.free_counts`` — and the routing mass shifts toward
replicas with headroom. Zero headroom gets zero mass; when *every*
replica in a group has zero headroom the group's vector stays an
unnormalized zero vector, so ``route``/``reroute`` raise
:class:`RouteError` and the scheduler backpressures into its pending
queue instead of dropping.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core.policies import POLICIES
from .budget import ReplicaBudget

__all__ = ["Router", "RouteError"]


class RouteError(RuntimeError):
    """No admissible replica in some group — request must wait or drop."""


@dataclasses.dataclass
class Router:
    policy: str = "adaptive"  # uniform | long_term | adaptive
    long_term_rates: np.ndarray | None = None  # [G, R] q_lims (Eq. 6)
    seed: int | np.random.SeedSequence = 0

    def __post_init__(self) -> None:
        if self.policy not in POLICIES:
            raise ValueError(f"unknown policy {self.policy!r}")
        self._rng = np.random.default_rng(self.seed)
        # Bumped by every membership event — lets telemetry/tests observe
        # that a live process join/leave actually re-solved the routing
        # table, independent of whether the rates object is replaced.
        self.membership_version = 0

    def probabilities(
        self,
        budgets: list[list[ReplicaBudget]],
        free_slots: list[list[int]] | None = None,
        inflight: list[list[int]] | None = None,
    ) -> list[np.ndarray]:
        """Per-group routing distributions (Alg. 1 lines 7-9).

        Groups may have different replica counts (elastic membership), so
        the result is a list of per-group vectors. ``free_slots`` (same
        nesting as ``budgets``) reweights each replica by its free batch
        capacity: full replicas are masked out and emptier replicas
        attract proportionally more new requests. ``inflight`` (async
        engine: per-replica in-flight ring depths) soft-de-weights busy
        replicas by ``1 / (1 + depth)`` — a deeper completion queue
        means later commit, so admissions prefer idler siblings. Uniform
        depths (in particular all-zero, the sync engine) cancel under
        normalization, keeping depth 0/1 routing identical.
        """
        fn = POLICIES[self.policy]
        out: list[np.ndarray] = []
        for g, group in enumerate(budgets):
            R = len(group)
            if self.long_term_rates is not None:
                rates = np.asarray(self.long_term_rates[g], dtype=np.float32)
            else:
                rates = np.ones(R, dtype=np.float32)
            avail = np.array([b.available for b in group])
            pm = np.array([b.pm for b in group])
            p = np.asarray(fn(rates, pm, avail), dtype=np.float64)
            if inflight is not None:
                depth = np.maximum(np.asarray(inflight[g], dtype=np.float64), 0.0)
                p = p / (1.0 + depth)
            if free_slots is not None:
                p = p * np.maximum(np.asarray(free_slots[g], dtype=np.float64), 0.0)
            if inflight is not None or free_slots is not None:
                total = p.sum()
                if total > 0:
                    p = p / total
            out.append(p)
        return out

    def _pick(self, p: np.ndarray, g: int) -> int:
        total = p.sum()
        if total <= 0:
            raise RouteError(f"no admissible replica in group {g}")
        return int(self._rng.choice(len(p), p=p / total))

    def route(
        self,
        budgets: list[list[ReplicaBudget]],
        free_slots: list[list[int]] | None = None,
        inflight: list[list[int]] | None = None,
    ) -> list[int]:
        """Designate one replica per group for a new request."""
        probs = self.probabilities(budgets, free_slots, inflight)
        return [self._pick(p, g) for g, p in enumerate(probs)]

    def reroute(
        self,
        budgets: list[list[ReplicaBudget]],
        g: int,
        free_slots: list[list[int]] | None = None,
        inflight: list[list[int]] | None = None,
    ) -> int:
        """Pick a failover sibling in group ``g`` for an in-flight stage."""
        return self._pick(self.probabilities(budgets, free_slots, inflight)[g], g)

    def on_membership_change(self, rates: np.ndarray | None) -> None:
        """Elastic event: new long-term rates after add/remove of nodes
        (the paper recomputes the stationary solution only when network
        parameters change).

        Live process leave (multi-process serving) is expressed as a
        zero entry in the group's rate vector — the member keeps its
        grid index so in-flight bookkeeping stays valid, but long-term /
        adaptive routing immediately stops sending it mass; a respawned
        process rejoins by restoring its rate
        (:meth:`repro.ft.elastic.ElasticController.fail` / ``rejoin``).
        """
        self.long_term_rates = rates
        self.membership_version += 1
