"""Energy-aware replica router — the paper's Algorithm 1 as the serving
fleet's request router.

Given the per-replica budgets of each pipeline group, the router returns
which replica serves each stage of a new request, using uniform /
long-term / adaptive scheduling (:mod:`repro.core.policies`).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core.policies import POLICIES
from .budget import ReplicaBudget

__all__ = ["Router", "RouteError"]


class RouteError(RuntimeError):
    """No available replica in some group — request must be dropped."""


@dataclasses.dataclass
class Router:
    policy: str = "adaptive"  # uniform | long_term | adaptive
    long_term_rates: np.ndarray | None = None  # [G, R] q_lims (Eq. 6)
    seed: int = 0

    def __post_init__(self) -> None:
        if self.policy not in POLICIES:
            raise ValueError(f"unknown policy {self.policy!r}")
        self._rng = np.random.default_rng(self.seed)

    def probabilities(self, budgets: list[list[ReplicaBudget]]) -> list[np.ndarray]:
        """Per-group routing distributions (Alg. 1 lines 7-9).

        Groups may have different replica counts (elastic membership), so
        the result is a list of per-group vectors.
        """
        fn = POLICIES[self.policy]
        out: list[np.ndarray] = []
        for g, group in enumerate(budgets):
            R = len(group)
            if self.long_term_rates is not None:
                rates = np.asarray(self.long_term_rates[g], dtype=np.float32)
            else:
                rates = np.ones(R, dtype=np.float32)
            avail = np.array([b.available for b in group])
            pm = np.array([b.pm for b in group])
            out.append(np.asarray(fn(rates, pm, avail)))
        return out

    def route(self, budgets: list[list[ReplicaBudget]]) -> list[int]:
        """Designate one replica per group for a new request."""
        probs = self.probabilities(budgets)
        choice = []
        for g, p in enumerate(probs):
            total = p.sum()
            if total <= 0:
                raise RouteError(f"no available replica in group {g}")
            choice.append(int(self._rng.choice(len(p), p=p / total)))
        return choice

    def on_membership_change(self, rates: np.ndarray | None) -> None:
        """Elastic event: new long-term rates after add/remove of nodes
        (the paper recomputes the stationary solution only when network
        parameters change)."""
        self.long_term_rates = rates
