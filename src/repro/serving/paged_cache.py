"""Paged KV-cache page pool: block tables, alloc/free, conservation.

The dense continuous-batching cache (PR 2) reserves ``max_batch x
max_len`` KV entries per replica, so one long-context slot prices every
short request at worst-case memory. Paging replaces that reservation
with a shared pool of fixed-size pages per (group, replica): a request
holds ``ceil(context / page_size)`` pages named by its block table, the
pool's free list is the replica's true admission capacity, and the
router weighs replicas by free pages instead of free slots.

This module is the *host-side* accounting: which physical page belongs
to which request. The device-side pool arrays (``[n_layers, n_pages+1,
page_size, KV, head_dim]`` — the extra page is scratch for masked
lanes) live in the engine's per-replica cache dict and are read by
:func:`repro.models.transformer.decode_step_paged` through the block
tables this module hands out.

Invariants (fuzz-tested in ``tests/test_paged_cache.py``):

* conservation — ``free_pages + sum(allocated) == n_pages`` always;
* exclusivity — a page has at most one owner; double-free and
  foreign-free raise instead of corrupting the pool.
"""

from __future__ import annotations

import dataclasses

__all__ = ["PagePool", "PageError"]


class PageError(RuntimeError):
    """Pool accounting violation (double free / foreign free / overdraw)."""


@dataclasses.dataclass
class PagePool:
    """Fixed-size page allocator for one replica's KV pool.

    Pages are plain indices into the device pool arrays; index
    ``n_pages`` (one past the end) is the reserved scratch page and is
    never handed out.
    """

    n_pages: int
    page_size: int

    def __post_init__(self) -> None:
        if self.n_pages <= 0 or self.page_size <= 0:
            raise ValueError("need n_pages > 0 and page_size > 0")
        # LIFO free list: lowest indices first so allocation order is
        # deterministic (seed-reproducible serving runs).
        self._free: list[int] = list(range(self.n_pages - 1, -1, -1))
        self._owner: dict[int, int] = {}  # page -> rid

    @property
    def scratch(self) -> int:
        return self.n_pages

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return len(self._owner)

    def blocks_for(self, length: int) -> int:
        """Pages needed to hold ``length`` cache entries (min 1)."""
        return max(1, -(-int(length) // self.page_size))

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int, rid: int) -> list[int]:
        if n > len(self._free):
            raise PageError(
                f"pool overdraw: want {n}, have {len(self._free)} free"
            )
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._owner[p] = rid
        return pages

    def free(self, pages: list[int], rid: int) -> None:
        for p in pages:
            owner = self._owner.get(p)
            if owner is None:
                raise PageError(f"double free of page {p} (rid {rid})")
            if owner != rid:
                raise PageError(
                    f"foreign free of page {p}: owned by {owner}, freed by {rid}"
                )
            del self._owner[p]
            self._free.append(p)

    def owned_by(self, rid: int) -> list[int]:
        return [p for p, o in self._owner.items() if o == rid]

    def check_conservation(self) -> None:
        """Raise unless free + allocated is exactly the pool, disjointly."""
        free = set(self._free)
        used = set(self._owner)
        if len(free) != len(self._free):
            raise PageError("free list contains duplicates")
        if free & used:
            raise PageError(f"pages both free and owned: {sorted(free & used)}")
        if free | used != set(range(self.n_pages)):
            missing = set(range(self.n_pages)) - (free | used)
            raise PageError(f"pages leaked: {sorted(missing)}")
