"""Multi-process serving: pipeline stages in real worker processes.

``MPPipelineServer`` keeps the whole control plane of
:class:`~repro.serving.engine.PipelineServer` — router, scheduler,
budgets, in-flight rings — and swaps the execution substrate: every
(group, replica) cell becomes a separate OS process hosting that
stage's parameters and dense slot cache. Stage handoffs (the slimmed
``[1, D]`` decode hidden, or a ``[1, S, D]`` prefill handoff) cross
process boundaries over a length-prefixed pickle pipe.

Design points:

* **No parameter shipping.** A worker rebuilds its stage
  deterministically from the model *spec* — architecture name, config
  overrides and the init seed — via ``init_from_template`` +
  ``slice_stage_params`` (through :func:`partition_model`), exactly the
  coordinator's own construction. Spawn cost is one model init, not a
  weight transfer.
* **Dispatch stays async.** ``_RemoteExec`` writes the RPC request and
  returns immediately; the reply is wrapped in a :class:`_PendingReply`
  that rides the call's deferred ``readbacks`` and is only drained at
  *commit*, exactly like the in-process engine's device readbacks. The
  dispatch phase performs no device->host sync and no pipe read, so the
  in-flight ring overlaps compute across worker processes. Replies are
  strictly FIFO per worker (single-threaded coordinator + ordered
  pipe), matching the head-first ring drain order.
* **Per-worker tensor parallelism.** ``mesh_model > 1`` gives each
  worker its own forced-host device mesh
  (``--xla_force_host_platform_device_count``) and places its stage
  params with ``SERVE_RULES`` — tensor-parallel within the process,
  pipeline handoffs between processes.
* **Real failure semantics.** ``fail_replica`` SIGKILLs the worker;
  :class:`~repro.ft.health.ProcessMonitor` turns unexpected process
  exits into the same membership-leave path (budget fail +
  ``ElasticController.fail`` -> ``Router.on_membership_change``), and
  the loss-free re-prefill failover recovers every in-flight request.
  ``recover_replica`` respawns the process; because the fresh worker's
  cache is empty, any resident still holding stage state there is
  re-placed and re-prefills.

Scope: dense whole-prompt mode only. Paged KV, chunked prefill and
speculative decoding run in-process (their substrate is shared device
memory); requesting them here raises a clear ``ValueError``.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import pickle
import struct
import subprocess
import sys
import traceback
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from ..analysis.sanitizer import host_readback
from ..configs import get_config, get_smoke_config
from ..core.network import DeviceSpec
from ..distributed.sharding import SERVE_RULES, param_shardings
from ..ft.elastic import ElasticController
from ..ft.health import ProcessMonitor
from ..launch.mesh import make_serving_mesh
from ..models.common import init_from_template
from ..models.registry import build_model
from .engine import PipelineServer, _group_by_len
from .partition import partition_model

__all__ = [
    "MPPipelineServer",
    "StageHost",
    "WorkerHandle",
    "WorkerDied",
    "WorkerError",
    "build_from_spec",
]


class WorkerDied(RuntimeError):
    """The worker process exited (pipe EOF / broken pipe)."""


class WorkerError(RuntimeError):
    """The worker is alive but its stage execution raised."""


# ---------------------------------------------------------------------------
# Wire protocol: 8-byte little-endian length prefix + pickle payload.
# ---------------------------------------------------------------------------

_F_SETPIPE_SZ = 1031  # Linux fcntl; pipes default to 64 KiB


def _widen_pipe(f, size: int = 1 << 20) -> None:
    """Grow a pipe so one in-flight ring of [N, 1, S, D] handoffs fits
    without write-side blocking (writer and reader are one thread)."""
    try:
        import fcntl

        fcntl.fcntl(f.fileno(), _F_SETPIPE_SZ, size)
    except (ImportError, OSError, ValueError):
        pass  # non-Linux: small handoffs still fit the default buffer


def _write_msg(stream, obj) -> None:
    data = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    stream.write(struct.pack("<Q", len(data)))
    stream.write(data)
    stream.flush()


def _read_msg(stream):
    head = stream.read(8)
    if len(head) < 8:
        raise WorkerDied("pipe closed")
    (n,) = struct.unpack("<Q", head)
    data = stream.read(n)
    if len(data) < n:
        raise WorkerDied("pipe closed mid-frame")
    return pickle.loads(data)


# ---------------------------------------------------------------------------
# Model spec: the deterministic recipe both sides build from.
# ---------------------------------------------------------------------------


def build_from_spec(spec: dict):
    """(cfg, model, params) from a JSON-serializable spec.

    ``{"arch": name, "smoke": bool, "overrides": {field: value},
    "seed": int}`` — coordinator and every worker call this with the
    same spec, so stage parameters agree bit-for-bit without ever
    crossing a pipe.
    """
    arch = spec["arch"]
    cfg = get_smoke_config(arch) if spec.get("smoke", True) else get_config(arch)
    overrides = spec.get("overrides") or {}
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    model = build_model(cfg)
    params = init_from_template(
        model.template, jax.random.PRNGKey(spec.get("seed", 0)), cfg.param_dtype
    )
    return cfg, model, params


# ---------------------------------------------------------------------------
# Worker side
# ---------------------------------------------------------------------------


class StageHost:
    """One pipeline stage's execution state inside a worker process.

    Mirrors ``_DenseExec`` exactly — same jit bodies, same full-width
    masked decode assembly, same slot-indexed prefill scatter — so the
    multi-process token stream is bit-identical to the in-process one.
    Also usable in-process (tests exercise it without a subprocess).
    """

    def __init__(
        self,
        spec: dict,
        g: int,
        n_groups: int,
        max_batch: int,
        max_len: int,
        mesh_model: int = 1,
    ):
        cfg, _, params = build_from_spec(spec)
        stages = partition_model(cfg, params, n_groups)
        model_g, params_g = stages[g]
        del stages, params  # keep only this stage's weights resident
        self.g, self.G = g, n_groups
        self.last = g == n_groups - 1
        self.max_batch = max_batch
        self.max_len = max_len
        self.d_model = cfg.d_model
        self._sharding = None
        if mesh_model > 1:
            mesh = make_serving_mesh(model_axis=mesh_model)
            self._sharding = NamedSharding(mesh, PartitionSpec())
            params_g = jax.device_put(
                params_g, param_shardings(model_g.template, mesh, SERVE_RULES)
            )
        self.params = params_g

        @partial(jax.jit, donate_argnums=(2,))
        def prefill_into(params, batch, cache, slot_idx):
            out, new = model_g.prefill_batch(params, batch, max_len)
            cache = jax.tree_util.tree_map(
                lambda big, small: big.at[slot_idx].set(small), cache, new
            )
            return out, cache

        @partial(jax.jit, donate_argnums=(2,))
        def decode_masked(params, inp, cache, mask):
            out, new = model_g.decode_batch(params, inp, cache)
            merged = jax.tree_util.tree_map(
                lambda n, o: jnp.where(
                    mask.reshape((mask.shape[0],) + (1,) * (n.ndim - 1)), n, o
                ),
                new,
                cache,
            )
            return out, merged

        self.prefill_into = prefill_into
        self.decode_masked = decode_masked
        shapes = model_g.cache_shapes(1, max_len)
        cache = jax.tree_util.tree_map(
            lambda sh: jnp.zeros((max_batch,) + tuple(sh.shape), sh.dtype), shapes
        )
        self.cache = self._place(cache)

    def _place(self, x):
        if self._sharding is None:
            return x
        return jax.device_put(x, self._sharding)

    # -- ops -------------------------------------------------------------
    def handle(self, msg: tuple) -> dict:
        op = msg[0]
        if op == "ping":
            return {"ok": True, "n_devices": jax.device_count()}
        if op == "prefill":
            return self._prefill(msg[1], msg[2])
        if op == "decode":
            return self._decode(msg[1], msg[2])
        raise ValueError(f"unknown op {op!r}")

    def _prefill(self, slots: list[int], payload: np.ndarray) -> dict:
        # payload: [N, 1, S] int32 tokens (stage 0) / [N, 1, S, D] hidden.
        key = "tokens" if self.g == 0 else "hidden"
        stacked = self._place(jnp.asarray(payload))
        out, self.cache = self.prefill_into(
            self.params, {key: stacked}, self.cache, jnp.asarray(slots, jnp.int32)
        )
        if self.last:
            toks = np.asarray(jnp.argmax(out[:, 0, -1], axis=-1))
            return {"ok": True, "tokens": toks}
        return {"ok": True, "hidden": np.asarray(out)}

    def _decode(self, slots: list[int], payload: np.ndarray) -> dict:
        # payload: [N, 1, 1] int32 tokens (stage 0) / [N, 1, 1, D] hidden.
        W = self.max_batch
        idx = np.asarray(slots, np.int32)
        mask = np.zeros((W,), bool)
        mask[idx] = True
        if self.g == 0:
            buf = np.zeros((W, 1, 1), np.int32)
            buf[idx] = payload
            inp = jnp.asarray(buf)
        else:
            hs = self._place(jnp.asarray(payload))
            inp = (
                jnp.zeros((W, 1, 1, self.d_model), hs.dtype)
                .at[jnp.asarray(idx)]
                .set(hs)
            )
        out, self.cache = self.decode_masked(
            self.params, inp, self.cache, jnp.asarray(mask)
        )
        if self.last:
            toks = np.asarray(jnp.argmax(out[:, 0, -1], axis=-1))
            return {"ok": True, "tokens": toks[idx]}
        return {"ok": True, "hidden": np.asarray(out)[idx]}


def worker_main(args) -> int:
    host = StageHost(
        json.loads(args.spec),
        args.group,
        args.n_groups,
        args.max_batch,
        args.max_len,
        mesh_model=args.mesh_model,
    )
    stdin, stdout = sys.stdin.buffer, sys.stdout.buffer
    while True:
        try:
            msg = _read_msg(stdin)
        except WorkerDied:
            return 0  # coordinator went away: exit quietly
        if msg[0] == "exit":
            return 0
        try:
            reply = host.handle(msg)
        except Exception:  # alive-but-failed: report, keep serving
            reply = {"ok": False, "error": traceback.format_exc()}
        _write_msg(stdout, reply)


# ---------------------------------------------------------------------------
# Coordinator side
# ---------------------------------------------------------------------------


class WorkerHandle:
    """Coordinator-side endpoint of one worker process."""

    def __init__(
        self,
        g: int,
        r: int,
        spec: dict,
        *,
        n_groups: int,
        max_batch: int,
        max_len: int,
        mesh_model: int = 1,
        monitor: ProcessMonitor | None = None,
    ):
        self.key = (g, r)
        self.monitor = monitor
        self.pending = 0  # requests written whose reply is still unread
        import repro

        env = dict(os.environ)
        # repro is a namespace package (__file__ is None): locate the
        # import root from __path__ so workers resolve the same tree.
        src_root = os.path.dirname(next(iter(repro.__path__)))
        env["PYTHONPATH"] = src_root + os.pathsep + env.get("PYTHONPATH", "")
        if mesh_model > 1:
            env["XLA_FLAGS"] = (
                f"--xla_force_host_platform_device_count={mesh_model}"
            )
        # -c (not -m): runpy would re-execute this already-imported
        # module and warn about unpredictable double-init.
        cmd = [
            sys.executable,
            "-c",
            "import sys; from repro.serving.mpserve import main; "
            "sys.exit(main(sys.argv[1:]))",
            "--worker",
            "--group",
            str(g),
            "--n-groups",
            str(n_groups),
            "--max-batch",
            str(max_batch),
            "--max-len",
            str(max_len),
            "--mesh-model",
            str(mesh_model),
            "--spec",
            json.dumps(spec),
        ]
        self.proc = subprocess.Popen(
            cmd, stdin=subprocess.PIPE, stdout=subprocess.PIPE, env=env
        )
        _widen_pipe(self.proc.stdin)
        _widen_pipe(self.proc.stdout)

    @property
    def alive(self) -> bool:
        return self.proc.poll() is None

    def request(self, msg: tuple) -> None:
        """Non-blocking dispatch: write the frame, defer the reply."""
        try:
            _write_msg(self.proc.stdin, msg)
        except (BrokenPipeError, OSError) as e:
            raise WorkerDied(f"worker {self.key}: {e}") from None
        self.pending += 1

    def response(self) -> dict:
        """Blocking commit-phase read of the oldest outstanding reply."""
        reply = _read_msg(self.proc.stdout)
        self.pending -= 1
        if self.monitor is not None:
            self.monitor.beat(self.key)
        if not reply.get("ok"):
            raise WorkerError(f"worker {self.key}: {reply.get('error')}")
        return reply

    def discard_pending(self) -> None:
        """Drain replies whose calls were aborted (ring discard): the
        pipe must re-align request<->reply before any new dispatch."""
        try:
            while self.pending > 0:
                _read_msg(self.proc.stdout)
                self.pending -= 1
        except WorkerDied:
            self.pending = 0

    def kill(self) -> None:
        if self.alive:
            self.proc.kill()
        self.proc.wait()

    def close(self) -> None:
        if not self.alive:
            return
        try:
            _write_msg(self.proc.stdin, ("exit",))
            self.proc.stdin.close()
            self.proc.wait(timeout=10)
        except Exception:
            self.proc.kill()
            self.proc.wait()


class _PendingReply:
    """A deferred RPC reply riding a call's readbacks list — the remote
    analogue of an in-flight device array. Replies are FIFO per worker,
    and readbacks drain in dispatch order, so ``result`` always reads
    this request's own frame."""

    def __init__(self, worker: WorkerHandle):
        self.worker = worker

    def result(self) -> dict:
        return self.worker.response()


class _RemoteExec:
    """Execution backend proxying one stage to its worker processes.

    Same interface as ``_DenseExec``; dispatch methods write RPC frames
    and append ``(_PendingReply, finalizer)`` readbacks — no pipe read,
    no device sync in the dispatch phase.
    """

    def __init__(self, server: "MPPipelineServer", g: int):
        self.server = server
        self.g = g

    def init_cache(self, r):
        return None  # state lives in the worker

    def run_prefill_whole(self, r, jobs, outputs, mgr, readbacks):
        s, g = self.server, self.g
        w = s._workers[(g, r)]
        last = g == s.G - 1
        for length, grp in sorted(_group_by_len(jobs).items()):
            slots = [int(m.slot_ids[g]) for _, m, _ in grp]
            payload = np.stack([np.asarray(inp) for _, _, inp in grp])
            w.request(("prefill", slots, payload))
            s.stats.prefill_calls += 1
            for _, m, _ in grp:
                mgr.lengths[m.slot_ids[g]] = length
            idxs = [i for i, _, _ in grp]
            if last:

                def fin(reply, idxs=idxs):
                    for j, i in enumerate(idxs):
                        outputs[i] = ("token", int(reply["tokens"][j]), 0)

            else:

                def fin(reply, idxs=idxs):
                    for j, i in enumerate(idxs):
                        outputs[i] = ("hidden", reply["hidden"][j], 0)

            readbacks.append((_PendingReply(w), fin))

    def run_decode(self, r, jobs, outputs, mgr, readbacks):
        s, g = self.server, self.g
        w = s._workers[(g, r)]
        last = g == s.G - 1
        slots = [int(m.slot_ids[g]) for _, m in jobs]
        if g == 0:
            payload = np.asarray(
                [[[m.generated[-1]]] for _, m in jobs], np.int32
            )  # [N, 1, 1]
        else:
            # After an upstream re-prefill the handoff carries the whole
            # prefix; a caching stage only consumes the newest position.
            payload = np.stack(
                [
                    np.asarray(m.hidden if m.hidden.shape[1] == 1 else m.hidden[:, -1:])
                    for _, m in jobs
                ]
            )  # [N, 1, 1, D]
        w.request(("decode", slots, payload))
        s.stats.decode_calls += 1
        for _, m in jobs:
            mgr.lengths[m.slot_ids[g]] += 1
        idxs = [i for i, _ in jobs]
        if last:

            def fin(reply, idxs=idxs):
                for j, i in enumerate(idxs):
                    outputs[i] = ("token", int(reply["tokens"][j]), 0)

        else:

            def fin(reply, idxs=idxs):
                for j, i in enumerate(idxs):
                    outputs[i] = ("hidden", reply["hidden"][j], 0)

        readbacks.append((_PendingReply(w), fin))

    def run_chunks(self, *a, **kw):
        raise ValueError("multi-process serving: chunked prefill is in-process only")

    def run_verify(self, *a, **kw):
        raise ValueError("multi-process serving: speculative decoding is in-process only")


class MPPipelineServer(PipelineServer):
    """PipelineServer whose stages execute in real worker processes.

    ``model_spec`` replaces the ``(model, params)`` pair — both the
    coordinator (for submit-side bookkeeping and the differential
    baseline) and every worker build from it deterministically. The
    elastic controller is wired by default, so a worker death flows
    process exit -> ``ProcessMonitor`` -> ``fail_replica`` ->
    ``ElasticController.fail`` -> ``Router.on_membership_change``.
    """

    def __init__(
        self,
        model_spec: dict,
        *,
        mesh_model: int = 1,
        n_groups: int = 2,
        n_replicas: int = 2,
        **kw,
    ):
        for bad in ("paged", "prefill_chunk", "spec_draft", "kv_dtype", "mesh"):
            if kw.get(bad):
                raise ValueError(
                    "multi-process serving runs dense whole-prompt stages "
                    f"only; {bad!r} is unsupported (use PipelineServer)"
                )
        self.model_spec = dict(model_spec)
        self.mesh_model = int(mesh_model)
        self.monitor = ProcessMonitor()
        self._workers: dict[tuple[int, int], WorkerHandle] = {}
        _, model, params = build_from_spec(self.model_spec)
        super().__init__(
            model, params, n_groups=n_groups, n_replicas=n_replicas, **kw
        )
        if self.elastic is None:
            specs = [
                [DeviceSpec(6, 10, self.pm_policy) for _ in range(self.R)]
                for _ in range(self.G)
            ]
            self.elastic = ElasticController(self.router, specs)
        # Surface worker import/config errors now, not at first dispatch
        # (all workers booted concurrently above — this drains in order).
        for w in self._workers.values():
            w.request(("ping",))
            w.response()

    # -- substrate -------------------------------------------------------
    def _build_exec(self):
        if self.paged or self.prefill_chunk is not None or self._spec is not None:
            raise ValueError(
                "multi-process serving runs dense whole-prompt stages only"
            )
        for g in range(self.G):
            for r in range(self.R):
                self._workers[(g, r)] = self._spawn(g, r)
        return [_RemoteExec(self, g) for g in range(self.G)]

    def _spawn(self, g: int, r: int) -> WorkerHandle:
        w = WorkerHandle(
            g,
            r,
            self.model_spec,
            n_groups=self.G,
            max_batch=self.max_batch,
            max_len=self.max_len,
            mesh_model=self.mesh_model,
            monitor=self.monitor,
        )
        self.monitor.register((g, r), w.proc)
        return w

    def _finalize(self, call) -> None:
        for dev, fin in call.readbacks:
            fin(
                dev.result()
                if isinstance(dev, _PendingReply)
                else host_readback(dev)
            )
        call.readbacks = []

    def _on_ring_abort(self, g: int, r: int) -> None:
        w = self._workers.get((g, r))
        if w is not None:
            w.discard_pending()

    # -- lifecycle -------------------------------------------------------
    def step(self) -> None:
        # Real-process health sweep first: a worker that exited since the
        # last slot is a membership leave — the base step's ring abort
        # then reroutes its in-flight members loss-free.
        for (g, r) in self.monitor.poll():
            if self.budgets[g][r].alive:
                self.fail_replica(g, r)
        super().step()

    def fail_replica(self, g: int, r: int) -> None:
        """Fault injection kills the real process (and the base path
        marks the budget + elastic membership)."""
        w = self._workers.get((g, r))
        if w is not None and w.alive:
            w.kill()
        super().fail_replica(g, r)
        # Abort immediately (not at the next step): a fail->recover pair
        # with no step between them must not leave doomed calls queued.
        self._abort_ring(g, r)

    def recover_replica(self, g: int, r: int) -> None:
        w = self._workers.get((g, r))
        if w is None or not w.alive:
            # The respawned worker starts with an EMPTY cache — any
            # resident still holding stage-g state on this replica must
            # re-place and re-prefill against it.
            self.scheduler.evict_stage_residents(g, r)
            self._workers[(g, r)] = self._spawn(g, r)
        super().recover_replica(g, r)

    def close(self) -> None:
        for w in self._workers.values():
            w.close()

    def __enter__(self) -> "MPPipelineServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="mpserve worker entry point")
    ap.add_argument("--worker", action="store_true", required=True)
    ap.add_argument("--group", type=int, required=True)
    ap.add_argument("--n-groups", type=int, required=True)
    ap.add_argument("--max-batch", type=int, required=True)
    ap.add_argument("--max-len", type=int, required=True)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--spec", type=str, required=True)
    return worker_main(ap.parse_args(argv))


if __name__ == "__main__":
    sys.exit(main())
