from .budget import ReplicaBudget
from .engine import PipelineServer, Request, ServerStats
from .partition import partition_model, slice_stage_params, stage_configs
from .router import RouteError, Router

__all__ = [
    "ReplicaBudget",
    "PipelineServer",
    "Request",
    "ServerStats",
    "partition_model",
    "slice_stage_params",
    "stage_configs",
    "RouteError",
    "Router",
]
