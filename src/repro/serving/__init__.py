from .budget import ReplicaBudget
from .engine import PipelineServer, Request, ServerStats
from .paged_cache import PageError, PagePool
from .partition import partition_model, slice_stage_params, stage_configs
from .router import RouteError, Router

__all__ = [
    "ReplicaBudget",
    "PipelineServer",
    "Request",
    "ServerStats",
    "PageError",
    "PagePool",
    "partition_model",
    "slice_stage_params",
    "stage_configs",
    "RouteError",
    "Router",
]
