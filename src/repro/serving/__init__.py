from .budget import ReplicaBudget
from .cache import (
    DenseSlotCache,
    KVCacheManager,
    PagedKVCache,
    PageError,
    PagePool,
    kv_page_bytes,
)
from .engine import (
    PipelineServer,
    Request,
    ServerStats,
    reset_trace_counts,
    trace_counts,
)
from .mpserve import MPPipelineServer, StageHost, WorkerDied, WorkerError
from .partition import partition_model, slice_stage_params, stage_configs
from .router import RouteError, Router
from .scheduler import StepScheduler

__all__ = [
    "ReplicaBudget",
    "PipelineServer",
    "Request",
    "ServerStats",
    "KVCacheManager",
    "DenseSlotCache",
    "PagedKVCache",
    "PageError",
    "PagePool",
    "kv_page_bytes",
    "StepScheduler",
    "MPPipelineServer",
    "StageHost",
    "WorkerDied",
    "WorkerError",
    "partition_model",
    "slice_stage_params",
    "stage_configs",
    "RouteError",
    "Router",
    "trace_counts",
    "reset_trace_counts",
]
