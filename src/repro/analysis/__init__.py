"""Static-analysis pass framework over jaxprs (+ runtime sanitizers).

On battery-powered edge devices every wasted recompile, silent fp32
upcast, and hidden device->host sync burns energy the paper's
semi-Markov model assumes is going to useful inference. This package
is the guard rail: a recursive jaxpr walker (:mod:`.walker`), a rule
registry (:mod:`.rules`) with a budgets file (:mod:`.budgets`,
``budgets.json``), lint entry points over the serving surface
(:mod:`.entry_points`), a compile-count gate (:mod:`.recompile`), a
runtime device->host transfer sanitizer (:mod:`.sanitizer`), and a CLI
(``python -m repro.analysis.cli --check``) emitting a machine-readable
JSON report.

Rules shipped out of the box:

* ``primitive-budget`` — per-entry-point primitive count ceilings
  (e.g. zero pool gathers in the Pallas paged decode/prefill paths);
* ``host-sync`` — statically forbid ``io_callback`` /
  ``debug_callback``-style host round-trips inside jitted serving
  entry points;
* ``dtype-promotion`` — bound silent upcasts from bf16/fp16/int8 to
  fp32 (LSE accumulators and per-row KV scales are budgeted, anything
  beyond fails);
* ``recompile-budget`` — per-(kind, stage) compiled-shape budgets over
  :func:`repro.serving.trace_counts`, enforced after engine smoke runs;
* ``bytes-per-token`` / ``peak-live-bytes`` — the static memory-flow
  pass (:mod:`.memory`): per-equation byte costs with trip-weighted
  loop bodies and block-spec DMA accounting for Pallas kernels, plus a
  liveness-based peak-residency sweep, pinned to measured-exact values
  in ``budgets.json`` (regenerate with ``cli --update-budgets``);
* ``kv-page-ratio`` — int8 paged entries must show the ~4x
  dtype-normalized KV pool byte reduction vs fp32;
* ``donation`` — the engine's jitted dispatches must donate every
  cache-sized consumed-and-rebuilt input (``donate_argnums``), checked
  against the lowered MLIR aliasing attributes and
  ``compiled.memory_analysis()``.
"""

from .budgets import default_budgets, load_budgets, resolve_budget
from .entry_points import EntryPoint, build_entry_points
from .memory import (
    DispatchReport,
    MemoryStats,
    analyze_dispatch,
    aval_bytes,
    entry_memory,
    eqn_bytes,
    io_bytes,
    memory_report,
    memory_section,
    pallas_dma_bytes,
    peak_live_bytes,
    run_donation_gate,
    transfer_bytes,
    update_memory_budgets,
    while_trip_count,
)
from .recompile import check_trace_budgets, run_host_sync_gate, run_recompile_gate
from .rules import RULES, Finding, Rule, register_rule, run_static_rules
from .sanitizer import (
    HostSyncError,
    TransferSanitizer,
    active_sanitizer,
    host_readback,
)
from .walker import count_primitive, iter_eqns, primitive_counts, subjaxprs

__all__ = [
    "DispatchReport",
    "EntryPoint",
    "Finding",
    "HostSyncError",
    "MemoryStats",
    "RULES",
    "Rule",
    "TransferSanitizer",
    "active_sanitizer",
    "analyze_dispatch",
    "aval_bytes",
    "build_entry_points",
    "check_trace_budgets",
    "count_primitive",
    "default_budgets",
    "entry_memory",
    "eqn_bytes",
    "host_readback",
    "io_bytes",
    "iter_eqns",
    "load_budgets",
    "memory_report",
    "memory_section",
    "pallas_dma_bytes",
    "peak_live_bytes",
    "primitive_counts",
    "register_rule",
    "resolve_budget",
    "run_donation_gate",
    "run_host_sync_gate",
    "run_recompile_gate",
    "run_static_rules",
    "subjaxprs",
    "transfer_bytes",
    "update_memory_budgets",
    "while_trip_count",
]
