"""Static-analysis pass framework over jaxprs (+ runtime sanitizers).

On battery-powered edge devices every wasted recompile, silent fp32
upcast, and hidden device->host sync burns energy the paper's
semi-Markov model assumes is going to useful inference. This package
is the guard rail: a recursive jaxpr walker (:mod:`.walker`), a rule
registry (:mod:`.rules`) with a budgets file (:mod:`.budgets`,
``budgets.json``), lint entry points over the serving surface
(:mod:`.entry_points`), a compile-count gate (:mod:`.recompile`), a
runtime device->host transfer sanitizer (:mod:`.sanitizer`), and a CLI
(``python -m repro.analysis.cli --check``) emitting a machine-readable
JSON report.

Rules shipped out of the box:

* ``primitive-budget`` — per-entry-point primitive count ceilings
  (e.g. zero pool gathers in the Pallas paged decode/prefill paths);
* ``host-sync`` — statically forbid ``io_callback`` /
  ``debug_callback``-style host round-trips inside jitted serving
  entry points;
* ``dtype-promotion`` — bound silent upcasts from bf16/fp16/int8 to
  fp32 (LSE accumulators and per-row KV scales are budgeted, anything
  beyond fails);
* ``recompile-budget`` — per-(kind, stage) compiled-shape budgets over
  :func:`repro.serving.trace_counts`, enforced after engine smoke runs.
"""

from .budgets import default_budgets, load_budgets, resolve_budget
from .entry_points import EntryPoint, build_entry_points
from .recompile import check_trace_budgets, run_host_sync_gate, run_recompile_gate
from .rules import RULES, Finding, Rule, register_rule, run_static_rules
from .sanitizer import (
    HostSyncError,
    TransferSanitizer,
    active_sanitizer,
    host_readback,
)
from .walker import count_primitive, iter_eqns, primitive_counts, subjaxprs

__all__ = [
    "EntryPoint",
    "Finding",
    "HostSyncError",
    "RULES",
    "Rule",
    "TransferSanitizer",
    "active_sanitizer",
    "build_entry_points",
    "check_trace_budgets",
    "count_primitive",
    "default_budgets",
    "host_readback",
    "iter_eqns",
    "load_budgets",
    "primitive_counts",
    "register_rule",
    "resolve_budget",
    "run_host_sync_gate",
    "run_recompile_gate",
    "run_static_rules",
    "subjaxprs",
]
