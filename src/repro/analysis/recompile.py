"""Recompile-hazard gate + engine runtime smoke gates.

:func:`repro.serving.trace_counts` counts actual jit cache misses per
``(kind, stage, *shape)``. This module turns those observations into
*enforced budgets*: per ``kind`` a maximum number of distinct compiled
shapes per stage (``trace_budgets`` in ``budgets.json``). A code
change that reintroduces shape-dependent re-jitting — e.g. keying the
chunked-prefill dispatch on prompt length again — multiplies the
shapes per stage and fails the gate with a named rule and entry point.

Two engine smoke gates (both run by ``cli --check``; the compile gate
is also wired into the main-lane smoke benchmarks):

* :func:`run_recompile_gate` — drains a mixed-prompt-length workload
  through a chunked dense and a chunked paged server and applies the
  trace budgets; chunked runs must additionally contain *zero*
  whole-prompt prefill traces (their shape count scales with the
  workload's prompt lengths).
* :func:`run_host_sync_gate` — repeats the drain under a
  :class:`~.sanitizer.TransferSanitizer` and enforces the per-step
  device->host sync budget (``host_sync.per_step_budget``).

Serving imports stay function-local so ``repro.analysis`` never drags
the engine in at import time (the engine imports the sanitizer).
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict

from .budgets import resolve_budget
from .rules import Finding

__all__ = [
    "check_trace_budgets",
    "run_recompile_gate",
    "run_host_sync_gate",
]

# Trace kinds whose dispatch shape must not depend on the workload.
_CHUNKED_FORBIDDEN = ("prefill", "prefill_pages")


def shapes_per_stage(counts: dict) -> dict:
    """{(kind, stage): set of traced shapes} from a trace_counts dict."""
    out: dict = defaultdict(set)
    for key in counts:
        kind, stage, *shape = key
        out[(kind, stage)].add(tuple(shape))
    return dict(out)


def check_trace_budgets(
    counts: dict, budgets: dict, context: str = "engine"
) -> list[Finding]:
    """Apply ``trace_budgets`` to a ``trace_counts()`` snapshot."""
    section = budgets.get("trace_budgets", {})
    findings = []
    for (kind, stage), shapes in sorted(shapes_per_stage(counts).items()):
        limits = resolve_budget(section, kind)
        max_shapes = limits.get("max_shapes_per_stage")
        if max_shapes is not None and len(shapes) > max_shapes:
            sample = ", ".join(str(s) for s in sorted(shapes)[:4])
            findings.append(
                Finding(
                    "recompile-budget",
                    f"{context}:{kind}:stage{stage}",
                    f"{len(shapes)} distinct compiled shapes for one stage "
                    f"(shapes: {sample}) — shape-dependent re-jitting",
                    measured=len(shapes),
                    budget=max_shapes,
                )
            )
    return findings


# ---------------------------------------------------------------------------
# Engine smoke harness
# ---------------------------------------------------------------------------

# Mixed prompt lengths: enough distinct values that any length-keyed
# dispatch shows up as multiple compiled shapes immediately.
_PROMPT_LENS = (4, 6, 10, 14)


def _smoke_server(paged: bool, prefill_chunk: int | None = 4):
    from ..configs import get_smoke_config
    from ..models import build_model
    from ..models.common import init_from_template
    from ..serving import PipelineServer

    import jax

    cfg = dataclasses.replace(
        get_smoke_config("stablelm-1.6b"), dtype="float32", param_dtype="float32"
    )
    model = build_model(cfg)
    params = init_from_template(model.template, jax.random.PRNGKey(0), "float32")
    server = PipelineServer(
        model, params,
        n_groups=2, n_replicas=1, policy="uniform",
        harvest_bounds=(60.0, 80.0),  # energy-unconstrained smoke
        max_len=64, max_batch=4,
        paged=paged, page_size=8,
        prefill_chunk=prefill_chunk, seed=0,
    )
    return cfg, server


def _drain(server, cfg, n_requests: int = 6, n_tokens: int = 3) -> None:
    import numpy as np

    reqs = [
        server.submit(
            (np.arange(_PROMPT_LENS[i % len(_PROMPT_LENS)]) + i) % cfg.vocab_size,
            n_tokens=n_tokens,
        )
        for i in range(n_requests)
    ]
    steps = 0
    while not all(r.done for r in reqs):
        server.step()
        steps += 1
        if steps > 10_000:  # pragma: no cover
            raise RuntimeError("smoke drain did not converge")


def run_recompile_gate(budgets: dict) -> list[Finding]:
    """Chunked dense + paged smoke drains under the trace budgets."""
    from ..serving import reset_trace_counts, trace_counts

    findings: list[Finding] = []
    for paged in (False, True):
        context = "paged" if paged else "dense"
        reset_trace_counts()
        cfg, server = _smoke_server(paged)
        _drain(server, cfg)
        counts = trace_counts()
        findings.extend(check_trace_budgets(counts, budgets, context=context))
        for kind in _CHUNKED_FORBIDDEN:
            hits = {k: v for k, v in counts.items() if k[0] == kind}
            if hits:
                findings.append(
                    Finding(
                        "recompile-budget",
                        f"{context}:{kind}",
                        "whole-prompt prefill traced in a chunked run — "
                        "compile count scales with workload prompt lengths "
                        f"(traces: {sorted(hits)})",
                        measured=len(hits),
                        budget=0,
                    )
                )
    return findings


def run_host_sync_gate(budgets: dict) -> list[Finding]:
    """Warmed engine steps under the transfer sanitizer: per-step
    device->host syncs must stay within ``host_sync.per_step_budget``
    and every one must flow through the sanctioned choke point."""
    from .sanitizer import TransferSanitizer

    section = budgets.get("host_sync", {})
    per_step = section.get("per_step_budget", {})
    findings: list[Finding] = []
    for paged in (False, True):
        context = "paged" if paged else "dense"
        budget = int(per_step.get(context, 3))
        cfg, server = _smoke_server(paged)
        _drain(server, cfg)  # warmup: compile every dispatch shape first
        with TransferSanitizer() as san:
            _drain(server, cfg)
        if san.max_per_step > budget:
            findings.append(
                Finding(
                    "host-sync",
                    f"{context}:replica-step",
                    "device->host syncs per replica-step over budget",
                    measured=san.max_per_step,
                    budget=budget,
                )
            )
        if san.unsanctioned_total > 0:
            findings.append(
                Finding(
                    "host-sync",
                    f"{context}:replica-step",
                    f"{san.unsanctioned_total} device->host sync(s) bypassed "
                    "the sanctioned host_readback choke point",
                    measured=san.unsanctioned_total,
                    budget=0,
                )
            )
    return findings
