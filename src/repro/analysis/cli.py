"""Static-analysis CLI: ``python -m repro.analysis.cli --check``.

Runs the static rules (primitive budgets, host-sync lint, dtype
promotion, memory-flow budgets) over every lint entry point, then the
engine smoke gates (recompile-hazard trace budgets + runtime host-sync
sanitizer + KV donation lint), prints one line per finding, optionally
writes a machine-readable JSON report (including a ``memory`` section
with per-entry ``bytes_per_token`` / ``peak_live_bytes``), and exits
non-zero when anything is over budget.

    python -m repro.analysis.cli --check                 # full gate
    python -m repro.analysis.cli --check --static-only   # no engine runs
    python -m repro.analysis.cli --check --json report.json
    python -m repro.analysis.cli --check --models stablelm-1.6b
    python -m repro.analysis.cli --update-budgets        # refresh memory_budgets
    python -m repro.analysis.cli --list                  # entry points
"""

from __future__ import annotations

import argparse
import json
import sys

from .budgets import DEFAULT_BUDGETS_PATH, load_budgets
from .entry_points import build_entry_points
from .memory import memory_section, run_donation_gate, update_memory_budgets
from .recompile import run_host_sync_gate, run_recompile_gate
from .rules import RULES, run_static_rules


def _report(findings, entries, rules, budgets_path) -> dict:
    return {
        "version": 2,
        "passed": not findings,
        "budgets": str(budgets_path) if budgets_path else "default",
        "rules": sorted(rules),
        "entry_points_checked": [e.name for e in entries],
        "findings": [f.as_dict() for f in findings],
        "memory": memory_section(entries),
    }


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.cli", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("--check", action="store_true", help="run the lint gate")
    ap.add_argument("--list", action="store_true", help="list entry points + rules")
    ap.add_argument("--json", metavar="PATH", help="write the JSON report here")
    ap.add_argument(
        "--models", metavar="CSV",
        help="restrict to these registry models (comma-separated)",
    )
    ap.add_argument(
        "--rules", metavar="CSV",
        help=f"restrict static rules (available: {', '.join(sorted(RULES))})",
    )
    ap.add_argument("--budgets", metavar="PATH", help="override budgets.json")
    ap.add_argument(
        "--static-only", action="store_true",
        help="skip the engine smoke gates (recompile + runtime host-sync)",
    )
    ap.add_argument(
        "--no-kernels", action="store_true",
        help="skip the standalone Pallas kernel entry points",
    )
    ap.add_argument(
        "--update-budgets", action="store_true",
        help="regenerate the measured-exact memory_budgets section of "
        "budgets.json in place and exit",
    )
    args = ap.parse_args(argv)

    models = args.models.split(",") if args.models else None
    rules = args.rules.split(",") if args.rules else None
    if rules:
        unknown = [r for r in rules if r not in RULES]
        if unknown:
            ap.error(f"unknown rule(s): {', '.join(unknown)}")
    entries = build_entry_points(models, include_kernels=not args.no_kernels)

    if args.list:
        print("rules:")
        for rule in RULES.values():
            print(f"  {rule.name}: {rule.description}")
        print("entry points:")
        for e in entries:
            print(f"  {e.name}")
        return 0
    if args.update_budgets:
        # Regenerate against the *full* matrix regardless of filters so a
        # partial run can never silently shrink the committed section.
        path = args.budgets or DEFAULT_BUDGETS_PATH
        budgets = load_budgets(path)
        update_memory_budgets(budgets, build_entry_points())
        with open(path, "w") as f:
            json.dump(budgets, f, indent=2, ensure_ascii=False)
            f.write("\n")
        print(f"memory_budgets regenerated in {path}")
        return 0
    if not args.check:
        ap.error("nothing to do: pass --check (or --list)")

    budgets = load_budgets(args.budgets)
    findings = list(run_static_rules(entries, budgets, rules))
    checked_rules = set(rules or RULES)
    if not args.static_only:
        print("static rules done; running engine smoke gates...", flush=True)
        findings += run_recompile_gate(budgets)
        findings += run_host_sync_gate(budgets)
        _, donation_findings = run_donation_gate(budgets)
        findings += donation_findings
        checked_rules |= {"recompile-budget", "host-sync", "donation"}

    report = _report(findings, entries, checked_rules, args.budgets)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
            f.write("\n")
    for f in findings:
        print(f"FAIL {f}")
    n = len(entries)
    if findings:
        print(f"analysis: {len(findings)} finding(s) over {n} entry points")
        return 1
    print(f"analysis: OK ({n} entry points, rules: {', '.join(sorted(checked_rules))})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
