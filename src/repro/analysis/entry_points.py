"""Lint entry points: the serving surface as traceable jaxprs.

Out of the box the lint covers, for every ``supports_paged`` registry
model (smoke config, real compute dtype): ``prefill_batch`` /
``decode_batch`` (the dense continuous-batching paths),
``prefill_chunk_batch`` (dense chunked prefill), ``decode_step_paged``,
``prefill_chunk_paged`` and ``verify_step_paged`` (the speculative
draft-verify chunk, traced at the default ``spec_k``) in both
``attn_impl`` variants (``xla`` gather fallback vs ``pallas`` kernels)
plus an int8-pool variant, and
the dense paths of every non-paged LM family. The two Pallas paged
kernels are also traced standalone (``kernel:*``) so the zero-gather
budget binds at the kernel boundary, not just through the model.

Entry-point names are ``model:kind:variant`` (e.g.
``stablelm-1.6b:decode_step_paged:pallas``) — the glob keys of
``budgets.json`` resolve against them. Tracing is lazy and abstract
(``jax.make_jaxpr`` over ``ShapeDtypeStruct`` params), so building the
full matrix never allocates model weights.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from ..configs import ARCH_NAMES, get_smoke_config
from ..kernels.decode_attention import PALLAS_PAGED_KERNELS
from ..models import build_model
from ..models.common import abstract_params
from ..models.transformer import supports_paged

__all__ = ["EntryPoint", "build_entry_points", "paged_model_names"]

# Trace shapes: tiny but structurally faithful (W slot lanes, C-token
# chunks, an NB-block table over a P-page pool plus the scratch page).
_W, _C, _S, _N, _MAX_LEN = 4, 8, 8, 2, 64
_PAGE, _NB, _P = 16, 4, 16
# Speculative verify traces at the engine's default spec_k: the chunk
# carries [last_token, d_1..d_k] = k + 1 positions per lane.
_SPEC_K = 4


@dataclasses.dataclass
class EntryPoint:
    """One lintable entry point; ``jaxpr`` traces lazily and caches.

    ``tokens`` is the number of tokens one invocation advances (the
    denominator of the memory pass's ``bytes_per_token``);
    ``kv_pool_bytes`` / ``kv_pool_bytes_fp32`` carry the paged KV pool
    footprint at the traced dtype and its fp32 equivalent, so the
    ``kv-page-ratio`` rule can enforce the int8 reduction
    dtype-normalized (smoke configs trace bf16 pools)."""

    name: str  # "model:kind:variant"
    model: str
    kind: str
    variant: str
    _make: Callable[[], jax.core.ClosedJaxpr]
    _jaxpr: jax.core.ClosedJaxpr | None = None
    tokens: int = 1
    kv_pool_bytes: int | None = None
    kv_pool_bytes_fp32: int | None = None
    _memory: object = None  # MemoryStats cache (see analysis.memory)

    @property
    def jaxpr(self) -> jax.core.ClosedJaxpr:
        if self._jaxpr is None:
            self._jaxpr = self._make()
        return self._jaxpr


def paged_model_names() -> list[str]:
    """Registry models the paged serving paths cover."""
    out = []
    for name in ARCH_NAMES:
        cfg = get_smoke_config(name)
        if not cfg.is_encdec and supports_paged(cfg):
            out.append(name)
    return out


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def _pool_sds(cfg, kv_dtype):
    shape = (cfg.n_layers, _P + 1, _PAGE, cfg.n_kv_heads, cfg.head_dim)
    pools = {"k": _sds(shape, kv_dtype), "v": _sds(shape, kv_dtype)}
    if jnp.dtype(kv_dtype) == jnp.int8:
        pools["k_scale"] = _sds(shape[:3], jnp.float32)
        pools["v_scale"] = _sds(shape[:3], jnp.float32)
    return pools


def _pool_bytes(cfg, kv_dtype) -> int:
    """Total paged KV pool footprint at the trace shapes (k + v pools,
    plus per-row fp32 scales for int8)."""
    rows = cfg.n_layers * (_P + 1) * _PAGE
    data = 2 * rows * cfg.n_kv_heads * cfg.head_dim * jnp.dtype(kv_dtype).itemsize
    scales = 2 * rows * 4 if jnp.dtype(kv_dtype) == jnp.int8 else 0
    return data + scales


def _stacked_cache_sds(model, n: int):
    shapes = model.cache_shapes(1, _MAX_LEN)
    return jax.tree_util.tree_map(
        lambda s: _sds((n,) + tuple(s.shape), s.dtype), shapes
    )


def _model_entries(name: str) -> list[EntryPoint]:
    cfg = get_smoke_config(name)
    if cfg.is_encdec:
        # The engine's submit() path carries decoder-only token streams;
        # encoder-decoder serving is out of the lint's scope for now.
        return []
    entries: list[EntryPoint] = []

    def add(kind: str, variant: str, make, tokens: int = 1, **meta):
        entries.append(
            EntryPoint(
                f"{name}:{kind}:{variant}", name, kind, variant, make,
                tokens=tokens, **meta,
            )
        )

    def dense_model():
        return build_model(cfg)

    def make_prefill_batch():
        model = dense_model()
        params = abstract_params(model.template, cfg.param_dtype)
        batch = {"tokens": _sds((_N, 1, _S), jnp.int32)}
        return jax.make_jaxpr(
            lambda p, b: model.prefill_batch(p, b, _MAX_LEN)
        )(params, batch)

    def make_decode_batch():
        model = dense_model()
        params = abstract_params(model.template, cfg.param_dtype)
        tok = _sds((_N, 1, 1), jnp.int32)
        caches = _stacked_cache_sds(model, _N)
        return jax.make_jaxpr(model.decode_batch)(params, tok, caches)

    add("prefill_batch", "dense", make_prefill_batch, tokens=_N * _S)
    add("decode_batch", "dense", make_decode_batch, tokens=_N)
    if not supports_paged(cfg):
        return entries

    def make_prefill_chunk_batch():
        model = dense_model()
        params = abstract_params(model.template, cfg.param_dtype)
        chunk = {"tokens": _sds((_N, 1, _C), jnp.int32)}
        caches = _stacked_cache_sds(model, _N)
        offs = _sds((_N,), jnp.int32)
        valids = _sds((_N,), jnp.int32)
        return jax.make_jaxpr(model.prefill_chunk_batch)(
            params, chunk, caches, offs, valids
        )

    add("prefill_chunk_batch", "dense", make_prefill_chunk_batch,
        tokens=_N * _C)

    for impl in ("xla", "pallas"):
        kv_dtypes = [cfg.dtype] if impl == "xla" else [cfg.dtype, "int8"]
        for kv_dtype in kv_dtypes:
            variant = impl if kv_dtype != "int8" else f"{impl}-int8"
            cfg_v = dataclasses.replace(cfg, attn_impl=impl)

            def make_decode_paged(cfg_v=cfg_v, kv_dtype=kv_dtype):
                model = build_model(cfg_v)
                params = abstract_params(model.template, cfg_v.param_dtype)
                tok = _sds((_W, 1), jnp.int32)
                pools = _pool_sds(cfg_v, kv_dtype)
                lens = _sds((_W,), jnp.int32)
                bt = _sds((_W, _NB), jnp.int32)
                return jax.make_jaxpr(model.decode_paged)(
                    params, tok, pools, lens, bt
                )

            def make_chunk_paged(cfg_v=cfg_v, kv_dtype=kv_dtype):
                model = build_model(cfg_v)
                params = abstract_params(model.template, cfg_v.param_dtype)
                chunk = _sds((_W, _C), jnp.int32)
                pools = _pool_sds(cfg_v, kv_dtype)
                offs = _sds((_W,), jnp.int32)
                valids = _sds((_W,), jnp.int32)
                bt = _sds((_W, _NB), jnp.int32)
                return jax.make_jaxpr(model.prefill_chunk_paged)(
                    params, chunk, pools, offs, valids, bt
                )

            def make_verify_paged(cfg_v=cfg_v, kv_dtype=kv_dtype):
                model = build_model(cfg_v)
                params = abstract_params(model.template, cfg_v.param_dtype)
                chunk = _sds((_W, _SPEC_K + 1), jnp.int32)
                pools = _pool_sds(cfg_v, kv_dtype)
                offs = _sds((_W,), jnp.int32)
                valids = _sds((_W,), jnp.int32)
                bt = _sds((_W, _NB), jnp.int32)
                return jax.make_jaxpr(model.verify_step_paged)(
                    params, chunk, pools, offs, valids, bt
                )

            pool_meta = dict(
                kv_pool_bytes=_pool_bytes(cfg_v, kv_dtype),
                kv_pool_bytes_fp32=_pool_bytes(cfg_v, jnp.float32),
            )
            add("decode_step_paged", variant, make_decode_paged,
                tokens=_W, **pool_meta)
            add("prefill_chunk_paged", variant, make_chunk_paged,
                tokens=_W * _C, **pool_meta)
            add("verify_step_paged", variant, make_verify_paged,
                tokens=_W * (_SPEC_K + 1), **pool_meta)
    return entries


def _kernel_entries() -> list[EntryPoint]:
    """The Pallas paged kernels traced standalone: the zero-gather
    budget binds directly at the kernel boundary."""
    B, KV, G, D = 2, 2, 2, 8
    page, NB, C = 8, 3, 4
    P = B * NB + 1
    entries: list[EntryPoint] = []
    for kernel_name, fn in PALLAS_PAGED_KERNELS.items():
        prefill = "prefill" in kernel_name

        def make(fn=fn, prefill=prefill):
            q_shape = (B, C, KV * G, D) if prefill else (B, 1, KV * G, D)
            q = _sds(q_shape, jnp.float32)
            k = _sds((P, page, KV, D), jnp.float32)
            v = _sds((P, page, KV, D), jnp.float32)
            bt = _sds((B, NB), jnp.int32)
            idx = _sds((B,), jnp.int32)  # lengths (decode) / offsets (prefill)
            return jax.make_jaxpr(fn)(q, k, v, bt, idx)

        entries.append(
            EntryPoint(f"kernel:{kernel_name}:pallas", "kernel", kernel_name,
                       "pallas", make, tokens=B * C if prefill else B)
        )
    return entries


def build_entry_points(
    models: list[str] | None = None, include_kernels: bool = True
) -> list[EntryPoint]:
    """The full lint matrix (lazily traced). ``models`` filters by
    registry name; kernels ride along unless disabled."""
    entries: list[EntryPoint] = []
    for name in models if models is not None else ARCH_NAMES:
        entries.extend(_model_entries(name))
    if include_kernels:
        entries.extend(_kernel_entries())
    return entries
