"""Budget file: load, merge, and per-entry-point resolution.

Budgets live in ``budgets.json`` next to this module (the repo's
checked-in source of truth; ``--budgets PATH`` on the CLI overrides).
Sections keyed by *glob patterns* over entry-point names are resolved
with :func:`resolve_budget`: every matching pattern applies in file
order, later (more specific) patterns overriding earlier ones — so
``"*:decode_step_paged:pallas"`` sets the fleet-wide ceiling and
``"qwen3-moe-30b-a3b:*:pallas"`` below it can carve out the MoE
exception. (Names are colon-separated on purpose: fnmatch treats
square brackets as character classes.)
"""

from __future__ import annotations

import fnmatch
import json
import pathlib

__all__ = ["DEFAULT_BUDGETS_PATH", "default_budgets", "load_budgets", "resolve_budget"]

DEFAULT_BUDGETS_PATH = pathlib.Path(__file__).with_name("budgets.json")


def load_budgets(path: str | pathlib.Path | None = None) -> dict:
    """Parse a budgets file (the checked-in default when ``path=None``)."""
    p = pathlib.Path(path) if path is not None else DEFAULT_BUDGETS_PATH
    with open(p) as f:
        budgets = json.load(f)
    if not isinstance(budgets, dict):
        raise ValueError(f"{p}: budgets file must be a JSON object")
    return budgets


def default_budgets() -> dict:
    return load_budgets(None)


def resolve_budget(section: dict, name: str) -> dict:
    """Merge every pattern in ``section`` matching ``name`` (file order,
    later patterns override). Returns {} when nothing matches."""
    out: dict = {}
    for pattern, values in section.items():
        if fnmatch.fnmatchcase(name, pattern):
            out.update(values)
    return out
