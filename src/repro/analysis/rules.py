"""Rule registry + the shipped static rules.

A rule is a named check over one :class:`~.entry_points.EntryPoint`'s
jaxpr, parameterized by the budgets file. Rules return
:class:`Finding`s — one per violation, always naming the rule and the
entry point — and the CLI aggregates them into the JSON report.

Registering a new rule::

    @register_rule("my-rule", "one-line description")
    def my_rule(entry, budgets):
        return [Finding("my-rule", entry.name, "...")] if bad else []
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

from .budgets import resolve_budget
from .walker import iter_eqns, primitive_counts

__all__ = ["Finding", "Rule", "RULES", "register_rule", "run_static_rules"]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation, machine-readable for the JSON report."""

    rule: str
    entry_point: str
    message: str
    measured: int | None = None
    budget: int | None = None

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        extra = (
            f" (measured {self.measured}, budget {self.budget})"
            if self.measured is not None
            else ""
        )
        return f"[{self.rule}] {self.entry_point}: {self.message}{extra}"


@dataclasses.dataclass(frozen=True)
class Rule:
    name: str
    description: str
    check: Callable  # (EntryPoint, budgets: dict) -> list[Finding]


RULES: dict[str, Rule] = {}


def register_rule(name: str, description: str):
    def wrap(fn):
        RULES[name] = Rule(name, description, fn)
        return fn

    return wrap


def run_static_rules(
    entries, budgets: dict, rules: list[str] | None = None
) -> list[Finding]:
    """Every selected rule over every entry point, findings aggregated."""
    selected = [RULES[r] for r in rules] if rules is not None else list(RULES.values())
    findings: list[Finding] = []
    for entry in entries:
        for rule in selected:
            findings.extend(rule.check(entry, budgets))
    return findings


# ---------------------------------------------------------------------------
# primitive-budget: per-entry-point primitive count ceilings
# ---------------------------------------------------------------------------

@register_rule(
    "primitive-budget",
    "per-entry-point primitive count ceilings (zero pool gathers in "
    "Pallas paged paths, bounded scatter/convert counts)",
)
def primitive_budget(entry, budgets: dict) -> list[Finding]:
    section = budgets.get("primitive_budgets", {})
    limits = resolve_budget(section, entry.name)
    if not limits:
        return []
    counts = primitive_counts(entry.jaxpr)
    findings = []
    for prim, max_count in sorted(limits.items()):
        measured = counts.get(prim, 0)
        if measured > max_count:
            findings.append(
                Finding(
                    "primitive-budget",
                    entry.name,
                    f"primitive '{prim}' over budget",
                    measured=measured,
                    budget=max_count,
                )
            )
    return findings


# ---------------------------------------------------------------------------
# host-sync: no host round-trip primitives inside jitted entry points
# ---------------------------------------------------------------------------

_DEFAULT_FORBIDDEN = (
    "pure_callback",
    "io_callback",
    "debug_callback",
    "debug_print",
    "infeed",
    "outfeed",
    "host_callback_call",
)


@register_rule(
    "host-sync",
    "statically forbid io_callback/debug_callback-style host round-trips "
    "inside jitted serving entry points",
)
def host_sync(entry, budgets: dict) -> list[Finding]:
    section = budgets.get("host_sync", {})
    forbidden = set(section.get("forbidden_primitives", _DEFAULT_FORBIDDEN))
    findings = []
    for path, eqn in iter_eqns(entry.jaxpr):
        name = eqn.primitive.name
        if name in forbidden:
            where = " -> ".join(path) or "<top level>"
            findings.append(
                Finding(
                    "host-sync",
                    entry.name,
                    f"host-callback primitive '{name}' inside jitted entry "
                    f"point (at {where}) — a hidden device->host sync per "
                    "dispatch",
                )
            )
    return findings


# ---------------------------------------------------------------------------
# dtype-promotion: bounded silent upcasts narrow -> fp32
# ---------------------------------------------------------------------------

_DEFAULT_NARROW = ("bfloat16", "float16", "int8", "uint8")


@register_rule(
    "dtype-promotion",
    "bound silent upcasts from bf16/fp16/int8 to fp32 (LSE accumulators "
    "and per-row KV scale dequant are budgeted; anything beyond fails)",
)
def dtype_promotion(entry, budgets: dict) -> list[Finding]:
    section = budgets.get("dtype_promotion", {})
    limits = resolve_budget(section.get("budgets", {}), entry.name)
    if "max_upcasts" not in limits:
        return []
    narrow = {jnp.dtype(d) for d in section.get("narrow", _DEFAULT_NARROW)}
    wide = jnp.dtype(jnp.float32)
    upcasts: list[str] = []
    for path, eqn in iter_eqns(entry.jaxpr):
        if eqn.primitive.name != "convert_element_type":
            continue
        new_dtype = eqn.params.get("new_dtype")
        if new_dtype is None or jnp.dtype(new_dtype) != wide:
            continue
        old = eqn.invars[0].aval.dtype
        if jnp.dtype(old) in narrow:
            upcasts.append(f"{old}->f32 at {' -> '.join(path) or '<top level>'}")
    budget = int(limits["max_upcasts"])
    if len(upcasts) > budget:
        head = "; ".join(upcasts[:6]) + ("; ..." if len(upcasts) > 6 else "")
        return [
            Finding(
                "dtype-promotion",
                entry.name,
                f"narrow->fp32 upcasts over budget ({head})",
                measured=len(upcasts),
                budget=budget,
            )
        ]
    return []


# ---------------------------------------------------------------------------
# memory rules: bytes-per-token / peak-live-bytes / kv-page-ratio
# ---------------------------------------------------------------------------
# (memory.py imports Finding from here, so these import it lazily.)

@register_rule(
    "bytes-per-token",
    "static per-token memory traffic must match the measured-exact value "
    "in memory_budgets (regenerate with `cli --update-budgets`)",
)
def bytes_per_token(entry, budgets: dict) -> list[Finding]:
    from .memory import entry_memory

    limits = resolve_budget(budgets.get("memory_budgets", {}), entry.name)
    if "bytes_per_token" not in limits:
        return []
    budget = int(limits["bytes_per_token"])
    measured = entry_memory(entry).bytes_per_token
    if measured != budget:
        return [
            Finding(
                "bytes-per-token",
                entry.name,
                "static bytes/token drifted from the committed budget — "
                "a memory-traffic regression (or run --update-budgets "
                "if intentional)",
                measured=measured,
                budget=budget,
            )
        ]
    return []


@register_rule(
    "peak-live-bytes",
    "liveness-based peak resident bytes must match the measured-exact "
    "value in memory_budgets",
)
def peak_live(entry, budgets: dict) -> list[Finding]:
    from .memory import entry_memory

    limits = resolve_budget(budgets.get("memory_budgets", {}), entry.name)
    if "peak_live_bytes" not in limits:
        return []
    budget = int(limits["peak_live_bytes"])
    measured = entry_memory(entry).peak_live_bytes
    if measured != budget:
        return [
            Finding(
                "peak-live-bytes",
                entry.name,
                "peak live bytes drifted from the committed budget",
                measured=measured,
                budget=budget,
            )
        ]
    return []


@register_rule(
    "kv-page-ratio",
    "int8 paged entries must shrink the KV pool ~4x vs the fp32-equivalent "
    "pool (dtype-normalized; per-row scales eat a little of the 4x)",
)
def kv_page_ratio(entry, budgets: dict) -> list[Finding]:
    limits = resolve_budget(budgets.get("kv_page_ratio", {}), entry.name)
    if not limits or not entry.kv_pool_bytes or not entry.kv_pool_bytes_fp32:
        return []
    ratio = entry.kv_pool_bytes_fp32 / entry.kv_pool_bytes
    lo = float(limits.get("min_ratio", 0.0))
    hi = float(limits.get("max_ratio", float("inf")))
    if not (lo <= ratio <= hi):
        return [
            Finding(
                "kv-page-ratio",
                entry.name,
                f"fp32/actual KV pool byte ratio {ratio:.2f} outside "
                f"[{lo}, {hi}] — the int8 page reduction regressed",
                measured=entry.kv_pool_bytes,
                budget=entry.kv_pool_bytes_fp32,
            )
        ]
    return []
