"""Static memory-flow pass: byte costs, liveness peaks, donation lint.

The paper's binding constraint is memory on energy-starved edge
devices, and the roofline's "fast as the hardware allows" claim needs
*bytes moved per step* as a first-class, statically-enforced quantity.
This module adds that third axis to the PR-6 analysis subsystem (which
counts primitives and host syncs but is blind to memory):

* :func:`transfer_bytes` — a per-equation byte-cost model over jaxprs.
  Every equation charges operand-read + result-write bytes from its
  avals; ``scan``/``while`` bodies are weighted by their trip counts
  (``while`` trips recovered from the loop condition's literal bound,
  the jaxpr-level analog of the roofline's HLO
  :func:`~repro.roofline.analysis.call_multipliers` machinery);
  ``cond`` charges its widest branch; ``pjit``/custom-vjp descend x1;
  ``pallas_call`` kernels are accounted at their *block-spec DMA
  granularity* — ``prod(grid) * block_bytes`` per operand/output, which
  is exactly what the TPU memory system moves (an int8 page pool
  therefore shows ~1/4 the fp32 DMA bytes with no further modeling).
  Index-driven ops (``gather``/``scatter``/``dynamic_update_slice``)
  charge the rows actually touched, not the whole buffer — the XLA
  in-place/gather semantics the roofline HLO walker also assumes.

* :func:`peak_live_bytes` — a liveness-based peak-residency estimate:
  a backward last-use sweep over the equations, then a forward walk of
  the live set (inputs live from entry, values die at last use,
  jaxpr outputs live to the end). Call-like equations add their
  sub-jaxpr's *internal* peak (boundary values are the caller's
  operands/results and counted once, at the call site). Donated input
  indices are excluded from the peak — their buffers alias outputs.

* :func:`entry_memory` — both of the above for one lint
  :class:`~.entry_points.EntryPoint`, normalized to ``bytes_per_token``
  via the entry's ``tokens`` metadata, plus the static roofline term
  (:func:`repro.roofline.analysis.static_memory_seconds`).

* :func:`analyze_dispatch` / :func:`run_donation_gate` — the
  donation/aliasing lint over the engine's *real* jitted dispatch
  signatures: any large (>= ``donation.min_bytes``) input that is
  consumed-and-rebuilt (an output with the identical aval exists) must
  be donated. Donation intent is read from the lowered MLIR
  (``tf.aliasing_output`` arg attributes) and cross-checked against
  ``compiled.memory_analysis()`` aliased bytes and the compiled HLO's
  ``input_output_alias`` table — the same artifacts
  :mod:`repro.launch.dryrun` records one-off, now shared via
  :func:`memory_report`.
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Iterable

from jax.core import Literal

from .rules import Finding
from .walker import subjaxprs

__all__ = [
    "MemoryStats",
    "DispatchReport",
    "aval_bytes",
    "eqn_bytes",
    "pallas_dma_bytes",
    "while_trip_count",
    "transfer_bytes",
    "io_bytes",
    "peak_live_bytes",
    "entry_memory",
    "memory_report",
    "analyze_dispatch",
    "engine_dispatches",
    "run_donation_gate",
    "memory_section",
    "update_memory_budgets",
]


# ---------------------------------------------------------------------------
# Per-equation byte cost model
# ---------------------------------------------------------------------------

def aval_bytes(aval) -> int:
    """Bytes of one abstract value (0 for tokens/opaque avals)."""
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0
    return math.prod(shape) * dtype.itemsize if shape else dtype.itemsize


def _invar_bytes(eqn) -> int:
    return sum(
        aval_bytes(v.aval) for v in eqn.invars if not isinstance(v, Literal)
    )


def _outvar_bytes(eqn) -> int:
    return sum(aval_bytes(v.aval) for v in eqn.outvars)


# Ops whose big operand is addressed by index: traffic is the rows
# actually touched (the result / the updates), never the whole buffer.
_GATHER_LIKE = ("gather", "take", "dynamic_slice")
_SCATTER_LIKE = (
    "scatter", "scatter-add", "scatter_add", "scatter_mul", "scatter_min",
    "scatter_max", "dynamic_update_slice",
)


def eqn_bytes(eqn) -> int:
    """Memory traffic one equation moves, from its operand/result avals.

    * gather/dynamic_slice: read the gathered rows + indices, write the
      result — ``2 * result + indices`` (the source buffer is only
      touched at row granularity);
    * scatter/dynamic_update_slice: read-modify-write at update
      granularity — ``2 * (updates + indices)``; the big operand is
      updated in place (XLA aliases it), so the result is free;
    * everything else: operand reads + result writes.
    """
    name = eqn.primitive.name
    if name in _GATHER_LIKE:
        idx = sum(
            aval_bytes(v.aval)
            for v in eqn.invars[1:]
            if not isinstance(v, Literal)
        )
        return 2 * _outvar_bytes(eqn) + idx
    if name in _SCATTER_LIKE:
        small = sum(
            aval_bytes(v.aval)
            for v in eqn.invars[1:]
            if not isinstance(v, Literal)
        )
        return 2 * small
    return _invar_bytes(eqn) + _outvar_bytes(eqn)


def pallas_dma_bytes(eqn) -> int:
    """DMA traffic of one ``pallas_call``: block-spec granularity.

    Every grid cell DMAs one block per (non-scalar-prefetch) operand and
    per output — ``prod(grid) * prod(block_shape) * itemsize`` each.
    Scalar-prefetch operands (block tables, lengths) are read once, in
    full. The kernel body's VMEM arithmetic moves no HBM bytes, so this
    is the whole memory cost of the kernel — and it is exactly where an
    int8 page pool shows its ~4x byte reduction over fp32 pages.
    """
    gm = eqn.params["grid_mapping"]
    grid = 1
    for d in gm.grid:
        grid *= int(d)
    per_cell = 0
    for bm in gm.block_mappings:
        block = 1
        for d in bm.block_shape:
            if isinstance(d, int):
                block *= d
        per_cell += block * bm.array_shape_dtype.dtype.itemsize
    n_prefetch = gm.num_index_operands
    prefetch = sum(
        aval_bytes(v.aval)
        for v in eqn.invars[:n_prefetch]
        if not isinstance(v, Literal)
    )
    return grid * per_cell + prefetch


def while_trip_count(eqn) -> int:
    """Trip count of a ``while`` equation, recovered from the literal
    bound in its condition jaxpr (the jaxpr-level analog of the roofline
    HLO walker's :func:`~repro.roofline.analysis.trip_count`). Falls
    back to 1 when the condition carries no literal comparison."""
    cond = eqn.params["cond_jaxpr"].jaxpr
    bounds = []
    for ceqn in cond.eqns:
        if ceqn.primitive.name in ("lt", "le", "gt", "ge"):
            for v in ceqn.invars:
                if isinstance(v, Literal) and isinstance(v.val, (int,)):
                    bounds.append(int(v.val))
    return max(bounds) if bounds else 1


def _as_jaxpr(obj):
    inner = getattr(obj, "jaxpr", None)
    return _as_jaxpr(inner) if inner is not None else obj


def transfer_bytes(jaxpr) -> int:
    """Trip-weighted bytes the jaxpr tree moves per invocation."""
    jaxpr = _as_jaxpr(jaxpr)
    total = 0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "scan":
            trip = int(eqn.params.get("length", 1))
            total += trip * transfer_bytes(eqn.params["jaxpr"])
        elif name == "while":
            trip = while_trip_count(eqn)
            total += trip * transfer_bytes(eqn.params["body_jaxpr"])
            total += (trip + 1) * transfer_bytes(eqn.params["cond_jaxpr"])
        elif name == "cond":
            branches = eqn.params.get("branches", ())
            total += max(
                (transfer_bytes(b) for b in branches), default=0
            ) + _invar_bytes(eqn) - sum(
                aval_bytes(v.aval)
                for v in eqn.invars[1:]
                if not isinstance(v, Literal)
            )
        elif name == "pallas_call":
            total += pallas_dma_bytes(eqn)
        else:
            subs = list(subjaxprs(eqn))
            if subs:
                # pjit / custom-vjp / remat: descend x1, no call-site cost
                # (the sub-jaxpr's own equations charge the traffic).
                total += sum(transfer_bytes(s) for s in subs)
            else:
                total += eqn_bytes(eqn)
    return total


def io_bytes(jaxpr) -> tuple[int, int]:
    """(input_bytes, output_bytes) of a (closed) jaxpr's boundary."""
    jaxpr = _as_jaxpr(jaxpr)
    ins = sum(aval_bytes(v.aval) for v in jaxpr.invars)
    ins += sum(aval_bytes(v.aval) for v in jaxpr.constvars)
    outs = sum(aval_bytes(v.aval) for v in jaxpr.outvars)
    return ins, outs


# ---------------------------------------------------------------------------
# Liveness: peak resident bytes
# ---------------------------------------------------------------------------

def peak_live_bytes(jaxpr, donated: Iterable[int] = ()) -> int:
    """Liveness-based peak-resident-bytes estimate.

    Backward sweep records each variable's last use; the forward walk
    then grows the live set at every definition and shrinks it at last
    use. Inputs are live from entry; jaxpr outputs stay live to the
    end; ``donated`` input *indices* contribute nothing (their buffers
    alias outputs). Call-like equations (scan/while/cond/pjit) add
    their sub-jaxpr's internal peak on top of the caller's live set —
    boundary values are the caller's operands/results, counted once.
    """
    return _sweep(_as_jaxpr(jaxpr), boundary=True, donated=frozenset(donated))


def _sweep(jaxpr, *, boundary: bool, donated: frozenset[int]) -> int:
    jaxpr = _as_jaxpr(jaxpr)
    n = len(jaxpr.eqns)
    out_set = {id(v) for v in jaxpr.outvars if not isinstance(v, Literal)}

    # Backward: last equation index using each var (outputs live to end).
    last_use: dict[int, int] = {}
    for i, eqn in enumerate(jaxpr.eqns):
        for v in eqn.invars:
            if not isinstance(v, Literal):
                last_use[id(v)] = i
    for vid in out_set:
        last_use[vid] = n

    live: dict[int, int] = {}

    def _add(v, nbytes):
        live[id(v)] = nbytes

    for i, v in enumerate(jaxpr.constvars):
        _add(v, aval_bytes(v.aval) if boundary else 0)
    for i, v in enumerate(jaxpr.invars):
        keep = boundary and i not in donated
        _add(v, aval_bytes(v.aval) if keep else 0)
    # Inputs never read still occupy memory until the call returns; give
    # them last_use = n so they are not dropped mid-walk.
    for v in list(jaxpr.constvars) + list(jaxpr.invars):
        last_use.setdefault(id(v), n)

    peak = sum(live.values())
    for i, eqn in enumerate(jaxpr.eqns):
        internal = 0
        if eqn.primitive.name != "pallas_call":
            for sub in subjaxprs(eqn):
                internal = max(
                    internal, _sweep(sub, boundary=False, donated=frozenset())
                )
        for v in eqn.outvars:
            nb = aval_bytes(v.aval)
            if not boundary and id(v) in out_set:
                nb = 0  # caller accounts for the call's results
            _add(v, nb)
            last_use.setdefault(id(v), i)
        peak = max(peak, sum(live.values()) + internal)
        for v in list(eqn.invars) + list(eqn.outvars):
            if not isinstance(v, Literal) and last_use.get(id(v), n) == i:
                live.pop(id(v), None)
    return peak


# ---------------------------------------------------------------------------
# Per-entry-point stats
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MemoryStats:
    """Static memory profile of one lint entry point."""

    entry_point: str
    tokens_per_call: int
    input_bytes: int
    output_bytes: int
    transfer_bytes: int
    bytes_per_token: int
    peak_live_bytes: int
    kv_pool_bytes: int | None
    roofline_memory_s: float

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def entry_memory(entry) -> MemoryStats:
    """Compute (and cache on the entry) one entry point's MemoryStats."""
    cached = getattr(entry, "_memory", None)
    if cached is not None:
        return cached
    from ..roofline.analysis import static_memory_seconds

    jaxpr = entry.jaxpr
    ins, outs = io_bytes(jaxpr)
    moved = transfer_bytes(jaxpr)
    tokens = max(int(getattr(entry, "tokens", 1)), 1)
    stats = MemoryStats(
        entry_point=entry.name,
        tokens_per_call=tokens,
        input_bytes=ins,
        output_bytes=outs,
        transfer_bytes=moved,
        bytes_per_token=-(-moved // tokens),
        peak_live_bytes=peak_live_bytes(jaxpr),
        kv_pool_bytes=getattr(entry, "kv_pool_bytes", None),
        roofline_memory_s=static_memory_seconds(float(moved)),
    )
    entry._memory = stats
    return stats


# ---------------------------------------------------------------------------
# Shared compiled-artifact byte accounting (used by launch/dryrun too)
# ---------------------------------------------------------------------------

def memory_report(compiled) -> dict:
    """``compiled.memory_analysis()`` as a plain dict — the one byte
    accounting shared by the donation gate, the CLI report, and
    ``repro.launch.dryrun``'s per-cell artifacts."""
    mem = compiled.memory_analysis()
    return {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
    }


# ---------------------------------------------------------------------------
# Donation / aliasing lint over the engine's jitted dispatches
# ---------------------------------------------------------------------------

_ALIAS_RE = re.compile(r"tf\.aliasing_output")
_MAIN_RE = re.compile(
    r"func\.func public @main\((?P<args>.*?)\)\s*->", re.S
)


def _donated_arg_indices(mlir_text: str) -> set[int]:
    """Flat input indices carrying ``tf.aliasing_output`` in the lowered
    MLIR main signature (jit flattens arguments in pytree order, so MLIR
    arg N is flat input N)."""
    m = _MAIN_RE.search(mlir_text)
    if not m:
        return set()
    donated: set[int] = set()
    # Split on "%argN:" boundaries; attributes for argN trail its type.
    parts = re.split(r"%arg(\d+):", m.group("args"))
    # parts = ["", "0", "<type+attrs>", "1", ...]
    for idx_str, body in zip(parts[1::2], parts[2::2]):
        if _ALIAS_RE.search(body):
            donated.add(int(idx_str))
    return donated


@dataclasses.dataclass(frozen=True)
class DispatchReport:
    """Donation/aliasing verdict for one real engine dispatch."""

    name: str
    inputs: int
    large_rebuilt: int  # inputs >= min_bytes with an identically-shaped output
    donated: int  # of those, how many are donated (tf.aliasing_output)
    aliased_bytes: int | None  # compiled.memory_analysis() cross-check
    memory: dict

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def analyze_dispatch(
    name: str,
    fn,
    args: tuple,
    *,
    min_bytes: int,
    compile_check: bool = True,
) -> tuple[DispatchReport, list[Finding]]:
    """Lint one jitted dispatch: every large consumed-and-rebuilt input
    must be donated. ``fn`` is the engine's real jitted callable; args
    may mix concrete arrays and ShapeDtypeStructs."""
    import jax

    lowered = fn.lower(*args)
    donated = _donated_arg_indices(lowered.as_text())
    flat_in = jax.tree_util.tree_leaves(args)
    out = jax.eval_shape(fn, *args)
    out_avals = [
        (tuple(l.shape), str(l.dtype)) for l in jax.tree_util.tree_leaves(out)
    ]

    findings: list[Finding] = []
    large_rebuilt: list[int] = []
    out_pool = list(out_avals)
    for i, leaf in enumerate(flat_in):
        nbytes = math.prod(tuple(leaf.shape)) * leaf.dtype.itemsize
        key = (tuple(leaf.shape), str(leaf.dtype))
        if nbytes < min_bytes or key not in out_pool:
            continue
        out_pool.remove(key)  # each output absorbs at most one input
        large_rebuilt.append(i)
        if i not in donated:
            findings.append(
                Finding(
                    "donation",
                    name,
                    f"input #{i} {key[1]}{list(key[0])} ({nbytes} bytes) is "
                    "consumed-and-rebuilt without donate_argnums — every "
                    "dispatch pays a full copy of a cache-sized buffer",
                    measured=nbytes,
                    budget=min_bytes,
                )
            )

    aliased = None
    mem: dict = {}
    if compile_check:
        compiled = lowered.compile()
        mem = memory_report(compiled)
        aliased = mem.get("alias_bytes")
        donated_bytes = sum(
            math.prod(tuple(flat_in[i].shape)) * flat_in[i].dtype.itemsize
            for i in large_rebuilt
            if i in donated
        )
        if donated_bytes and aliased is not None and aliased < donated_bytes:
            findings.append(
                Finding(
                    "donation",
                    name,
                    "donation declared but not honored by the compiler "
                    "(aliased bytes below the donated input bytes)",
                    measured=int(aliased),
                    budget=donated_bytes,
                )
            )
    report = DispatchReport(
        name=name,
        inputs=len(flat_in),
        large_rebuilt=len(large_rebuilt),
        donated=sum(1 for i in large_rebuilt if i in donated),
        aliased_bytes=aliased,
        memory=mem,
    )
    return report, findings


def engine_dispatches(paged: bool):
    """The engine's real jitted stage dispatches with faithful abstract
    argument signatures, from a smoke server (stage 0; the cache/pool
    signature — what donation is about — is identical across stages)."""
    import jax
    import jax.numpy as jnp

    from .recompile import _smoke_server

    cfg, server = _smoke_server(paged)
    g = 0
    ex = server._exec[g]
    _, params_g = server.stages[g]
    cache = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), server._caches[(g, 0)]
    )
    W, C = server.max_batch, server.prefill_chunk
    kind = "paged" if paged else "dense"
    out = []
    if paged:
        nb = -(-server.max_len // server.page_size)
        tok = jax.ShapeDtypeStruct((W, 1), jnp.int32)
        lens = jax.ShapeDtypeStruct((W,), jnp.int32)
        bt = jax.ShapeDtypeStruct((W, nb), jnp.int32)
        chunk_tok = jax.ShapeDtypeStruct((W, C), jnp.int32)
        offs = jax.ShapeDtypeStruct((W,), jnp.int32)
        valids = jax.ShapeDtypeStruct((W,), jnp.int32)
        out.append(
            (f"engine:{kind}:decode", ex.decode_fn,
             (params_g, tok, cache, lens, bt))
        )
        out.append(
            (f"engine:{kind}:chunk", ex.chunk_pages,
             (params_g, chunk_tok, cache, offs, valids, bt))
        )
        page_ids = jax.ShapeDtypeStruct((2, 2), jnp.int32)
        batch = {"tokens": jax.ShapeDtypeStruct((2, 1, 16), jnp.int32)}
        out.append(
            (f"engine:{kind}:prefill", ex.prefill_pages,
             (params_g, batch, cache, page_ids))
        )
    else:
        tok = jax.ShapeDtypeStruct((W, 1, 1), jnp.int32)
        mask = jax.ShapeDtypeStruct((W,), jnp.bool_)
        chunk_tok = {"tokens": jax.ShapeDtypeStruct((W, 1, C), jnp.int32)}
        offs = jax.ShapeDtypeStruct((W,), jnp.int32)
        valids = jax.ShapeDtypeStruct((W,), jnp.int32)
        out.append(
            (f"engine:{kind}:decode", ex.decode_masked,
             (params_g, tok, cache, mask))
        )
        out.append(
            (f"engine:{kind}:chunk", ex.chunk_masked,
             (params_g, chunk_tok, cache, offs, valids, mask))
        )
        batch = {"tokens": jax.ShapeDtypeStruct((2, 1, 16), jnp.int32)}
        slots = jax.ShapeDtypeStruct((2,), jnp.int32)
        out.append(
            (f"engine:{kind}:prefill", ex.prefill_into,
             (params_g, batch, cache, slots))
        )
    return out


def run_donation_gate(budgets: dict) -> tuple[list[dict], list[Finding]]:
    """Donation lint over every engine dispatch (dense + paged)."""
    section = budgets.get("donation", {})
    min_bytes = int(section.get("min_bytes", 16384))
    reports: list[dict] = []
    findings: list[Finding] = []
    for paged in (False, True):
        for name, fn, args in engine_dispatches(paged):
            report, found = analyze_dispatch(
                name, fn, args, min_bytes=min_bytes
            )
            reports.append(report.as_dict())
            findings.extend(found)
    return reports, findings


# ---------------------------------------------------------------------------
# CLI report section + budget regeneration
# ---------------------------------------------------------------------------

def memory_section(entries) -> dict:
    """The ``memory`` block of the CLI JSON report."""
    return {e.name: entry_memory(e).as_dict() for e in entries}


def update_memory_budgets(budgets: dict, entries) -> dict:
    """Regenerate the measured-exact ``memory_budgets`` section in place
    (``cli --update-budgets``; the budgets-drift test asserts the
    committed file matches this)."""
    section = {}
    for e in entries:
        stats = entry_memory(e)
        section[e.name] = {
            "bytes_per_token": stats.bytes_per_token,
            "peak_live_bytes": stats.peak_live_bytes,
        }
    budgets["memory_budgets"] = section
    return budgets
