"""Recursive jaxpr walking shared by the lint rules and the tests.

One walker for every consumer (the primitive-budget rule, the host-sync
lint, the dtype-promotion lint, and ``tests/test_paged_prefill``'s
zero-gather acceptance) so the tests and the lint can never drift
apart. The walk descends into every sub-jaxpr a primitive carries in
its params — ``pjit``'s inner jaxpr, ``scan``/``while``/``cond``
bodies, ``custom_vjp``/``custom_jvp`` branches, and Pallas kernel
bodies alike — whether the param value is a ``ClosedJaxpr``, a raw
``Jaxpr``, or a list/tuple of either.
"""

from __future__ import annotations

from collections import Counter
from typing import Iterator

from jax.core import ClosedJaxpr, Jaxpr

__all__ = ["subjaxprs", "iter_eqns", "count_primitive", "primitive_counts"]


def _as_jaxpr(obj) -> Jaxpr:
    """Normalize ClosedJaxpr / make_jaxpr output / raw Jaxpr to Jaxpr."""
    inner = getattr(obj, "jaxpr", None)
    if inner is not None:
        return _as_jaxpr(inner)
    return obj


def subjaxprs(eqn) -> Iterator[Jaxpr]:
    """Every sub-jaxpr referenced by one equation's params."""
    for val in eqn.params.values():
        for sub in val if isinstance(val, (list, tuple)) else (val,):
            if isinstance(sub, ClosedJaxpr):
                yield sub.jaxpr
            elif isinstance(sub, Jaxpr):
                yield sub


def iter_eqns(jaxpr, *, path: tuple[str, ...] = ()) -> Iterator[tuple[tuple[str, ...], "object"]]:
    """Yield ``(path, eqn)`` for every equation in the jaxpr tree.

    ``path`` is the tuple of enclosing primitive names (e.g.
    ``("pjit", "scan")``), so findings can say *where* a flagged
    primitive lives, not just that it exists.
    """
    jaxpr = _as_jaxpr(jaxpr)
    for eqn in jaxpr.eqns:
        yield path, eqn
        sub_path = path + (eqn.primitive.name,)
        for sub in subjaxprs(eqn):
            yield from iter_eqns(sub, path=sub_path)


def count_primitive(jaxpr, name: str) -> int:
    """Occurrences of a primitive anywhere in a (closed) jaxpr tree."""
    return sum(1 for _, eqn in iter_eqns(jaxpr) if eqn.primitive.name == name)


def primitive_counts(jaxpr) -> Counter:
    """Counter of every primitive name in the jaxpr tree."""
    return Counter(eqn.primitive.name for _, eqn in iter_eqns(jaxpr))
