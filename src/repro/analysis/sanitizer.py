"""Runtime device->host transfer sanitizer for the serving engine.

Each hidden per-step host sync serializes the host scheduler against
device compute — exactly what blocks the async-engine refactor
(ROADMAP). This module makes the syncs *visible and countable*:

* :func:`host_readback` is the engine's single sanctioned choke point
  for device->host reads (the batched argmax readbacks). Under an
  active :class:`TransferSanitizer` every call is counted against the
  current replica-step.
* :class:`TransferSanitizer` additionally installs
  ``jax.transfer_guard_device_to_host`` (inert on CPU where d2h is a
  zero-copy buffer view, but it turns unsanctioned transfers into hard
  errors on accelerator backends) and intercepts the common host
  materialization paths (``ArrayImpl._value`` — behind ``int()`` /
  ``float()`` / ``.tolist()`` — and ``ArrayImpl.__array__`` — behind
  ``jax.device_get``) to count *unsanctioned* syncs; ``strict=True``
  raises :class:`HostSyncError` on the spot.

The engine calls :func:`mark_engine_step` once per
``PipelineServer.step`` so counts bucket per replica-step and tests
can assert "<= K syncs per step" — the measurable precondition for
the async engine core. With the async engine it additionally calls
:func:`mark_engine_phase` around the producer ("dispatch") and
consumer ("commit") halves of the step, so sanctioned syncs bucket by
*where* in the step they happened: the async contract is zero
sanctioned syncs inside the dispatch phase — readbacks drain only at
the commit boundary (``sanctioned_by_phase``).

Caveat: on the CPU backend a raw ``np.asarray(device_array)`` goes
through the C-level buffer protocol, which neither the transfer guard
nor the interception sees (it is also genuinely copy-free there). Run
the sanitizer on an accelerator backend for airtight enforcement; on
CPU the counted choke point plus the ``_value``/``__array__`` hooks
cover the engine's and the common injected sync paths. Enter the
sanitizer *after* warmup: tracing/compilation legitimately reads
constants through ``_value``.
"""

from __future__ import annotations

import contextlib

import jax
import numpy as np

__all__ = [
    "HostSyncError",
    "TransferSanitizer",
    "active_sanitizer",
    "host_readback",
    "mark_engine_phase",
    "mark_engine_step",
]


class HostSyncError(RuntimeError):
    """An unsanctioned device->host sync under a strict sanitizer."""


_ACTIVE: "TransferSanitizer | None" = None
_IN_SANCTIONED = False


def active_sanitizer() -> "TransferSanitizer | None":
    return _ACTIVE


def host_readback(x) -> np.ndarray:
    """THE sanctioned device->host readback. Engine code must route
    every device read through here; anything else is a lint finding."""
    global _IN_SANCTIONED
    s = _ACTIVE
    if s is None:
        return np.asarray(x)
    s._step_sanctioned += 1
    s.sanctioned_by_phase[s.phase] = s.sanctioned_by_phase.get(s.phase, 0) + 1
    _IN_SANCTIONED = True
    try:
        with jax.transfer_guard_device_to_host("allow"):
            return np.asarray(x)
    finally:
        _IN_SANCTIONED = False


def mark_engine_step() -> None:
    """Close the current replica-step's sync bucket (engine hook)."""
    if _ACTIVE is not None:
        _ACTIVE.mark_step()


def mark_engine_phase(phase: str) -> None:
    """Tag subsequent syncs with the engine step phase ("dispatch" /
    "commit" / "other") — engine hook, no-op without a sanitizer."""
    if _ACTIVE is not None:
        _ACTIVE.phase = phase


def _array_impl_type():
    import jax.numpy as jnp

    return type(jnp.zeros((), jnp.float32))


class _CountingValue:
    """Replacement ``ArrayImpl._value`` descriptor: counts (or rejects)
    host materializations that bypassed :func:`host_readback`."""

    def __init__(self, orig):
        self._orig = orig

    def __get__(self, obj, objtype=None):
        if obj is None:
            return self
        _note_unsanctioned("ArrayImpl._value (int()/float()/.tolist() path)")
        return self._orig.__get__(obj, objtype)


def _note_unsanctioned(via: str) -> None:
    s = _ACTIVE
    if s is None or _IN_SANCTIONED:
        return
    s._step_unsanctioned += 1
    if s.strict:
        raise HostSyncError(
            f"unsanctioned device->host sync via {via}; route engine "
            "readbacks through repro.analysis.sanitizer.host_readback"
        )


class TransferSanitizer:
    """Count device->host syncs per replica-step; optionally fail fast.

    ::

        with TransferSanitizer() as san:
            for _ in range(n):
                server.step()          # engine marks each step
        assert san.max_per_step <= K
        assert san.unsanctioned_total == 0
    """

    def __init__(self, strict: bool = False, guard: str = "disallow"):
        self.strict = strict
        self.guard = guard
        self.per_step: list[int] = []  # sanctioned + unsanctioned per step
        self.sanctioned_total = 0
        self.unsanctioned_total = 0
        # Engine step phase of each sanctioned sync ("dispatch" /
        # "commit"; "other" outside the engine's phase markers). The
        # async engine's contract: sanctioned_by_phase["dispatch"] == 0.
        self.phase = "other"
        self.sanctioned_by_phase: dict[str, int] = {
            "dispatch": 0, "commit": 0, "other": 0,
        }
        self._step_sanctioned = 0
        self._step_unsanctioned = 0
        self._stack: contextlib.ExitStack | None = None
        self._patched: list[tuple] = []

    # -- step accounting -------------------------------------------------
    def mark_step(self) -> None:
        self.per_step.append(self._step_sanctioned + self._step_unsanctioned)
        self.sanctioned_total += self._step_sanctioned
        self.unsanctioned_total += self._step_unsanctioned
        self._step_sanctioned = 0
        self._step_unsanctioned = 0

    @property
    def max_per_step(self) -> int:
        return max(self.per_step, default=0)

    @property
    def total(self) -> int:
        return self.sanctioned_total + self.unsanctioned_total

    # -- install / restore ----------------------------------------------
    def __enter__(self) -> "TransferSanitizer":
        global _ACTIVE
        if _ACTIVE is not None:
            raise RuntimeError("TransferSanitizer does not nest")
        impl = _array_impl_type()
        orig_value = impl.__dict__["_value"]
        orig_array = impl.__dict__["__array__"]

        def counting_array(array_self, *args, **kwargs):
            _note_unsanctioned("ArrayImpl.__array__ (jax.device_get path)")
            return orig_array(array_self, *args, **kwargs)

        impl._value = _CountingValue(orig_value)
        impl.__array__ = counting_array
        self._patched = [(impl, "_value", orig_value), (impl, "__array__", orig_array)]
        self._stack = contextlib.ExitStack()
        self._stack.enter_context(jax.transfer_guard_device_to_host(self.guard))
        _ACTIVE = self
        return self

    def __exit__(self, *exc) -> None:
        global _ACTIVE
        _ACTIVE = None
        for impl, name, orig in self._patched:
            setattr(impl, name, orig)
        self._patched = []
        if self._step_sanctioned or self._step_unsanctioned:
            self.mark_step()  # flush a trailing partial step
        if self._stack is not None:
            self._stack.close()
            self._stack = None
