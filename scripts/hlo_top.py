"""Top byte/FLOP contributors of a dry-run HLO artifact.

    PYTHONPATH=src python scripts/hlo_top.py artifacts/dryrun/<cell>.hlo.gz [bytes|flops|coll]
"""

import gzip
import sys
from collections import deque

from repro.roofline import analysis as A


def main() -> None:
    path = sys.argv[1]
    mode = sys.argv[2] if len(sys.argv) > 2 else "bytes"
    opener = gzip.open if path.endswith(".gz") else open
    with opener(path, "rt") as f:
        hlo = f.read()

    comps = A._parse_computations(hlo)
    entry = comps["__entry__"].name
    names = [n for n in comps if n != "__entry__"]
    comp_edges = {n: [] for n in names}
    in_deg = {n: 0 for n in names}
    for name in names:
        for op in comps[name].ops:
            callees = A._callees(op)
            trip = None
            if op.kind == "while":
                cond = next((c for c, k in callees.items() if k == "condition"), None)
                trip = A._trip_count(comps, op, cond)
            for callee, kind in callees.items():
                if callee not in in_deg:
                    continue
                factor = (
                    float((trip or 1) + 1)
                    if kind == "condition"
                    else float(trip or 1)
                    if kind == "body"
                    else 1.0
                )
                comp_edges[name].append((callee, factor, kind in ("condition", "fusion")))
                in_deg[callee] += 1
    mult = {n: 0.0 for n in names}
    fused = {n: None for n in names}
    mult[entry] = 1.0
    fused[entry] = False
    q = deque([n for n in names if in_deg[n] == 0])
    while q:
        n = q.popleft()
        for callee, factor, fe in comp_edges[n]:
            mult[callee] += mult[n] * factor
            cf = bool(fused[n]) or fe
            fused[callee] = cf if fused[callee] is None else (fused[callee] and cf)
            in_deg[callee] -= 1
            if in_deg[callee] == 0:
                q.append(callee)

    contrib = []
    for n in names:
        m = mult.get(n, 0)
        if m == 0:
            continue
        for op in comps[n].ops:
            if mode == "flops":
                if op.kind == "dot":
                    v = m * A._dot_flops(comps[n], op)
                elif op.kind == "convolution":
                    v = m * A._conv_flops(comps[n], op)
                else:
                    continue
            elif mode == "coll":
                base = op.kind[:-6] if op.kind.endswith("-start") else op.kind
                if base not in A._COLLECTIVES or op.kind.endswith("-done"):
                    continue
                v = m * A._all_shape_bytes(op.result_type)
            else:
                if fused.get(n) or op.kind in A._BYTE_FREE:
                    continue
                v = m * A._op_bytes(comps[n], op)
            if v > 0:
                contrib.append((v, op.kind, op.line[:130]))
    contrib.sort(key=lambda t: -t[0])
    unit = 1e9 if mode != "flops" else 1e12
    suffix = "GB" if mode != "flops" else "TF"
    total = sum(c[0] for c in contrib)
    print(f"total: {total/unit:.1f} {suffix}")
    for v, k, l in contrib[:15]:
        print(f"{v/unit:9.2f} {suffix} {k:12s} {l}")


if __name__ == "__main__":
    main()
