"""Top byte/FLOP contributors of a dry-run HLO artifact.

    PYTHONPATH=src python scripts/hlo_top.py artifacts/dryrun/<cell>.hlo.gz [bytes|flops|coll]

Thin shell over :func:`repro.roofline.top_contributors`, which shares
the call-multiplier propagation with ``analyze_hlo`` so the drill-down
always agrees with the roofline totals on loop trip scaling.
"""

import gzip
import sys

from repro.roofline import top_contributors


def main() -> None:
    path = sys.argv[1]
    mode = sys.argv[2] if len(sys.argv) > 2 else "bytes"
    opener = gzip.open if path.endswith(".gz") else open
    with opener(path, "rt") as f:
        hlo = f.read()

    contrib = top_contributors(hlo, mode)
    unit = 1e9 if mode != "flops" else 1e12
    suffix = "GB" if mode != "flops" else "TF"
    total = sum(c[0] for c in contrib)
    print(f"total: {total/unit:.1f} {suffix}")
    for v, k, line in contrib[:15]:
        print(f"{v/unit:9.2f} {suffix} {k:12s} {line[:130]}")


if __name__ == "__main__":
    main()
