import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs import SHAPES, get_config
from repro.distributed.sharding import (
    DECODE_RULES,
    divisible_spec,
    param_shardings,
    use_mesh_rules,
)
from repro.launch.mesh import make_production_mesh
from repro.models import abstract_params, build_model
from repro.models.inputs import input_specs
from repro.models.transformer import cache_logical_axes
from repro.roofline import analysis as A

cfg = dataclasses.replace(get_config("qwen2.5-14b"), remat=False)
cell = SHAPES["decode_32k"]
mesh = make_production_mesh()
model = build_model(cfg)
abstract = abstract_params(model.template, cfg.param_dtype)
p_sh = param_shardings(model.template, mesh, DECODE_RULES)
cache_abs = model.cache_shapes(cell.global_batch, cell.seq_len + 128)
cache_axes = cache_logical_axes(cfg)
cache_sh = jax.tree_util.tree_map(
    lambda s, a: NamedSharding(mesh, divisible_spec(s.shape, a, mesh, DECODE_RULES)),
    cache_abs,
    cache_axes,
    is_leaf=lambda v: isinstance(v, jax.ShapeDtypeStruct),
)
tok = jax.ShapeDtypeStruct((cell.global_batch, 1), jnp.int32)
tok_sh = NamedSharding(mesh, divisible_spec(tok.shape, ("batch", "seq"), mesh, DECODE_RULES))
with use_mesh_rules(mesh, DECODE_RULES):
    hlo = (
        jax.jit(
            lambda p, t, c: model.decode_step(p, t, c),
            in_shardings=(p_sh, tok_sh, cache_sh),
            donate_argnums=(2,),
        )
        .lower(abstract, tok, cache_abs)
        .compile()
        .as_text()
    )
open("/tmp/qwen_decode.hlo", "w").write(hlo)

comps = A._parse_computations(hlo)
entry = comps["__entry__"].name
names = [n for n in comps if n != "__entry__"]
comp_edges = {n: [] for n in names}
in_deg = {n: 0 for n in names}
for name in names:
    for op in comps[name].ops:
        callees = A._callees(op)
        trip = None
        if op.kind == "while":
            cond = next((c for c, k in callees.items() if k == "condition"), None)
            trip = A._trip_count(comps, op, cond)
        for callee, kind in callees.items():
            if callee not in in_deg:
                continue
            factor = (
                float((trip or 1) + 1)
                if kind == "condition"
                else float(trip or 1)
                if kind == "body"
                else 1.0
            )
            comp_edges[name].append((callee, factor, kind in ("condition", "fusion")))
            in_deg[callee] += 1
mult = {n: 0.0 for n in names}
fused = {n: None for n in names}
mult[entry] = 1.0
fused[entry] = False
q = deque([n for n in names if in_deg[n] == 0])
while q:
    n = q.popleft()
    for callee, factor, fe in comp_edges[n]:
        mult[callee] += mult[n] * factor
        cf = bool(fused[n]) or fe
        fused[callee] = cf if fused[callee] is None else (fused[callee] and cf)
        in_deg[callee] -= 1
        if in_deg[callee] == 0:
            q.append(callee)
contrib = []
for n in names:
    if fused.get(n):
        continue
    m = mult.get(n, 0)
    if m == 0:
        continue
    for op in comps[n].ops:
        if op.kind in A._BYTE_FREE:
            continue
        b = A._op_bytes(comps[n], op) * m
        if b > 2e9:
            contrib.append((b, n, op.kind, op.line[:100]))
contrib.sort(key=lambda t: -t[0])
total = A.analyze_hlo(hlo)
print(f"total bytes/device {total.bytes/1e9:.1f} GB")
for b, n, k, l in contrib[:10]:
    print(f"{b/1e9:8.1f} GB  {k:14s} in {n[:28]:28s} {l[:86]}")
