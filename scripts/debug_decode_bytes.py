import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs import SHAPES, get_config
from repro.distributed.sharding import (
    DECODE_RULES,
    divisible_spec,
    param_shardings,
    use_mesh_rules,
)
from repro.launch.mesh import make_production_mesh
from repro.models import abstract_params, build_model
from repro.models.inputs import input_specs
from repro.models.transformer import cache_logical_axes
from repro.roofline import analysis as A

cfg = dataclasses.replace(get_config("qwen2.5-14b"), remat=False)
cell = SHAPES["decode_32k"]
mesh = make_production_mesh(shape=(16, 16))
model = build_model(cfg)
abstract = abstract_params(model.template, cfg.param_dtype)
p_sh = param_shardings(model.template, mesh, DECODE_RULES)
cache_abs = model.cache_shapes(cell.global_batch, cell.seq_len + 128)
cache_axes = cache_logical_axes(cfg)
cache_sh = jax.tree_util.tree_map(
    lambda s, a: NamedSharding(mesh, divisible_spec(s.shape, a, mesh, DECODE_RULES)),
    cache_abs,
    cache_axes,
    is_leaf=lambda v: isinstance(v, jax.ShapeDtypeStruct),
)
tok = jax.ShapeDtypeStruct((cell.global_batch, 1), jnp.int32)
tok_sh = NamedSharding(mesh, divisible_spec(tok.shape, ("batch", "seq"), mesh, DECODE_RULES))
with use_mesh_rules(mesh, DECODE_RULES):
    hlo = (
        jax.jit(
            lambda p, t, c: model.decode_step(p, t, c),
            in_shardings=(p_sh, tok_sh, cache_sh),
            donate_argnums=(2,),
        )
        .lower(abstract, tok, cache_abs)
        .compile()
        .as_text()
    )
open("/tmp/qwen_decode.hlo", "w").write(hlo)

total = A.analyze_hlo(hlo)
print(f"total bytes/device {total.bytes/1e9:.1f} GB")
for b, k, line in A.top_contributors(hlo, "bytes", limit=10):
    if b > 2e9:
        print(f"{b/1e9:8.1f} GB  {k:14s} {line[:100]}")
