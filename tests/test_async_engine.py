"""Async engine core: cross-engine differential tests.

The headline evidence for the in-flight dispatch refactor: the same
seeded trace driven through the legacy synchronous engine
(``async_depth=0``) and the async engine (depth 1, 2, 3) must produce
bit-for-bit identical token streams — under plain admission, chunked
prefill, paged preemption, and double failover — because

* greedy argmax is deterministic per request and per-request calls are
  serialized (a member never joins two in-flight calls at once);
* preemption and failover are loss-free (prompt + generated re-prefill);
* an aborted in-flight call is discarded without finalizing its
  readbacks, so a dead dispatch can never mutate request state.

Also here: the async port of the paged lifecycle fuzzer (per-step
conservation on the working block table AND the live device snapshot),
seed determinism at depth 2, the dispatch-phase sync regression
(sanctioned syncs only at commit), and dispatch-observable TTFT
accounting under deferred commits.
"""

import dataclasses

import numpy as np
import pytest
from conftest import direct_greedy, tiny_model

from repro.core.power import dynamic_policy, fixed_policy
from repro.serving import PipelineServer

MODEL = None


def _model():
    global MODEL
    if MODEL is None:
        MODEL = tiny_model()
    return MODEL


def _server(depth, **kw):
    cfg, model, params = _model()
    defaults = dict(
        n_groups=2, n_replicas=2, policy="uniform",
        harvest_bounds=(50.0, 60.0), max_len=64, max_batch=4,
        page_size=8, seed=0,
    )
    defaults.update(kw)
    return cfg, PipelineServer(model, params, async_depth=depth, **defaults)


def _run_trace(depth, *, kappa_pm=None, staggered=False, fail_steps=(),
               recover_steps=(), n_requests=5, n_tokens=4, **kw):
    """One seeded trace: submissions, optional double failover/recovery,
    drained to completion. Returns (per-request token tuples, stats).

    ``fail_steps``/``recover_steps`` map step -> [(g, r), ...]. With
    ``staggered`` the requests arrive one per slot — against multi-slot
    calls (kappa >= 2) that is what actually stacks a replica's ring
    past depth 1: a fresh admission dispatches while the previous call
    is still in flight. Without it, every request is submitted up front
    and members commit in lockstep (ring never exceeds 1)."""
    if kappa_pm is not None:
        kw.setdefault("pm_policy", fixed_policy(kappa_pm))
        kw.setdefault("harvest_bounds", (60.0, 80.0))
    cfg, server = _server(depth, **kw)
    fail = dict(fail_steps)
    recover = dict(recover_steps)
    reqs = []
    steps = 0
    n_sub = 0
    while n_sub < n_requests or not all(r.done or r.dropped for r in reqs):
        while n_sub < n_requests:
            req = server.submit(
                (np.arange(4 + n_sub) + n_sub) % cfg.vocab_size, n_tokens
            )
            if req is not None:
                reqs.append(req)
            n_sub += 1
            if staggered:
                break
        for g, r in fail.get(steps, ()):
            server.fail_replica(g, r)
        for g, r in recover.get(steps, ()):
            server.recover_replica(g, r)
        server.step()
        steps += 1
        assert steps < 5000, "trace did not drain"
    return [tuple(r.generated) for r in reqs], server, reqs


class TestAsyncDifferential:
    """Token streams must be bit-for-bit equal across every depth."""

    @pytest.mark.parametrize(
        "kw",
        [
            dict(),
            dict(paged=True),
            dict(paged=True, kv_dtype="int8"),
            dict(prefill_chunk=4),
            dict(paged=True, prefill_chunk=4),
        ],
        ids=["dense", "paged", "paged-int8", "dense-chunked", "paged-chunked"],
    )
    def test_depths_token_exact(self, kw):
        base, _, _ = _run_trace(0, **kw)
        for depth in (1, 2, 3):
            toks, _, _ = _run_trace(depth, **kw)
            assert toks == base, f"depth {depth} diverged: {kw}"

    @pytest.mark.parametrize(
        "cache_kw",
        [dict(), dict(paged=True), dict(paged=True, kv_dtype="int8")],
        ids=["dense", "paged", "paged-int8"],
    )
    def test_double_failover_token_exact(self, cache_kw):
        """Two replicas die at different steps mid-flight (one per
        group), later recover; every depth discards its in-flight ring
        without committing and re-queues — tokens stay identical."""
        trace = dict(
            **cache_kw,
            kappa_pm=2,  # calls span 2 slots: failures hit mid-flight
            staggered=True,
            fail_steps={3: [(0, 0)], 6: [(1, 1)]},
            recover_steps={9: [(0, 0)], 11: [(1, 1)]},
        )
        base, _, _ = _run_trace(0, **trace)
        assert any(len(t) > 0 for t in base)
        for depth in (1, 2, 3):
            toks, server, _ = _run_trace(depth, **trace)
            assert toks == base, f"depth {depth} diverged after failover"
            assert server.stats.rerouted_stages > 0

    def test_preemption_token_exact(self):
        """Paged pool too small for every context: preemption/requeue
        churn under every depth, same tokens."""
        trace = dict(
            paged=True, page_size=4, max_pages=6, n_groups=1, n_replicas=1,
            n_requests=3, n_tokens=12, max_batch=4,
        )
        base, server0, _ = _run_trace(0, **trace)
        assert server0.stats.preempted_jobs > 0
        cfg, model, params = _model()
        for t, n in zip(base, range(3)):
            assert list(t) == direct_greedy(
                model, params, (np.arange(4 + n) + n) % cfg.vocab_size, 12
            )
        for depth in (2, 3):
            toks, server, _ = _run_trace(depth, **trace)
            assert toks == base, f"depth {depth} diverged under preemption"

    def test_ring_depth_engages_and_stays_exact(self):
        """Staggered arrivals at kappa=2: the ring actually holds >= 2
        in-flight calls at depth 2 (pipelining is real, not vacuous) and
        the stream still matches sync."""
        trace = dict(kappa_pm=2, staggered=True, n_requests=6,
                     n_replicas=1)
        base, server0, _ = _run_trace(0, **trace)
        toks, server2, _ = _run_trace(2, **trace)
        assert toks == base
        assert server0.stats.inflight_peak == 1
        assert server2.stats.inflight_peak >= 2

    def test_depth1_degenerates_to_sync_exactly(self):
        """depth=1 is today's sync engine with the readback moved to the
        commit boundary: identical tokens AND identical ServerStats
        (scheduling, dispatch counts, downtime — everything)."""
        for kw in (
            dict(kappa_pm=2, staggered=True),
            dict(paged=True, prefill_chunk=4, kappa_pm=2, staggered=True),
            dict(harvest_bounds=(8.0, 14.0)),  # battery-constrained
        ):
            base, server0, _ = _run_trace(0, **kw)
            toks, server1, _ = _run_trace(1, **kw)
            assert toks == base
            assert dataclasses.asdict(server0.stats) == dataclasses.asdict(
                server1.stats
            )


def _assert_page_invariants(server: PipelineServer):
    """Conservation + exclusivity + block-table/snapshot consistency
    across the whole fleet (check_conservation also verifies the live
    device snapshot buffer against the working table)."""
    for (g, r), mgr in server.managers.items():
        mgr.check_conservation()
        resident = {
            req.rid
            for req in server._active
            if req.replicas is not None and req.replicas[g] == r
        }
        owners = {rid for rid, pages in mgr.pages.items() if pages}
        assert owners <= resident, (
            f"manager ({g},{r}) holds pages for non-residents "
            f"{sorted(owners - resident)}"
        )


class TestAsyncLifecycleFuzz:
    """The paged lifecycle fuzzer ported to the async engine: random
    admit / fail / recover per step at depth 2, page conservation and
    block-table/snapshot consistency checked after every step."""

    def _fuzz(self, seed, steps=60, depth=2):
        cfg, model, params = _model()
        G, R = 2, 2
        server = PipelineServer(
            model, params, n_groups=G, n_replicas=R,
            harvest_bounds=(12.0, 20.0), max_len=32, max_batch=2,
            paged=True, page_size=4, max_pages=10,
            async_depth=depth, seed=seed,
        )
        rng = np.random.default_rng(1000 + seed)
        for _ in range(steps):
            u = rng.uniform()
            if u < 0.35:
                server.submit(
                    rng.integers(0, cfg.vocab_size, size=int(rng.integers(2, 9))),
                    n_tokens=int(rng.integers(1, 5)),
                )
            elif u < 0.45:
                server.fail_replica(int(rng.integers(G)), int(rng.integers(R)))
            elif u < 0.60:
                server.recover_replica(int(rng.integers(G)), int(rng.integers(R)))
            server.step()
            _assert_page_invariants(server)
        for g in range(G):
            for r in range(R):
                server.recover_replica(g, r)
        for _ in range(1500):
            if not server._active and not server._pending:
                break
            server.step()
            _assert_page_invariants(server)
        assert not server._active and not server._pending
        for mgr in server.managers.values():
            assert mgr.pool.free_pages == mgr.pool.n_pages
        stats = server.stats
        assert stats.submitted == stats.completed_jobs + stats.dropped_jobs
        return stats

    def test_random_lifecycle_conserves_pages(self):
        self._fuzz(seed=0)

    @pytest.mark.slow
    @pytest.mark.parametrize("seed", [1, 2])
    def test_random_lifecycle_deep(self, seed):
        self._fuzz(seed=seed, steps=120, depth=3)

    def test_seed_determinism_async(self):
        """Same seed, depth 2, battery-constrained (kappa varies):
        identical ServerStats and identical token streams."""
        cfg, model, params = _model()

        def run():
            server = PipelineServer(
                model, params, n_groups=2, n_replicas=2,
                harvest_bounds=(8.0, 14.0), max_len=64, max_batch=2,
                paged=True, page_size=8, max_pages=8,
                async_depth=2, seed=11,
            )
            stats = server.run(40, arrival_p=0.7, prompt_len=6, n_tokens=3)
            tokens = sorted(
                (r.rid, tuple(r.generated))
                for r in server._active + list(server._pending)
            )
            return dataclasses.asdict(stats), tokens

        s1, t1 = run()
        s2, t2 = run()
        assert s1 == s2
        assert t1 == t2


@pytest.mark.slow
class TestAsyncSanitizer:
    """The async step loop's sync contract: zero unsanctioned syncs,
    per-step sanctioned count within the PR-6 budget, and every
    sanctioned sync at the commit boundary — never during dispatch."""

    def _drain(self, server, cfg, n_requests=4, n_tokens=3):
        reqs = [
            server.submit((np.arange(4 + 2 * (i % 2)) + i) % cfg.vocab_size,
                          n_tokens=n_tokens)
            for i in range(n_requests)
        ]
        while not all(r.done for r in reqs):
            server.step()

    @pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
    def test_syncs_only_at_commit(self, paged):
        from repro.analysis import TransferSanitizer, load_budgets

        budgets = load_budgets()
        budget = budgets["host_sync"]["per_step_budget"][
            "paged" if paged else "dense"
        ]
        cfg, server = _server(
            2, n_groups=1, n_replicas=1, harvest_bounds=(60.0, 80.0),
            paged=paged, prefill_chunk=4,
        )
        self._drain(server, cfg)  # warmup: compile every dispatch shape
        with TransferSanitizer() as san:
            self._drain(server, cfg)
        assert san.unsanctioned_total == 0
        assert san.max_per_step <= budget
        assert san.sanctioned_by_phase["dispatch"] == 0
        assert san.sanctioned_by_phase["commit"] == san.sanctioned_total > 0

    def test_injected_early_float_fails_by_rule_and_entry(self, monkeypatch):
        """An injected eager float() readback at dispatch time must
        surface through the host-sync gate as an unsanctioned sync,
        named by rule and entry."""
        import jax.numpy as jnp

        from repro.analysis import load_budgets
        from repro.analysis.recompile import run_host_sync_gate
        from repro.serving import engine as engine_mod

        orig = engine_mod.PipelineServer._start_call

        def leaky_start_call(self, g, r, members):
            call = orig(self, g, r, members)
            if call is not None and call.readbacks:
                float(jnp.sum(call.readbacks[0][0]))  # early host sync
            return call

        monkeypatch.setattr(
            engine_mod.PipelineServer, "_start_call", leaky_start_call
        )
        findings = run_host_sync_gate(load_budgets())
        assert findings, "injected dispatch-time float() was not caught"
        assert all(f.rule == "host-sync" for f in findings)
        entries = {f.entry_point for f in findings}
        assert "dense:replica-step" in entries
        assert any("bypassed" in f.message for f in findings)


class TestAsyncTTFT:
    """TTFT/downtime accounting under deferred commits: stamps happen at
    dispatch-observable time (the slot the producing call's device work
    completes), not when the completion queue drains."""

    def test_depth2_queue_does_not_inflate_ttft(self):
        """A kappa=1 call (B) queued behind a kappa=3 head (A) is ready
        two slots before the ring drains it. Its TTFT must reflect the
        ready slot — and beat the sync engine, which could not even
        dispatch B until A finished."""
        cfg, model, params = _model()

        def run(depth):
            server = PipelineServer(
                model, params, n_groups=1, n_replicas=1, policy="uniform",
                pm_policy=dynamic_policy(100), harvest_bounds=(0.0, 0.0),
                max_len=64, max_batch=4, async_depth=depth, seed=0,
            )
            b = server.budgets[0][0]
            b.level = 30.0  # < 40: PM1, kappa=3
            server.submit(np.arange(6) % cfg.vocab_size, n_tokens=1)
            server.step()  # slot 1: A dispatched at kappa=3
            b.level = 100.0  # >= 60: PM3, kappa=1 for the next dispatch
            req_b = server.submit(np.arange(5) % cfg.vocab_size, n_tokens=1)
            for _ in range(8):
                server.step()
                if req_b.done:
                    break
            assert req_b.done
            return req_b

        fast = run(2)
        # B submitted at slot 1, dispatched slot 2 at kappa=1 -> device
        # work done at slot 2 (ttft_slots == 1) even though the ring
        # drains it behind A at slot 3. Commit-drain stamping would
        # report 2.
        assert fast.ttft_slots == 1
        slow = run(0)
        # Sync engine: B waits for A's call to finish before it can even
        # dispatch.
        assert slow.ttft_slots > fast.ttft_slots

    def test_downtime_identical_across_depths(self):
        """downtime_replica_slots is stamped in the harvest phase
        (dispatch-observable), so a constrained trace reports identical
        downtime at every depth where scheduling coincides (0 vs 1)."""
        # Enough work that replicas repeatedly drain below e_th between
        # recharges (a call admitted just above CE ends below the
        # availability floor).
        kw = dict(harvest_bounds=(1.0, 3.0), n_requests=4, n_tokens=8)
        _, s0, _ = _run_trace(0, **kw)
        _, s1, _ = _run_trace(1, **kw)
        assert s0.stats.downtime_replica_slots == s1.stats.downtime_replica_slots
        assert s0.stats.downtime_replica_slots > 0
