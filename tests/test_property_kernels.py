"""Property-based kernel validation (hypothesis): random shapes/params
within TPU-plausible bounds, Pallas (interpret) vs jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (test extra)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.decode_attention import decode_attention, decode_attention_ref
from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.rmsnorm import rmsnorm, rmsnorm_ref
from repro.kernels.selective_scan import selective_scan, selective_scan_ref

SETTINGS = dict(max_examples=12, deadline=None)


@st.composite
def attn_shapes(draw):
    B = draw(st.integers(1, 2))
    S = draw(st.integers(17, 96))
    KV = draw(st.sampled_from([1, 2, 4]))
    G = draw(st.sampled_from([1, 2, 3]))
    D = draw(st.sampled_from([8, 16, 32]))
    causal = draw(st.booleans())
    window = draw(st.sampled_from([None, 16, 33]))
    return B, S, KV, G, D, causal, window


@given(attn_shapes(), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_flash_attention_property(shape, seed):
    B, S, KV, G, D, causal, window = shape
    H = KV * G
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32)
    if not causal and window is not None:
        window = None  # windowed bidirectional isn't a served pattern
    out = flash_attention(
        q, k, v, causal=causal, window=window, block_q=16, block_kv=16,
        interpret=True,
    )
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-5)


@st.composite
def decode_shapes(draw):
    B = draw(st.integers(1, 3))
    S = draw(st.integers(8, 160))
    KV = draw(st.sampled_from([1, 2]))
    G = draw(st.sampled_from([1, 4]))
    D = draw(st.sampled_from([8, 32]))
    length = draw(st.integers(1, S))
    chunk = draw(st.sampled_from([16, 64]))
    return B, S, KV, G, D, length, chunk


@given(decode_shapes(), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_decode_attention_property(shape, seed):
    B, S, KV, G, D, length, chunk = shape
    H = KV * G
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, 1, H, D), jnp.float32)
    kc = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32)
    vc = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32)
    lengths = jnp.full((B,), length, jnp.int32)
    out = decode_attention(q, kc, vc, lengths, chunk=chunk, interpret=True)
    ref = decode_attention_ref(q, kc, vc, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-5)


@given(
    st.integers(1, 64),
    st.sampled_from([32, 128, 384]),
    st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_rmsnorm_property(R, D, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    x = jax.random.normal(ks[0], (R, D), jnp.float32)
    w = 1.0 + 0.1 * jax.random.normal(ks[1], (D,), jnp.float32)
    out = rmsnorm(x, w, block_rows=16, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(rmsnorm_ref(x, w)), rtol=2e-5, atol=2e-5
    )
    # Invariant: unit weight => unit RMS rows.
    out1 = rmsnorm(x, jnp.ones((D,)), interpret=True)
    rms = np.sqrt(np.mean(np.square(np.asarray(out1)), axis=-1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-3)


@st.composite
def scan_shapes(draw):
    B = draw(st.integers(1, 2))
    S = draw(st.integers(9, 80))
    Din = draw(st.sampled_from([8, 24, 48]))
    N = draw(st.sampled_from([4, 8]))
    chunk = draw(st.sampled_from([8, 32]))
    return B, S, Din, N, chunk


@given(scan_shapes(), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_selective_scan_property(shape, seed):
    B, S, Din, N, chunk = shape
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (B, S, Din), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, Din), jnp.float32))
    Bm = jax.random.normal(ks[2], (B, S, N), jnp.float32)
    Cm = jax.random.normal(ks[3], (B, S, N), jnp.float32)
    A = -jnp.exp(0.5 * jax.random.normal(ks[4], (Din, N), jnp.float32))
    y, h = selective_scan(x, dt, Bm, Cm, A, chunk=chunk, block_d=16, interpret=True)
    y_ref, h_ref = selective_scan_ref(x, dt, Bm, Cm, A)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), rtol=2e-4, atol=2e-4)
    # Stability invariant: A < 0 and bounded inputs => finite outputs.
    assert np.all(np.isfinite(np.asarray(y)))
