"""Speculative draft-verify decoding: differential + lemma tests.

The headline claim of the draft-verify refactor: greedy accept makes
speculation a pure latency optimization, so the same seeded trace
driven through the plain paged engine and the speculative engine (any
``spec_k``, any ``async_depth``, fp32 or int8 pools) must produce
bit-for-bit identical token streams — because

* one ``verify_step_paged`` chunk call reproduces, per position, the
  exact logits sequential ``decode_step_paged`` calls would have
  produced (the per-row reductions are independent of the other rows
  and the scattered page rows are byte-identical — the lemma tests
  below pin both);
* the accept finalizer commits only the longest verified prefix and
  rolls every stage's optimistic KV advance back to the committed
  stream (``KVCacheManager.rollback``), so a rejected draft leaves no
  phantom context;
* an aborted round (failover, drop) is rewound by
  ``StepScheduler.rewind_spec`` to exactly the state a plain decode
  round would have left.

Known, documented exception: mid-pipeline (stage > 0) failover recovery
re-prefills from the latest hidden handoff, which is lossy in the
existing engine; multi-token rounds reach a given failure step at
different progress than single-token rounds, so plain-vs-spec equality
is asserted at G=1 (token-exact stage-0 recovery) while G>=2 failover
asserts depth-invariance, page conservation, and completion instead.

Also here: a seeded random-ops fuzzer for ``rollback(n)`` (page
conservation + block-table consistency after every op; the hypothesis
twin lives in ``test_property_spec.py``), the verify path's zero-new-
gathers guarantee at the jaxpr level, the spec engine's host-sync
contract (no dispatch-phase syncs at any depth), and ServerStats
acceptance accounting.
"""

import dataclasses

import numpy as np
import pytest
from conftest import direct_greedy, tiny_model

from repro.core.power import fixed_policy
from repro.serving import PipelineServer

MODEL = None


def _model():
    global MODEL
    if MODEL is None:
        MODEL = tiny_model()
    return MODEL


def _server(depth, spec_k=None, **kw):
    """Paged server; ``spec_k`` switches on self-draft speculation (the
    draft IS the target model, so fp32 acceptance is ~1.0 — correctness
    must hold for any draft, which the pairing test covers)."""
    cfg, model, params = _model()
    defaults = dict(
        n_groups=1, n_replicas=2, policy="uniform",
        harvest_bounds=(50.0, 60.0), max_len=64, max_batch=4,
        paged=True, page_size=8, seed=0,
    )
    defaults.update(kw)
    if spec_k is not None:
        defaults.update(spec_draft=(model, params), spec_k=spec_k)
    return cfg, PipelineServer(model, params, async_depth=depth, **defaults)


def _prompt(cfg, n, prompt_len=4):
    return (np.arange(prompt_len + n) + n) % cfg.vocab_size


def _run_trace(depth, *, spec_k=None, kappa_pm=None, staggered=False,
               fail_steps=(), recover_steps=(), n_requests=5, n_tokens=6,
               prompt_len=4, **kw):
    """One seeded trace (same shape as the async differential harness):
    submissions, optional failover/recovery, drained to completion."""
    if kappa_pm is not None:
        kw.setdefault("pm_policy", fixed_policy(kappa_pm))
        kw.setdefault("harvest_bounds", (60.0, 80.0))
    cfg, server = _server(depth, spec_k=spec_k, **kw)
    fail = dict(fail_steps)
    recover = dict(recover_steps)
    reqs = []
    steps = 0
    n_sub = 0
    while n_sub < n_requests or not all(r.done or r.dropped for r in reqs):
        while n_sub < n_requests:
            req = server.submit(_prompt(cfg, n_sub, prompt_len), n_tokens)
            if req is not None:
                reqs.append(req)
            n_sub += 1
            if staggered:
                break
        for g, r in fail.get(steps, ()):
            server.fail_replica(g, r)
        for g, r in recover.get(steps, ()):
            server.recover_replica(g, r)
        server.step()
        steps += 1
        assert steps < 5000, "trace did not drain"
    return [tuple(r.generated) for r in reqs], server, reqs


class TestSpecDifferential:
    """Spec streams must be bit-for-bit equal to plain paged decode."""

    @pytest.mark.parametrize("kv", [None, "int8"], ids=["fp32", "int8"])
    def test_spec_matches_plain(self, kv):
        """{k=2,4} x {depth 0,2} against one plain baseline per pool
        dtype: identical tokens, and speculation actually engaged."""
        kw = dict(kv_dtype=kv)
        base, _, _ = _run_trace(0, **kw)
        assert any(len(t) > 0 for t in base)
        for k in (2, 4):
            for depth in (0, 2):
                toks, server, _ = _run_trace(depth, spec_k=k, **kw)
                assert toks == base, f"spec k={k} depth={depth} diverged ({kv})"
                st = server.stats
                assert st.spec_rounds > 0
                assert st.accepted_tokens == st.tokens_generated

    def test_spec_matches_direct_greedy(self):
        """The end-to-end oracle: spec streams equal direct greedy
        decoding of the same prompts on the raw model."""
        toks, _, _ = _run_trace(2, spec_k=4, n_requests=3)
        cfg, model, params = _model()
        for n, t in enumerate(toks):
            assert list(t) == direct_greedy(model, params, _prompt(cfg, n), 6)

    def test_spec_pipeline_g2(self):
        """Two pipeline stages: stage 0 drafts + verifies tokens, stage 1
        verifies the hidden handoff in its own chunk call. Streams still
        match plain at both depths."""
        kw = dict(n_groups=2, n_replicas=1)
        base, _, _ = _run_trace(0, **kw)
        for depth in (0, 2):
            toks, server, _ = _run_trace(depth, spec_k=4, **kw)
            assert toks == base, f"G=2 spec depth={depth} diverged"
            assert server.stats.spec_rounds > 0

    @pytest.mark.parametrize("kv", [None, "int8"], ids=["fp32", "int8"])
    def test_failover_token_exact(self, kv):
        """G=1 mid-flight failover + recovery: rewind_spec discards the
        in-flight round, stage-0 re-prefill is loss-free, so spec still
        equals plain bit-for-bit."""
        trace = dict(
            kv_dtype=kv, kappa_pm=2, staggered=True,
            fail_steps={6: [(0, 0)]}, recover_steps={12: [(0, 0)]},
        )
        base, _, _ = _run_trace(0, **trace)
        assert any(len(t) > 0 for t in base)
        for depth in (0, 2):
            toks, server, _ = _run_trace(depth, spec_k=4, **trace)
            assert toks == base, f"spec depth={depth} diverged after failover"
            assert server.stats.rerouted_stages > 0

    def test_g2_failover_depth_invariant(self):
        """Mid-pipeline failover recovery re-prefills from the hidden
        handoff (lossy by design), so plain equality cannot hold at
        G>=2 — but the spec engine must still be exactly depth-invariant,
        conserve pages, and drain every request."""
        trace = dict(
            n_groups=2, n_replicas=2, kappa_pm=2, staggered=True,
            fail_steps={3: [(0, 0)], 6: [(1, 1)]},
            recover_steps={9: [(0, 0)], 11: [(1, 1)]},
        )
        t0, s0, r0 = _run_trace(0, spec_k=4, **trace)
        t2, s2, r2 = _run_trace(2, spec_k=4, **trace)
        assert t0 == t2, "spec G=2 failover streams depend on async depth"
        for server, reqs in ((s0, r0), (s2, r2)):
            assert server.stats.rerouted_stages > 0
            assert all(r.done or r.dropped for r in reqs)
            for mgr in server.managers.values():
                mgr.check_conservation()

    def test_preemption_token_exact(self):
        """Page pool too small for every context: preemption/requeue
        churn rewinds in-flight rounds (victims re-prefill from the
        committed stream), tokens stay identical to plain and to the
        direct greedy oracle."""
        trace = dict(
            max_pages=7, n_groups=1, n_replicas=1,
            n_requests=3, n_tokens=24, prompt_len=10,
        )
        base, s0, _ = _run_trace(0, **trace)
        assert s0.stats.preempted_jobs > 0
        cfg, model, params = _model()
        for n, t in enumerate(base):
            assert list(t) == direct_greedy(
                model, params, _prompt(cfg, n, 10), 24
            )
        for depth in (0, 2):
            toks, server, _ = _run_trace(depth, spec_k=4, **trace)
            assert toks == base, f"spec depth={depth} diverged under preemption"
            assert server.stats.preempted_jobs > 0

    @pytest.mark.slow
    def test_spec_k_sweep(self):
        """Any draft depth (including k=1 and k > remaining tokens)
        yields the same stream."""
        base, _, _ = _run_trace(0)
        for k in (1, 3, 6, 9):
            toks, server, _ = _run_trace(2, spec_k=k)
            assert toks == base, f"spec k={k} diverged"
            assert server.stats.spec_rounds > 0


class TestSpecStats:
    def test_acceptance_accounting(self):
        _, server, _ = _run_trace(2, spec_k=4, n_tokens=8)
        st = server.stats
        assert st.spec_rounds > 0
        assert st.draft_calls > 0
        assert st.verify_calls > 0
        assert st.spec_accepted <= st.spec_proposed
        assert 0.0 < st.acceptance_rate <= 1.0
        # Self-draft at fp32: the draft replays the target's greedy path.
        assert st.acceptance_rate > 0.9
        assert st.accepted_tokens == st.tokens_generated > 0
        assert st.energy_charged > 0.0
        # Speculation must beat one-dispatch-per-token on dispatch count.
        assert st.verify_calls + st.draft_calls < st.accepted_tokens

    def test_plain_engine_accounting_unchanged(self):
        _, server, _ = _run_trace(0)
        st = server.stats
        assert st.spec_rounds == st.draft_calls == st.verify_calls == 0
        assert st.spec_proposed == st.spec_accepted == 0
        assert st.acceptance_rate == 0.0
        assert st.accepted_tokens == st.tokens_generated > 0
        assert st.energy_charged > 0.0

    @pytest.mark.slow
    def test_pairing_draft_model(self):
        """A *different* draft model (registry-style pairing, here with
        random weights: acceptance ~0) must still produce the plain
        stream — verification, not the draft, owns correctness."""
        import jax

        from repro.models import build_model, init_from_template

        cfg, model, params = _model()
        draft = build_model(cfg)
        dparams = init_from_template(
            draft.template, jax.random.PRNGKey(7), "float32"
        )
        base, _, _ = _run_trace(0)
        server = PipelineServer(
            model, params, n_groups=1, n_replicas=2, policy="uniform",
            harvest_bounds=(50.0, 60.0), max_len=64, max_batch=4,
            paged=True, page_size=8, async_depth=2,
            spec_draft=(draft, dparams), spec_k=4, seed=0,
        )
        reqs = [server.submit(_prompt(cfg, n), 6) for n in range(5)]
        steps = 0
        while not all(r.done or r.dropped for r in reqs):
            server.step()
            steps += 1
            assert steps < 5000
        assert [tuple(r.generated) for r in reqs] == base
        # Every round still commits the verify's own bonus token.
        assert server.stats.spec_rounds > 0
        assert server.stats.accepted_tokens == server.stats.tokens_generated


class TestSpecValidation:
    def test_requires_paged_substrate(self):
        cfg, model, params = _model()
        with pytest.raises(ValueError, match="paged"):
            PipelineServer(
                model, params, n_groups=1, n_replicas=1,
                harvest_bounds=(50.0, 60.0), max_len=64, max_batch=2,
                spec_draft=(model, params),
            )

    def test_requires_positive_k(self):
        cfg, model, params = _model()
        with pytest.raises(ValueError, match="spec_k"):
            PipelineServer(
                model, params, n_groups=1, n_replicas=1,
                harvest_bounds=(50.0, 60.0), max_len=64, max_batch=2,
                paged=True, spec_draft=(model, params), spec_k=0,
            )


class TestVerifyLemma:
    """The kernel-level fact the engine's exactness rests on: one
    ``verify_step_paged`` chunk call == k+1 sequential
    ``decode_step_paged`` calls, bit-for-bit, in logits AND in the page
    rows it scatters."""

    W, PAGE, NB, L0, K = 2, 8, 4, 5, 5

    def _pools(self, cfg, kv_dtype):
        import jax.numpy as jnp

        P = self.W * self.NB  # + 1 scratch page at index P
        shape = (cfg.n_layers, P + 1, self.PAGE, cfg.n_kv_heads, cfg.head_dim)
        pools = {
            "k": jnp.zeros(shape, jnp.dtype(kv_dtype)),
            "v": jnp.zeros(shape, jnp.dtype(kv_dtype)),
        }
        if jnp.dtype(kv_dtype) == jnp.int8:
            pools["k_scale"] = jnp.ones(shape[:3], jnp.float32)
            pools["v_scale"] = jnp.ones(shape[:3], jnp.float32)
        return pools

    @pytest.mark.parametrize(
        "impl,kv", [("xla", None), ("pallas", None), ("pallas", "int8")],
        ids=["xla", "pallas", "pallas-int8"],
    )
    def test_verify_chunk_equals_sequential_decode(self, impl, kv):
        import jax
        import jax.numpy as jnp

        from repro.models import build_model

        cfg, _, params = _model()
        model = build_model(dataclasses.replace(cfg, attn_impl=impl))
        kv_dtype = kv or cfg.dtype
        W, L0, K = self.W, self.L0, self.K
        bt = jnp.asarray(
            np.arange(W * self.NB, dtype=np.int32).reshape(W, self.NB)
        )
        rng = np.random.default_rng(3)
        prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(W, L0)),
                             jnp.int32)
        logits, pools = model.prefill_chunk_paged(
            params, prompt, self._pools(cfg, kv_dtype),
            jnp.zeros((W,), jnp.int32), jnp.full((W,), L0, jnp.int32), bt,
        )
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)

        # Sequential oracle: K greedy decode_step_paged calls.
        seq_pools = jax.tree_util.tree_map(jnp.array, pools)
        lane = [tok]
        seq_logits = []
        for j in range(K):
            lg, seq_pools = model.decode_paged(
                params, lane[-1][:, None], seq_pools,
                jnp.full((W,), L0 + j, jnp.int32), bt,
            )
            seq_logits.append(lg[:, 0])
            lane.append(jnp.argmax(lg[:, 0], axis=-1).astype(jnp.int32))

        # One verify chunk over [tok, d_1 .. d_{K-1}] (self-draft lane).
        chunk = jnp.stack(lane[:K], axis=1)
        ver_logits, ver_pools = model.verify_step_paged(
            params, chunk, pools,
            jnp.full((W,), L0, jnp.int32), jnp.full((W,), K, jnp.int32), bt,
        )
        for j in range(K):
            np.testing.assert_array_equal(
                np.asarray(ver_logits[:, j]), np.asarray(seq_logits[j]),
                err_msg=f"verify position {j} != sequential decode ({impl})",
            )
        # The scattered page rows are byte-identical too (scratch page
        # excluded: both paths park masked/padding writes there).
        P = W * self.NB
        for name in pools:
            np.testing.assert_array_equal(
                np.asarray(ver_pools[name][:, :P]),
                np.asarray(seq_pools[name][:, :P]),
                err_msg=f"pool {name!r} rows diverged ({impl})",
            )

    def test_verify_adds_no_gathers(self):
        """Acceptance criterion: the verify entry point introduces zero
        XLA gathers beyond the chunk-prefill path it delegates to."""
        from repro.analysis import count_primitive
        from repro.analysis.entry_points import build_entry_points

        entries = {
            e.kind: e
            for e in build_entry_points(["stablelm-1.6b"],
                                        include_kernels=False)
            if e.variant == "pallas"
        }
        verify = entries["verify_step_paged"].jaxpr
        chunk = entries["prefill_chunk_paged"].jaxpr
        assert count_primitive(verify, "gather") == count_primitive(
            chunk, "gather"
        )


class TestRollbackFuzz:
    """Seeded random-ops fuzzer for ``rollback(n)``: after every op the
    pool conserves pages, held pages exactly cover the rolled-back
    length, and the block-table row mirrors the held pages. (The
    hypothesis-driven twin lives in test_property_spec.py.)"""

    def _fuzz(self, make_mgr, paged, seed):
        from repro.serving.cache import PageError

        rng = np.random.default_rng(seed)
        mgr = make_mgr()
        live = {}  # rid -> slot
        next_rid = 0
        for _ in range(300):
            u = rng.uniform()
            if u < 0.3 and mgr.free_slots() > 0:
                length = int(rng.integers(0, 40))
                if mgr.can_reserve(length):
                    slot = mgr.reserve(next_rid, length)
                    # The engine stamps the host mirror at dispatch time;
                    # the fuzzer plays that role here.
                    mgr.lengths[slot] = length
                    live[next_rid] = slot
                    next_rid += 1
            elif u < 0.5 and live:
                rid = int(rng.choice(list(live)))
                slot = live[rid]
                target = int(rng.integers(0, 49))
                if mgr.try_extend(rid, slot, target):
                    mgr.lengths[slot] = max(int(mgr.lengths[slot]), target)
            elif u < 0.85 and live:
                rid = int(rng.choice(list(live)))
                slot = live[rid]
                n = int(rng.integers(0, int(mgr.lengths[slot]) + 1))
                mgr.rollback(rid, slot, n)
                if paged and n > 0:
                    # Rollback trims the claim to exactly the shorter
                    # context's page need.
                    length = int(mgr.lengths[slot])
                    need = mgr.pool.blocks_for(length) if length > 0 else 0
                    assert len(mgr.pages.get(rid, [])) == need
            elif live:
                rid = int(rng.choice(list(live)))
                mgr.release(rid, live.pop(rid))
            mgr.check_conservation()
            for rid, slot in live.items():
                length = int(mgr.lengths[slot])
                assert mgr.slots[slot] == rid
                if paged:
                    held = mgr.pages.get(rid, [])
                    # Pages always cover the committed mirror ...
                    if length > 0:
                        assert len(held) >= mgr.pool.blocks_for(length)
                    # ... and the block-table row mirrors them, with the
                    # tail re-scratched (no aliasing of freed pages).
                    row = list(mgr.block_table[slot])
                    assert row[: len(held)] == held
                    assert all(p == mgr.pool.scratch
                               for p in row[len(held):])
            # Over-rollback must refuse, not corrupt.
            if live:
                rid = next(iter(live))
                with pytest.raises(PageError):
                    mgr.rollback(rid, live[rid], int(mgr.lengths[live[rid]]) + 1)
                mgr.check_conservation()
        for rid, slot in list(live.items()):
            mgr.release(rid, slot)
        mgr.check_conservation()
        if paged:
            assert mgr.pool.free_pages == mgr.pool.n_pages

    @pytest.mark.parametrize("seed", [0, 1])
    def test_paged_rollback_random_ops(self, seed):
        from repro.serving.cache import PagedKVCache

        self._fuzz(
            lambda: PagedKVCache(n_slots=3, max_len=64, page_size=4,
                                 n_pages=20),
            paged=True, seed=seed,
        )

    def test_dense_rollback_random_ops(self):
        from repro.serving.cache import DenseSlotCache

        self._fuzz(lambda: DenseSlotCache(n_slots=3, max_len=64),
                   paged=False, seed=0)


@pytest.mark.slow
class TestSpecSanitizer:
    """The spec step loop's sync contract: drafts and verify argmaxes
    read back only at the commit boundary, never during dispatch, and
    per-step sanctioned syncs stay within the ``spec`` budget."""

    def _drain(self, server, cfg, n_requests=4, n_tokens=6):
        reqs = [
            server.submit(_prompt(cfg, i), n_tokens=n_tokens)
            for i in range(n_requests)
        ]
        while not all(r.done for r in reqs):
            server.step()

    def test_syncs_only_at_commit(self):
        from repro.analysis import TransferSanitizer, load_budgets

        budget = load_budgets()["host_sync"]["per_step_budget"]["spec"]
        cfg, server = _server(
            2, spec_k=4, n_groups=1, n_replicas=1,
            harvest_bounds=(60.0, 80.0), prefill_chunk=4,
        )
        self._drain(server, cfg)  # warmup: compile every dispatch shape
        with TransferSanitizer() as san:
            self._drain(server, cfg)
        assert server.stats.spec_rounds > 0
        assert san.unsanctioned_total == 0
        assert san.max_per_step <= budget
        assert san.sanctioned_by_phase["dispatch"] == 0
        assert san.sanctioned_by_phase["commit"] == san.sanctioned_total > 0
