"""Paged KV-cache tests: pool accounting, fuzzed lifecycle invariants,
token-exactness vs the dense engine, preemption, failover, determinism."""

import dataclasses

import numpy as np
import pytest
from conftest import direct_greedy, tiny_model

from repro.serving import (
    DenseSlotCache,
    PagedKVCache,
    PageError,
    PagePool,
    PipelineServer,
)


class TestCacheManagers:
    """The KVCacheManager contract both engines schedule against."""

    def test_dense_is_one_page_per_slot(self):
        mgr = DenseSlotCache(n_slots=2, max_len=32)
        assert mgr.fits(32) and not mgr.fits(33)
        assert mgr.capacity_weight() == 2
        s0 = mgr.reserve(7, 10)
        assert mgr.capacity_weight() == 1
        # Dense extending never fails within max_len...
        assert mgr.try_extend(7, s0, 32)
        # ...and a context submit should have rejected raises loudly.
        with pytest.raises(PageError):
            mgr.try_extend(7, s0, 33)
        s1 = mgr.reserve(8, 4)
        assert not mgr.can_reserve(1)  # full
        mgr.release(7, s0)
        mgr.release(8, s1)
        assert mgr.capacity_weight() == 2
        mgr.check_conservation()

    def test_paged_reserve_extend_release(self):
        mgr = PagedKVCache(n_slots=4, max_len=64, page_size=4, n_pages=6)
        slot = mgr.reserve(1, 9)  # 3 pages
        assert mgr.held(1) == 3
        assert mgr.capacity_weight() == 3
        assert mgr.try_extend(1, slot, 12)  # still 3 pages
        assert mgr.held(1) == 3
        assert mgr.try_extend(1, slot, 13)  # grows to 4
        assert mgr.held(1) == 4
        slot2 = mgr.reserve(2, 8)  # takes the last 2 pages
        assert not mgr.try_extend(1, slot, 17)  # pool exhausted -> preempt
        mgr.release(2, slot2)
        assert mgr.try_extend(1, slot, 17)
        # Block-table row names exactly the held pages, scratch elsewhere.
        row = mgr.block_table[slot]
        assert sorted(row[: mgr.held(1)]) == sorted(mgr.pages[1])
        assert (row[mgr.held(1):] == mgr.pool.scratch).all()
        mgr.release(1, slot)
        assert mgr.pool.free_pages == mgr.pool.n_pages
        mgr.check_conservation()

    def test_paged_slot_only_reservation(self):
        """Failover re-placement reserves the slot with zero pages; the
        memory grows lazily at call time."""
        mgr = PagedKVCache(n_slots=2, max_len=32, page_size=4, n_pages=4)
        slot = mgr.reserve(5, 0)
        assert mgr.held(5) == 0
        assert (mgr.block_table[slot] == mgr.pool.scratch).all()
        assert mgr.try_extend(5, slot, 7)
        assert mgr.held(5) == 2
        mgr.release(5, slot)
        mgr.check_conservation()

    def test_paged_row_overflow_raises(self):
        mgr = PagedKVCache(n_slots=1, max_len=16, page_size=4, n_pages=8)
        slot = mgr.reserve(1, 4)
        with pytest.raises(PageError):  # 17 entries > 4-page row
            mgr.try_extend(1, slot, 17)


class TestPagePool:
    def test_alloc_free_conservation(self):
        pool = PagePool(8, 4)
        a = pool.alloc(3, rid=1)
        b = pool.alloc(5, rid=2)
        assert pool.free_pages == 0 and len(set(a) | set(b)) == 8
        pool.check_conservation()
        pool.free(a, rid=1)
        assert pool.free_pages == 3
        pool.check_conservation()

    def test_double_free_and_foreign_free_raise(self):
        pool = PagePool(4, 4)
        a = pool.alloc(2, rid=1)
        pool.free(a, rid=1)
        with pytest.raises(PageError):
            pool.free(a, rid=1)  # double free
        b = pool.alloc(1, rid=2)
        with pytest.raises(PageError):
            pool.free(b, rid=3)  # foreign free

    def test_overdraw_raises(self):
        pool = PagePool(2, 4)
        assert not pool.can_alloc(3)
        with pytest.raises(PageError):
            pool.alloc(3, rid=0)

    def test_blocks_for(self):
        pool = PagePool(8, 16)
        assert pool.blocks_for(0) == 1  # min one page
        assert pool.blocks_for(16) == 1
        assert pool.blocks_for(17) == 2
        assert pool.scratch == 8


def _assert_page_invariants(server: PipelineServer):
    """Conservation + exclusivity across the whole fleet, every step."""
    for (g, r), mgr in server.managers.items():
        mgr.check_conservation()  # pool conservation + single ownership
        resident = {
            req.rid
            for req in server._active
            if req.replicas is not None and req.replicas[g] == r
        }
        owners = {rid for rid, pages in mgr.pages.items() if pages}
        assert owners <= resident, (
            f"manager ({g},{r}) holds pages for non-residents "
            f"{sorted(owners - resident)}"
        )
        held = sum(len(p) for p in mgr.pages.values())
        assert mgr.pool.used_pages == held
        assert mgr.pool.free_pages + mgr.pool.used_pages == mgr.pool.n_pages


class TestPagedEngine:
    def test_token_exact_vs_dense_engine(self):
        """Acceptance: the paged engine is token-exact vs the dense PR 2
        engine on an identical workload (and vs monolithic greedy)."""
        cfg, model, params = tiny_model()
        n_tok = 4
        prompts = [(np.arange(6) * (i + 1) + i) % cfg.vocab_size for i in range(3)]

        def serve(paged):
            server = PipelineServer(
                model, params, n_groups=2, n_replicas=1,
                harvest_bounds=(50.0, 60.0), max_len=64, max_batch=4,
                paged=paged, page_size=8, seed=5,
            )
            reqs = [server.submit(p, n_tokens=n_tok) for p in prompts]
            for _ in range(400):
                if all(r.done for r in reqs):
                    break
                server.step()
            assert all(r.done for r in reqs)
            return server, reqs

        d_server, d_reqs = serve(False)
        p_server, p_reqs = serve(True)
        for d, p, prompt in zip(d_reqs, p_reqs, prompts):
            assert p.generated == d.generated
            assert p.generated == direct_greedy(model, params, prompt, n_tok)
        # Same dispatch accounting: one paged decode per (stage, round).
        assert p_server.stats.decode_calls == d_server.stats.decode_calls
        # Fully drained fleet returns every page.
        for mgr in p_server.managers.values():
            mgr.check_conservation()
            assert mgr.pool.free_pages == mgr.pool.n_pages

    def test_preemption_on_page_exhaustion(self):
        """A pool too small for every context preempts the youngest back
        to the queue (no crash, no drop) and still finishes token-exact."""
        cfg, model, params = tiny_model()
        server = PipelineServer(
            model, params, n_groups=1, n_replicas=1,
            harvest_bounds=(50.0, 60.0), max_len=64, max_batch=4,
            paged=True, page_size=4, max_pages=6, seed=0,
        )
        prompts = [(np.arange(6) + i) % cfg.vocab_size for i in range(3)]
        # 6 prompt + 12 generated = 18 entries -> 5 pages each; pool = 6.
        reqs = [server.submit(p, n_tokens=12) for p in prompts]
        for _ in range(3000):
            if all(r.done for r in reqs):
                break
            server.step()
            _assert_page_invariants(server)
        assert all(r.done for r in reqs)
        assert server.stats.preempted_jobs > 0
        assert server.stats.dropped_jobs == 0
        for r, p in zip(reqs, prompts):
            assert r.generated == direct_greedy(model, params, p, 12)

    def test_context_beyond_max_len_rejected_at_submit(self):
        """Regression: prompt + n_tokens > max_len used to overflow the
        block-table row mid-decode and crash the whole fleet (dense mode
        silently corrupted the cache tail). Both engines now reject."""
        cfg, model, params = tiny_model()
        for paged in (False, True):
            server = PipelineServer(
                model, params, n_groups=1, n_replicas=1,
                harvest_bounds=(50.0, 60.0), max_len=32, max_batch=2,
                paged=paged, page_size=8, seed=0,
            )
            req = server.submit(np.arange(30), n_tokens=8)
            assert req is None
            assert server.stats.dropped_jobs == 1
            ok = server.submit(np.arange(6), n_tokens=8)  # fits: admitted
            assert ok is not None and not ok.dropped
            for _ in range(200):
                if ok.done:
                    break
                server.step()
            assert ok.done

    def test_oversized_request_rejected_at_submit(self):
        """A request whose *final* context can never fit the pool is
        rejected up front — admitting it would only preempt healthy
        residents on the way to an inevitable mid-decode drop."""
        cfg, model, params = tiny_model()
        server = PipelineServer(
            model, params, n_groups=1, n_replicas=1,
            harvest_bounds=(50.0, 60.0), max_len=64, max_batch=2,
            paged=True, page_size=4, max_pages=2, seed=0,
        )
        # 6 prompt + 8 generated = 14 entries -> 4 pages > 2-page pool.
        req = server.submit(np.arange(6), n_tokens=8)
        assert req is None
        assert server.stats.dropped_jobs == 1
        _assert_page_invariants(server)

    def test_unadmittable_prompt_rejected_not_queue_blocking(self):
        """Regression: a prompt whose pages can never fit the pool used
        to park at the FIFO head forever, starving everything behind
        it. It is rejected at submit; later requests still run."""
        cfg, model, params = tiny_model()
        server = PipelineServer(
            model, params, n_groups=1, n_replicas=1,
            harvest_bounds=(50.0, 60.0), max_len=64, max_batch=2,
            paged=True, page_size=4, max_pages=2, seed=0,
        )
        big = server.submit(np.arange(12), n_tokens=4)  # 3 pages > 2-page pool
        assert big is None
        assert server.stats.dropped_jobs == 1
        small = server.submit(np.arange(4), n_tokens=4)
        assert small is not None
        for _ in range(200):
            if small.done:
                break
            server.step()
        assert small.done
        _assert_page_invariants(server)

    def test_readmission_reserves_full_context(self):
        """Regression: a preempted request re-admits with pages for its
        whole prefix (prompt + generated), not just the prompt — an
        under-reserved re-admit would immediately preempt healthy
        residents again (churn)."""
        cfg, model, params = tiny_model()
        server = PipelineServer(
            model, params, n_groups=1, n_replicas=1,
            harvest_bounds=(50.0, 60.0), max_len=64, max_batch=4,
            paged=True, page_size=4, max_pages=6, seed=0,
        )
        prompts = [(np.arange(6) + i) % cfg.vocab_size for i in range(3)]
        reqs = [server.submit(p, n_tokens=12) for p in prompts]
        for _ in range(3000):
            if all(r.done for r in reqs):
                break
            server.step()
            # Admission (including re-admission after preemption) must
            # reserve the whole prefix up front: before its first
            # prefill a resident holds blocks for prompt + generated,
            # not just the prompt.
            for req in server._active:
                if req.generated and not any(req.cache_ready):
                    need = server.managers[(0, 0)].pool.blocks_for(
                        len(req.prompt) + len(req.generated)
                    )
                    for g in range(server.G):
                        mgr = server.managers[(g, req.replicas[g])]
                        assert mgr.held(req.rid) >= need
        assert all(r.done for r in reqs)
        assert server.stats.preempted_jobs > 0

    def test_failover_token_exact_and_pages_released(self):
        cfg, model, params = tiny_model()
        server = PipelineServer(
            model, params, n_groups=2, n_replicas=3,
            harvest_bounds=(50.0, 60.0), max_len=64, max_batch=2,
            paged=True, page_size=8, seed=4,
        )
        prompt = np.arange(6) % cfg.vocab_size
        req = server.submit(prompt, n_tokens=5)
        fails = 0
        for _ in range(600):
            if req.done:
                break
            if fails < 2 and len(req.generated) > fails:
                server.fail_replica(0, req.replicas[0])
                fails += 1
            server.step()
        assert req.done and fails == 2
        assert server.stats.rerouted_stages >= 2
        assert req.generated == direct_greedy(model, params, prompt, 5)
        for mgr in server.managers.values():
            mgr.check_conservation()
            assert mgr.pool.free_pages == mgr.pool.n_pages

    def test_paged_requires_uniform_full_attention(self):
        cfg, model, params = tiny_model("hymba-1.5b")
        with pytest.raises(ValueError, match="paged"):
            PipelineServer(model, params, n_groups=1, n_replicas=1, paged=True)

    def test_seed_determinism(self):
        """Two paged runs with the same seed produce identical token
        streams and stats (page allocation is deterministic)."""
        cfg, model, params = tiny_model()

        def run():
            server = PipelineServer(
                model, params, n_groups=2, n_replicas=2,
                harvest_bounds=(8.0, 14.0), max_len=64, max_batch=2,
                paged=True, page_size=8, max_pages=8, seed=11,
            )
            stats = server.run(40, arrival_p=0.7, prompt_len=6, n_tokens=3)
            tokens = sorted(
                (r.rid, tuple(r.generated))
                for r in server._active + list(server._pending)
            )
            return dataclasses.asdict(stats), tokens

        s1, t1 = run()
        s2, t2 = run()
        assert s1 == s2
        assert t1 == t2


class TestPagedLifecycleFuzz:
    """Drive the paged fleet through random admit / complete /
    fail_replica / recover_replica sequences; pages must be conserved —
    no leaks, no double frees, free + resident == pool — after every
    step."""

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_random_lifecycle_conserves_pages(self, seed):
        cfg, model, params = tiny_model()
        G, R = 2, 2
        server = PipelineServer(
            model, params, n_groups=G, n_replicas=R,
            harvest_bounds=(12.0, 20.0), max_len=32, max_batch=2,
            paged=True, page_size=4, max_pages=10, seed=seed,
        )
        rng = np.random.default_rng(1000 + seed)
        submitted = []
        for step in range(80):
            u = rng.uniform()
            if u < 0.35:
                prompt_len = int(rng.integers(2, 9))
                n_tok = int(rng.integers(1, 5))
                req = server.submit(
                    rng.integers(0, cfg.vocab_size, size=prompt_len),
                    n_tokens=n_tok,
                )
                if req is not None:
                    submitted.append(req)
            elif u < 0.45:
                server.fail_replica(int(rng.integers(G)), int(rng.integers(R)))
            elif u < 0.60:
                server.recover_replica(int(rng.integers(G)), int(rng.integers(R)))
            server.step()
            _assert_page_invariants(server)
        # Recover everything and drain; all pages must come home.
        for g in range(G):
            for r in range(R):
                server.recover_replica(g, r)
        for _ in range(1500):
            if not server._active and not server._pending:
                break
            server.step()
            _assert_page_invariants(server)
        assert not server._active and not server._pending
        for mgr in server.managers.values():
            assert mgr.pool.free_pages == mgr.pool.n_pages
        stats = server.stats
        assert stats.submitted == stats.completed_jobs + stats.dropped_jobs
