"""Network simulator tests (paper Sec. V semantics)."""

import dataclasses

import numpy as np
import pytest

from repro.core.network import paper_topology
from repro.core.simulator import SimConfig, simulate, simulate_single_device

BASE = SimConfig(n_groups=1, n_per_group=1, n_steps=100, p_arrival=0.6)


def fixed_cfg(pm: int, **kw) -> SimConfig:
    """Single fixed power mode."""
    return dataclasses.replace(
        BASE, pm_thresholds=(), pm_allowed=(pm,), **kw
    )


class TestSingleDevice:
    def test_fixed_15w_time_bound(self):
        """kappa=3 caps completions at ~n_steps/3 regardless of energy."""
        res = simulate_single_device(fixed_cfg(1), 20, 30, n_runs=32)
        assert res.completed.mean() <= 34
        assert res.completed.mean() > 25

    def test_rich_harvest_no_downtime(self):
        res = simulate_single_device(fixed_cfg(3), 30, 40, n_runs=32)
        assert res.downtime_fraction.mean() < 1e-3
        assert res.mean_battery.mean() > 80

    def test_poor_harvest_energy_limited(self):
        """Throughput ~ income/CE when energy-bound (60 W, CE=23)."""
        res = simulate_single_device(fixed_cfg(3, p_arrival=1.0), 2, 6, n_runs=32)
        # income 4/slot -> ~4/23 jobs/slot plus initial battery (100/23).
        expect = 100 * 4 / 23 + 100 / 23
        assert res.completed.mean() == pytest.approx(expect, rel=0.25)

    def test_no_arrivals(self):
        res = simulate_single_device(
            dataclasses.replace(BASE, p_arrival=0.0), 6, 10, n_runs=8
        )
        assert res.completed.sum() == 0
        assert res.arrivals.sum() == 0
        assert res.mean_battery.mean() == pytest.approx(100.0, abs=1.0)

    def test_battery_within_bounds(self):
        res = simulate_single_device(BASE, 0, 30, n_runs=16)
        assert np.all(res.mean_battery >= 0)
        assert np.all(res.mean_battery <= 100)

    def test_fig2a_orderings(self):
        """Paper Fig. 2a orderings under the documented calibration
        (p=0.62, arrivals U[7,13]; see EXPERIMENTS.md Paper-validation):
        jobs 15W < 30W <= DYN <= 60W; DYN has zero downtime while 60 W
        power-saves; DYN holds more battery than 60 W."""
        arrival = (7, 13)
        runs = dict(n_runs=200)
        res = {
            "15W": simulate_single_device(fixed_cfg(1, p_arrival=0.62), *arrival, **runs),
            "30W": simulate_single_device(fixed_cfg(2, p_arrival=0.62), *arrival, **runs),
            "60W": simulate_single_device(fixed_cfg(3, p_arrival=0.62), *arrival, **runs),
            "DYN": simulate_single_device(
                dataclasses.replace(BASE, p_arrival=0.62), *arrival, **runs
            ),
        }
        jobs = {k: v.completed.mean() for k, v in res.items()}
        assert jobs["15W"] == pytest.approx(31, abs=2)  # paper: 31
        assert jobs["15W"] < jobs["30W"] <= jobs["DYN"] + 1.5 <= jobs["60W"] + 3.5
        assert res["DYN"].downtime_fraction.mean() < 1e-3
        assert res["60W"].downtime_fraction.mean() > 0.01
        assert res["DYN"].mean_battery.mean() > res["60W"].mean_battery.mean()


class TestNetwork:
    def test_conservation(self):
        """completed + dropped + in-flight == arrivals."""
        topo = paper_topology()
        cfg = SimConfig(n_groups=3, n_per_group=3, n_steps=200, p_arrival=0.5)
        res = simulate(topo, cfg, n_runs=16)
        in_flight = res.arrivals - res.completed - res.dropped
        assert np.all(in_flight >= 0)
        # At most 2N jobs can be in flight at the end.
        assert np.all(in_flight <= 2 * 3)

    def test_policies_run(self):
        topo = paper_topology()
        rates = np.full((3, 3), 0.4)
        for policy in ("uniform", "long_term", "adaptive"):
            cfg = SimConfig(
                n_groups=3, n_per_group=3, n_steps=50, p_arrival=0.5, policy=policy
            )
            res = simulate(topo, cfg, n_runs=8, long_term_rates=rates)
            assert np.all(res.completed >= 0)
            assert np.all(res.downtime_fraction >= 0)
            assert np.all(res.downtime_fraction <= 1)

    def test_long_term_reduces_downtime_heterogeneous(self):
        """Paper Fig. 3: model-based policies beat uniform on downtime
        when devices are heterogeneous in harvest rates."""
        topo = paper_topology(arrival_means=(3.0, 6.0, 12.0), half_width=2)
        rates = topo.long_term_rates(0.01)
        kw = dict(n_groups=3, n_per_group=3, n_steps=300, p_arrival=0.7)
        uni = simulate(
            topo, SimConfig(policy="uniform", **kw), n_runs=64, long_term_rates=rates
        )
        lt = simulate(
            topo, SimConfig(policy="long_term", **kw), n_runs=64, long_term_rates=rates
        )
        ada = simulate(
            topo, SimConfig(policy="adaptive", **kw), n_runs=64, long_term_rates=rates
        )
        assert lt.downtime_fraction.mean() < uni.downtime_fraction.mean()
        assert ada.downtime_fraction.mean() <= lt.downtime_fraction.mean() * 1.15

    def test_throughput_increases_with_energy(self):
        cfg = SimConfig(n_groups=3, n_per_group=3, n_steps=200, p_arrival=0.8)
        poor = simulate(paper_topology(arrival_means=(3, 3, 3)), cfg, n_runs=32)
        rich = simulate(paper_topology(arrival_means=(12, 12, 12)), cfg, n_runs=32)
        assert (
            rich.normalized_throughput.mean() > poor.normalized_throughput.mean()
        )

    def test_drops_increase_with_load(self):
        topo = paper_topology(arrival_means=(4, 5, 6))
        lo = simulate(
            topo,
            SimConfig(n_groups=3, n_per_group=3, n_steps=200, p_arrival=0.3),
            n_runs=32,
        )
        hi = simulate(
            topo,
            SimConfig(n_groups=3, n_per_group=3, n_steps=200, p_arrival=0.95),
            n_runs=32,
        )
        assert hi.dropped.mean() > lo.dropped.mean()
