"""Static memory-flow pass tests: byte cost model, liveness peaks,
budget drift, and the KV donation lint.

The acceptance criteria live here: the budgets-drift test pins the
committed ``memory_budgets`` to ``--update-budgets`` output, parity
tests tie static boundary bytes to ``compiled.memory_analysis()`` on
CPU, int8 paged entries must show the ~4x dtype-normalized pool
reduction, and an injected *undonated* engine dispatch must fail the
``donation`` rule with a named finding."""

import copy
import json

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (
    analyze_dispatch,
    aval_bytes,
    build_entry_points,
    entry_memory,
    io_bytes,
    load_budgets,
    memory_report,
    peak_live_bytes,
    run_static_rules,
    transfer_bytes,
    update_memory_budgets,
    while_trip_count,
)
from repro.analysis.cli import main as cli_main
from repro.analysis.memory import engine_dispatches

_F32 = jnp.float32


def _jx(fn, *args):
    return jax.make_jaxpr(fn)(*args)


def _sds(shape, dtype=_F32):
    return jax.ShapeDtypeStruct(shape, dtype)


class TestByteModel:
    def test_aval_bytes(self):
        j = _jx(lambda x: x + 1.0, _sds((4, 8)))
        assert aval_bytes(j.jaxpr.invars[0].aval) == 4 * 8 * 4

    def test_elementwise_bytes(self):
        # y = x + x: one add, reads x twice, writes y once.
        j = _jx(lambda x: x + x, _sds((16,)))
        assert transfer_bytes(j) == 3 * 16 * 4

    def test_scan_body_trip_weighted(self):
        def f(c):
            return jax.lax.scan(lambda c, _: (c * 2.0, ()), c, None, length=5)[0]

        n = 16 * 4  # carry bytes
        j = _jx(f, _sds((16,)))
        # body: mul reads carry + writes carry (the 2.0 is a literal)
        assert transfer_bytes(j) == 5 * 2 * n

    def test_while_trip_from_cond_literal(self):
        def f(x):
            return jax.lax.while_loop(
                lambda s: s[0] < 7, lambda s: (s[0] + 1, s[1] * 2.0), (0, x)
            )[1]

        j = _jx(f, _sds((16,)))
        (weqn,) = [e for e in j.jaxpr.eqns if e.primitive.name == "while"]
        assert while_trip_count(weqn) == 7
        assert transfer_bytes(j) > 7 * 16 * 4  # body runs 7x

    def test_gather_charges_rows_not_table(self):
        table = _sds((1000, 64))
        idx = jax.ShapeDtypeStruct((4,), jnp.int32)
        j = _jx(lambda t, i: t[i], table, idx)
        # Rows actually touched (2x: read + write) + indices — never the
        # 256KB table.
        assert transfer_bytes(j) < 3 * 4 * 64 * 4 + 2 * 4 * 4

    def test_dynamic_update_slice_in_place(self):
        big = _sds((1024, 64))
        small = _sds((1, 64))
        j = _jx(
            lambda b, s: jax.lax.dynamic_update_slice(b, s, (3, 0)), big, small
        )
        assert transfer_bytes(j) < 2 * (64 * 4) + 64  # ~2x the slice
        assert transfer_bytes(j) < aval_bytes(j.jaxpr.invars[0].aval)

    def test_pallas_kernel_dma_granularity(self):
        """The standalone paged kernel entry charges grid x block bytes,
        far below reading whole pools per grid cell."""
        entries = {e.name: e for e in build_entry_points([])}
        e = entries["kernel:paged_decode_attention:pallas"]
        stats = entry_memory(e)
        ins, outs = io_bytes(e.jaxpr)
        # DMA total stays within a small multiple of the boundary bytes
        # (each pool page is visited ~once), nowhere near grid x pool.
        assert stats.transfer_bytes < 3 * (ins + outs)


class TestLiveness:
    def test_chain_releases_dead_values(self):
        # b = a+a; c = b*b; d = c-1 — at most 2 arrays live at once.
        n = 1024 * 4
        j = _jx(lambda a: (a + a) * (a + a) - 1.0, _sds((1024,)))
        assert peak_live_bytes(j) <= 3 * n

    def test_outputs_stay_live(self):
        j = _jx(lambda a: (a + 1.0, a * 2.0, a - 3.0), _sds((256,)))
        assert peak_live_bytes(j) == 4 * 256 * 4  # input + all 3 outputs

    def test_donated_input_excluded(self):
        j = _jx(lambda a: a + 1.0, _sds((4096,)))
        full = peak_live_bytes(j)
        donated = peak_live_bytes(j, donated=(0,))
        assert donated == full - 4096 * 4

    def test_scan_body_internal_peak_counted(self):
        # The body allocates a big temporary; the scan eqn must surface it.
        def f(c):
            def body(c, _):
                t = jnp.outer(c, c)  # (64, 64) temp
                return c + t.sum(axis=1), ()

            return jax.lax.scan(body, c, None, length=3)[0]

        j = _jx(f, _sds((64,)))
        assert peak_live_bytes(j) >= 64 * 64 * 4

    def test_while_body_internal_peak_counted(self):
        def f(x):
            def body(s):
                i, v = s
                t = jnp.outer(v, v)
                return i + 1, v + t.sum(axis=1)

            return jax.lax.while_loop(lambda s: s[0] < 4, body, (0, x))[1]

        j = _jx(f, _sds((64,)))
        assert peak_live_bytes(j) >= 64 * 64 * 4


class TestEntryStats:
    @pytest.fixture(scope="class")
    def entries(self):
        return {e.name: e for e in build_entry_points(["stablelm-1.6b"])}

    def test_stats_for_every_entry(self, entries):
        for e in entries.values():
            s = entry_memory(e)
            assert s.transfer_bytes > 0
            assert s.bytes_per_token > 0
            assert s.peak_live_bytes > 0
            assert s.roofline_memory_s > 0

    def test_bytes_per_token_normalization(self, entries):
        e = entries["stablelm-1.6b:decode_step_paged:pallas"]
        s = entry_memory(e)
        assert s.tokens_per_call == 4
        assert s.bytes_per_token == -(-s.transfer_bytes // 4)

    def test_pallas_beats_xla_gather_fallback(self, entries):
        """The kernel path moves fewer static bytes than the XLA gather
        fallback — the reason the kernels exist, now a checked number."""
        pallas = entry_memory(entries["stablelm-1.6b:decode_step_paged:pallas"])
        xla = entry_memory(entries["stablelm-1.6b:decode_step_paged:xla"])
        assert pallas.bytes_per_token < xla.bytes_per_token

    def test_dense_decode_parity_with_xla(self, entries):
        """Static boundary bytes match compiled.memory_analysis() on CPU
        (XLA pads scalars; allow 1%)."""
        from repro.analysis.entry_points import _N, _sds as sds, _stacked_cache_sds
        from repro.configs import get_smoke_config
        from repro.models import build_model
        from repro.models.common import abstract_params

        cfg = get_smoke_config("stablelm-1.6b")
        model = build_model(cfg)
        params = abstract_params(model.template, cfg.param_dtype)
        tok = sds((_N, 1, 1), jnp.int32)
        caches = _stacked_cache_sds(model, _N)
        compiled = jax.jit(model.decode_batch).lower(params, tok, caches).compile()
        rep = memory_report(compiled)
        ins, outs = io_bytes(entries["stablelm-1.6b:decode_batch:dense"].jaxpr)
        assert ins == pytest.approx(rep["argument_bytes"], rel=0.01)
        assert outs == pytest.approx(rep["output_bytes"], rel=0.01)

    def test_paged_decode_parity_with_xla(self, entries):
        import dataclasses

        from repro.analysis.entry_points import _NB, _W, _pool_sds, _sds as sds
        from repro.configs import get_smoke_config
        from repro.models import build_model
        from repro.models.common import abstract_params

        cfg = dataclasses.replace(
            get_smoke_config("stablelm-1.6b"), attn_impl="pallas"
        )
        model = build_model(cfg)
        params = abstract_params(model.template, cfg.param_dtype)
        tok = sds((_W, 1), jnp.int32)
        pools = _pool_sds(cfg, cfg.dtype)
        lens = sds((_W,), jnp.int32)
        bt = sds((_W, _NB), jnp.int32)
        compiled = (
            jax.jit(model.decode_paged)
            .lower(params, tok, pools, lens, bt)
            .compile()
        )
        rep = memory_report(compiled)
        ins, outs = io_bytes(
            entries["stablelm-1.6b:decode_step_paged:pallas"].jaxpr
        )
        assert ins == pytest.approx(rep["argument_bytes"], rel=0.01)
        assert outs == pytest.approx(rep["output_bytes"], rel=0.01)


class TestKvPageRatio:
    def test_int8_pool_is_4x_smaller_fp32_normalized(self):
        """int8 paged entries carry ~4x less KV pool than the fp32
        equivalent (per-row scales eat a sliver of the 4x)."""
        entries = build_entry_points(["stablelm-1.6b"])
        int8 = [e for e in entries if e.variant == "pallas-int8"]
        assert int8
        for e in int8:
            ratio = e.kv_pool_bytes_fp32 / e.kv_pool_bytes
            assert 3.0 <= ratio <= 4.0

    def test_ratio_rule_fires_on_regression(self):
        entries = build_entry_points(["stablelm-1.6b"])
        e = next(e for e in entries if e.variant == "pallas-int8")
        e.kv_pool_bytes = e.kv_pool_bytes_fp32  # int8 reduction "lost"
        budgets = load_budgets(None)
        findings = run_static_rules([e], budgets, ["kv-page-ratio"])
        assert findings and findings[0].rule == "kv-page-ratio"
        assert findings[0].entry_point == e.name


class TestBudgetDrift:
    def test_committed_memory_budgets_match_regeneration(self):
        """--update-budgets over the full matrix must be a no-op against
        the committed budgets.json — stale budgets fail fast."""
        budgets = load_budgets(None)
        committed = budgets.get("memory_budgets", {})
        regenerated = update_memory_budgets(copy.deepcopy(budgets),
                                            build_entry_points())
        assert committed == regenerated["memory_budgets"]

    def test_memory_rules_green_on_committed_budgets(self):
        entries = build_entry_points(["stablelm-1.6b"])
        budgets = load_budgets(None)
        findings = run_static_rules(
            entries, budgets, ["bytes-per-token", "peak-live-bytes", "kv-page-ratio"]
        )
        assert findings == []

    def test_bytes_per_token_rule_fires_on_drift(self, tmp_path):
        entries = build_entry_points(["stablelm-1.6b"])
        budgets = copy.deepcopy(load_budgets(None))
        name = "stablelm-1.6b:decode_step_paged:pallas"
        budgets["memory_budgets"][name]["bytes_per_token"] -= 1
        findings = run_static_rules(entries, budgets, ["bytes-per-token"])
        assert [f.entry_point for f in findings] == [name]
        assert findings[0].rule == "bytes-per-token"

    def test_update_budgets_cli_roundtrip(self, tmp_path):
        """`cli --update-budgets --budgets tmp` rewrites only the
        memory_budgets section, and --check is green against it."""
        budgets = copy.deepcopy(load_budgets(None))
        budgets["memory_budgets"] = {}
        path = tmp_path / "budgets.json"
        path.write_text(json.dumps(budgets))
        assert cli_main(["--update-budgets", "--budgets", str(path)]) == 0
        rewritten = json.loads(path.read_text())
        assert rewritten["memory_budgets"] == load_budgets(None)["memory_budgets"]


class TestCliMemorySection:
    def test_report_has_memory_for_every_entry(self, tmp_path):
        out = tmp_path / "report.json"
        rc = cli_main([
            "--check", "--static-only", "--models", "stablelm-1.6b",
            "--json", str(out),
        ])
        assert rc == 0
        report = json.loads(out.read_text())
        assert set(report["memory"]) == set(report["entry_points_checked"])
        for stats in report["memory"].values():
            assert stats["bytes_per_token"] > 0
            assert stats["peak_live_bytes"] > 0


@pytest.mark.slow
class TestDonationLint:
    def test_engine_dispatches_donate(self):
        """Every real engine dispatch (dense + paged) donates its
        cache/pool argument, and the compiler honors it."""
        for paged in (False, True):
            for name, fn, args in engine_dispatches(paged):
                report, findings = analyze_dispatch(
                    name, fn, args, min_bytes=16384
                )
                assert findings == [], [str(f) for f in findings]
                assert report.large_rebuilt >= 1
                assert report.donated == report.large_rebuilt
                assert report.aliased_bytes and report.aliased_bytes > 0

    def test_undonated_injection_fails_by_name(self):
        """An undonated variant of the real decode dispatch must fail
        the donation rule with a named finding."""
        name, fn, args = engine_dispatches(True)[0]
        undonated = jax.jit(lambda p, t, c, l, b: fn(p, t, c, l, b))
        report, findings = analyze_dispatch(
            "engine:paged:decode-undonated", undonated, args, min_bytes=16384
        )
        assert findings, "undonated dispatch must produce a finding"
        assert all(f.rule == "donation" for f in findings)
        assert findings[0].entry_point == "engine:paged:decode-undonated"
        assert report.donated == 0
        assert report.large_rebuilt >= 1

    def test_donation_executes_and_frees(self):
        """Donated decode actually runs, stays correct, and deletes the
        donated pool buffers (the per-step cache copy is gone)."""
        import numpy as np

        from repro.analysis.recompile import _smoke_server

        cfg, server = _smoke_server(paged=True)
        ex = server._exec[0]
        _, params = server.stages[0]
        cache = server._caches[(0, 0)]
        W = server.max_batch
        nb = -(-server.max_len // server.page_size)
        tok = jnp.zeros((W, 1), jnp.int32)
        lens = jnp.ones((W,), jnp.int32)
        bt = jnp.zeros((W, nb), jnp.int32)
        old_k = cache["k"]
        out, new_cache = ex.decode_fn(params, tok, cache, lens, bt)
        np.asarray(out)  # force completion
        assert new_cache["k"].shape == old_k.shape
        assert old_k.is_deleted()
