"""Serving tests: partition equivalence, router semantics, engine runs,
failure handling."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import direct_greedy, tiny_model

from repro.core.power import dynamic_policy, fixed_policy
from repro.serving import (
    PipelineServer,
    ReplicaBudget,
    RouteError,
    Router,
    partition_model,
)


class TestPartition:
    @pytest.mark.parametrize("name,G", [("stablelm-1.6b", 2), ("phi4-mini-3.8b", 3), ("hymba-1.5b", 2)])
    def test_stage_split_matches_full_forward(self, name, G):
        """Chaining stage forwards == full model forward."""
        cfg, model, params = tiny_model(name)
        stages = partition_model(cfg, params, G)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)

        full_logits, _ = model.forward(params, {"tokens": tokens})

        x = {"tokens": tokens}
        for g, (m_g, p_g) in enumerate(stages):
            out, _ = m_g.forward(p_g, x)
            x = {"hidden": out}
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(full_logits), rtol=2e-4, atol=2e-4
        )

    def test_stage_decode_matches_full(self):
        cfg, model, params = tiny_model()
        G = 2
        stages = partition_model(cfg, params, G)
        tokens = jax.random.randint(jax.random.PRNGKey(2), (1, 12), 0, cfg.vocab_size)

        _, full_cache = model.prefill(params, {"tokens": tokens[:, :-1]}, 20)
        full_logits, _ = model.decode_step(params, tokens[:, -1:], full_cache)

        # stage prefill chain
        caches = []
        x = {"tokens": tokens[:, :-1]}
        for m_g, p_g in stages:
            out, c = m_g.prefill(p_g, x, 20)
            caches.append(c)
            x = {"hidden": out}
        # stage decode chain
        inp = tokens[:, -1:]
        for g, (m_g, p_g) in enumerate(stages):
            out, caches[g] = m_g.decode_step(p_g, inp, caches[g])
            inp = out
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(full_logits), rtol=2e-4, atol=2e-4
        )


class TestBudget:
    def test_rejects_inverted_hysteresis(self):
        pol = dynamic_policy(100)
        with pytest.raises(ValueError):
            ReplicaBudget(policy=pol, e_th=30.0, e_th_hi=20.0)
        with pytest.raises(ValueError):
            ReplicaBudget(policy=pol, e_th=-1.0, e_th_hi=25.0)
        with pytest.raises(ValueError):
            ReplicaBudget(policy=pol, e_th=10.0, e_th_hi=150.0, e_max=100.0)

    def test_recover_clamps_to_e_max(self):
        pol = dynamic_policy(100)
        b = ReplicaBudget(policy=pol, e_max=100.0, e_th=10.0, e_th_hi=99.5)
        b.fail()
        b.recover()
        assert b.level == 100.0  # e_th_hi + 1 would exceed e_max
        b.recover(level=500.0)
        assert b.level == 100.0
        assert b.available


class TestRouter:
    def _budgets(self, levels, G=1):
        pol = dynamic_policy(100)
        return [
            [ReplicaBudget(policy=pol, level=l) for l in levels] for _ in range(G)
        ]

    def test_uniform_over_available(self):
        r = Router(policy="uniform", seed=0)
        budgets = self._budgets([50.0, 50.0, 5.0])  # third in power save
        budgets[0][2].active = False
        probs = r.probabilities(budgets)[0]
        np.testing.assert_allclose(probs, [0.5, 0.5, 0.0])

    def test_adaptive_downweights_critical(self):
        r = Router(policy="adaptive", seed=0)
        budgets = self._budgets([30.0, 80.0, 80.0])  # first is PM1 (critical)
        probs = r.probabilities(budgets)[0]
        assert probs[0] < probs[1]
        assert probs[1] == pytest.approx(probs[2])

    def test_route_error_when_group_empty(self):
        r = Router(policy="uniform")
        budgets = self._budgets([50.0, 50.0])
        for b in budgets[0]:
            b.fail()
        with pytest.raises(RouteError):
            r.route(budgets)

    def test_free_slots_mask_full_replicas(self):
        r = Router(policy="uniform", seed=0)
        budgets = self._budgets([50.0, 50.0, 50.0])
        probs = r.probabilities(budgets, free_slots=[[0, 2, 2]])[0]
        np.testing.assert_allclose(probs, [0.0, 0.5, 0.5])
        with pytest.raises(RouteError):
            r.route(budgets, free_slots=[[0, 0, 0]])

    def test_all_zero_headroom_returns_unnormalized_zero_mass(self):
        """When every replica in a group has zero headroom the group's
        vector must stay an unnormalized all-zeros (NOT renormalized to
        uniform): callers detect sum == 0 and queue the request."""
        r = Router(policy="adaptive", seed=0)
        budgets = self._budgets([60.0, 80.0, 90.0], G=2)
        probs = r.probabilities(budgets, free_slots=[[0, 0, 0], [1, 1, 1]])
        np.testing.assert_array_equal(probs[0], [0.0, 0.0, 0.0])
        assert probs[0].sum() == 0.0  # unnormalized: full group = no mass
        assert probs[1].sum() == pytest.approx(1.0)
        with pytest.raises(RouteError):
            r.route(budgets, free_slots=[[0, 0, 0], [1, 1, 1]])
        with pytest.raises(RouteError):
            r.reroute(budgets, 0, free_slots=[[0, 0, 0], [1, 1, 1]])

    def test_mixed_free_slot_dtypes_do_not_change_distribution(self):
        """Headroom weights arrive as python ints (dense free slots),
        numpy ints of various widths (paged free pages) or floats; the
        distribution must be identical across all of them."""
        r = Router(policy="adaptive", seed=0)
        budgets = self._budgets([50.0, 80.0, 80.0])
        ref = r.probabilities(budgets, free_slots=[[1, 2, 4]])[0]
        variants = [
            [[1.0, 2.0, 4.0]],
            [[np.int32(1), np.int64(2), np.int32(4)]],
            [np.array([1, 2, 4], dtype=np.int16)],
            [np.array([1.0, 2.0, 4.0], dtype=np.float32)],
            [[True, 2.0, np.uint8(4)]],  # bool/np-scalar soup
        ]
        for fs in variants:
            np.testing.assert_allclose(
                r.probabilities(budgets, free_slots=fs)[0], ref
            )
        assert ref.sum() == pytest.approx(1.0)


class TestEngine:
    def test_generates_tokens(self):
        cfg, model, params = tiny_model()
        server = PipelineServer(
            model, params, n_groups=2, n_replicas=2, policy="adaptive",
            harvest_bounds=(20.0, 30.0), max_len=64, seed=0,
        )
        stats = server.run(n_slots=40, arrival_p=0.5, prompt_len=6, n_tokens=2)
        assert stats.tokens_generated > 0
        assert stats.completed_jobs > 0
        assert stats.stage_executions >= stats.tokens_generated

    def test_engine_output_matches_direct_decode(self):
        """The pipelined engine's greedy tokens == monolithic greedy."""
        cfg, model, params = tiny_model()
        server = PipelineServer(
            model, params, n_groups=2, n_replicas=1,
            harvest_bounds=(50.0, 60.0), max_len=64, seed=1,
        )
        prompt = np.arange(5) % cfg.vocab_size
        req = server.submit(prompt, n_tokens=3)
        for _ in range(100):
            if req.done:
                break
            server.step()
        assert req.done

        # Direct greedy decode.
        logits, cache = model.prefill(params, {"tokens": jnp.asarray(prompt)[None]}, 64)
        toks = []
        tok = int(jnp.argmax(logits[0, -1]))
        toks.append(tok)
        for _ in range(2):
            logits, cache = model.decode_step(params, jnp.asarray([[tok]]), cache)
            tok = int(jnp.argmax(logits[0, -1]))
            toks.append(tok)
        assert req.generated == toks

    def test_failover_reroutes_and_continues(self):
        cfg, model, params = tiny_model()
        server = PipelineServer(
            model, params, n_groups=2, n_replicas=2,
            harvest_bounds=(50.0, 60.0), max_len=64, seed=2,
        )
        req = server.submit(np.arange(6), n_tokens=4)
        for _ in range(3):
            server.step()
        g = req.stage
        server.fail_replica(g, req.replicas[g])
        for _ in range(200):
            if req.done or req.dropped:
                break
            server.step()
        assert req.done
        assert server.stats.rerouted_stages >= 1
        assert len(req.generated) == 4

    def test_low_budget_causes_downtime(self):
        cfg, model, params = tiny_model()
        server = PipelineServer(
            model, params, n_groups=1, n_replicas=2,
            harvest_bounds=(1.0, 3.0), max_len=64, seed=3,
            pm_policy=fixed_policy(3),
        )
        stats = server.run(n_slots=60, arrival_p=0.9, prompt_len=4, n_tokens=2)
        assert stats.downtime_fraction > 0.0
        # Whole replica-slots, counted as integers and normalized by G*R.
        assert isinstance(stats.downtime_replica_slots, int)
        assert stats.downtime_replica_slots <= stats.slots * 1 * 2
        assert stats.downtime_fraction <= 1.0

    def test_rng_streams_independent(self):
        """Harvest/arrival draws and routing draws come from spawned,
        uncorrelated SeedSequence streams — not the same integer seed."""
        cfg, model, params = tiny_model()
        server = PipelineServer(model, params, n_groups=2, n_replicas=2, seed=7)
        a = server._rng.uniform(size=16)
        b = server.router._rng.uniform(size=16)
        assert not np.allclose(a, b)
        # Same seed still means a reproducible fleet.
        other = PipelineServer(model, params, n_groups=2, n_replicas=2, seed=7)
        np.testing.assert_allclose(server.harvest, other.harvest)


class TestContinuousBatching:
    def test_batched_equals_sequential_and_direct(self):
        """Same requests through max_batch=1 and max_batch=4 servers give
        identical tokens, and one stage call serves the whole batch."""
        cfg, model, params = tiny_model()
        n_tok = 3
        prompts = [(np.arange(6) * (i + 1) + i) % cfg.vocab_size for i in range(3)]

        def serve(max_batch):
            server = PipelineServer(
                model, params, n_groups=2, n_replicas=1,
                harvest_bounds=(50.0, 60.0), max_len=64,
                max_batch=max_batch, seed=5,
            )
            reqs = [server.submit(p, n_tokens=n_tok) for p in prompts]
            for _ in range(300):
                if all(r.done for r in reqs):
                    break
                server.step()
            assert all(r.done for r in reqs)
            return server, reqs

        seq_server, seq_reqs = serve(1)
        bat_server, bat_reqs = serve(4)
        for s, b, p in zip(seq_reqs, bat_reqs, prompts):
            assert s.generated == b.generated
            assert b.generated == direct_greedy(model, params, p, n_tok)

        # Sequential capacity is one request per replica: the other two
        # waited in the backpressure queue instead of being dropped.
        assert seq_server.stats.queued_jobs == 2
        assert seq_server.stats.dropped_jobs == 0
        assert bat_server.stats.queued_jobs == 0

        # Dispatch accounting: batched serving issues ONE decode call per
        # (stage, round) for all three residents — 2*(n_tok-1) calls total
        # — while the sequential server pays per request.
        assert bat_server.stats.decode_calls == 2 * (n_tok - 1)
        assert bat_server.stats.prefill_calls == 2
        assert bat_server.stats.stage_executions == 3 * 2 * n_tok
        assert seq_server.stats.decode_calls == 3 * 2 * (n_tok - 1)
        assert bat_server.stats.decode_calls * 3 == seq_server.stats.decode_calls

    def test_two_failovers_token_exact(self):
        """Regression: two stage-0 failovers must not duplicate prompt
        tokens in the re-prefill context — generated tokens stay equal to
        the monolithic greedy decode."""
        cfg, model, params = tiny_model()
        server = PipelineServer(
            model, params, n_groups=2, n_replicas=3,
            harvest_bounds=(50.0, 60.0), max_len=64, max_batch=2, seed=4,
        )
        prompt = np.arange(6) % cfg.vocab_size
        req = server.submit(prompt, n_tokens=5)
        fails = 0
        for _ in range(400):
            if req.done:
                break
            # Kill the stage-0 replica after the 1st and again after the
            # 2nd generated token: each failover re-prefills from the
            # prompt + all generated tokens.
            if fails < 2 and len(req.generated) > fails:
                server.fail_replica(0, req.replicas[0])
                fails += 1
            server.step()
        assert req.done
        assert fails == 2
        assert server.stats.rerouted_stages >= 2
        assert req.generated == direct_greedy(model, params, prompt, 5)
        # The prompt itself was never mutated by the failovers.
        np.testing.assert_array_equal(req.prompt, prompt)

    def test_failover_waits_for_full_sibling(self):
        """A failover victim whose live siblings are momentarily full is
        parked and retried, not dropped."""
        cfg, model, params = tiny_model()
        server = PipelineServer(
            model, params, n_groups=1, n_replicas=2,
            harvest_bounds=(50.0, 60.0), max_len=64, max_batch=1, seed=8,
        )
        a = server.submit(np.arange(4), n_tokens=3)
        b = server.submit(np.arange(4) + 1, n_tokens=2)
        assert a.replicas[0] != b.replicas[0]  # slot-aware routing spreads them
        server.step()
        server.fail_replica(0, a.replicas[0])
        for _ in range(200):
            if a.done and b.done:
                break
            server.step()
        assert a.done and b.done
        assert server.stats.dropped_jobs == 0
        assert server.stats.rerouted_stages >= 1
        assert a.generated == direct_greedy(model, params, np.arange(4), 3)

    def test_parked_request_resumes_on_replica_recovery(self):
        """Regression: a failover victim parked because its live sibling
        was full must be re-placed when its old replica recovers — the
        engine used to pick it up as a slotless call member and crash."""
        cfg, model, params = tiny_model()
        server = PipelineServer(
            model, params, n_groups=1, n_replicas=2,
            harvest_bounds=(50.0, 60.0), max_len=64, max_batch=1, seed=8,
        )
        a = server.submit(np.arange(4), n_tokens=6)
        b = server.submit(np.arange(4) + 1, n_tokens=6)
        assert a.replicas[0] != b.replicas[0]
        server.step()
        dead = a.replicas[0]
        server.fail_replica(0, dead)
        for _ in range(3):
            server.step()  # sibling full: a is parked, slotless
        server.recover_replica(0, dead)
        for _ in range(300):
            if a.done and b.done:
                break
            server.step()
        assert a.done and b.done
        assert server.stats.dropped_jobs == 0
        assert a.generated == direct_greedy(model, params, np.arange(4), 6)

    def test_dead_group_drops_queued_requests(self):
        cfg, model, params = tiny_model()
        server = PipelineServer(
            model, params, n_groups=1, n_replicas=1,
            harvest_bounds=(50.0, 60.0), max_len=64, max_batch=1, seed=9,
        )
        a = server.submit(np.arange(4), n_tokens=4)
        b = server.submit(np.arange(4) + 1, n_tokens=4)
        assert b.queued
        server.fail_replica(0, 0)
        for _ in range(5):
            server.step()
        # Nothing to wait for: both the resident and the queued request drop.
        assert a.dropped and b.dropped and not b.queued
        assert server.queue_depth == 0
        assert server.stats.dropped_jobs == 2
        stats = server.stats
        assert stats.submitted == stats.completed_jobs + stats.dropped_jobs

    def test_parked_request_beats_fresh_admissions_to_freed_capacity(self):
        """Regression: freed slots used to go to the queue head before the
        slot-loop re-placed parked in-flight requests, so sustained
        arrivals starved a failover victim indefinitely."""
        cfg, model, params = tiny_model()
        server = PipelineServer(
            model, params, n_groups=1, n_replicas=2,
            harvest_bounds=(50.0, 60.0), max_len=64, max_batch=1, seed=8,
        )
        a = server.submit(np.arange(4), n_tokens=4)
        b = server.submit(np.arange(4) + 1, n_tokens=4)
        assert a.replicas[0] != b.replicas[0]
        server.step()
        server.fail_replica(0, a.replicas[0])  # a parks: sibling is full
        rid = 0
        for _ in range(120):
            if a.done:
                break
            # Sustained fresh traffic competing for every freed slot.
            server.submit(np.arange(3) + rid, n_tokens=1)
            rid += 1
            server.step()
        assert a.done  # the parked request reclaimed capacity first

    def test_aging_force_places_starved_parked_victim(self):
        """Satellite: re-placement alone is not starvation-free — when
        the only live sibling stays saturated (here: one slot held by a
        long decode that outlives the test horizon), a failover victim
        used to park indefinitely. With ``max_park_steps`` the scheduler
        force-places it by preempting the sibling's youngest resident
        (requeued loss-free), and decoding stays token-exact."""
        cfg, model, params = tiny_model()

        def park_scenario(max_park_steps):
            server = PipelineServer(
                model, params, n_groups=1, n_replicas=2,
                harvest_bounds=(50.0, 60.0), max_len=128, max_batch=1,
                max_park_steps=max_park_steps, seed=8,
            )
            a = server.submit(np.arange(4), n_tokens=3)
            # b's decode outlives the horizon: its slot never frees.
            b = server.submit(np.arange(4) + 1, n_tokens=120)
            assert a.replicas[0] != b.replicas[0]
            server.step()
            server.fail_replica(0, a.replicas[0])
            for _ in range(100):
                if a.done:
                    break
                server.step()
            return server, a

        # Without aging the victim starves for the whole horizon.
        server, a = park_scenario(None)
        assert not a.done and a.park_steps > 50
        assert server.stats.aged_placements == 0

        # With aging it lands within max_park_steps + a few slots.
        server, a = park_scenario(6)
        assert a.done
        assert server.stats.aged_placements >= 1
        assert server.stats.preempted_jobs >= 1
        assert server.stats.dropped_jobs == 0
        assert a.generated == direct_greedy(model, params, np.arange(4), 3)

    def test_new_submit_never_jumps_the_queue(self):
        """Regression: capacity freed between steps used to go to the
        newest submit() instead of the FIFO head, starving queued
        requests under sustained traffic."""
        cfg, model, params = tiny_model()
        server = PipelineServer(
            model, params, n_groups=1, n_replicas=1,
            harvest_bounds=(50.0, 60.0), max_len=64, max_batch=1, seed=6,
        )
        a = server.submit(np.arange(4), n_tokens=2)
        b = server.submit(np.arange(4) + 1, n_tokens=2)
        assert b.queued
        while not a.done:
            server.step()  # the slot is now free, b still queued
        c = server.submit(np.arange(4) + 2, n_tokens=2)
        assert c.queued and not c.done  # b holds its place at the head
        for _ in range(200):
            if b.done and c.done:
                break
            server.step()
        assert b.done and c.done

    def test_queue_drains_and_completes(self):
        cfg, model, params = tiny_model()
        server = PipelineServer(
            model, params, n_groups=2, n_replicas=1,
            harvest_bounds=(50.0, 60.0), max_len=64,
            max_batch=1, max_queue=1, seed=6,
        )
        a = server.submit(np.arange(4), n_tokens=2)
        b = server.submit(np.arange(4) + 1, n_tokens=2)
        c = server.submit(np.arange(4) + 2, n_tokens=2)  # queue full -> dropped
        assert not a.queued and b.queued and c is None
        assert server.queue_depth == 1
        assert server.stats.dropped_jobs == 1
        for _ in range(200):
            if a.done and b.done:
                break
            server.step()
        assert a.done and b.done
        assert server.queue_depth == 0
