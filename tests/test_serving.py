"""Serving tests: partition equivalence, router semantics, engine runs,
failure handling."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.power import dynamic_policy, fixed_policy
from repro.models import build_model, init_from_template
from repro.serving import (
    PipelineServer,
    ReplicaBudget,
    RouteError,
    Router,
    partition_model,
)


def tiny_model(name="stablelm-1.6b"):
    cfg = dataclasses.replace(
        get_smoke_config(name), dtype="float32", param_dtype="float32"
    )
    model = build_model(cfg)
    params = init_from_template(model.template, jax.random.PRNGKey(0), "float32")
    return cfg, model, params


class TestPartition:
    @pytest.mark.parametrize("name,G", [("stablelm-1.6b", 2), ("phi4-mini-3.8b", 3), ("hymba-1.5b", 2)])
    def test_stage_split_matches_full_forward(self, name, G):
        """Chaining stage forwards == full model forward."""
        cfg, model, params = tiny_model(name)
        stages = partition_model(cfg, params, G)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)

        full_logits, _ = model.forward(params, {"tokens": tokens})

        x = {"tokens": tokens}
        for g, (m_g, p_g) in enumerate(stages):
            out, _ = m_g.forward(p_g, x)
            x = {"hidden": out}
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(full_logits), rtol=2e-4, atol=2e-4
        )

    def test_stage_decode_matches_full(self):
        cfg, model, params = tiny_model()
        G = 2
        stages = partition_model(cfg, params, G)
        tokens = jax.random.randint(jax.random.PRNGKey(2), (1, 12), 0, cfg.vocab_size)

        _, full_cache = model.prefill(params, {"tokens": tokens[:, :-1]}, 20)
        full_logits, _ = model.decode_step(params, tokens[:, -1:], full_cache)

        # stage prefill chain
        caches = []
        x = {"tokens": tokens[:, :-1]}
        for m_g, p_g in stages:
            out, c = m_g.prefill(p_g, x, 20)
            caches.append(c)
            x = {"hidden": out}
        # stage decode chain
        inp = tokens[:, -1:]
        for g, (m_g, p_g) in enumerate(stages):
            out, caches[g] = m_g.decode_step(p_g, inp, caches[g])
            inp = out
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(full_logits), rtol=2e-4, atol=2e-4
        )


class TestRouter:
    def _budgets(self, levels, G=1):
        pol = dynamic_policy(100)
        return [
            [ReplicaBudget(policy=pol, level=l) for l in levels] for _ in range(G)
        ]

    def test_uniform_over_available(self):
        r = Router(policy="uniform", seed=0)
        budgets = self._budgets([50.0, 50.0, 5.0])  # third in power save
        budgets[0][2].active = False
        probs = r.probabilities(budgets)[0]
        np.testing.assert_allclose(probs, [0.5, 0.5, 0.0])

    def test_adaptive_downweights_critical(self):
        r = Router(policy="adaptive", seed=0)
        budgets = self._budgets([30.0, 80.0, 80.0])  # first is PM1 (critical)
        probs = r.probabilities(budgets)[0]
        assert probs[0] < probs[1]
        assert probs[1] == pytest.approx(probs[2])

    def test_route_error_when_group_empty(self):
        r = Router(policy="uniform")
        budgets = self._budgets([50.0, 50.0])
        for b in budgets[0]:
            b.fail()
        with pytest.raises(RouteError):
            r.route(budgets)


class TestEngine:
    def test_generates_tokens(self):
        cfg, model, params = tiny_model()
        server = PipelineServer(
            model, params, n_groups=2, n_replicas=2, policy="adaptive",
            harvest_bounds=(20.0, 30.0), max_len=64, seed=0,
        )
        stats = server.run(n_slots=40, arrival_p=0.5, prompt_len=6, n_tokens=2)
        assert stats.tokens_generated > 0
        assert stats.completed_jobs > 0
        assert stats.stage_executions >= stats.tokens_generated

    def test_engine_output_matches_direct_decode(self):
        """The pipelined engine's greedy tokens == monolithic greedy."""
        cfg, model, params = tiny_model()
        server = PipelineServer(
            model, params, n_groups=2, n_replicas=1,
            harvest_bounds=(50.0, 60.0), max_len=64, seed=1,
        )
        prompt = np.arange(5) % cfg.vocab_size
        req = server.submit(prompt, n_tokens=3)
        for _ in range(100):
            if req.done:
                break
            server.step()
        assert req.done

        # Direct greedy decode.
        logits, cache = model.prefill(params, {"tokens": jnp.asarray(prompt)[None]}, 64)
        toks = []
        tok = int(jnp.argmax(logits[0, -1]))
        toks.append(tok)
        for _ in range(2):
            logits, cache = model.decode_step(params, jnp.asarray([[tok]]), cache)
            tok = int(jnp.argmax(logits[0, -1]))
            toks.append(tok)
        assert req.generated == toks

    def test_failover_reroutes_and_continues(self):
        cfg, model, params = tiny_model()
        server = PipelineServer(
            model, params, n_groups=2, n_replicas=2,
            harvest_bounds=(50.0, 60.0), max_len=64, seed=2,
        )
        req = server.submit(np.arange(6), n_tokens=4)
        for _ in range(3):
            server.step()
        g = req.stage
        server.fail_replica(g, req.replicas[g])
        for _ in range(200):
            if req.done or req.dropped:
                break
            server.step()
        assert req.done
        assert server.stats.rerouted_stages >= 1
        assert len(req.generated) == 4

    def test_low_budget_causes_downtime(self):
        cfg, model, params = tiny_model()
        server = PipelineServer(
            model, params, n_groups=1, n_replicas=2,
            harvest_bounds=(1.0, 3.0), max_len=64, seed=3,
            pm_policy=fixed_policy(3),
        )
        stats = server.run(n_slots=60, arrival_p=0.9, prompt_len=4, n_tokens=2)
        assert stats.downtime_fraction > 0.0
