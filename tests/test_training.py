"""Training substrate tests: optimizer math, loss, end-to-end tiny run."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model, init_from_template
from repro.training import (
    AdamWConfig,
    SyntheticLM,
    TrainState,
    adamw_init,
    adamw_update,
    cross_entropy,
    init_train_state,
    lr_schedule,
    make_batch,
    make_train_step,
)


class TestOptimizer:
    def test_adamw_decreases_quadratic(self):
        cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100, weight_decay=0.0)
        params = {"w": jnp.array([3.0, -2.0])}
        opt = adamw_init(params)
        for _ in range(60):
            grads = {"w": 2 * params["w"]}  # d/dw ||w||^2
            params, opt, _ = adamw_update(grads, opt, params, cfg)
        assert float(jnp.sum(jnp.square(params["w"]))) < 0.3

    def test_weight_decay_shrinks(self):
        cfg = AdamWConfig(lr=0.1, warmup_steps=0, weight_decay=1.0)
        params = {"w": jnp.array([5.0])}
        opt = adamw_init(params)
        grads = {"w": jnp.array([0.0])}
        params2, _, _ = adamw_update(grads, opt, params, cfg)
        assert float(params2["w"][0]) < 5.0

    def test_clipping(self):
        cfg = AdamWConfig(clip_norm=1.0, warmup_steps=0)
        params = {"w": jnp.zeros(4)}
        opt = adamw_init(params)
        _, _, m = adamw_update({"w": jnp.full(4, 100.0)}, opt, params, cfg)
        assert m["grad_norm"] > 100  # reported pre-clip

    def test_lr_schedule_shape(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
        lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in (0, 5, 10, 55, 100)]
        assert lrs[0] == 0.0
        assert lrs[1] == pytest.approx(0.5)
        assert lrs[2] == pytest.approx(1.0)
        assert 0.1 < lrs[3] < 1.0
        assert lrs[4] == pytest.approx(0.1, abs=1e-6)


class TestLoss:
    def test_cross_entropy_perfect_prediction(self):
        logits = jnp.full((1, 3, 5), -20.0)
        labels = jnp.array([[1, 2, 3]])
        logits = logits.at[0, jnp.arange(3), labels[0]].set(20.0)
        assert float(cross_entropy(logits, labels)) < 1e-3

    def test_cross_entropy_uniform(self):
        V = 7
        logits = jnp.zeros((2, 4, V))
        labels = jnp.zeros((2, 4), jnp.int32)
        assert float(cross_entropy(logits, labels)) == pytest.approx(np.log(V), rel=1e-5)


class TestEndToEnd:
    @pytest.mark.parametrize("name", ["stablelm-1.6b", "granite-moe-1b-a400m"])
    def test_loss_decreases(self, name):
        cfg = dataclasses.replace(
            get_smoke_config(name), dtype="float32", param_dtype="float32"
        )
        model = build_model(cfg)
        params = init_from_template(model.template, jax.random.PRNGKey(0), "float32")
        state = init_train_state(model, params)
        step_fn = jax.jit(
            make_train_step(model, AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60))
        )
        data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
        losses = []
        for i in range(30):
            state, metrics = step_fn(state, make_batch(cfg, data, i))
            losses.append(float(metrics["loss"]))
        assert all(np.isfinite(losses))
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2
        assert int(state.step) == 30
