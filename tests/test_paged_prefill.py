"""Pallas paged prefill kernel: oracle/dense parity, int8 dequant, the
no-materialized-gather acceptance (jaxpr inspection — the Pallas path's
block-table walk happens in the kernel's DMA index map, so the traced
computation contains no XLA gather over the pool), and model-level
chunk-vs-prefill equality on the Pallas path."""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import tiny_model

from repro.analysis import count_primitive
from repro.kernels.decode_attention import (
    paged_prefill_attention,
    paged_prefill_attention_pallas,
    quantize_kv,
)
from repro.models.attention import chunked_attention, paged_chunk_attention_block


def _scattered_cache(rng, B, NB, page, KV, D, spare=2):
    """A contiguous per-request cache scattered over a shuffled pool."""
    S = NB * page
    P = B * NB + spare
    k_dense = rng.normal(size=(B, S, KV, D)).astype(np.float32)
    v_dense = rng.normal(size=(B, S, KV, D)).astype(np.float32)
    bt = rng.permutation(P)[: B * NB].reshape(B, NB).astype(np.int32)
    k_pages = rng.normal(size=(P, page, KV, D)).astype(np.float32)  # garbage
    v_pages = rng.normal(size=(P, page, KV, D)).astype(np.float32)
    for b in range(B):
        for j in range(NB):
            k_pages[bt[b, j]] = k_dense[b, j * page : (j + 1) * page]
            v_pages[bt[b, j]] = v_dense[b, j * page : (j + 1) * page]
    return k_dense, v_dense, k_pages, v_pages, bt


class TestPagedPrefillKernel:
    def test_matches_oracle_and_dense(self):
        """Kernel == gather oracle == dense chunked_attention, under
        arbitrary page scatter and ragged per-lane offsets."""
        rng = np.random.default_rng(0)
        B, C, KV, G, D, page, NB = 3, 5, 2, 3, 8, 4, 6
        H = KV * G
        S = NB * page
        k_dense, v_dense, k_pages, v_pages, bt = _scattered_cache(
            rng, B, NB, page, KV, D
        )
        q = rng.normal(size=(B, C, H, D)).astype(np.float32)
        offs = np.array([0, 7, S - C], np.int32)  # ragged lane offsets

        out = paged_prefill_attention_pallas(
            jnp.asarray(q), jnp.asarray(k_pages), jnp.asarray(v_pages),
            jnp.asarray(bt), jnp.asarray(offs), interpret=True,
        )
        ref = paged_prefill_attention(
            jnp.asarray(q), jnp.asarray(k_pages), jnp.asarray(v_pages),
            jnp.asarray(bt), jnp.asarray(offs),
        )
        dense = chunked_attention(
            jnp.asarray(q), jnp.asarray(k_dense), jnp.asarray(v_dense),
            causal=True, q_offset=jnp.asarray(offs), chunk=8,
        )
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-5
        )
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(dense), rtol=3e-5, atol=3e-5
        )
        assert np.all(np.isfinite(np.asarray(out)))

    def test_int8_pages_match_oracle(self):
        """Kernel and fallback dequantize identically (both read the
        same int8 rows + per-row scales), and int8 error vs fp32 stays
        at quantization scale."""
        rng = np.random.default_rng(1)
        B, C, KV, G, D, page, NB = 2, 4, 1, 4, 8, 8, 3
        H = KV * G
        _, _, k_pages, v_pages, bt = _scattered_cache(rng, B, NB, page, KV, D)
        q = rng.normal(size=(B, C, H, D)).astype(np.float32)
        offs = np.array([0, 5], np.int32)
        qk, ks = quantize_kv(jnp.asarray(k_pages))
        qv, vs = quantize_kv(jnp.asarray(v_pages))

        out = paged_prefill_attention_pallas(
            jnp.asarray(q), qk, qv, jnp.asarray(bt), jnp.asarray(offs),
            k_scales=ks, v_scales=vs, interpret=True,
        )
        ref = paged_prefill_attention(
            jnp.asarray(q), qk, qv, jnp.asarray(bt), jnp.asarray(offs),
            k_scales=ks, v_scales=vs,
        )
        fp = paged_prefill_attention_pallas(
            jnp.asarray(q), jnp.asarray(k_pages), jnp.asarray(v_pages),
            jnp.asarray(bt), jnp.asarray(offs), interpret=True,
        )
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-5
        )
        assert float(np.max(np.abs(np.asarray(out) - np.asarray(fp)))) < 0.05


class TestNoMaterializedGather:
    """Acceptance: chunked paged prefill no longer materializes a
    ``gather_pages`` copy when the Pallas path is active."""

    def _trace(self, impl):
        cfg, model, params = tiny_model()
        cfg = dataclasses.replace(cfg, attn_impl=impl)
        p_layer = jax.tree_util.tree_map(
            lambda a: a[0], params["classes"]["c0"]["attn"]
        )
        W, C, page, P = 2, 4, 8, 6
        KV, Dh = cfg.n_kv_heads, cfg.head_dim
        pages = {
            "k": jnp.zeros((P + 1, page, KV, Dh), jnp.float32),
            "v": jnp.zeros((P + 1, page, KV, Dh), jnp.float32),
        }
        bt = jnp.asarray(np.arange(W * 3).reshape(W, 3).astype(np.int32))
        positions = jnp.asarray(np.tile(np.arange(C), (W, 1)).astype(np.int32))
        x = jnp.zeros((W, C, cfg.d_model), jnp.float32)
        wp = jnp.zeros((W, C), jnp.int32)
        wo = positions % page

        fn = functools.partial(
            paged_chunk_attention_block, p=p_layer, cfg=cfg,
            positions=positions, pages=pages, block_tables=bt,
            write_pages=wp, write_offs=wo,
        )
        return jax.make_jaxpr(lambda x: fn(x))(x)

    def test_pallas_path_has_no_gather(self):
        fallback = self._trace("xla")
        pallas = self._trace("pallas")
        # The fallback's gather_pages materializes the prefix: >= 2 XLA
        # gathers (K and V pools). The Pallas path's page walk lives in
        # the kernel's BlockSpec index map — zero gathers in the trace.
        assert count_primitive(fallback.jaxpr, "gather") >= 2
        assert count_primitive(pallas.jaxpr, "gather") == 0
        # Both still scatter the chunk's K/V into the pool.
        assert count_primitive(pallas.jaxpr, "scatter") >= 2


class TestPallasChunkModelParity:
    def test_chunk_steps_match_whole_prefill_pallas(self):
        """Model-level: driving prefill_chunk_paged chunk-by-chunk on
        the Pallas path (interpret) matches whole-prompt dense prefill
        logits at the final position."""
        cfg, model, params = tiny_model()
        cfg_p = dataclasses.replace(cfg, attn_impl="pallas")
        from repro.models import build_model

        model_p = build_model(cfg_p)
        S, C, page, W = 11, 4, 8, 2
        NB = 3
        prompt = (np.arange(S) * 5 + 2) % cfg.vocab_size
        ref_logits, _ = model.prefill(
            params, {"tokens": jnp.asarray(prompt)[None]}, 32
        )

        shape = (cfg.n_layers, W * NB + 1, page, cfg.n_kv_heads, cfg.head_dim)
        pools = {
            "k": jnp.zeros(shape, jnp.float32),
            "v": jnp.zeros(shape, jnp.float32),
        }
        bt = jnp.asarray(np.arange(W * NB).reshape(W, NB).astype(np.int32))
        pos = 0
        while pos < S:
            valid = min(C, S - pos)
            buf = np.zeros((W, C), np.int32)
            buf[0, :valid] = prompt[pos : pos + valid]
            offs = jnp.asarray(np.array([pos, -1], np.int32))
            valids = jnp.asarray(np.array([valid, 0], np.int32))
            out, pools = model_p.prefill_chunk_paged(
                params, jnp.asarray(buf), pools, offs, valids, bt
            )
            pos += valid
        np.testing.assert_allclose(
            np.asarray(out[0, valid - 1]),
            np.asarray(ref_logits[0, -1]),
            rtol=2e-4, atol=2e-4,
        )
