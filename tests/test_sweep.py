"""Sweep-engine tests: scalar/sweep equivalence, compile accounting,
heterogeneous per-device scenarios, and SimConfig validation."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import simulator
from repro.core.network import paper_topology
from repro.core.policies import POLICY_IDS, POLICY_LIST, POLICIES
from repro.core.simulator import (
    ScenarioParams,
    SimConfig,
    scenario_from_config,
    scenario_params,
    simulate,
    simulate_sweep,
    stack_scenarios,
)


class TestSweepScalarEquivalence:
    def test_one_element_grid_bit_for_bit(self):
        """simulate == simulate_sweep over a 1-element grid, same seed."""
        topo = paper_topology()
        cfg = SimConfig(n_groups=3, n_per_group=3, n_steps=60, p_arrival=0.7,
                        policy="adaptive")
        scalar = simulate(topo, cfg, n_runs=16, seed=3)
        sweep = simulate_sweep(topo, [cfg], n_runs=16, seed=3)
        assert len(sweep) == 1
        for field in ("completed", "dropped", "arrivals", "downtime_fraction",
                      "mean_battery"):
            np.testing.assert_array_equal(
                getattr(sweep[0], field), getattr(scalar, field), err_msg=field
            )

    def test_multi_point_rows_match_scalar(self):
        """Each row of a mixed-policy/mixed-p grid equals its scalar run —
        vmap batching over the scenario axis must not perturb results."""
        topo = paper_topology(arrival_means=(3.0, 5.0, 7.0))
        cfgs = [
            SimConfig(n_groups=3, n_per_group=3, n_steps=50, p_arrival=p, policy=pol)
            for p in (0.4, 0.9)
            for pol in ("uniform", "long_term", "adaptive")
        ]
        sweep = simulate_sweep(topo, cfgs, n_runs=8, seed=0)
        for i, cfg in enumerate(cfgs):
            scalar = simulate(topo, cfg, n_runs=8, seed=0)
            np.testing.assert_array_equal(sweep.completed[i], scalar.completed)
            np.testing.assert_array_equal(
                sweep.downtime_fraction[i], scalar.downtime_fraction
            )

    def test_single_device_padded_tables_match(self):
        """Fixed-PM scenarios padded to the dynamic table length behave
        identically to their unpadded lowering."""
        cfg = SimConfig(n_groups=1, n_per_group=1, n_steps=80, p_arrival=0.6,
                        pm_thresholds=(), pm_allowed=(2,))
        lo, hi = np.array([[7]]), np.array([[13]])
        plain = scenario_from_config(cfg, lo, hi)
        padded = scenario_from_config(cfg, lo, hi, n_thresholds=2)
        r_plain = simulate_sweep(None, [plain], n_runs=8, n_steps=80)
        r_pad = simulate_sweep(None, [padded], n_runs=8, n_steps=80)
        np.testing.assert_array_equal(r_plain.completed, r_pad.completed)
        np.testing.assert_array_equal(r_plain.mean_battery, r_pad.mean_battery)


class TestCompileAccounting:
    def test_one_compile_per_shape_across_sweep(self):
        """A multi-point sweep over one network shape traces exactly once,
        and re-running with different scenario values does not re-trace."""
        # Distinctive shape so other tests' cached runners don't interfere.
        topo = paper_topology(n_groups=2, n_per_group=4,
                              arrival_means=(4.0, 6.0, 8.0, 10.0))
        simulator.reset_trace_counts()
        cfgs = [
            SimConfig(n_groups=2, n_per_group=4, n_steps=37, p_arrival=p, policy=pol)
            for p in (0.3, 0.6, 0.9)
            for pol in ("uniform", "adaptive")
        ]
        simulate_sweep(topo, cfgs, n_runs=4)
        counts = simulator.trace_counts()
        assert counts == {(2, 4, 37, 8): 1}
        # Same shape, new parameter values -> cache hit, still one trace.
        cfgs2 = [dataclasses.replace(c, p_arrival=0.5, e_th=20.0, e_th_hi=30.0)
                 for c in cfgs]
        simulate_sweep(topo, cfgs2, n_runs=4)
        assert simulator.trace_counts() == {(2, 4, 37, 8): 1}

    def test_scalar_reuses_sweep_executable(self):
        """simulate() is a 1-element sweep; repeated configs of one shape
        share a single compile."""
        topo = paper_topology(n_groups=2, n_per_group=2, arrival_means=(5.0, 9.0))
        simulator.reset_trace_counts()
        for p in (0.2, 0.5, 0.8):
            simulate(topo, SimConfig(n_groups=2, n_per_group=2, n_steps=23,
                                     p_arrival=p), n_runs=4)
        assert simulator.trace_counts() == {(2, 2, 23, 4): 1}


class TestHeterogeneousDevices:
    def test_per_device_thresholds(self):
        """Per-device hysteresis thresholds (inexpressible pre-sweep):
        a device with a near-full power-save band must accrue downtime
        while its scenario twin with a tiny band does not."""
        cfg = SimConfig(n_groups=1, n_per_group=2, n_steps=120, p_arrival=0.0)
        lo = np.full((1, 2), 2)
        hi = np.full((1, 2), 4)
        base = scenario_from_config(cfg, lo, hi)
        hetero = dataclasses.replace(
            base,
            e_init=jnp.asarray([[100.0, 50.0]], jnp.float32),
            e_th=jnp.asarray([[10.0, 96.0]], jnp.float32),
            e_th_hi=jnp.asarray([[25.0, 98.0]], jnp.float32),
        )
        res = simulate_sweep(None, [base, hetero], n_runs=8, n_steps=120)
        assert res.downtime_fraction[0].max() == 0.0
        # Device 1 of the hetero scenario starts below e_th=96 with harvest
        # <= 4/slot: it spends many slots recharging in power save.
        assert res.downtime_fraction[1].min() > 0.0

    def test_per_device_pm_tables(self):
        """A group mixing a fast (kappa=1) and a slow (kappa=3) device
        completes more than an all-slow group under uniform routing."""
        cfg = SimConfig(n_groups=1, n_per_group=2, n_steps=150, p_arrival=1.0,
                        pm_thresholds=(), pm_allowed=(1,))
        lo = np.full((1, 2), 20)
        hi = np.full((1, 2), 30)
        slow = scenario_from_config(cfg, lo, hi)
        kappa = np.asarray(slow.kappa).copy()
        kappa[0, 1, 1] = 1.0  # device 1: 3 slots/stage -> 1 slot/stage
        mixed = dataclasses.replace(slow, kappa=jnp.asarray(kappa))
        res = simulate_sweep(None, [slow, mixed], n_runs=16, n_steps=150)
        assert res.completed[1].mean() > res.completed[0].mean()


class TestStacking:
    def test_mismatched_tables_rejected(self):
        lo, hi = np.array([[5]]), np.array([[9]])
        a = scenario_from_config(
            SimConfig(n_groups=1, n_per_group=1, pm_thresholds=(), pm_allowed=(1,)),
            lo, hi,
        )
        b = scenario_from_config(SimConfig(n_groups=1, n_per_group=1), lo, hi)
        with pytest.raises(ValueError, match="n_thresholds"):
            stack_scenarios([a, b])

    def test_mixed_config_and_params_pad_to_widest(self):
        """SimConfig entries pad up to a prebuilt ScenarioParams' wider
        threshold table inside one mixed simulate_sweep list."""
        topo = paper_topology(n_groups=1, n_per_group=1, arrival_means=(8.0,))
        lo, hi = topo.arrival_bounds()
        wide = scenario_from_config(
            SimConfig(n_groups=1, n_per_group=1, n_steps=30), lo, hi, n_thresholds=3
        )
        cfg = SimConfig(n_groups=1, n_per_group=1, n_steps=30,
                        pm_thresholds=(), pm_allowed=(2,))
        res = simulate_sweep(topo, [cfg, wide], n_runs=4)
        assert len(res) == 2
        scalar = simulate(topo, cfg, n_runs=4)
        np.testing.assert_array_equal(res.completed[0], scalar.completed)

    def test_mixed_n_steps_rejected(self):
        topo = paper_topology()
        cfgs = [
            SimConfig(n_groups=3, n_per_group=3, n_steps=50),
            SimConfig(n_groups=3, n_per_group=3, n_steps=60),
        ]
        with pytest.raises(ValueError, match="n_steps"):
            simulate_sweep(topo, cfgs, n_runs=2)


class TestPolicyDispatch:
    def test_policy_ids_cover_registry(self):
        assert set(POLICY_IDS) == set(POLICIES)
        for name, i in POLICY_IDS.items():
            assert POLICY_LIST[i] is POLICIES[name]

    def test_scenario_carries_policy_id(self):
        topo = paper_topology()
        for name, i in POLICY_IDS.items():
            p = scenario_params(
                topo,
                SimConfig(n_groups=3, n_per_group=3, policy=name),
                long_term_rates=np.ones((3, 3)),
            )
            assert int(p.policy_id) == i


class TestSimConfigValidation:
    def test_inverted_hysteresis_rejected(self):
        """Mirrors DeviceModel's 0 <= e_th < e_th_hi <= e_max check."""
        with pytest.raises(ValueError, match="e_th"):
            SimConfig(n_groups=1, n_per_group=1, e_th=30.0, e_th_hi=20.0)

    def test_threshold_above_capacity_rejected(self):
        with pytest.raises(ValueError, match="e_th"):
            SimConfig(n_groups=1, n_per_group=1, e_th=50.0, e_th_hi=120.0,
                      e_max=100.0)

    def test_negative_threshold_rejected(self):
        with pytest.raises(ValueError, match="e_th"):
            SimConfig(n_groups=1, n_per_group=1, e_th=-1.0)

    def test_e_init_out_of_range_rejected(self):
        with pytest.raises(ValueError, match="e_init"):
            SimConfig(n_groups=1, n_per_group=1, e_init=150.0)

    def test_valid_config_accepted(self):
        SimConfig(n_groups=1, n_per_group=1, e_th=0.0, e_th_hi=100.0, e_max=100.0)
