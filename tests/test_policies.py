"""Scheduling policy tests (paper Algorithm 1)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policies import adaptive_probs, long_term_probs, uniform_probs


def arr(x):
    return jnp.asarray(x)


class TestUniform:
    def test_all_available(self):
        p = uniform_probs(None, None, arr([True, True, True, True]))
        np.testing.assert_allclose(p, [0.25] * 4)

    def test_some_unavailable(self):
        p = uniform_probs(None, None, arr([True, False, True, False]))
        np.testing.assert_allclose(p, [0.5, 0.0, 0.5, 0.0])

    def test_none_available(self):
        p = uniform_probs(None, None, arr([False, False]))
        np.testing.assert_allclose(p, [0.0, 0.0])


class TestLongTerm:
    def test_eq6_normalization(self):
        """Eq. (6): r_i = q_lim,i / sum q_lim,j."""
        q = arr([0.2, 0.3, 0.5])
        p = long_term_probs(q, None, arr([True] * 3))
        np.testing.assert_allclose(p, [0.2, 0.3, 0.5], rtol=1e-6)

    def test_mask_renormalizes(self):
        q = arr([0.2, 0.3, 0.5])
        p = long_term_probs(q, None, arr([True, False, True]))
        np.testing.assert_allclose(p, [0.2 / 0.7, 0.0, 0.5 / 0.7], rtol=1e-6)

    def test_richer_device_preferred(self):
        q = arr([0.1, 0.6])
        p = long_term_probs(q, None, arr([True, True]))
        assert p[1] > p[0]


class TestAdaptive:
    def test_critical_devices_downweighted(self):
        """Alg. 1 line 25: PM1 devices scaled by z = alpha/N."""
        q = arr([0.25, 0.25, 0.25, 0.25])
        pm = arr([1, 2, 3, 2])  # device 0 critical
        p = adaptive_probs(q, pm, arr([True] * 4))
        # alpha = 1 critical device, N = 4 -> z = 1/4; x0 = 0.25 * 0.25.
        expected = np.array([0.0625, 0.25, 0.25, 0.25])
        expected /= expected.sum()
        np.testing.assert_allclose(p, expected, rtol=1e-5)
        assert p[0] < p[1]

    def test_no_critical_reduces_to_long_term(self):
        q = arr([0.2, 0.3, 0.5])
        pm = arr([2, 3, 2])
        p = adaptive_probs(q, pm, arr([True] * 3))
        np.testing.assert_allclose(p, [0.2, 0.3, 0.5], rtol=1e-5)

    def test_all_critical_reduces_to_long_term(self):
        """If every device is PM1, the z-scaling cancels after renorm."""
        q = arr([0.2, 0.8])
        pm = arr([1, 1])
        p = adaptive_probs(q, pm, arr([True, True]))
        np.testing.assert_allclose(p, [0.2, 0.8], rtol=1e-5)

    def test_explicit_alpha(self):
        q = arr([0.5, 0.5])
        pm = arr([1, 3])
        p = adaptive_probs(q, pm, arr([True, True]), alpha=2.0)
        # z = 2/2 = 1 -> no down-weighting.
        np.testing.assert_allclose(p, [0.5, 0.5], rtol=1e-5)

    def test_probability_simplex(self):
        q = arr([0.3, 0.1, 0.6])
        pm = arr([1, 1, 2])
        p = adaptive_probs(q, pm, arr([True, True, False]))
        assert float(jnp.sum(p)) == pytest.approx(1.0, abs=1e-6)
        assert float(p[2]) == 0.0
