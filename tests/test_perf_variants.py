"""Perf-variant parity: every §Perf optimization must be numerically
equivalent to its baseline (debug-forward, not revert — see EXPERIMENTS.md)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ShapeCell, get_smoke_config
from repro.models import build_model, init_from_template
from repro.models.inputs import make_inputs

CELL = ShapeCell("smoke", "train", seq_len=48, global_batch=2)


def build(name, **kw):
    cfg = dataclasses.replace(
        get_smoke_config(name), dtype="float32", param_dtype="float32", **kw
    )
    model = build_model(cfg)
    params = init_from_template(model.template, jax.random.PRNGKey(0), "float32")
    return cfg, model, params


def test_moe_gather_matches_einsum_dispatch():
    """Identical routing => identical outputs in the dropless regime, and
    equal outputs under drops too (same GShard position priority)."""
    for cap in (16.0, 1.0):  # dropless and capacity-dropping
        cfg_e, model_e, params = build("granite-moe-1b-a400m", capacity_factor=cap)
        cfg_g, model_g, _ = build(
            "granite-moe-1b-a400m", capacity_factor=cap, moe_impl="gather"
        )
        batch = make_inputs(cfg_e, CELL)
        le, _ = model_e.forward(params, batch)
        lg, _ = model_g.forward(params, batch)
        np.testing.assert_allclose(
            np.asarray(le), np.asarray(lg), rtol=2e-4, atol=2e-4,
            err_msg=f"capacity_factor={cap}",
        )


def test_decode_mulsum_matches_dot():
    cfg_d, model_d, params = build("qwen2.5-14b")
    cfg_m, model_m, _ = build("qwen2.5-14b", decode_mulsum=True)
    batch = make_inputs(cfg_d, CELL)
    tokens = batch["tokens"]
    S = tokens.shape[1]
    _, cache_d = model_d.prefill(params, dict(tokens=tokens[:, :-1]), S + 4)
    _, cache_m = model_m.prefill(params, dict(tokens=tokens[:, :-1]), S + 4)
    ld, _ = model_d.decode_step(params, tokens[:, -1:], cache_d)
    lm, _ = model_m.decode_step(params, tokens[:, -1:], cache_m)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(lm), rtol=2e-4, atol=2e-4)


def test_kv_stream_matches_baseline():
    """attn_kv_stream (chunk-sliced K/V, bf16 dot operands) == baseline."""
    cfg_b, model_b, params = build("phi4-mini-3.8b")
    cfg_s, model_s, _ = build("phi4-mini-3.8b", attn_kv_stream=True)
    batch = make_inputs(cfg_b, CELL)
    lb, _ = model_b.forward(params, batch)
    ls, _ = model_s.forward(params, batch)
    np.testing.assert_allclose(np.asarray(lb), np.asarray(ls), rtol=2e-4, atol=2e-4)


@pytest.mark.slow
def test_ring_index_matches_roll():
    """Hymba ring-buffer decode far past the window, both ring impls."""
    cfg_r, model_r, params = build("hymba-1.5b")
    cfg_i, model_i, _ = build("hymba-1.5b", ring_impl="index")
    S = 3 * cfg_r.attn_window + 5
    cell = ShapeCell("long", "train", seq_len=S, global_batch=1)
    batch = make_inputs(cfg_r, cell, seed=5)
    tokens = batch["tokens"]
    n_prompt = S - 6
    _, cache_r = model_r.prefill(params, dict(tokens=tokens[:, :n_prompt]), S + 4)
    _, cache_i = model_i.prefill(params, dict(tokens=tokens[:, :n_prompt]), S + 4)
    for t in range(n_prompt, S):
        lr, cache_r = model_r.decode_step(params, tokens[:, t : t + 1], cache_r)
        li, cache_i = model_i.decode_step(params, tokens[:, t : t + 1], cache_i)
        np.testing.assert_allclose(
            np.asarray(lr), np.asarray(li), rtol=5e-4, atol=5e-4,
            err_msg=f"position {t}",
        )
