"""int8 quantized KV pages: serving token-exactness (chunked == whole
at both kv dtypes — the whole-prompt int8 path prefills as one
whole-length chunk precisely so both read the same quantized pages),
the greedy-agreement accuracy sweep vs fp32-KV over >= 64 decode steps
for every ``supports_paged`` registry model, dtype-aware page math, and
constructor validation."""

import pathlib

import jax.numpy as jnp
import numpy as np
import pytest
from conftest import direct_greedy, tiny_model

from repro.configs import ARCH_NAMES, get_smoke_config
from repro.models.transformer import supports_paged
from repro.serving import PipelineServer, kv_page_bytes

# Teacher-forced argmax agreement floor, measured across every
# supports_paged smoke model at random init (the hardest case: logits
# are near-flat, so argmax gaps are at their smallest): observed range
# 0.898 (stablelm) .. 0.984 (qwen3-moe) over 64 steps x 2 lanes. The
# computation is deterministic, so 0.85 is margin, not flake budget.
AGREEMENT_TOL = 0.85


def _drain(server, reqs, limit=4000):
    for _ in range(limit):
        if all(r.done for r in reqs):
            return
        server.step()
    raise AssertionError("workload did not drain")


class TestInt8Serving:
    @pytest.mark.parametrize("kv_dtype", [None, "int8"])
    def test_chunked_token_exact_vs_whole_prefill(self, kv_dtype):
        """Acceptance: chunked paged prefill == whole-prompt paged
        prefill, token for token, at BOTH kv dtypes (int8 reads
        identical quantized pages on both paths)."""
        cfg, model, params = tiny_model()
        prompts = [
            (np.arange(L) * 3 + i) % cfg.vocab_size
            for i, L in enumerate([5, 9, 12])
        ]

        def serve(prefill_chunk):
            server = PipelineServer(
                model, params, n_groups=2, n_replicas=1,
                harvest_bounds=(50.0, 60.0), max_len=64, max_batch=4,
                paged=True, page_size=8, kv_dtype=kv_dtype,
                prefill_chunk=prefill_chunk, seed=3,
            )
            reqs = [server.submit(p, n_tokens=6) for p in prompts]
            _drain(server, reqs)
            return [r.generated for r in reqs]

        whole = serve(None)
        chunked = serve(4)
        assert whole == chunked
        if kv_dtype is None:
            # fp pages additionally match the monolithic reference.
            for gen, p in zip(whole, prompts):
                assert gen == direct_greedy(model, params, p, 6)

    def test_int8_pool_conservation_and_completion(self):
        """int8 pools run the same preemption machinery; pages stay
        conserved and nothing is lost under pool pressure."""
        cfg, model, params = tiny_model()
        server = PipelineServer(
            model, params, n_groups=1, n_replicas=1,
            harvest_bounds=(50.0, 60.0), max_len=64, max_batch=4,
            paged=True, page_size=4, max_pages=6, kv_dtype="int8",
            prefill_chunk=3, seed=0,
        )
        prompts = [(np.arange(6) + i) % cfg.vocab_size for i in range(3)]
        reqs = [server.submit(p, n_tokens=12) for p in prompts]
        for _ in range(4000):
            if all(r.done for r in reqs):
                break
            server.step()
            for mgr in server.managers.values():
                mgr.check_conservation()
        assert all(r.done for r in reqs)
        assert server.stats.dropped_jobs == 0
        for mgr in server.managers.values():
            assert mgr.pool.free_pages == mgr.pool.n_pages
            assert mgr.kv_dtype == "int8"

    def test_kv_dtype_requires_paged(self):
        cfg, model, params = tiny_model()
        with pytest.raises(ValueError, match="paged"):
            PipelineServer(model, params, n_groups=1, n_replicas=1,
                           kv_dtype="int8")
        with pytest.raises(ValueError, match="int8"):
            PipelineServer(model, params, n_groups=1, n_replicas=1,
                           paged=True, kv_dtype="float16")


class TestInt8DecodeKernel:
    def test_pallas_decode_matches_oracle_with_scales(self):
        """The paged decode kernel dequantizes in-kernel exactly as the
        gather oracle does (deterministic twin of the hypothesis
        property, which needs the test extra)."""
        from repro.kernels.decode_attention import (
            paged_decode_attention,
            paged_decode_attention_ref,
            quantize_kv,
        )

        rng = np.random.default_rng(1)
        B, KV, G, D, page, NB = 2, 2, 4, 8, 4, 5
        H = KV * G
        P = B * NB + 1
        k_pages = rng.normal(size=(P, page, KV, D)).astype(np.float32)
        v_pages = rng.normal(size=(P, page, KV, D)).astype(np.float32)
        bt = rng.permutation(P)[: B * NB].reshape(B, NB).astype(np.int32)
        q = rng.normal(size=(B, 1, H, D)).astype(np.float32)
        lens = np.array([3, NB * page], np.int32)
        qk, ks = quantize_kv(jnp.asarray(k_pages))
        qv, vs = quantize_kv(jnp.asarray(v_pages))
        out = paged_decode_attention(
            jnp.asarray(q), qk, qv, jnp.asarray(bt), jnp.asarray(lens),
            k_scales=ks, v_scales=vs, interpret=True,
        )
        ref = paged_decode_attention_ref(
            jnp.asarray(q), qk, qv, jnp.asarray(bt), jnp.asarray(lens),
            k_scales=ks, v_scales=vs,
        )
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-5
        )
        fp = paged_decode_attention(
            jnp.asarray(q), jnp.asarray(k_pages), jnp.asarray(v_pages),
            jnp.asarray(bt), jnp.asarray(lens), interpret=True,
        )
        assert float(np.max(np.abs(np.asarray(out) - np.asarray(fp)))) < 0.05


class TestPageBytes:
    def test_int8_page_math(self):
        """An int8 page costs values + one fp32 scale per row per pool;
        fp32 costs 4 bytes per entry — the ratio that sizes equal-byte
        pools in benchmarks/quant_kv_bench.py."""
        ps, kv, dh, nl = 16, 4, 16, 2
        fp = kv_page_bytes(ps, kv, dh, nl, "float32")
        i8 = kv_page_bytes(ps, kv, dh, nl, "int8")
        assert fp == 2 * nl * ps * kv * dh * 4
        assert i8 == 2 * nl * (ps * kv * dh + ps * 4)
        assert fp / i8 > 3  # ~3.76x more int8 pages per byte at fp32


def _greedy_agreement(name: str, n_steps: int = 64) -> float:
    """The ONE teacher-forced agreement harness — shared with
    ``benchmarks/quant_kv_bench.py`` so the accuracy sweep and the
    recorded bench number cannot drift apart."""
    import sys

    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
    try:
        from benchmarks.quant_kv_bench import greedy_agreement_for
    finally:
        sys.path.pop(0)
    return greedy_agreement_for(name, n_steps=n_steps)


def test_int8_greedy_agreement():
    """Fast lane: the weakest-agreement model from the sweep."""
    assert _greedy_agreement("stablelm-1.6b") >= AGREEMENT_TOL


@pytest.mark.slow
@pytest.mark.parametrize("name", ARCH_NAMES)
def test_int8_greedy_agreement_registry_sweep(name):
    """Acceptance: >= 64 teacher-forced decode steps of greedy-token
    agreement vs fp32-KV for every supports_paged registry model."""
    cfg = get_smoke_config(name)
    if not supports_paged(cfg):
        pytest.skip(f"{name}: no uniform full attention; serves dense")
    assert _greedy_agreement(name) >= AGREEMENT_TOL
