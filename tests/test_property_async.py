"""Property-based cross-engine differential test (hypothesis).

Randomized traces — cache layout x KV dtype x async depth x power mode
x arrival pattern — driven through the synchronous engine
(``async_depth=0``) and the async engine must produce identical token
streams, and ``async_depth=1`` must degenerate to the sync engine
*exactly* (same ServerStats, not just same tokens).

Skipped cleanly when hypothesis is not installed (the container image
does not bake it in); the deterministic trace matrix in
``test_async_engine.py`` covers the named configurations either way.
"""

import dataclasses

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from conftest import tiny_model  # noqa: E402

from repro.core.power import fixed_policy  # noqa: E402
from repro.serving import PipelineServer  # noqa: E402

MODEL = None


def _model():
    global MODEL
    if MODEL is None:
        MODEL = tiny_model()
    return MODEL


# One trace shape: every degree of freedom the async refactor touches.
TRACES = st.fixed_dictionaries(
    {
        "paged": st.booleans(),
        "int8": st.booleans(),  # applied only when paged
        "prefill_chunk": st.sampled_from([None, 4]),
        "kappa_pm": st.integers(min_value=0, max_value=2),
        "staggered": st.booleans(),
        "n_requests": st.integers(min_value=2, max_value=5),
        "n_tokens": st.integers(min_value=1, max_value=4),
        "seed": st.integers(min_value=0, max_value=3),
    }
)


def _run(depth: int, t: dict):
    cfg, model, params = _model()
    server = PipelineServer(
        model,
        params,
        n_groups=2,
        n_replicas=2,
        policy="uniform",
        pm_policy=fixed_policy(t["kappa_pm"]),
        harvest_bounds=(60.0, 80.0),
        max_len=64,
        max_batch=4,
        paged=t["paged"],
        page_size=8,
        kv_dtype="int8" if (t["paged"] and t["int8"]) else None,
        prefill_chunk=t["prefill_chunk"],
        async_depth=depth,
        seed=t["seed"],
    )
    reqs = []
    steps = 0
    n_sub = 0
    while n_sub < t["n_requests"] or not all(
        r.done or r.dropped for r in reqs
    ):
        while n_sub < t["n_requests"]:
            req = server.submit(
                (np.arange(4 + n_sub) + n_sub) % cfg.vocab_size,
                t["n_tokens"],
            )
            if req is not None:
                reqs.append(req)
            n_sub += 1
            if t["staggered"]:
                break
        server.step()
        steps += 1
        assert steps < 5000, "trace did not drain"
    return [tuple(r.generated) for r in reqs], server.stats


@pytest.mark.slow
class TestAsyncProperty:
    @settings(
        max_examples=20,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(trace=TRACES, depth=st.integers(min_value=1, max_value=3))
    def test_async_tokens_equal_sync(self, trace, depth):
        sync_tokens, _ = _run(0, trace)
        async_tokens, _ = _run(depth, trace)
        assert async_tokens == sync_tokens

    @settings(
        max_examples=10,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(trace=TRACES)
    def test_depth1_is_sync_exactly(self, trace):
        sync_tokens, sync_stats = _run(0, trace)
        d1_tokens, d1_stats = _run(1, trace)
        assert d1_tokens == sync_tokens
        assert dataclasses.asdict(d1_stats) == dataclasses.asdict(sync_stats)
