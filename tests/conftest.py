"""Shared serving-test helpers (imported by test_serving / test_paged_cache)."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model, init_from_template


@pytest.fixture(autouse=True, scope="module")
def _release_compiled_executables():
    """Drop XLA's compiled-executable caches after every test module.

    The CPU JIT keeps ~3-4 mmap regions live per compiled executable for
    the life of the process; the full tier-1 suite compiles enough
    distinct shapes that a single pytest process crosses the kernel's
    default ``vm.max_map_count`` (65530) and XLA segfaults mid-compile.
    Within-module sharing is untouched — only cross-module reuse (a few
    conftest helpers) recompiles."""
    yield
    jax.clear_caches()


def tiny_model(name="stablelm-1.6b"):
    cfg = dataclasses.replace(
        get_smoke_config(name), dtype="float32", param_dtype="float32"
    )
    model = build_model(cfg)
    params = init_from_template(model.template, jax.random.PRNGKey(0), "float32")
    return cfg, model, params


def direct_greedy(model, params, prompt, n_tokens, max_len=64):
    """Monolithic greedy decode — the token-exact reference."""
    logits, cache = model.prefill(params, {"tokens": jnp.asarray(prompt)[None]}, max_len)
    toks = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(n_tokens - 1):
        logits, cache = model.decode_step(params, jnp.asarray([[toks[-1]]]), cache)
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks
