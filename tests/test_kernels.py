"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
swept across shapes and dtypes per the deliverable-(c) requirement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import decode_attention, decode_attention_ref
from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.rmsnorm import rmsnorm, rmsnorm_ref
from repro.kernels.selective_scan import selective_scan, selective_scan_ref


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "B,Sq,Skv,H,KV,D,causal,window",
        [
            (2, 64, 64, 4, 2, 32, True, None),  # GQA causal
            (1, 96, 96, 4, 4, 64, True, None),  # MHA
            (2, 64, 64, 8, 1, 32, True, None),  # MQA
            (1, 100, 100, 4, 4, 16, True, None),  # ragged tail (padding)
            (2, 64, 64, 4, 2, 32, False, None),  # bidirectional (encoder)
            (1, 128, 128, 2, 2, 32, True, 48),  # sliding window
            (1, 160, 160, 5, 1, 32, True, 64),  # window + MQA + ragged
        ],
    )
    def test_matches_oracle(self, B, Sq, Skv, H, KV, D, causal, window, dtype):
        ks = jax.random.split(jax.random.PRNGKey(42), 3)
        q = rand(ks[0], (B, Sq, H, D), dtype)
        k = rand(ks[1], (B, Skv, KV, D), dtype)
        v = rand(ks[2], (B, Skv, KV, D), dtype)
        out = flash_attention(
            q, k, v, causal=causal, window=window,
            block_q=32, block_kv=32, interpret=True,
        )
        ref = flash_attention_ref(q, k, v, causal=causal, window=window)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32), **tol(dtype)
        )

    @pytest.mark.parametrize("block_q,block_kv", [(16, 16), (32, 64), (64, 32)])
    def test_block_shape_invariance(self, block_q, block_kv):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = rand(ks[0], (1, 128, 4, 32), jnp.float32)
        k = rand(ks[1], (1, 128, 2, 32), jnp.float32)
        v = rand(ks[2], (1, 128, 2, 32), jnp.float32)
        out = flash_attention(
            q, k, v, block_q=block_q, block_kv=block_kv, interpret=True
        )
        ref = flash_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


class TestDecodeAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "B,S,H,KV,D,length,window,chunk",
        [
            (2, 256, 4, 2, 32, 256, None, 64),  # full cache
            (2, 256, 4, 2, 32, 100, None, 64),  # partial cache
            (1, 512, 8, 1, 64, 300, None, 128),  # MQA long
            (2, 256, 4, 4, 32, 200, 64, 64),  # sliding window
            (1, 130, 2, 2, 16, 77, None, 64),  # ragged chunks
        ],
    )
    def test_matches_oracle(self, B, S, H, KV, D, length, window, chunk, dtype):
        ks = jax.random.split(jax.random.PRNGKey(7), 3)
        q = rand(ks[0], (B, 1, H, D), dtype)
        kc = rand(ks[1], (B, S, KV, D), dtype)
        vc = rand(ks[2], (B, S, KV, D), dtype)
        lengths = jnp.array([length] * B, jnp.int32)
        out = decode_attention(
            q, kc, vc, lengths, window=window, chunk=chunk, interpret=True
        )
        ref = decode_attention_ref(q, kc, vc, lengths, window=window)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32), **tol(dtype)
        )

    def test_per_sequence_lengths(self):
        """Continuous batching: each row has its own cache length."""
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        B, S, H, KV, D = 4, 128, 4, 2, 32
        q = rand(ks[0], (B, 1, H, D), jnp.float32)
        kc = rand(ks[1], (B, S, KV, D), jnp.float32)
        vc = rand(ks[2], (B, S, KV, D), jnp.float32)
        lengths = jnp.array([1, 37, 100, 128], jnp.int32)
        out = decode_attention(q, kc, vc, lengths, chunk=32, interpret=True)
        ref = decode_attention_ref(q, kc, vc, lengths)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


class TestRMSNorm:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("R,D", [(8, 128), (100, 256), (1, 512), (300, 64)])
    def test_matches_oracle(self, R, D, dtype):
        ks = jax.random.split(jax.random.PRNGKey(1), 2)
        x = rand(ks[0], (R, D), dtype)
        w = rand(ks[1], (D,), jnp.float32) * 0.1 + 1.0
        out = rmsnorm(x, w, block_rows=32, interpret=True)
        ref = rmsnorm_ref(x, w)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32), **tol(dtype)
        )

    def test_3d_input(self):
        ks = jax.random.split(jax.random.PRNGKey(2), 2)
        x = rand(ks[0], (2, 17, 128), jnp.float32)
        w = jnp.ones((128,), jnp.float32)
        out = rmsnorm(x, w, interpret=True)
        ref = rmsnorm_ref(x, w)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


class TestSelectiveScan:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "B,S,Din,N,chunk,block_d",
        [
            (2, 64, 32, 8, 16, 16),
            (1, 100, 48, 16, 32, 48),  # ragged seq
            (2, 128, 64, 4, 128, 32),  # single chunk
            (1, 96, 40, 8, 16, 64),  # block_d > Din
        ],
    )
    def test_matches_oracle(self, B, S, Din, N, chunk, block_d, dtype):
        ks = jax.random.split(jax.random.PRNGKey(5), 5)
        x = rand(ks[0], (B, S, Din), dtype)
        dt = jax.nn.softplus(rand(ks[1], (B, S, Din), jnp.float32))
        Bm = rand(ks[2], (B, S, N), jnp.float32)
        Cm = rand(ks[3], (B, S, N), jnp.float32)
        A = -jnp.exp(rand(ks[4], (Din, N), jnp.float32) * 0.5)
        y, h = selective_scan(
            x, dt, Bm, Cm, A, chunk=chunk, block_d=block_d, interpret=True
        )
        y_ref, h_ref = selective_scan_ref(x, dt, Bm, Cm, A)
        np.testing.assert_allclose(
            np.asarray(y, np.float32), np.asarray(y_ref, np.float32),
            **(dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-4, atol=1e-4)),
        )
        np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), rtol=1e-3, atol=1e-3)

    def test_initial_state_carried(self):
        """Scanning [x1; x2] == scan(x2, h0=scan(x1).h)."""
        ks = jax.random.split(jax.random.PRNGKey(9), 5)
        B, S, Din, N = 1, 64, 16, 4
        x = rand(ks[0], (B, S, Din), jnp.float32)
        dt = jax.nn.softplus(rand(ks[1], (B, S, Din), jnp.float32))
        Bm = rand(ks[2], (B, S, N), jnp.float32)
        Cm = rand(ks[3], (B, S, N), jnp.float32)
        A = -jnp.exp(rand(ks[4], (Din, N), jnp.float32) * 0.5)
        y_full, h_full = selective_scan(x, dt, Bm, Cm, A, chunk=16, interpret=True)
        half = S // 2
        _, h1 = selective_scan(
            x[:, :half], dt[:, :half], Bm[:, :half], Cm[:, :half], A,
            chunk=16, interpret=True,
        )
        y2, h2 = selective_scan(
            x[:, half:], dt[:, half:], Bm[:, half:], Cm[:, half:], A, h1,
            chunk=16, interpret=True,
        )
        np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(
            np.asarray(y2), np.asarray(y_full[:, half:]), rtol=1e-4, atol=1e-4
        )
