"""Static-analysis subsystem tests: jaxpr walker, budget resolution,
rule registry, recompile/host-sync gates, and the CLI.

The injected-violation tests are the acceptance criteria: a gather in a
Pallas paged path, a forced per-step host sync, or a reintroduced
prompt-length-dependent re-jit must each fail with a named rule and
entry point."""

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import tiny_model

from repro.analysis import (
    EntryPoint,
    Finding,
    HostSyncError,
    TransferSanitizer,
    build_entry_points,
    check_trace_budgets,
    count_primitive,
    host_readback,
    iter_eqns,
    load_budgets,
    primitive_counts,
    register_rule,
    resolve_budget,
    run_static_rules,
)
from repro.analysis import rules as rules_mod
from repro.analysis.cli import main as cli_main


def entry_for(fn, *args, name="toy:kind:variant"):
    """A lint entry point over an ad-hoc traced function."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    model, kind, variant = name.split(":")
    return EntryPoint(name, model, kind, variant, lambda: jaxpr)


class TestWalker:
    def test_counts_toplevel(self):
        j = jax.make_jaxpr(lambda x: jnp.sin(jnp.sin(x)))(1.0)
        assert count_primitive(j, "sin") == 2
        assert primitive_counts(j)["sin"] == 2

    def test_recurses_into_scan(self):
        def f(x):
            return jax.lax.scan(lambda c, _: (jnp.sin(c), ()), x, None, length=3)[0]

        j = jax.make_jaxpr(f)(1.0)
        assert count_primitive(j, "sin") == 1
        paths = [p for p, e in iter_eqns(j) if e.primitive.name == "sin"]
        assert paths == [("scan",)]

    def test_recurses_into_cond(self):
        def f(x):
            return jax.lax.cond(x > 0, jnp.sin, jnp.cos, x)

        j = jax.make_jaxpr(f)(1.0)
        assert count_primitive(j, "sin") == 1
        assert count_primitive(j, "cos") == 1

    def test_recurses_into_pallas_kernel_body(self):
        """pallas_call carries a raw (non-closed) kernel jaxpr — the
        walker must descend into it."""
        from repro.kernels.decode_attention import PALLAS_PAGED_KERNELS

        fn = PALLAS_PAGED_KERNELS["paged_decode_attention"]
        B, KV, G, D, page, NB = 2, 2, 2, 8, 8, 3
        j = jax.make_jaxpr(fn)(
            jax.ShapeDtypeStruct((B, 1, KV * G, D), jnp.float32),
            jax.ShapeDtypeStruct((B * NB + 1, page, KV, D), jnp.float32),
            jax.ShapeDtypeStruct((B * NB + 1, page, KV, D), jnp.float32),
            jax.ShapeDtypeStruct((B, NB), jnp.int32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
        )
        assert count_primitive(j, "pallas_call") == 1
        inside = [p for p, e in iter_eqns(j) if "pallas_call" in p]
        assert inside  # kernel body equations were visited
        assert count_primitive(j, "gather") == 0


class TestBudgets:
    def test_default_budgets_load(self):
        b = load_budgets()
        for section in ("primitive_budgets", "host_sync", "dtype_promotion",
                        "trace_budgets"):
            assert section in b
        assert b["primitive_budgets"]  # real ceilings, not placeholders

    def test_resolve_merges_in_file_order(self):
        section = {
            "*": {"gather": 5, "scatter": 1},
            "m:*": {"gather": 2},
            "m:decode:pallas": {"gather": 0},
        }
        assert resolve_budget(section, "other:x:y") == {"gather": 5, "scatter": 1}
        assert resolve_budget(section, "m:prefill:xla") == {"gather": 2, "scatter": 1}
        assert resolve_budget(section, "m:decode:pallas") == {"gather": 0, "scatter": 1}

    def test_no_match_is_empty(self):
        assert resolve_budget({"a:*": {"gather": 1}}, "b:x:y") == {}


class TestStaticRules:
    def test_primitive_budget_violation_names_rule_and_entry(self):
        def two_gathers(x, idx):
            return jnp.take(x, idx) + jnp.take(x, idx + 1)

        e = entry_for(
            two_gathers, jnp.zeros((8,)), jnp.asarray([2, 3]),
            name="toy:decode:pallas",
        )
        budgets = {"primitive_budgets": {"toy:decode:pallas": {"gather": 1}}}
        findings = run_static_rules([e], budgets, rules=["primitive-budget"])
        assert len(findings) == 1
        f = findings[0]
        assert f.rule == "primitive-budget"
        assert f.entry_point == "toy:decode:pallas"
        assert f.measured == 2 and f.budget == 1
        assert "gather" in str(f)

    def test_within_budget_is_clean(self):
        e = entry_for(lambda x: x + 1, jnp.zeros((4,)))
        budgets = {"primitive_budgets": {"*": {"gather": 0}}}
        assert run_static_rules([e], budgets, rules=["primitive-budget"]) == []

    def test_host_sync_flags_debug_callback(self):
        def leaky(x):
            jax.debug.print("x={x}", x=x)
            return x * 2

        e = entry_for(leaky, jnp.zeros((2,)), name="toy:decode:dense")
        findings = run_static_rules([e], {}, rules=["host-sync"])
        assert findings and findings[0].rule == "host-sync"
        assert findings[0].entry_point == "toy:decode:dense"

    def test_dtype_promotion_over_budget(self):
        def upcasts(x):
            return x.astype(jnp.float32).sum() + x.astype(jnp.float32).prod()

        e = entry_for(upcasts, jnp.zeros((4,), jnp.bfloat16), name="toy:p:d")
        budgets = {"dtype_promotion": {"budgets": {"toy:*": {"max_upcasts": 1}}}}
        findings = run_static_rules([e], budgets, rules=["dtype-promotion"])
        assert findings and findings[0].rule == "dtype-promotion"
        assert findings[0].measured == 2 and findings[0].budget == 1

    def test_dtype_promotion_unbudgeted_entry_skipped(self):
        e = entry_for(
            lambda x: x.astype(jnp.float32), jnp.zeros((4,), jnp.bfloat16)
        )
        assert run_static_rules([e], {}, rules=["dtype-promotion"]) == []

    def test_register_rule_runs(self):
        name = "test-only-rule"
        try:
            @register_rule(name, "always fires")
            def always(entry, budgets):
                return [Finding(name, entry.name, "boom")]

            e = entry_for(lambda x: x, 1.0)
            findings = run_static_rules([e], {}, rules=[name])
            assert [f.rule for f in findings] == [name]
        finally:
            rules_mod.RULES.pop(name, None)


class TestInjectedGather:
    """Acceptance: a pool gather injected into a Pallas paged path fails
    the default budgets with the rule and entry point named."""

    def test_gather_injected_into_pallas_paged_path(self):
        cfg, model, _ = tiny_model()
        cfg = dataclasses.replace(cfg, attn_impl="pallas")
        from repro.models import build_model as _build

        model = _build(cfg)
        from repro.models.common import abstract_params

        W, NB, page, P = 4, 4, 16, 16
        params = abstract_params(model.template, cfg.param_dtype)
        tok = jax.ShapeDtypeStruct((W, 1), jnp.int32)
        pools = {
            "k": jax.ShapeDtypeStruct(
                (cfg.n_layers, P + 1, page, cfg.n_kv_heads, cfg.head_dim),
                jnp.dtype(cfg.dtype)),
            "v": jax.ShapeDtypeStruct(
                (cfg.n_layers, P + 1, page, cfg.n_kv_heads, cfg.head_dim),
                jnp.dtype(cfg.dtype)),
        }
        lens = jax.ShapeDtypeStruct((W,), jnp.int32)
        bt = jax.ShapeDtypeStruct((W, NB), jnp.int32)

        def with_injected_gather(p, t, pl, ln, b):
            # The regression under test: materializing pool pages with an
            # XLA gather instead of walking them inside the kernel.
            leaked = jnp.take(pl["k"], b.reshape(-1), axis=1)
            out, pl2 = model.decode_paged(p, t, pl, ln, b)
            return out + leaked.sum().astype(out.dtype) * 0, pl2

        jaxpr = jax.make_jaxpr(with_injected_gather)(params, tok, pools, lens, bt)
        name = "stablelm-1.6b:decode_step_paged:pallas"
        e = EntryPoint(name, "stablelm-1.6b", "decode_step_paged", "pallas",
                       lambda: jaxpr)
        findings = run_static_rules([e], load_budgets(), rules=["primitive-budget"])
        gather = [f for f in findings if "gather" in f.message]
        assert gather, "injected gather must fail the default budgets"
        assert gather[0].rule == "primitive-budget"
        assert gather[0].entry_point == name
        assert gather[0].measured > gather[0].budget == 2


class TestRecompileGate:
    def test_shape_dependent_rejit_flagged(self):
        """Synthetic trace_counts with a prompt-length-keyed chunk
        dispatch: two compiled shapes for one stage -> finding."""
        counts = {
            ("chunk", 0, 4, 8): 3,
            ("chunk", 0, 4, 12): 2,  # second shape: length-keyed re-jit
            ("decode", 0, 4): 5,
        }
        budgets = {"trace_budgets": {"chunk": {"max_shapes_per_stage": 1},
                                     "decode": {"max_shapes_per_stage": 1}}}
        findings = check_trace_budgets(counts, budgets, context="dense")
        assert len(findings) == 1
        f = findings[0]
        assert f.rule == "recompile-budget"
        assert f.entry_point == "dense:chunk:stage0"
        assert f.measured == 2 and f.budget == 1

    def test_within_budget_clean(self):
        counts = {("decode", 0, 4): 9, ("decode", 1, 4): 9}
        budgets = {"trace_budgets": {"decode": {"max_shapes_per_stage": 1}}}
        assert check_trace_budgets(counts, budgets) == []


class TestSanitizer:
    def test_host_readback_inactive_is_plain_asarray(self):
        out = host_readback(jnp.arange(3))
        assert isinstance(out, np.ndarray)
        assert out.tolist() == [0, 1, 2]

    def test_counts_sanctioned_per_step(self):
        x = jnp.arange(4.0)
        with TransferSanitizer() as san:
            host_readback(x)
            host_readback(x)
            san.mark_step()
            host_readback(x)
            san.mark_step()
        assert san.per_step == [2, 1]
        assert san.max_per_step == 2
        assert san.sanctioned_total == 3
        assert san.unsanctioned_total == 0

    def test_unsanctioned_int_counted(self):
        x = jnp.asarray(7)
        with TransferSanitizer() as san:
            assert int(x) == 7
        assert san.unsanctioned_total == 1

    def test_strict_raises_on_unsanctioned(self):
        x = jnp.asarray(1.0)
        with TransferSanitizer(strict=True):
            with pytest.raises(HostSyncError):
                float(x)

    def test_no_nesting(self):
        with TransferSanitizer():
            with pytest.raises(RuntimeError):
                TransferSanitizer().__enter__()

    def test_trailing_partial_step_flushed(self):
        with TransferSanitizer() as san:
            host_readback(jnp.zeros(()))
        assert san.per_step == [1]


@pytest.mark.slow
class TestEngineSyncRegression:
    """Satellite acceptance: dense and paged replica-steps stay within
    the per-step device->host budget, and the count is exactly the
    batched-argmax-readback minimum (one sanctioned sync per last-stage
    dispatch, nothing unsanctioned)."""

    def _server(self, paged):
        from repro.serving import PipelineServer

        cfg, model, params = tiny_model()
        server = PipelineServer(
            model, params, n_groups=1, n_replicas=1, policy="uniform",
            harvest_bounds=(60.0, 80.0), max_len=64, max_batch=4,
            paged=paged, page_size=8, prefill_chunk=4, seed=0,
        )
        return cfg, server

    def _drain(self, server, cfg, n_requests=4, n_tokens=3):
        reqs = [
            server.submit((np.arange(4 + 2 * (i % 2)) + i) % cfg.vocab_size,
                          n_tokens=n_tokens)
            for i in range(n_requests)
        ]
        while not all(r.done for r in reqs):
            server.step()

    @pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
    def test_step_syncs_at_argmax_minimum(self, paged):
        budgets = load_budgets()
        budget = budgets["host_sync"]["per_step_budget"]["paged" if paged else "dense"]
        cfg, server = self._server(paged)
        self._drain(server, cfg)  # warmup: compile all dispatch shapes
        st = server.stats
        calls_before = (st.prefill_calls + st.chunk_prefill_calls
                        + st.decode_calls)
        with TransferSanitizer() as san:
            self._drain(server, cfg)
        calls = (st.prefill_calls + st.chunk_prefill_calls
                 + st.decode_calls) - calls_before
        assert san.unsanctioned_total == 0
        assert san.max_per_step <= budget
        # G=1: every dispatch is the last stage -> exactly one batched
        # argmax readback each. Any extra per-step sync fails here.
        assert san.sanctioned_total == calls


class TestCli:
    def test_list(self, capsys):
        assert cli_main(["--list", "--models", "stablelm-1.6b"]) == 0
        out = capsys.readouterr().out
        assert "primitive-budget" in out
        assert "stablelm-1.6b:decode_step_paged:pallas" in out

    def test_static_check_passes_and_reports(self, tmp_path, capsys):
        report_path = tmp_path / "report.json"
        rc = cli_main([
            "--check", "--static-only", "--models", "stablelm-1.6b",
            "--no-kernels", "--json", str(report_path),
        ])
        assert rc == 0
        report = json.loads(report_path.read_text())
        assert report["passed"] is True
        assert report["findings"] == []
        assert "stablelm-1.6b:decode_step_paged:pallas" in report[
            "entry_points_checked"]

    def test_tightened_budgets_fail_with_named_finding(self, tmp_path, capsys):
        budgets = load_budgets()
        tight = json.loads(json.dumps(budgets))
        tight["primitive_budgets"]["*:decode_step_paged:pallas"]["gather"] = 0
        path = tmp_path / "tight.json"
        path.write_text(json.dumps(tight))
        report_path = tmp_path / "report.json"
        rc = cli_main([
            "--check", "--static-only", "--models", "stablelm-1.6b",
            "--no-kernels", "--budgets", str(path), "--json", str(report_path),
        ])
        assert rc == 1
        report = json.loads(report_path.read_text())
        assert report["passed"] is False
        rules = {f["rule"] for f in report["findings"]}
        entries = {f["entry_point"] for f in report["findings"]}
        assert "primitive-budget" in rules
        assert "stablelm-1.6b:decode_step_paged:pallas" in entries
        out = capsys.readouterr().out
        assert "FAIL [primitive-budget] stablelm-1.6b:decode_step_paged:pallas" in out

    def test_unknown_rule_rejected(self):
        with pytest.raises(SystemExit):
            cli_main(["--check", "--rules", "nope"])
