"""Per-architecture smoke tests: reduced same-family configs, one
forward / prefill / decode step on CPU; output shapes + finiteness; and
prefill+decode vs teacher-forcing consistency (exercises every cache
path: full KV, ring-buffer SWA, SSM states, cross-attention)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, ShapeCell, get_smoke_config
from repro.models import build_model, count_params, init_from_template
from repro.models.inputs import make_inputs

SMOKE_CELL = ShapeCell("smoke", "train", seq_len=32, global_batch=2)


def fp32(cfg):
    """Run smoke numerics in fp32 for tight decode-consistency checks."""
    return dataclasses.replace(cfg, dtype="float32", param_dtype="float32")


def build(name):
    cfg = fp32(get_smoke_config(name))
    model = build_model(cfg)
    params = init_from_template(model.template, jax.random.PRNGKey(0), cfg.param_dtype)
    return cfg, model, params


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_and_finite(name):
    cfg, model, params = build(name)
    batch = make_inputs(cfg, SMOKE_CELL)
    logits, aux = model.forward(params, batch)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert np.isfinite(float(aux["lb_loss"]))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_param_count_positive(name):
    cfg, model, _ = build(name)
    n = count_params(model.template)
    assert n > 10_000  # reduced but real


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_decode_matches_forward(name):
    """logits(prefill S-1) + decode(token S-1) == forward(S)[:, -1].

    MoE archs run with a generous capacity factor: capacity-based token
    dropping is batch-shape dependent by design, so exact consistency is
    only defined in the dropless regime.
    """
    cfg = fp32(get_smoke_config(name))
    if cfg.is_moe:
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    model = build_model(cfg)
    params = init_from_template(model.template, jax.random.PRNGKey(0), cfg.param_dtype)
    batch = make_inputs(cfg, SMOKE_CELL)
    tokens = batch["tokens"]
    B, S = tokens.shape

    full_logits, _ = model.forward(params, batch)

    prompt = dict(batch, tokens=tokens[:, : S - 1])
    if "patch_embeds" in prompt:
        P = prompt["patch_embeds"].shape[1]
        assert P <= S - 1
    logits_p, cache = model.prefill(params, prompt, S + 8)
    np.testing.assert_allclose(
        np.asarray(logits_p[:, 0]),
        np.asarray(full_logits[:, S - 2]),
        rtol=2e-4,
        atol=2e-4,
    )

    logits_d, cache2 = model.decode_step(params, tokens[:, S - 1 :], cache)
    np.testing.assert_allclose(
        np.asarray(logits_d[:, 0]),
        np.asarray(full_logits[:, S - 1]),
        rtol=2e-4,
        atol=2e-4,
    )
    assert int(cache2["len"]) == S


@pytest.mark.slow
def test_hymba_ring_buffer_consistency():
    """Decode far past the window: ring cache must equal teacher forcing."""
    cfg = fp32(get_smoke_config("hymba-1.5b"))  # window 16
    model = build_model(cfg)
    params = init_from_template(model.template, jax.random.PRNGKey(1), cfg.param_dtype)
    S = 3 * cfg.attn_window + 5  # far beyond one window
    cell = ShapeCell("long-smoke", "train", seq_len=S, global_batch=1)
    batch = make_inputs(cfg, cell, seed=3)
    tokens = batch["tokens"]

    full_logits, _ = model.forward(params, batch)

    n_prompt = S - 4
    logits_p, cache = model.prefill(params, dict(tokens=tokens[:, :n_prompt]), S + 4)
    np.testing.assert_allclose(
        np.asarray(logits_p[:, 0]),
        np.asarray(full_logits[:, n_prompt - 1]),
        rtol=5e-4,
        atol=5e-4,
    )
    for t in range(n_prompt, S):
        logits_d, cache = model.decode_step(params, tokens[:, t : t + 1], cache)
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0]),
            np.asarray(full_logits[:, t]),
            rtol=5e-4,
            atol=5e-4,
            err_msg=f"decode step at position {t}",
        )


def test_moe_all_tokens_routed_with_high_capacity():
    cfg = fp32(get_smoke_config("granite-moe-1b-a400m"))
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    model = build_model(cfg)
    params = init_from_template(model.template, jax.random.PRNGKey(0), cfg.param_dtype)
    batch = make_inputs(cfg, SMOKE_CELL)
    _, aux = model.forward(params, batch)
    # With generous capacity nothing is dropped.
    assert float(aux["lb_loss"]) > 0.0


def test_vlm_patches_change_output():
    cfg, model, params = build("internvl2-76b")
    batch = make_inputs(cfg, SMOKE_CELL)
    logits1, _ = model.forward(params, batch)
    batch2 = dict(batch, patch_embeds=batch["patch_embeds"] + 1.0)
    logits2, _ = model.forward(params, batch2)
    assert not np.allclose(np.asarray(logits1), np.asarray(logits2))
