"""Benchmark-record schema check (tier-1): every ``BENCH_*.json`` at
the repo root shares the common envelope ``{name, commit, metrics{}}``
written by :func:`benchmarks.common.write_bench`, so
``benchmarks/run.py --summary`` can aggregate the perf trajectory."""

import json
import pathlib

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
SCHEMA_KEYS = {"name", "commit", "metrics"}


def _records():
    paths = sorted(REPO_ROOT.glob("BENCH_*.json"))
    assert paths, "no BENCH_*.json records at the repo root"
    return [(p, json.loads(p.read_text())) for p in paths]


def test_every_bench_record_has_the_envelope():
    for path, data in _records():
        assert set(data) == SCHEMA_KEYS, (
            f"{path.name}: expected exactly {sorted(SCHEMA_KEYS)}, "
            f"got {sorted(data)}"
        )
        assert isinstance(data["name"], str) and data["name"]
        assert isinstance(data["commit"], str) and data["commit"]
        assert isinstance(data["metrics"], dict) and data["metrics"]


def test_bench_names_are_unique():
    names = [data["name"] for _, data in _records()]
    assert len(names) == len(set(names)), names


def test_summary_aggregates_every_record(capsys):
    """--summary prints one block per record with headline metrics."""
    import sys

    sys.path.insert(0, str(REPO_ROOT))
    try:
        from benchmarks.run import summary
    finally:
        sys.path.pop(0)
    summary()
    out = capsys.readouterr().out
    for _, data in _records():
        assert f"{data['name']} @ {data['commit']}" in out
