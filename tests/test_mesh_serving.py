"""Mesh-sharded serving tests.

Three layers:

* ``SERVE_RULES`` invariants — params never shard over (pod, data), KV
  cache/pool leaves shard only on ``cache_batch``, rules whose mesh
  axes are absent are dropped — checked on the degenerate host mesh, a
  forced-8-device serving mesh, and with no mesh at all;
* production/serving mesh factoring — shapes derive from the visible
  device count with clear errors instead of hardcoded-shape crashes;
* differential token exactness — the tensor-parallel engine (params
  placed with ``SERVE_RULES``, caches committed to per-replica
  submeshes) must reproduce the single-device token stream bit-for-bit
  on both the dense and paged substrates.

The forced-device tests need
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI mesh
lane); elsewhere they skip.
"""

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from conftest import direct_greedy, tiny_model
from repro.distributed.sharding import (
    DEFAULT_RULES,
    SERVE_RULES,
    divisible_spec,
    param_shardings,
    replica_submeshes,
    serve_cache_spec,
)
from repro.launch.mesh import (
    make_host_mesh,
    make_production_mesh,
    make_serving_mesh,
)
from repro.serving import PipelineServer

N_DEV = jax.device_count()
forced8 = pytest.mark.skipif(
    N_DEV < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8",
)


def _param_specs(mesh):
    cfg, model, params = tiny_model()
    shardings = param_shardings(model.template, mesh, SERVE_RULES)
    return [
        s.spec for s in jax.tree_util.tree_leaves(shardings)
    ]


class TestServeRules:
    def test_embed_fsdp_dropped(self):
        """Serving has no FSDP: the vocab/embed gather must stay local."""
        assert DEFAULT_RULES["embed_fsdp"] == "data"
        assert SERVE_RULES["embed_fsdp"] is None

    def test_params_never_use_pod_or_data_host_mesh(self):
        for spec in _param_specs(make_host_mesh()):
            flat = {a for part in spec for a in (
                part if isinstance(part, tuple) else (part,)
            ) if part is not None}
            assert "data" not in flat and "pod" not in flat, spec

    @forced8
    def test_params_never_use_pod_or_data_forced_mesh(self):
        mesh = make_serving_mesh(model_axis=4, data_axis=2)
        for spec in _param_specs(mesh):
            flat = {a for part in spec for a in (
                part if isinstance(part, tuple) else (part,)
            ) if part is not None}
            assert "data" not in flat and "pod" not in flat, spec

    @forced8
    def test_params_do_use_model_axis(self):
        """Replication-only would vacuously pass the test above: at
        least one param leaf must actually shard over model."""
        mesh = make_serving_mesh(model_axis=4, data_axis=2)
        assert any("model" in tuple(spec) for spec in _param_specs(mesh))

    def test_cache_spec_masks_all_but_cache_batch_host(self):
        m = make_host_mesh()
        spec = serve_cache_spec(
            (4, 8, 64, 16), ("cache_batch", "kv_heads", "cache_seq", "head_dim"), m
        )
        assert all(a in (None, "data", ("pod", "data")) for a in tuple(spec))

    @forced8
    def test_cache_spec_masks_all_but_cache_batch_forced(self):
        mesh = make_serving_mesh(model_axis=4, data_axis=2)
        spec = serve_cache_spec(
            (4, 8, 64, 16), ("cache_batch", "kv_heads", "cache_seq", "head_dim"), mesh
        )
        # kv_heads would map to model under SERVE_RULES — masked out.
        assert "model" not in {
            a for part in tuple(spec)
            for a in (part if isinstance(part, tuple) else (part,))
        }

    def test_cache_spec_model_only_submesh_replicates(self):
        """No rule target for cache_batch on a model-only mesh: the
        whole leaf replicates inside the tensor-parallel device set."""
        mesh = Mesh(np.array(jax.devices()[:1]), ("model",))
        spec = serve_cache_spec(
            (4, 8, 64, 16), ("cache_batch", "kv_heads", "cache_seq", "head_dim"), mesh
        )
        assert spec == P(None, None, None, None) or spec == P()

    @forced8
    def test_engine_committed_cache_sharding(self):
        """The live engine's caches carry serve_cache_spec shardings:
        the slot axis maps to the owning slice's (size-1) data axis and
        no cache leaf ever shards over model."""
        cfg, model, params = tiny_model()
        mesh = make_serving_mesh(model_axis=4, data_axis=2)
        server = PipelineServer(
            model, params, mesh=mesh, n_groups=2, n_replicas=2,
            policy="uniform", max_len=64, max_batch=4, seed=3,
        )
        for (g, r), cache in server._caches.items():
            for leaf in jax.tree_util.tree_leaves(cache):
                spec = tuple(leaf.sharding.spec)
                flat = {
                    a for part in spec
                    for a in (part if isinstance(part, tuple) else (part,))
                    if a is not None
                }
                assert "model" not in flat, (g, r, spec)
                if spec:  # leading slot dim == cache_batch -> data
                    assert spec[0] == "data", (g, r, spec)

    def test_absent_mesh_axes_dropped_no_mesh_axis(self):
        """Rules referencing axes the mesh lacks resolve to replication."""
        mesh = Mesh(np.array(jax.devices()[:1]), ("model",))
        # batch -> ("pod", "data"): neither exists on a model-only mesh.
        spec = divisible_spec((8, 16), ("batch", "embed"), mesh, SERVE_RULES)
        assert spec == P(None, None) or spec == P()


class TestMeshFactoring:
    def test_production_mesh_derives_from_device_count(self):
        mesh = make_production_mesh()
        assert mesh.axis_names == ("data", "model")
        assert mesh.devices.size == N_DEV

    def test_production_mesh_shape_too_big_errors(self):
        with pytest.raises(ValueError, match="xla_force_host_platform_device_count"):
            make_production_mesh(shape=(N_DEV + 1, 2))

    def test_production_mesh_explicit_shape(self):
        mesh = make_production_mesh(shape=(1, 1))
        assert mesh.axis_names == ("data", "model")

    def test_multi_pod_odd_count_errors(self):
        if N_DEV % 2 == 0:
            mesh = make_production_mesh(multi_pod=True)
            assert mesh.axis_names == ("pod", "data", "model")
        else:
            with pytest.raises(ValueError, match="even device count"):
                make_production_mesh(multi_pod=True)

    def test_serving_mesh_too_big_errors(self):
        with pytest.raises(ValueError, match="xla_force_host_platform_device_count"):
            make_serving_mesh(model_axis=N_DEV + 1, data_axis=1)

    def test_serving_mesh_bad_data_axis(self):
        with pytest.raises(ValueError, match="data_axis"):
            make_serving_mesh(model_axis=1, data_axis=0)

    @forced8
    def test_serving_mesh_forced_shape(self):
        mesh = make_serving_mesh(model_axis=4, data_axis=2)
        assert mesh.devices.shape == (2, 4)
        assert mesh.axis_names == ("data", "model")


class TestReplicaSubmeshes:
    def test_host_mesh_single_slice_round_robin(self):
        slices, slice_of = replica_submeshes(make_host_mesh(), 3)
        assert len(slices) == 1 and slice_of == [0, 0, 0]
        assert slices[0].axis_names == ("data", "model")

    def test_rejects_foreign_axes(self):
        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("pod", "model"))
        with pytest.raises(ValueError, match="data"):
            replica_submeshes(mesh, 2)

    @forced8
    def test_forced_slices_are_disjoint(self):
        mesh = make_serving_mesh(model_axis=4, data_axis=2)
        slices, slice_of = replica_submeshes(mesh, 3)
        assert len(slices) == 2 and slice_of == [0, 1, 0]
        d0 = {d.id for d in slices[0].devices.flat}
        d1 = {d.id for d in slices[1].devices.flat}
        assert d0.isdisjoint(d1) and len(d0) == len(d1) == 4


def _drain(server, reqs, limit=5000):
    for _ in range(limit):
        if all(r.done or r.dropped for r in reqs):
            return [list(r.generated) for r in reqs]
        server.step()
    raise RuntimeError("did not drain")


def _streams(model, params, cfg, *, mesh, paged, n_tokens=5):
    server = PipelineServer(
        model,
        params,
        mesh=mesh,
        n_groups=2,
        n_replicas=2,
        policy="uniform",
        max_len=64,
        max_batch=4,
        paged=paged,
        page_size=8,
        seed=3,
    )
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n) for n in (5, 9, 7)]
    return _drain(server, [server.submit(p, n_tokens=n_tokens) for p in prompts])


@forced8
class TestMeshDifferential:
    @pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
    def test_tensor_parallel_token_exact(self, paged):
        """data=2 x model=4: two real replica device sets, each stage
        one jitted TP dispatch — streams must match single-device."""
        cfg, model, params = tiny_model()
        ref = _streams(model, params, cfg, mesh=None, paged=paged)
        mesh = make_serving_mesh(model_axis=4, data_axis=2)
        got = _streams(model, params, cfg, mesh=mesh, paged=paged)
        assert got == ref

    def test_failover_on_mesh_token_exact(self):
        cfg, model, params = tiny_model()
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab_size, size=n) for n in (5, 9, 7)]

        def run(mesh, kill):
            server = PipelineServer(
                model, params, mesh=mesh, n_groups=2, n_replicas=2,
                policy="uniform", max_len=64, max_batch=4, seed=3,
            )
            reqs = [server.submit(p, n_tokens=6) for p in prompts]
            if kill:
                for _ in range(3):
                    server.step()
                server.fail_replica(0, 0)
            return _drain(server, reqs)

        ref = run(None, kill=False)
        assert run(make_serving_mesh(model_axis=4, data_axis=2), kill=True) == ref
