"""Property-based paged-attention validation (hypothesis): random batch
sizes, context lengths, page sizes, GQA head counts, and sliding
windows; the Pallas block-table-gather kernels (interpret mode) and the
gather oracles must match the *dense* references on the equivalent
contiguous cache, under arbitrary page scatter — decode (single query)
and prefill (multi-query chunk with ragged per-lane offsets) alike."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (test extra)")
import jax
import jax.numpy as jnp
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.decode_attention import (
    decode_attention_ref,
    paged_decode_attention,
    paged_decode_attention_ref,
    paged_prefill_attention,
    paged_prefill_attention_pallas,
    quantize_kv,
)
from repro.models.attention import chunked_attention

SETTINGS = dict(max_examples=12, deadline=None)


@st.composite
def paged_shapes(draw):
    B = draw(st.integers(1, 3))
    page = draw(st.sampled_from([4, 8, 16]))
    NB = draw(st.integers(1, 4))
    KV = draw(st.sampled_from([1, 2]))
    G = draw(st.sampled_from([1, 2, 4]))
    D = draw(st.sampled_from([8, 32]))
    S = NB * page
    lengths = tuple(draw(st.integers(1, S)) for _ in range(B))
    window = draw(st.sampled_from([None, 5, 17]))
    spare = draw(st.integers(0, 3))  # unowned pages between allocations
    return B, page, NB, KV, G, D, lengths, window, spare


@given(paged_shapes(), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_paged_decode_attention_property(shape, seed):
    B, page, NB, KV, G, D, lengths, window, spare = shape
    H = KV * G
    S = NB * page
    P = B * NB + spare
    rng = np.random.default_rng(seed)

    # A contiguous per-request cache, scattered over a shuffled pool:
    # request b's logical block j lives at a random distinct page.
    k_dense = rng.normal(size=(B, S, KV, D)).astype(np.float32)
    v_dense = rng.normal(size=(B, S, KV, D)).astype(np.float32)
    block_tables = rng.permutation(P)[: B * NB].reshape(B, NB).astype(np.int32)
    k_pages = rng.normal(size=(P, page, KV, D)).astype(np.float32)  # garbage
    v_pages = rng.normal(size=(P, page, KV, D)).astype(np.float32)
    for b in range(B):
        for j in range(NB):
            k_pages[block_tables[b, j]] = k_dense[b, j * page : (j + 1) * page]
            v_pages[block_tables[b, j]] = v_dense[b, j * page : (j + 1) * page]

    q = rng.normal(size=(B, 1, H, D)).astype(np.float32)
    lens = np.asarray(lengths, np.int32)

    out = paged_decode_attention(
        jnp.asarray(q), jnp.asarray(k_pages), jnp.asarray(v_pages),
        jnp.asarray(block_tables), jnp.asarray(lens),
        window=window, interpret=True,
    )
    ref = paged_decode_attention_ref(
        jnp.asarray(q), jnp.asarray(k_pages), jnp.asarray(v_pages),
        jnp.asarray(block_tables), jnp.asarray(lens), window=window,
    )
    dense = decode_attention_ref(
        jnp.asarray(q), jnp.asarray(k_dense), jnp.asarray(v_dense),
        jnp.asarray(lens), window=window,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-5)
    # Paging is invisible: scattered == contiguous.
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense), rtol=3e-5, atol=3e-5)
    assert np.all(np.isfinite(np.asarray(out)))


@st.composite
def prefill_shapes(draw):
    B = draw(st.integers(1, 3))
    page = draw(st.sampled_from([4, 8, 16]))
    NB = draw(st.integers(1, 4))
    KV = draw(st.sampled_from([1, 2]))
    G = draw(st.sampled_from([1, 2, 4]))  # GQA ratio
    D = draw(st.sampled_from([8, 32]))
    C = draw(st.integers(1, min(6, NB * page)))  # chunk width
    S = NB * page
    # Ragged lanes: each lane continues its prefill from its own offset.
    offsets = tuple(draw(st.integers(0, S - C)) for _ in range(B))
    spare = draw(st.integers(0, 3))
    quantized = draw(st.booleans())
    return B, page, NB, KV, G, D, C, offsets, spare, quantized


@given(prefill_shapes(), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_paged_prefill_attention_property(shape, seed):
    """Pallas prefill kernel (interpret) == gather oracle == dense
    chunked_attention, under random page sizes, chunk widths, GQA
    ratios, ragged per-lane offsets, and int8 quantization."""
    B, page, NB, KV, G, D, C, offsets, spare, quantized = shape
    H = KV * G
    S = NB * page
    P = B * NB + spare
    rng = np.random.default_rng(seed)

    k_dense = rng.normal(size=(B, S, KV, D)).astype(np.float32)
    v_dense = rng.normal(size=(B, S, KV, D)).astype(np.float32)
    block_tables = rng.permutation(P)[: B * NB].reshape(B, NB).astype(np.int32)
    k_pages = rng.normal(size=(P, page, KV, D)).astype(np.float32)  # garbage
    v_pages = rng.normal(size=(P, page, KV, D)).astype(np.float32)
    for b in range(B):
        for j in range(NB):
            k_pages[block_tables[b, j]] = k_dense[b, j * page : (j + 1) * page]
            v_pages[block_tables[b, j]] = v_dense[b, j * page : (j + 1) * page]

    q = rng.normal(size=(B, C, H, D)).astype(np.float32)
    offs = np.asarray(offsets, np.int32)
    kp, vp = jnp.asarray(k_pages), jnp.asarray(v_pages)
    scales = {}
    if quantized:
        kp, ks = quantize_kv(kp)
        vp, vs = quantize_kv(vp)
        scales = dict(k_scales=ks, v_scales=vs)

    out = paged_prefill_attention_pallas(
        jnp.asarray(q), kp, vp, jnp.asarray(block_tables), jnp.asarray(offs),
        interpret=True, **scales,
    )
    ref = paged_prefill_attention(
        jnp.asarray(q), kp, vp, jnp.asarray(block_tables), jnp.asarray(offs),
        **scales,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-5)
    assert np.all(np.isfinite(np.asarray(out)))
    if not quantized:
        # Paging is invisible: scattered pages == the contiguous cache.
        dense = chunked_attention(
            jnp.asarray(q), jnp.asarray(k_dense), jnp.asarray(v_dense),
            causal=True, q_offset=jnp.asarray(offs), chunk=16,
        )
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(dense), rtol=3e-5, atol=3e-5
        )


@given(
    st.integers(1, 3),
    st.sampled_from([4, 8]),
    st.integers(1, 3),
    st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_paged_scatter_roundtrip_property(B, page, NB, seed):
    """gather(scatter(cache)) == cache for any block table: the pure
    reshape/gather plumbing the engine's prefill scatter relies on."""
    rng = np.random.default_rng(seed)
    KV, D = 2, 8
    S = NB * page
    P = B * NB + 2
    dense = rng.normal(size=(B, S, KV, D)).astype(np.float32)
    bt = rng.permutation(P)[: B * NB].reshape(B, NB).astype(np.int32)
    pool = np.zeros((P, page, KV, D), np.float32)
    pool[bt.reshape(-1)] = dense.reshape(B * NB, page, KV, D)
    from repro.kernels.decode_attention import gather_pages

    back = gather_pages(jnp.asarray(pool), jnp.asarray(bt))
    np.testing.assert_array_equal(np.asarray(back), dense)
