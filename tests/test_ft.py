"""Fault-tolerance layer tests: checkpoint round-trip/atomicity/retention,
heartbeats, hedging, elastic rate refresh."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.network import DeviceSpec
from repro.core.power import dynamic_policy
from repro.ft import (
    ElasticController,
    HeartbeatMonitor,
    HedgePolicy,
    latest_step,
    list_steps,
    restore_checkpoint,
    save_checkpoint,
)
from repro.serving import Router


def tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((2, 2), jnp.bfloat16), "c": jnp.int32(7)},
    }


class TestCheckpoint:
    def test_round_trip(self, tmp_path):
        t = tree()
        save_checkpoint(str(tmp_path), 5, t)
        restored, step = restore_checkpoint(str(tmp_path), t)
        assert step == 5
        for a, b in zip(jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))

    def test_latest_and_retention(self, tmp_path):
        t = tree()
        for s in (1, 2, 3, 4, 5):
            save_checkpoint(str(tmp_path), s, t, keep=3)
        assert list_steps(str(tmp_path)) == [3, 4, 5]
        assert latest_step(str(tmp_path)) == 5

    def test_structure_mismatch_rejected(self, tmp_path):
        save_checkpoint(str(tmp_path), 1, tree())
        with pytest.raises(ValueError):
            restore_checkpoint(str(tmp_path), {"different": jnp.zeros(3)})

    def test_no_partial_checkpoint_visible(self, tmp_path):
        """A tmp dir (simulated crash) is never listed as a checkpoint."""
        save_checkpoint(str(tmp_path), 1, tree())
        os.makedirs(tmp_path / ".tmp_step_0000000002")
        assert list_steps(str(tmp_path)) == [1]

    def test_restore_specific_step(self, tmp_path):
        t = tree()
        save_checkpoint(str(tmp_path), 1, t, keep=10)
        t2 = jax.tree_util.tree_map(lambda x: x + 1, t)
        save_checkpoint(str(tmp_path), 2, t2, keep=10)
        restored, step = restore_checkpoint(str(tmp_path), t, step=1)
        assert step == 1
        np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(t["a"]))


class TestHealth:
    def test_heartbeat_timeout(self):
        mon = HeartbeatMonitor(timeout=1.0)
        mon.beat("r0", now=0.0)
        mon.beat("r1", now=0.9)
        assert mon.dead(now=1.5) == {"r0"}
        assert mon.alive("r1", now=1.5)

    def test_hedge_threshold(self):
        h = HedgePolicy(quantile=0.9, min_samples=5)
        assert h.should_hedge(10.0) is False  # no data yet
        for _ in range(20):
            h.record(1.0)
        assert h.should_hedge(0.5) is False
        assert h.should_hedge(1.5) is True


class TestElastic:
    def test_rates_refresh_on_membership_change(self):
        pol = dynamic_policy(100)
        spec_rich = DeviceSpec(arrival_lo=10, arrival_hi=14, policy=pol)
        spec_poor = DeviceSpec(arrival_lo=3, arrival_hi=5, policy=pol)
        router = Router(policy="long_term")
        ctl = ElasticController(router, [[spec_rich, spec_poor]])
        rates = ctl.refresh()
        assert rates[0][0] > rates[0][1]  # richer node gets higher q_lim
        rates2 = ctl.join(0, spec_rich)
        assert len(rates2[0]) == 3
        rates3 = ctl.leave(0, 1)
        assert len(rates3[0]) == 2
        assert router.long_term_rates is not None
