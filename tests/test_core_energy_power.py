"""Unit tests for the energy and power-mode substrate."""

import numpy as np
import pytest

from repro.core.energy import DiscreteMDF, battery_update, convolve_mdf, uniform_mdf
from repro.core.power import (
    ORIN_POWER_MODES,
    PowerMode,
    dynamic_policy,
    fixed_policy,
)


class TestMDF:
    def test_uniform_mdf_mean(self):
        m = uniform_mdf(6, 10)
        assert m.mean == pytest.approx(8.0)
        assert m.array.sum() == pytest.approx(1.0)

    def test_uniform_mdf_support(self):
        m = uniform_mdf(2, 4)
        np.testing.assert_allclose(m.array, [0, 0, 1 / 3, 1 / 3, 1 / 3])

    def test_invalid_pmf_rejected(self):
        with pytest.raises(ValueError):
            DiscreteMDF((0.5, 0.2))  # doesn't sum to 1
        with pytest.raises(ValueError):
            uniform_mdf(5, 3)

    def test_convolution_mean_additivity(self):
        m = uniform_mdf(6, 10)
        for k in (1, 2, 3):
            g = convolve_mdf(m.array, k)
            assert g.sum() == pytest.approx(1.0)
            mean = np.dot(np.arange(len(g)), g)
            assert mean == pytest.approx(k * m.mean)

    def test_convolution_support(self):
        g = convolve_mdf(uniform_mdf(6, 10).array, 3)
        # support is 18..30
        assert g[17] == 0 and g[18] > 0 and g[30] > 0
        assert len(g) == 31


class TestBatteryUpdate:
    def test_eq1_clamps(self):
        assert battery_update(50, 10, 5, 100) == 55
        assert battery_update(95, 10, 0, 100) == 100  # cap
        assert battery_update(5, 0, 26, 100) == 0  # floor

    def test_eq1_identity(self):
        assert battery_update(40, 8, 8, 100) == 40


class TestPowerModes:
    def test_orin_table_matches_paper(self):
        # 15 W -> (300 s, 26 kJ); 30 W -> (200 s, 22 kJ); 60 W -> (100 s, 23 kJ)
        kappas = [m.kappa for m in ORIN_POWER_MODES]
        ces = [m.ce for m in ORIN_POWER_MODES]
        assert kappas == [3, 2, 1]
        assert ces == [26, 22, 23]
        # 50 W excluded as dominated by 30 W (paper Sec. V)
        assert all(m.watts != 50.0 for m in ORIN_POWER_MODES)

    def test_fixed_policy(self):
        pol = fixed_policy(2)
        for e in (0, 50, 100):
            assert pol.pm_for_energy(e) == 2
        assert pol.kappa_for_energy(0) == 2
        assert pol.ce_for_energy(0) == 22

    def test_dynamic_policy_thresholds(self):
        pol = dynamic_policy(e_max=100)
        # E < 40 -> PM1 (15 W); 40 <= E < 60 -> PM2 (30 W); E >= 60 -> PM3.
        assert pol.pm_for_energy(0) == 1
        assert pol.pm_for_energy(39) == 1
        assert pol.pm_for_energy(40) == 2
        assert pol.pm_for_energy(59) == 2
        assert pol.pm_for_energy(60) == 3
        assert pol.pm_for_energy(100) == 3

    def test_dynamic_policy_vectorized(self):
        pol = dynamic_policy(e_max=100)
        out = pol.pm_for_energy(np.array([0, 40, 60, 100]))
        np.testing.assert_array_equal(out, [1, 2, 3, 3])

    def test_invalid_mode(self):
        with pytest.raises(ValueError):
            PowerMode("x", 1.0, kappa=0, ce=1)
