"""Config registry tests: exact assigned hyper-parameters, shape cells,
family skips, and the dry-run helpers that don't need 512 devices."""

import pytest

from repro.configs import ARCH_NAMES, SHAPES, cells_for, get_config, get_smoke_config


EXPECTED = {
    # (layers, d_model, heads, kv, d_ff, vocab)
    "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
    "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
    "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
    "granite-20b": (52, 6144, 48, 1, 24576, 49152),
    "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
    "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
    "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
    "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
    "falcon-mamba-7b": (64, 4096, 1, 1, 0, 65024),
    "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
}


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_exact_assigned_config(name):
    cfg = get_config(name)
    L, D, H, KV, F, V = EXPECTED[name]
    assert cfg.n_layers == L
    assert cfg.d_model == D
    assert cfg.n_heads == H
    assert cfg.n_kv_heads == KV
    assert cfg.d_ff == F
    assert cfg.vocab_size == V


def test_moe_configs():
    q = get_config("qwen3-moe-30b-a3b")
    assert (q.n_experts, q.moe_top_k, q.d_ff_expert) == (128, 8, 768)
    g = get_config("granite-moe-1b-a400m")
    assert (g.n_experts, g.moe_top_k, g.d_ff_expert) == (32, 8, 512)


def test_ssm_configs():
    f = get_config("falcon-mamba-7b")
    assert f.block == "mamba" and f.ssm_state == 16 and f.d_inner == 8192
    h = get_config("hymba-1.5b")
    assert h.block == "hymba" and h.ssm_state == 16
    assert h.attn_window == 1024 and h.global_attn_layers == (0, 15, 31)


def test_encdec_config():
    s = get_config("seamless-m4t-large-v2")
    assert s.encoder_layers == 24 and s.is_encdec


def test_shape_cells():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1


def test_long_context_cells_only_for_subquadratic():
    """8 full-attention archs skip long_500k; ssm + hybrid run it: 32
    runnable cells + 8 documented skips = the full 40-cell matrix."""
    runnable = 0
    for name in ARCH_NAMES:
        cells = cells_for(name)
        runnable += len(cells)
        if name in ("hymba-1.5b", "falcon-mamba-7b"):
            assert "long_500k" in cells
        else:
            assert "long_500k" not in cells
    assert runnable == 32


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_configs_are_reduced(name):
    full, smoke = get_config(name), get_smoke_config(name)
    assert smoke.n_layers <= 4
    assert smoke.d_model <= 128
    assert smoke.family == full.family
    assert smoke.block == full.block
    assert smoke.is_encdec == full.is_encdec
    assert smoke.is_moe == full.is_moe
