"""Roofline analyzer tests: the trip-scaled HLO walker against programs
with known FLOP counts (XLA's own cost_analysis counts loop bodies once —
the motivation for the walker; see roofline/analysis.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import RooflineTerms, analyze_hlo


def compile_text(fn, *specs):
    return jax.jit(fn).lower(*specs).compile().as_text()


class TestWalker:
    def test_plain_matmul_flops(self):
        a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
        b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
        hlo = compile_text(lambda x, y: x @ y, a, b)
        c = analyze_hlo(hlo)
        assert c.flops == pytest.approx(2 * 64 * 128 * 32, rel=0.05)

    def test_scan_trip_scaling(self):
        """The critical property: loop bodies scale by trip count."""
        def f(x, w):
            def body(c, wi):
                return c @ wi, ()
            y, _ = jax.lax.scan(body, x, w)
            return y

        x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        w = jax.ShapeDtypeStruct((24, 128, 128), jnp.float32)
        hlo = compile_text(f, x, w)
        c = analyze_hlo(hlo)
        assert c.flops == pytest.approx(2 * 24 * 128**3, rel=0.02)

    def test_nested_scan_trip_scaling(self):
        def f(x, w):
            def inner(c, wi):
                return jnp.tanh(c @ wi), ()

            def outer(c, wc):
                y, _ = jax.lax.scan(inner, c, wc)
                return y, ()

            y, _ = jax.lax.scan(outer, x, w.reshape(3, 8, 64, 64))
            return y

        x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        w = jax.ShapeDtypeStruct((24, 64, 64), jnp.float32)
        c = analyze_hlo(compile_text(f, x, w))
        assert c.flops == pytest.approx(2 * 24 * 64**3, rel=0.05)

    def test_bytes_positive_and_bounded(self):
        a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
        hlo = compile_text(lambda x: x + 1.0, a)
        c = analyze_hlo(hlo)
        nbytes = 256 * 256 * 4
        assert nbytes <= c.bytes <= 4 * nbytes

    def test_empty_hlo(self):
        c = analyze_hlo("")
        assert c.flops == 0.0


class TestTerms:
    def test_dominant_selection(self):
        t = RooflineTerms(flops=1e15, hbm_bytes=1e12, collective_bytes=1e13, chips=256)
        assert t.compute_s > 0
        assert t.dominant == "collective"
        assert t.step_time_s == t.collective_s

    def test_scaling_invariance(self):
        """Per-chip time terms are independent of the chip count used to
        scale totals (totals = per-device x chips)."""
        t1 = RooflineTerms(flops=256e12, hbm_bytes=256e9, collective_bytes=0, chips=256)
        t2 = RooflineTerms(flops=512e12, hbm_bytes=512e9, collective_bytes=0, chips=512)
        assert t1.compute_s == pytest.approx(t2.compute_s)
        assert t1.memory_s == pytest.approx(t2.memory_s)
