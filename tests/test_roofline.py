"""Roofline analyzer tests: the trip-scaled HLO walker against programs
with known FLOP counts (XLA's own cost_analysis counts loop bodies once —
the motivation for the walker; see roofline/analysis.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import (
    RooflineTerms,
    analyze_hlo,
    call_multipliers,
    parse_computations,
    top_contributors,
)


def compile_text(fn, *specs):
    return jax.jit(fn).lower(*specs).compile().as_text()


class TestWalker:
    def test_plain_matmul_flops(self):
        a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
        b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
        hlo = compile_text(lambda x, y: x @ y, a, b)
        c = analyze_hlo(hlo)
        assert c.flops == pytest.approx(2 * 64 * 128 * 32, rel=0.05)

    def test_scan_trip_scaling(self):
        """The critical property: loop bodies scale by trip count."""
        def f(x, w):
            def body(c, wi):
                return c @ wi, ()
            y, _ = jax.lax.scan(body, x, w)
            return y

        x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        w = jax.ShapeDtypeStruct((24, 128, 128), jnp.float32)
        hlo = compile_text(f, x, w)
        c = analyze_hlo(hlo)
        assert c.flops == pytest.approx(2 * 24 * 128**3, rel=0.02)

    def test_nested_scan_trip_scaling(self):
        def f(x, w):
            def inner(c, wi):
                return jnp.tanh(c @ wi), ()

            def outer(c, wc):
                y, _ = jax.lax.scan(inner, c, wc)
                return y, ()

            y, _ = jax.lax.scan(outer, x, w.reshape(3, 8, 64, 64))
            return y

        x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        w = jax.ShapeDtypeStruct((24, 64, 64), jnp.float32)
        c = analyze_hlo(compile_text(f, x, w))
        assert c.flops == pytest.approx(2 * 24 * 64**3, rel=0.05)

    def test_bytes_positive_and_bounded(self):
        a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
        hlo = compile_text(lambda x: x + 1.0, a)
        c = analyze_hlo(hlo)
        nbytes = 256 * 256 * 4
        assert nbytes <= c.bytes <= 4 * nbytes

    def test_empty_hlo(self):
        c = analyze_hlo("")
        assert c.flops == 0.0


class TestPublicApi:
    """The promoted HLO-walking API (parse_computations /
    call_multipliers / top_contributors) that scripts/hlo_top.py and
    analyze_hlo share."""

    def _scan_hlo(self):
        def f(x, w):
            def body(c, wi):
                return c @ wi, ()
            y, _ = jax.lax.scan(body, x, w)
            return y

        x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        w = jax.ShapeDtypeStruct((24, 128, 128), jnp.float32)
        return compile_text(f, x, w)

    def test_parse_computations_entry(self):
        comps = parse_computations(self._scan_hlo())
        assert "__entry__" in comps
        entry = comps["__entry__"]
        assert comps[entry.name] is entry
        assert entry.ops  # ENTRY has instructions

    def test_call_multipliers_trip_scaled(self):
        """The while body's multiplier carries the trip count."""
        comps = parse_computations(self._scan_hlo())
        mult, fused = call_multipliers(comps)
        assert mult[comps["__entry__"].name] == 1.0
        assert max(mult.values()) >= 24.0  # loop body runs 24x
        assert set(fused) == set(mult)

    def test_call_multipliers_empty(self):
        assert call_multipliers({}) == ({}, {})

    def test_top_contributors_agree_with_analyze_hlo(self):
        """Drill-down FLOPs sum to the roofline total (shared multiplier
        propagation — the point of the refactor)."""
        hlo = self._scan_hlo()
        dots = sum(v for v, _, _ in top_contributors(hlo, "flops"))
        assert dots == pytest.approx(2 * 24 * 128**3, rel=0.01)
        total_bytes = sum(v for v, _, _ in top_contributors(hlo, "bytes"))
        assert total_bytes == pytest.approx(analyze_hlo(hlo).bytes, rel=1e-9)

    def test_top_contributors_sorted_and_limited(self):
        hlo = self._scan_hlo()
        contrib = top_contributors(hlo, "bytes")
        assert contrib == sorted(contrib, key=lambda t: -t[0])
        assert top_contributors(hlo, "bytes", limit=2) == contrib[:2]

    def test_top_contributors_bad_mode(self):
        with pytest.raises(ValueError):
            top_contributors("", "nope")


class TestTerms:
    def test_dominant_selection(self):
        t = RooflineTerms(flops=1e15, hbm_bytes=1e12, collective_bytes=1e13, chips=256)
        assert t.compute_s > 0
        assert t.dominant == "collective"
        assert t.step_time_s == t.collective_s

    def test_scaling_invariance(self):
        """Per-chip time terms are independent of the chip count used to
        scale totals (totals = per-device x chips)."""
        t1 = RooflineTerms(flops=256e12, hbm_bytes=256e9, collective_bytes=0, chips=256)
        t2 = RooflineTerms(flops=512e12, hbm_bytes=512e9, collective_bytes=0, chips=512)
        assert t1.compute_s == pytest.approx(t2.compute_s)
        assert t1.memory_s == pytest.approx(t2.memory_s)
