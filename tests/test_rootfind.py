"""Brent's method tests (paper ref [14])."""

import math

import pytest

from repro.core.rootfind import brentq, find_rate_for_risk


class TestBrentq:
    def test_polynomial(self):
        assert brentq(lambda x: x**2 - 2, 0, 2) == pytest.approx(math.sqrt(2), abs=1e-9)

    def test_transcendental(self):
        r = brentq(lambda x: math.cos(x) - x, 0, 1)
        assert r == pytest.approx(0.7390851332151607, abs=1e-9)

    def test_root_at_endpoint(self):
        assert brentq(lambda x: x, 0.0, 1.0) == 0.0
        assert brentq(lambda x: x - 1.0, 0.0, 1.0) == 1.0

    def test_sign_check(self):
        with pytest.raises(ValueError):
            brentq(lambda x: x**2 + 1, -1, 1)

    def test_steep_function(self):
        r = brentq(lambda x: math.tanh(50 * (x - 0.3)), 0, 1)
        assert r == pytest.approx(0.3, abs=1e-6)


class TestFindRateForRisk:
    def test_monotone_risk(self):
        # risk(q) = q^2: q_lim for xi=0.25 is 0.5.
        q = find_rate_for_risk(lambda q: q * q, 0.25)
        assert q == pytest.approx(0.5, abs=1e-4)

    def test_always_safe(self):
        assert find_rate_for_risk(lambda q: 0.0, 0.01) == 1.0

    def test_never_safe(self):
        assert find_rate_for_risk(lambda q: 1.0, 0.01) == pytest.approx(1e-6)
