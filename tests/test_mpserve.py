"""Multi-process serving tests.

Fast layer: the wire protocol and the worker-side ``StageHost`` run
in-process (no subprocess, no compile beyond the tiny smoke model) and
must match the monolithic greedy reference exactly.

Slow layer (tier-1 / the CI mesh lane): real worker processes — full
differential token exactness against the in-process engine, SIGKILL
failover with zero token loss, and respawn recovery.
"""

import io

import numpy as np
import pytest

from conftest import direct_greedy, tiny_model
from repro.serving import PipelineServer
from repro.serving.mpserve import (
    MPPipelineServer,
    StageHost,
    WorkerDied,
    _read_msg,
    _write_msg,
    build_from_spec,
)

SPEC = {
    "arch": "stablelm-1.6b",
    "smoke": True,
    "overrides": {"dtype": "float32", "param_dtype": "float32"},
    "seed": 0,
}


class TestProtocol:
    def test_roundtrip(self):
        buf = io.BytesIO()
        msg = ("prefill", [0, 2], np.arange(6, dtype=np.int32).reshape(2, 1, 3))
        _write_msg(buf, msg)
        buf.seek(0)
        out = _read_msg(buf)
        assert out[0] == "prefill" and out[1] == [0, 2]
        np.testing.assert_array_equal(out[2], msg[2])

    def test_eof_raises_worker_died(self):
        with pytest.raises(WorkerDied):
            _read_msg(io.BytesIO(b"\x01\x02"))

    def test_truncated_frame_raises(self):
        buf = io.BytesIO()
        _write_msg(buf, {"ok": True})
        frame = buf.getvalue()[:-2]
        with pytest.raises(WorkerDied):
            _read_msg(io.BytesIO(frame))


class TestBuildFromSpec:
    def test_deterministic(self):
        import jax

        _, _, p1 = build_from_spec(SPEC)
        _, _, p2 = build_from_spec(SPEC)
        l1 = jax.tree_util.tree_leaves(p1)
        l2 = jax.tree_util.tree_leaves(p2)
        for a, b in zip(l1, l2):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_overrides_applied(self):
        cfg, _, _ = build_from_spec(SPEC)
        assert cfg.dtype == "float32" and cfg.param_dtype == "float32"


class TestStageHostInProcess:
    """The worker's execution state, driven without a subprocess."""

    def test_single_stage_matches_direct_greedy(self):
        cfg, model, params = build_from_spec(SPEC)
        host = StageHost(SPEC, 0, 1, max_batch=4, max_len=64)
        rng = np.random.default_rng(0)
        prompt = rng.integers(0, cfg.vocab_size, size=6)
        ref = direct_greedy(model, params, prompt, 5)
        r = host.handle(("prefill", [1], np.asarray(prompt, np.int32)[None, None, :]))
        toks = [int(r["tokens"][0])]
        for _ in range(4):
            r = host.handle(
                ("decode", [1], np.asarray([[[toks[-1]]]], np.int32))
            )
            toks.append(int(r["tokens"][0]))
        assert toks == ref

    def test_two_stage_handoff(self):
        """Stage-0 hidden handoff feeds stage 1; tokens match direct."""
        cfg, model, params = build_from_spec(SPEC)
        h0 = StageHost(SPEC, 0, 2, max_batch=4, max_len=64)
        h1 = StageHost(SPEC, 1, 2, max_batch=4, max_len=64)
        rng = np.random.default_rng(1)
        prompt = rng.integers(0, cfg.vocab_size, size=5)
        ref = direct_greedy(model, params, prompt, 4)
        r0 = h0.handle(("prefill", [0], np.asarray(prompt, np.int32)[None, None, :]))
        r1 = h1.handle(("prefill", [0], r0["hidden"]))
        toks = [int(r1["tokens"][0])]
        for _ in range(3):
            r0 = h0.handle(("decode", [0], np.asarray([[[toks[-1]]]], np.int32)))
            r1 = h1.handle(("decode", [0], r0["hidden"]))
            toks.append(int(r1["tokens"][0]))
        assert toks == ref

    def test_unknown_op_errors(self):
        host = StageHost(SPEC, 0, 1, max_batch=2, max_len=32)
        with pytest.raises(ValueError, match="unknown op"):
            host.handle(("frobnicate",))


def _drain(server, reqs, limit=5000):
    for _ in range(limit):
        if all(r.done or r.dropped for r in reqs):
            return [list(r.generated) for r in reqs]
        server.step()
    raise RuntimeError("did not drain")


def _reference(prompts, n_tokens):
    _, model, params = build_from_spec(SPEC)
    ref = PipelineServer(
        model, params, n_groups=2, n_replicas=2,
        policy="uniform", max_len=64, max_batch=4, seed=3,
    )
    return _drain(ref, [ref.submit(p, n_tokens=n_tokens) for p in prompts])


@pytest.mark.slow
class TestMPServer:
    def test_differential_kill_and_recover(self):
        """One subprocess fleet end-to-end: exactness, SIGKILL failover
        (zero token loss, membership observed), respawn recovery."""
        cfg, _, _ = build_from_spec(SPEC)
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab_size, size=n) for n in (5, 9, 7, 5)]
        ref_a = _reference(prompts, 6)
        ref_b = _reference(prompts[:2], 4)
        with MPPipelineServer(
            SPEC, n_groups=2, n_replicas=2,
            policy="uniform", max_len=64, max_batch=4, seed=3,
        ) as mp:
            # wave 1: plain differential
            assert _drain(mp, [mp.submit(p, n_tokens=6) for p in prompts]) == ref_a

            # wave 2: kill the real process behind stage-0 replica 0
            # mid-stream. Stage 0's re-prefill rebuilds the full prompt +
            # generated prefix, so failover is loss-free and the stream
            # stays bit-exact. (A mid-pipeline kill re-prefills from the
            # latest hidden handoff — documented context loss — so it is
            # exercised for liveness elsewhere, not for exactness.)
            reqs = [mp.submit(p, n_tokens=4) for p in prompts[:2]]
            v0 = mp.router.membership_version
            for _ in range(3):
                mp.step()
            proc = mp._workers[(0, 0)].proc
            proc.kill()
            proc.wait()
            assert _drain(mp, reqs) == ref_b  # loss-free re-prefill
            assert mp.router.membership_version > v0
            assert not mp.budgets[0][0].alive
            # the dead member's routing rate is zeroed, sibling keeps mass
            rates = mp.router.long_term_rates
            assert rates is not None
            assert rates[0][0] == 0.0 and rates[0][1] > 0.0

            # recovery: respawn the worker, serve a third wave exactly
            mp.recover_replica(0, 0)
            assert mp._workers[(0, 0)].alive
            assert mp.budgets[0][0].alive
            assert _drain(mp, [mp.submit(p, n_tokens=4) for p in prompts[:2]]) == ref_b

    def test_unsupported_modes_raise(self):
        with pytest.raises(ValueError, match="dense whole-prompt"):
            MPPipelineServer(SPEC, paged=True)
        with pytest.raises(ValueError, match="dense whole-prompt"):
            MPPipelineServer(SPEC, prefill_chunk=4)
