"""Property-based tests (hypothesis) for the core invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (test extra)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.energy import battery_update, convolve_mdf, uniform_mdf
from repro.core.policies import adaptive_probs, long_term_probs, uniform_probs
from repro.core.power import ORIN_POWER_MODES, dynamic_policy, fixed_policy
from repro.core.rootfind import brentq
from repro.core.semi_markov import DeviceModel

SETTINGS = dict(max_examples=25, deadline=None)


@st.composite
def arrival_bounds(draw):
    lo = draw(st.integers(min_value=0, max_value=8))
    hi = draw(st.integers(min_value=lo, max_value=lo + 8))
    return lo, hi


@given(arrival_bounds())
@settings(**SETTINGS)
def test_uniform_mdf_is_distribution(bounds):
    lo, hi = bounds
    m = uniform_mdf(lo, hi)
    assert np.isclose(m.array.sum(), 1.0)
    assert np.all(m.array >= 0)
    assert np.isclose(m.mean, (lo + hi) / 2)


@given(arrival_bounds(), st.integers(min_value=1, max_value=4))
@settings(**SETTINGS)
def test_convolution_preserves_mass_and_mean(bounds, k):
    lo, hi = bounds
    m = uniform_mdf(lo, hi)
    g = convolve_mdf(m.array, k)
    assert np.isclose(g.sum(), 1.0)
    assert np.isclose(np.dot(np.arange(len(g)), g), k * m.mean)


@given(
    st.integers(min_value=0, max_value=100),
    st.integers(min_value=0, max_value=40),
    st.integers(min_value=0, max_value=40),
    st.integers(min_value=1, max_value=100),
)
@settings(**SETTINGS)
def test_battery_update_bounds(e, income, consumption, e_max):
    out = battery_update(min(e, e_max), income, consumption, e_max)
    assert 0 <= out <= e_max


@given(
    arrival_bounds(),
    st.floats(min_value=0.0, max_value=1.0),
    st.sampled_from([1, 2, 3]),
)
@settings(max_examples=10, deadline=None)
def test_transition_rows_are_distributions(bounds, q, pm):
    lo, hi = bounds
    dev = DeviceModel(
        mdf=uniform_mdf(lo, hi),
        policy=fixed_policy(pm),
        e_max=40,
        e_th=4,
        e_th_hi=10,
    )
    P = dev.chain(q).transition_matrix()
    np.testing.assert_allclose(P.sum(axis=1), 1.0, atol=1e-9)
    assert np.all(P >= 0)


@given(arrival_bounds(), st.floats(min_value=0.05, max_value=0.95))
@settings(max_examples=10, deadline=None)
def test_stationary_fixed_point(bounds, q):
    lo, hi = bounds
    dev = DeviceModel(
        mdf=uniform_mdf(lo, hi),
        policy=dynamic_policy(40, ORIN_POWER_MODES),
        e_max=40,
        e_th=4,
        e_th_hi=10,
    )
    chain = dev.chain(q)
    pi = chain.stationary()
    np.testing.assert_allclose(pi @ chain.transition_matrix(), pi, atol=1e-8)
    assert np.isclose(pi.sum(), 1.0)
    assert np.all(pi >= 0)
    # Risk is a probability; kappa_bar within mode range.
    assert 0.0 <= chain.risk() <= 1.0
    kb = chain.kappa_bar()
    assert 1.0 <= kb <= 3.0


@st.composite
def policy_inputs(draw):
    n = draw(st.integers(min_value=1, max_value=8))
    q_lims = draw(
        st.lists(
            st.floats(min_value=0.01, max_value=1.0), min_size=n, max_size=n
        )
    )
    pm = draw(st.lists(st.integers(min_value=1, max_value=3), min_size=n, max_size=n))
    avail = draw(st.lists(st.booleans(), min_size=n, max_size=n))
    return (
        jnp.asarray(q_lims, dtype=jnp.float32),
        jnp.asarray(pm),
        jnp.asarray(avail),
    )


@given(policy_inputs())
@settings(**SETTINGS)
def test_policies_produce_valid_distributions(inputs):
    q_lims, pm, avail = inputs
    n_avail = int(jnp.sum(avail))
    for fn in (uniform_probs, long_term_probs, adaptive_probs):
        p = np.asarray(fn(q_lims, pm, avail))
        assert np.all(p >= -1e-7)
        # No probability mass on unavailable devices.
        assert np.all(p[~np.asarray(avail)] <= 1e-7)
        if n_avail > 0:
            assert np.isclose(p.sum(), 1.0, atol=1e-5)


@given(
    st.floats(min_value=-5.0, max_value=-0.1),
    st.floats(min_value=0.1, max_value=5.0),
)
@settings(**SETTINGS)
def test_brentq_linear_roots(a, b):
    # f(x) = x - r with r uniform in (a, b): root recovered.
    r = (a + b) / 2
    assert abs(brentq(lambda x: x - r, a, b) - r) < 1e-8
