"""Semi-Markov chain tests (paper Sec. III)."""

import numpy as np
import pytest

from repro.core.energy import uniform_mdf
from repro.core.power import dynamic_policy, fixed_policy
from repro.core.rates import q_lim, q_lim_energy, q_lim_stable
from repro.core.semi_markov import DeviceModel, state_index, state_tuple


def small_device(pm=2, e_max=30, lo=2, hi=4):
    return DeviceModel(
        mdf=uniform_mdf(lo, hi),
        policy=fixed_policy(pm),
        e_max=e_max,
        e_th=3,
        e_th_hi=8,
    )


def orin_device(policy=None, lo=6, hi=10):
    policy = policy or dynamic_policy(100)
    return DeviceModel(mdf=uniform_mdf(lo, hi), policy=policy, e_max=100)


class TestStateIndexing:
    def test_roundtrip(self):
        e_max = 17
        for q in (0, 1):
            for g in (0, 1):
                for e in (0, 5, e_max):
                    idx = state_index(q, e, g, e_max)
                    assert state_tuple(idx, e_max) == (q, e, g)

    def test_bijective(self):
        e_max = 9
        seen = {state_index(q, e, g, e_max) for q in (0, 1) for g in (0, 1) for e in range(e_max + 1)}
        assert len(seen) == 4 * (e_max + 1)


class TestTransitionMatrix:
    def test_rows_are_distributions(self):
        chain = small_device().chain(0.4)
        P = chain.transition_matrix()
        np.testing.assert_allclose(P.sum(axis=1), 1.0, atol=1e-9)
        assert np.all(P >= 0)

    def test_idle_energy_never_decreases(self):
        """Case 1: gamma=1, Q=0 transitions must have E' >= E."""
        dev = small_device()
        chain = dev.chain(0.3)
        P = chain.transition_matrix()
        for e in range(dev.e_max + 1):
            src = state_index(0, e, 1, dev.e_max)
            for dst in np.nonzero(P[src] > 0)[0]:
                _, e2, _ = state_tuple(int(dst), dev.e_max)
                assert e2 >= min(e, dev.e_max)

    def test_power_save_rejects_jobs(self):
        """gamma=0 transitions preserve Q."""
        dev = small_device()
        P = dev.chain(0.9).transition_matrix()
        for qq in (0, 1):
            for e in range(dev.e_max + 1):
                src = state_index(qq, e, 0, dev.e_max)
                for dst in np.nonzero(P[src] > 0)[0]:
                    q2, _, _ = state_tuple(int(dst), dev.e_max)
                    assert q2 == qq

    def test_hysteresis_exit_threshold(self):
        """Power save exits only above e_th_hi."""
        dev = small_device()
        P = dev.chain(0.5).transition_matrix()
        for e in range(dev.e_max + 1):
            src = state_index(0, e, 0, dev.e_max)
            for dst in np.nonzero(P[src] > 0)[0]:
                _, e2, g2 = state_tuple(int(dst), dev.e_max)
                if g2 == 1:
                    assert e2 > dev.e_th_hi
                else:
                    assert e2 <= dev.e_th_hi

    def test_processing_consumes_energy(self):
        """From a high-energy processing state, E' reflects CE(PM)."""
        dev = small_device(pm=2, e_max=100, lo=0, hi=0)  # no income
        P = dev.chain(0.0).transition_matrix()
        e = 80
        src = state_index(1, e, 1, dev.e_max)
        dsts = np.nonzero(P[src] > 0)[0]
        assert len(dsts) == 1
        _, e2, _ = state_tuple(int(dsts[0]), dev.e_max)
        assert e2 == e - dev.policy.mode(2).ce

    def test_arrival_probability_scales_with_kappa(self):
        """p_m = 1-(1-q)^kappa: arrivals during long stages more likely."""
        q = 0.3
        dev = small_device(pm=1)  # kappa = 3, ce = 26
        P = dev.chain(q).transition_matrix()
        e = 28  # above the CE(PM1)=26 energy gate
        src = state_index(1, e, 1, dev.e_max)
        # mass going to Q=1 states:
        mass_q1 = sum(
            P[src, d]
            for d in np.nonzero(P[src] > 0)[0]
            if state_tuple(int(d), dev.e_max)[0] == 1
        )
        assert mass_q1 == pytest.approx(1 - (1 - q) ** 3, abs=1e-9)


class TestStationary:
    def test_stationary_is_fixed_point(self):
        chain = small_device().chain(0.4)
        P = chain.transition_matrix()
        pi = chain.stationary()
        np.testing.assert_allclose(pi @ P, pi, atol=1e-9)
        assert pi.sum() == pytest.approx(1.0)
        assert np.all(pi >= 0)

    def test_zero_arrivals_idle(self):
        """q=0: all stationary mass on idle full-battery states."""
        dev = small_device()
        chain = dev.chain(0.0)
        pi = chain.stationary()
        # Processing states have no mass.
        mass_proc = sum(
            pi[state_index(1, e, 1, dev.e_max)] for e in range(dev.e_max + 1)
        )
        assert mass_proc == pytest.approx(0.0, abs=1e-12)
        # Battery pinned at cap.
        assert chain.mean_energy() == pytest.approx(dev.e_max, abs=1e-6)

    def test_risk_monotone_in_q(self):
        dev = orin_device(policy=fixed_policy(3), lo=6, hi=10)
        risks = [dev.chain(q).risk() for q in (0.1, 0.3, 0.5, 0.8)]
        assert all(b >= a - 1e-12 for a, b in zip(risks, risks[1:]))

    def test_kappa_bar_fixed_mode(self):
        for pm, expect in ((1, 3.0), (2, 2.0), (3, 1.0)):
            dev = orin_device(policy=fixed_policy(pm))
            assert dev.chain(0.4).kappa_bar() == pytest.approx(expect)

    def test_mean_energy_rich_harvest(self):
        """Income >> consumption: battery hovers near capacity."""
        dev = DeviceModel(
            mdf=uniform_mdf(20, 30), policy=fixed_policy(3), e_max=100
        )
        assert dev.chain(0.5).mean_energy() > 85.0

    def test_downtime_increases_with_load(self):
        dev = orin_device(policy=fixed_policy(3), lo=4, hi=8)
        d_lo = dev.chain(0.2).downtime_fraction()
        d_hi = dev.chain(0.9).downtime_fraction()
        assert d_hi >= d_lo


class TestRates:
    def test_q_lim_time_bound_15w(self):
        """Paper Fig. 2b: 15 W is time-bound at q_lim = 1/3."""
        dev = orin_device(policy=fixed_policy(1), lo=6, hi=10)
        lims = q_lim(dev, xi_lim=0.01)
        assert lims.q_lim == pytest.approx(1 / 3, abs=0.02)
        assert lims.binding == "time"

    def test_q_lim_time_bound_30w(self):
        """Paper Fig. 2b: 30 W is time-bound at q_lim = 1/2."""
        dev = orin_device(policy=fixed_policy(2), lo=6, hi=10)
        lims = q_lim(dev, xi_lim=0.01)
        assert lims.q_lim == pytest.approx(1 / 2, abs=0.02)
        assert lims.binding == "time"

    def test_q_lim_energy_bound_60w(self):
        """Paper Fig. 2b: 60 W is energy-bound at q_lim ~ 0.33."""
        dev = orin_device(policy=fixed_policy(3), lo=6, hi=10)
        lims = q_lim(dev, xi_lim=0.01)
        assert lims.binding == "energy"
        assert lims.q_lim == pytest.approx(0.33, abs=0.04)

    def test_q_lim_dynamic_mode_paper_point(self):
        """Paper Fig. 2b blue circle: dynamic q_lim ~ 0.64 ~ 1/kappa_bar,
        kappa_bar ~ 1.56 — matched by Eq. (4) at the stable operating
        point (see EXPERIMENTS.md, Fig. 2b discussion)."""
        dev = orin_device(policy=dynamic_policy(100), lo=6, hi=10)
        # Energy gate => risk threshold is never reached for the dynamic
        # mode (paper: "cannot be reached" holds for 15/30 W; dynamic's
        # energy bound is far above its delay bound).
        assert q_lim_energy(dev, 0.01) == pytest.approx(1.0)
        kb = dev.chain(0.34).kappa_bar()
        assert kb == pytest.approx(1.56, abs=0.1)
        assert 1.0 / kb == pytest.approx(0.64, abs=0.03)

    def test_q_lim_stable_dynamic_risk_free_rate(self):
        """Dynamic PM sustains a higher input rate than 60 W's
        risk-constrained limit while keeping the downtime risk at zero
        (paper: "the dynamic power mode allows enduring a higher input
        rate, while controlling the downtime risk below xi_lim")."""
        dyn = orin_device(policy=dynamic_policy(100), lo=6, hi=10)
        stable = q_lim_stable(dyn, xi_lim=0.01)
        lim_60w = q_lim(orin_device(policy=fixed_policy(3), lo=6, hi=10), 0.01)
        lim_15w = q_lim(orin_device(policy=fixed_policy(1), lo=6, hi=10), 0.01)
        assert stable.q_lim > lim_60w.q_lim  # 0.43 > 0.34
        assert stable.q_lim > lim_15w.q_lim  # 0.43 > 1/3
        # At its stable rate the dynamic mode's downtime risk stays ~0
        # while 60 W at its own limit sits right at xi_lim.
        assert dyn.chain(stable.q_lim).risk() < 1e-3

    def test_q_lim_energy_monotone_in_income(self):
        rich = orin_device(policy=fixed_policy(3), lo=10, hi=14)
        poor = orin_device(policy=fixed_policy(3), lo=4, hi=8)
        assert q_lim_energy(rich, 0.01) > q_lim_energy(poor, 0.01)
