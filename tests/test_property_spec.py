"""Property-based ``rollback(n)`` validation (hypothesis): arbitrary
interleavings of reserve / extend / rollback / release against the
dense and paged KV cache managers must conserve memory after every
operation — free + allocated pages is exactly the pool capacity, a
rolled-back context holds exactly ``blocks_for(new_len)`` pages, and
the block-table row mirrors the held pages with everything beyond them
re-scratched (a freed lane must never alias a live page). The
speculative engine leans on this: every accept finalizer and every
aborted round rewinds optimistic KV advances through ``rollback``."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (test extra)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serving.cache import DenseSlotCache, PagedKVCache

SETTINGS = dict(max_examples=25, deadline=None)

# One op = (kind, rid-pick, length-ish). Interpreted against the live
# set at replay time so every generated sequence is applicable.
_OPS = st.lists(
    st.tuples(
        st.sampled_from(["reserve", "extend", "rollback", "release"]),
        st.integers(0, 7),
        st.integers(0, 48),
    ),
    min_size=1,
    max_size=60,
)


def _replay(mgr, ops, paged):
    live = {}
    next_rid = 0
    for kind, pick, length in ops:
        if kind == "reserve":
            if mgr.free_slots() > 0 and mgr.can_reserve(length):
                slot = mgr.reserve(next_rid, length)
                # The engine stamps the host mirror at dispatch time;
                # the replay plays that role here.
                mgr.lengths[slot] = length
                live[next_rid] = slot
                next_rid += 1
        elif live:
            rid = sorted(live)[pick % len(live)]
            slot = live[rid]
            if kind == "extend":
                if mgr.try_extend(rid, slot, length):
                    mgr.lengths[slot] = max(int(mgr.lengths[slot]), length)
            elif kind == "rollback":
                n = length % (int(mgr.lengths[slot]) + 1)
                mgr.rollback(rid, slot, n)
                if paged and n > 0:
                    # Rollback trims the claim to exactly the shorter
                    # context's page need.
                    new_len = int(mgr.lengths[slot])
                    need = mgr.pool.blocks_for(new_len) if new_len > 0 else 0
                    assert len(mgr.pages.get(rid, [])) == need
            else:
                mgr.release(rid, live.pop(rid))
        mgr.check_conservation()
        for rid, slot in live.items():
            assert mgr.slots[slot] == rid
            n = int(mgr.lengths[slot])
            assert 0 <= n <= mgr.max_len
            if paged:
                held = mgr.pages.get(rid, [])
                # Pages always cover the committed mirror, and the
                # block-table row mirrors them with a re-scratched tail
                # (a freed lane must never alias a live page).
                if n > 0:
                    assert len(held) >= mgr.pool.blocks_for(n)
                row = list(mgr.block_table[slot])
                assert row[: len(held)] == held
                assert all(p == mgr.pool.scratch for p in row[len(held):])
    for rid, slot in list(live.items()):
        mgr.release(rid, slot)
    mgr.check_conservation()
    if paged:
        assert mgr.pool.free_pages == mgr.pool.n_pages


@given(_OPS, st.sampled_from([4, 8, 16]), st.integers(6, 24))
@settings(**SETTINGS)
def test_paged_rollback_property(ops, page_size, n_pages):
    _replay(
        PagedKVCache(n_slots=3, max_len=48, page_size=page_size,
                     n_pages=n_pages),
        ops, paged=True,
    )


@given(_OPS)
@settings(**SETTINGS)
def test_dense_rollback_property(ops):
    _replay(DenseSlotCache(n_slots=3, max_len=48), ops, paged=False)


@given(_OPS, st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_rollback_then_rewrite_is_exact(ops, seed):
    """The engine's actual usage: rollback(n) then re-extend to the same
    length lands the context on pages that cover exactly the same
    positions — lengths and page math agree with a shadow model."""
    mgr = PagedKVCache(n_slots=2, max_len=48, page_size=8, n_pages=12)
    rng = np.random.default_rng(seed)
    slot = mgr.reserve(0, 0)
    length = 0
    for _, _, amount in ops:
        if rng.uniform() < 0.5:
            target = min(48, length + amount % 9)
            if mgr.try_extend(0, slot, target):
                length = max(length, target)
                mgr.lengths[slot] = length
        else:
            n = amount % (length + 1)
            mgr.rollback(0, slot, n)
            length -= n
        assert int(mgr.lengths[slot]) == length
        assert len(mgr.pages.get(0, [])) == (
            mgr.pool.blocks_for(length) if length > 0 else 0
        )
        mgr.check_conservation()
