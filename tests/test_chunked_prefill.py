"""Chunked-prefill tests: token-exactness vs whole-prompt prefill (dense
and paged, including failover re-prefill mid-chunk and preemption), the
model-level chunk step vs monolithic prefill, the registry sweep over
every paged-capable architecture, and the compile-count regression —
with ``prefill_chunk`` set, the number of traced prefill computations is
independent of the number of distinct prompt lengths in the workload."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import direct_greedy, tiny_model

from repro.configs import ARCH_NAMES, get_smoke_config
from repro.models import build_model, init_from_template
from repro.models.transformer import supports_paged
from repro.serving import PipelineServer, reset_trace_counts, trace_counts


def _drain(server, reqs, limit=2000):
    for _ in range(limit):
        if all(r.done for r in reqs):
            return
        server.step()
    raise AssertionError("workload did not drain")


def _chunk_trace_keys():
    return sorted(k for k in trace_counts() if k[0] in ("chunk", "chunk_paged"))


class TestChunkModelEntryPoint:
    def test_chunk_steps_match_whole_prefill(self):
        """Driving transformer.prefill_chunk chunk-by-chunk reproduces
        prefill's cache and final-position logits exactly."""
        cfg, model, params = tiny_model()
        max_len, S, C = 32, 11, 4
        prompt = jnp.asarray((np.arange(S) * 5 + 2) % cfg.vocab_size)[None]
        ref_logits, ref_cache = model.prefill(params, {"tokens": prompt}, max_len)

        cache = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), model.cache_shapes(1, max_len)
        )
        pos = 0
        while pos < S:
            valid = min(C, S - pos)
            buf = jnp.zeros((1, C), jnp.int32).at[:, :valid].set(
                prompt[:, pos : pos + valid]
            )
            out, cache = model.prefill_chunk(params, {"tokens": buf}, cache, pos, valid)
            pos += valid
        assert int(cache["len"]) == S == int(ref_cache["len"])
        # Valid cache entries match (beyond S is scratch in both layouts).
        np.testing.assert_allclose(
            np.asarray(cache["c0"]["k"][:, :, :S]),
            np.asarray(ref_cache["c0"]["k"][:, :, :S]),
            rtol=2e-4, atol=2e-4,
        )
        # Last valid chunk position's logits == prefill's final logits.
        np.testing.assert_allclose(
            np.asarray(out[:, valid - 1]),
            np.asarray(ref_logits[:, -1]),
            rtol=2e-4, atol=2e-4,
        )

    def test_chunked_requires_uniform_attention(self):
        cfg, model, params = tiny_model("hymba-1.5b")
        assert model.prefill_chunk is None
        with pytest.raises(ValueError, match="chunked prefill"):
            PipelineServer(
                model, params, n_groups=1, n_replicas=1, prefill_chunk=4
            )


class TestChunkedServing:
    @pytest.mark.parametrize("paged", [False, True])
    @pytest.mark.parametrize("chunk", [3, 16])
    def test_token_exact_vs_whole_prefill(self, paged, chunk):
        """Acceptance: chunked prefill is token-exact vs whole-prompt
        prefill (and vs monolithic greedy) for mixed prompt lengths —
        multi-chunk, exact-multiple, and single-chunk prompts."""
        cfg, model, params = tiny_model()
        n_tok = 3
        prompts = [
            (np.arange(L) * 3 + i) % cfg.vocab_size
            for i, L in enumerate([5, 6, 7, 11])
        ]

        def serve(prefill_chunk):
            server = PipelineServer(
                model, params, n_groups=2, n_replicas=1,
                harvest_bounds=(50.0, 60.0), max_len=64, max_batch=4,
                paged=paged, page_size=8, prefill_chunk=prefill_chunk, seed=5,
            )
            reqs = [server.submit(p, n_tokens=n_tok) for p in prompts]
            _drain(server, reqs)
            return server, reqs

        w_server, w_reqs = serve(None)
        c_server, c_reqs = serve(chunk)
        for w, c, p in zip(w_reqs, c_reqs, prompts):
            assert c.generated == w.generated
            assert c.generated == direct_greedy(model, params, p, n_tok)
        # Chunking replaces per-length prefill dispatches entirely. Decode
        # dispatch counts may differ (prompts finish prefill on different
        # steps, desynchronizing decode rounds) but every token still
        # arrives, as asserted above.
        assert c_server.stats.prefill_calls == 0
        assert c_server.stats.chunk_prefill_calls > 0
        assert w_server.stats.chunk_prefill_calls == 0

    @pytest.mark.parametrize("paged", [False, True])
    def test_failover_mid_chunk_token_exact(self, paged):
        """Acceptance: killing the replica while a prompt is only
        partially prefilled (mid-chunk) restarts the chunk stream on the
        sibling and stays token-exact."""
        cfg, model, params = tiny_model()
        server = PipelineServer(
            model, params, n_groups=2, n_replicas=3,
            harvest_bounds=(50.0, 60.0), max_len=64, max_batch=2,
            paged=paged, page_size=8, prefill_chunk=3, seed=4,
        )
        prompt = np.arange(11) % cfg.vocab_size
        req = server.submit(prompt, n_tokens=4)
        kills = 0
        for _ in range(800):
            if req.done:
                break
            if kills < 2 and req.chunk_pos > 0 and not req.cache_ready[req.stage]:
                server.fail_replica(req.stage, req.replicas[req.stage])
                kills += 1
            server.step()
        assert req.done and kills == 2
        assert server.stats.rerouted_stages >= 2
        assert req.generated == direct_greedy(model, params, prompt, 4)
        np.testing.assert_array_equal(req.prompt, prompt)

    def test_preemption_with_chunked_prefill(self):
        """Page exhaustion mid-chunk-stream preempts the youngest and
        still finishes token-exact; pages stay conserved."""
        cfg, model, params = tiny_model()
        server = PipelineServer(
            model, params, n_groups=1, n_replicas=1,
            harvest_bounds=(50.0, 60.0), max_len=64, max_batch=4,
            paged=True, page_size=4, max_pages=6, prefill_chunk=3, seed=0,
        )
        prompts = [(np.arange(6) + i) % cfg.vocab_size for i in range(3)]
        reqs = [server.submit(p, n_tokens=12) for p in prompts]
        for _ in range(4000):
            if all(r.done for r in reqs):
                break
            server.step()
            for mgr in server.managers.values():
                mgr.check_conservation()
        assert all(r.done for r in reqs)
        assert server.stats.preempted_jobs > 0
        assert server.stats.dropped_jobs == 0
        for r, p in zip(reqs, prompts):
            assert r.generated == direct_greedy(model, params, p, 12)
        for mgr in server.managers.values():
            assert mgr.pool.free_pages == mgr.pool.n_pages

    @pytest.mark.parametrize("paged", [False, True])
    def test_compile_count_independent_of_prompt_lengths(self, paged):
        """Satellite: with ``prefill_chunk`` set, the traced prefill
        computations (``trace_counts``) do not grow with the number of
        distinct prompt lengths — one length and four lengths compile
        the identical set of chunk shapes, and nothing else."""
        cfg, model, params = tiny_model()

        def serve(lens):
            server = PipelineServer(
                model, params, n_groups=2, n_replicas=1,
                harvest_bounds=(50.0, 60.0), max_len=64, max_batch=4,
                paged=paged, page_size=8, prefill_chunk=4, seed=5,
            )
            reqs = [
                server.submit((np.arange(L) + i) % cfg.vocab_size, n_tokens=2)
                for i, L in enumerate(lens)
            ]
            _drain(server, reqs)

        reset_trace_counts()
        serve([7, 7, 7, 7])  # one distinct prompt length
        uniform = _chunk_trace_keys()
        whole_kind = [
            k for k in trace_counts() if k[0] in ("prefill", "prefill_pages")
        ]
        assert not whole_kind  # chunking fully replaced per-length prefill
        reset_trace_counts()
        serve([3, 7, 9, 14])  # four distinct prompt lengths
        mixed = _chunk_trace_keys()
        assert mixed == uniform  # same traces, regardless of length mix
        # One chunk shape per pipeline stage, total.
        assert len(mixed) == 2


@pytest.mark.slow
class TestChunkedRegistrySweep:
    """Acceptance: token-exactness swept over every registry model with
    ``supports_paged`` (the chunked-prefill coverage), dense and paged."""

    @pytest.mark.parametrize("name", ARCH_NAMES)
    def test_registry_chunked_token_exact(self, name):
        cfg = dataclasses.replace(
            get_smoke_config(name), dtype="float32", param_dtype="float32"
        )
        if not supports_paged(cfg):
            pytest.skip(f"{name}: no uniform full attention; serves unchunked")
        model = build_model(cfg)
        params = init_from_template(model.template, jax.random.PRNGKey(0), "float32")
        prompt = (np.arange(9) * 2 + 1) % cfg.vocab_size
        ref = direct_greedy(model, params, prompt, 3)
        for paged in (False, True):
            server = PipelineServer(
                model, params, n_groups=2, n_replicas=1,
                harvest_bounds=(50.0, 60.0), max_len=64, max_batch=2,
                paged=paged, page_size=8, prefill_chunk=4, seed=1,
            )
            req = server.submit(prompt, n_tokens=3)
            _drain(server, [req])
            assert req.generated == ref, (name, paged)
