"""Pipeline-parallel tests.

The GPipe runner needs multiple devices for a real stage axis; a
subprocess with ``--xla_force_host_platform_device_count=4`` validates
the ppermute schedule against the sequential reference. In-process we
check the degenerate single-stage path.
"""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.distributed.pipeline import pipeline_apply


def test_single_stage_identity():
    dev = np.array(jax.devices()[:1]).reshape(1)
    mesh = Mesh(dev, ("stage",))
    w = jnp.full((1, 4, 4), 2.0)
    x = jnp.ones((8, 4))
    out = pipeline_apply(
        mesh, lambda p, h: h @ p, w, x, n_micro=4, axis="stage"
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w[0]), rtol=1e-6)


SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.distributed.pipeline import pipeline_apply

    n_stages, n_micro, B, D = 4, 8, 16, 32
    key = jax.random.PRNGKey(0)
    kw, kx = jax.random.split(key)
    w = jax.random.normal(kw, (n_stages, D, D)) * 0.3
    x = jax.random.normal(kx, (B, D))

    def stage_fn(p, h):
        return jnp.tanh(h @ p)

    # sequential reference
    ref = x
    for s in range(n_stages):
        ref = stage_fn(w[s], ref)

    mesh = Mesh(np.array(jax.devices()).reshape(n_stages), ("stage",))
    out = pipeline_apply(mesh, stage_fn, w, x, n_micro=n_micro, axis="stage")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)
    print("PIPELINE_OK")
    """
)


@pytest.mark.slow
def test_multi_stage_matches_sequential():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True,
        text=True,
        timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd=".",
    )
    assert "PIPELINE_OK" in proc.stdout, proc.stderr[-2000:]
