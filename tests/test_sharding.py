"""Sharding-rule tests: logical-axis resolution, divisibility fallback,
param-tree shardings, rule-set sanity."""

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.sharding import (
    DECODE_RULES,
    DEFAULT_RULES,
    PREFILL_RULES,
    RULE_SETS,
    TRAIN_RULES,
    _resolve,
    divisible_spec,
    logical,
    param_shardings,
    use_mesh_rules,
)
from repro.models.common import ParamSpec


def mesh2d():
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


class TestResolve:
    def test_basic_mapping(self):
        m = mesh2d()
        spec = _resolve(DEFAULT_RULES, m, ("batch", "seq", "heads"))
        assert spec == P("data", None, "model")

    def test_missing_axes_dropped(self):
        """'pod' is absent from the single-pod mesh -> batch maps to data only."""
        m = mesh2d()
        spec = _resolve(DEFAULT_RULES, m, ("batch",))
        assert spec == P("data")

    def test_no_double_use_of_mesh_axis(self):
        m = mesh2d()
        # TRAIN_RULES: act_seq -> model, heads -> model; in one spec the
        # second user of "model" must fall back to None.
        spec = _resolve(TRAIN_RULES, m, ("act_seq", "heads"))
        assert spec == P("model", None)

    def test_divisible_spec_fallback(self):
        m = mesh2d()
        # 25 heads on a 1-way axis is fine; force check with fake size via
        # a shape not divisible by the axis size 1 -> always divisible.
        spec = divisible_spec((25, 64), ("heads", "head_dim"), m, DEFAULT_RULES)
        assert spec == P("model", None)


class TestLogical:
    def test_noop_without_context(self):
        x = jax.numpy.ones((4, 4))
        y = logical(x, ("batch", "embed"))
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_constraint_applies_in_context(self):
        m = mesh2d()
        with use_mesh_rules(m, DEFAULT_RULES):
            x = jax.numpy.ones((4, 4))
            y = logical(x, ("batch", "embed"))
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestParamShardings:
    def test_tree_mapping(self):
        m = mesh2d()
        tree = {
            "w": ParamSpec((64, 128), ("embed_fsdp", "ff")),
            "b": ParamSpec((128,), ("ff",)),
        }
        sh = param_shardings(tree, m, TRAIN_RULES)
        assert sh["w"].spec == P("data", "model")
        assert sh["b"].spec == P("model")


class TestRuleSets:
    def test_all_rule_sets_resolvable(self):
        m = mesh2d()
        for name, rules in RULE_SETS.items():
            for logical_name in rules:
                spec = _resolve(rules, m, (logical_name,))
                assert isinstance(spec, P), (name, logical_name)

    def test_decode_rules_shard_cache_seq(self):
        m = mesh2d()
        spec = _resolve(DECODE_RULES, m, ("cache_seq",))
        assert spec == P("model")

    def test_prefill_replicates_params_across_data(self):
        m = mesh2d()
        spec = _resolve(PREFILL_RULES, m, ("embed_fsdp",))
        assert spec == P(None)
