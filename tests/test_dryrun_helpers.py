"""Dry-run helper tests that don't need 512 placeholder devices.

(The 512-device lower+compile matrix itself runs via
``python -m repro.launch.dryrun --all --both-meshes``; its 64 green cells
are recorded in artifacts/dryrun/ and EXPERIMENTS.md §Dry-run.)
"""

import importlib
import os

import pytest

from repro.configs import ARCH_NAMES, SHAPES, cells_for, get_config
from repro.models import build_model, count_params
from repro.models.inputs import input_specs


def test_input_specs_all_cells():
    """Every runnable (arch x shape) cell has well-formed abstract inputs."""
    for name in ARCH_NAMES:
        cfg = get_config(name)
        for cell_name in cells_for(name):
            cell = SHAPES[cell_name]
            specs = input_specs(cfg, cell)
            if cell.kind == "train":
                assert specs["tokens"].shape == (cell.global_batch, cell.seq_len)
                assert specs["labels"].shape == (cell.global_batch, cell.seq_len)
            elif cell.kind == "prefill":
                assert specs["tokens"].shape == (cell.global_batch, cell.seq_len)
            else:
                assert specs["token"].shape == (cell.global_batch, 1)
            if cfg.is_encdec and cell.kind != "decode":
                assert specs["frames"].shape[2] == cfg.frontend_dim
            if cfg.frontend == "patches" and cell.kind != "decode":
                assert specs["patch_embeds"].shape[1] <= cell.seq_len


@pytest.mark.parametrize(
    "name,approx_params",
    [
        ("stablelm-1.6b", 1.64e9),
        ("phi4-mini-3.8b", 3.8e9),
        ("qwen2.5-14b", 14.8e9),
        ("granite-20b", 20.5e9),
        ("qwen3-moe-30b-a3b", 30.3e9),
        ("falcon-mamba-7b", 7.3e9),
        ("internvl2-76b", 69.9e9),
        ("hymba-1.5b", 1.6e9),
        ("granite-moe-1b-a400m", 1.3e9),
        ("seamless-m4t-large-v2", 1.4e9),
    ],
)
def test_full_param_counts(name, approx_params):
    """Template parameter counts match the published model sizes.

    (seamless: backbone only — the speech frontend is a stub; internvl:
    LLM backbone only — InternViT is a stub; both per the assignment.)
    """
    model = build_model(get_config(name))
    n = count_params(model.template)
    assert n == pytest.approx(approx_params, rel=0.12), f"{name}: {n/1e9:.2f}B"


def test_cell_artifacts_recorded():
    """The dry-run artifact matrix exists and is fully green (no 'error'
    keys) for both meshes — regression guard for deliverable (e)."""
    import glob
    import json

    art = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")
    paths = glob.glob(os.path.join(art, "*__16x16.json")) + glob.glob(
        os.path.join(art, "*__2x16x16.json")
    )
    if not paths:
        pytest.skip("dry-run artifacts not generated in this checkout")
    assert len(paths) >= 64
    for p in paths:
        with open(p) as f:
            d = json.load(f)
        assert "error" not in d, f"{os.path.basename(p)}: {d.get('error')}"
        assert d["roofline"]["step_time_s"] > 0
