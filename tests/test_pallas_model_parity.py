"""End-to-end parity: models running with Pallas kernels (interpret mode)
must match the XLA path — covers the kernels *in situ* (GQA folding,
RoPE, ring caches, SSM chunk carry). The fast lane checks the three
families with distinct kernel paths; the slow sweep drives *every*
registry config through prefill + decode parity."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, ShapeCell, get_smoke_config
from repro.models import build_model, init_from_template
from repro.models.inputs import make_inputs

CELL = ShapeCell("smoke", "train", seq_len=48, global_batch=2)
SWEEP_CELL = ShapeCell("smoke", "train", seq_len=32, global_batch=1)

# Families that exercise distinct kernel paths:
#   dense GQA (flash), hymba (flash+window+scan), mamba (scan).
PARITY_ARCHS = ["phi4-mini-3.8b", "hymba-1.5b", "falcon-mamba-7b"]


def _build(name, impl):
    cfg = get_smoke_config(name)
    cfg = dataclasses.replace(
        cfg, dtype="float32", param_dtype="float32", attn_impl=impl
    )
    model = build_model(cfg)
    params = init_from_template(model.template, jax.random.PRNGKey(0), "float32")
    return cfg, model, params


@pytest.mark.parametrize("name", PARITY_ARCHS)
def test_forward_parity(name):
    cfg_x, model_x, params = _build(name, "xla")
    _, model_p, _ = _build(name, "pallas")
    batch = make_inputs(cfg_x, CELL)
    lx, _ = model_x.forward(params, batch)
    lp, _ = model_p.forward(params, batch)
    np.testing.assert_allclose(
        np.asarray(lx), np.asarray(lp), rtol=2e-4, atol=2e-4
    )


@pytest.mark.parametrize(
    "name",
    [
        "phi4-mini-3.8b",
        # hymba's scan-of-ring-buffers decode takes ~25 s in interpret mode.
        pytest.param("hymba-1.5b", marks=pytest.mark.slow),
    ],
)
def test_decode_parity(name):
    cfg_x, model_x, params = _build(name, "xla")
    _, model_p, _ = _build(name, "pallas")
    batch = make_inputs(cfg_x, CELL)
    tokens = batch["tokens"]
    S = tokens.shape[1]
    prompt = dict(batch, tokens=tokens[:, : S - 1])
    _, cache_x = model_x.prefill(params, prompt, S + 4)
    _, cache_p = model_p.prefill(params, prompt, S + 4)
    lx, _ = model_x.decode_step(params, tokens[:, -1:], cache_x)
    lp, _ = model_p.decode_step(params, tokens[:, -1:], cache_p)
    np.testing.assert_allclose(
        np.asarray(lx), np.asarray(lp), rtol=2e-4, atol=2e-4
    )


@pytest.mark.slow
@pytest.mark.parametrize("name", ARCH_NAMES)
def test_registry_prefill_decode_parity(name):
    """Every registry config: prefill the prompt and decode one token on
    both impls; logits must agree at each step (covers every family's
    cache layout — KV, ring, cross-attn, SSM — under the kernels)."""
    cfg_x, model_x, params = _build(name, "xla")
    _, model_p, _ = _build(name, "pallas")
    batch = make_inputs(cfg_x, SWEEP_CELL)
    # Parity of the token path; the VLM patch frontend is prefill-layout
    # sugar and has no kernel of its own.
    batch.pop("patch_embeds", None)
    tokens = batch["tokens"]
    S = tokens.shape[1]
    prompt = dict(batch, tokens=tokens[:, : S - 1])
    px, cache_x = model_x.prefill(params, prompt, S + 4)
    pp, cache_p = model_p.prefill(params, prompt, S + 4)
    np.testing.assert_allclose(
        np.asarray(px), np.asarray(pp), rtol=2e-4, atol=2e-4
    )
    lx, _ = model_x.decode_step(params, tokens[:, -1:], cache_x)
    lp, _ = model_p.decode_step(params, tokens[:, -1:], cache_p)
    np.testing.assert_allclose(
        np.asarray(lx), np.asarray(lp), rtol=2e-4, atol=2e-4
    )
