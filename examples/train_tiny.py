"""Train a reduced model end-to-end with checkpoint/restart.

Run: PYTHONPATH=src python examples/train_tiny.py
"""

import dataclasses
import tempfile

import jax

from repro.configs import get_smoke_config
from repro.ft import restore_checkpoint, save_checkpoint
from repro.models import build_model, init_from_template
from repro.training import (
    AdamWConfig,
    SyntheticLM,
    init_train_state,
    make_batch,
    make_train_step,
)

cfg = dataclasses.replace(get_smoke_config("phi4-mini-3.8b"),
                          dtype="float32", param_dtype="float32")
model = build_model(cfg)
params = init_from_template(model.template, jax.random.PRNGKey(0), "float32")
state = init_train_state(model, params)
step_fn = jax.jit(make_train_step(model, AdamWConfig(lr=3e-3, warmup_steps=5,
                                                     total_steps=60)))
data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=48, global_batch=4)

ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
first = last = None
for i in range(20):
    state, metrics = step_fn(state, make_batch(cfg, data, i))
    loss = float(metrics["loss"])
    first = first if first is not None else loss
    last = loss
    if (i + 1) % 10 == 0:
        save_checkpoint(ckpt_dir, i + 1, state)
        print(f"step {i+1}: loss={loss:.4f} (checkpointed)")

# Simulated crash + restart: restore and continue.
state, step = restore_checkpoint(ckpt_dir, state)
print(f"restored at step {step}; continuing...")
for i in range(step, step + 10):
    state, metrics = step_fn(state, make_batch(cfg, data, i))
print(f"final loss={float(metrics['loss']):.4f} (started at {first:.4f})")
assert float(metrics["loss"]) < first
print("train_tiny OK")
