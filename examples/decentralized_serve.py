"""Decentralized serving with failures: Petals-style groups, energy-aware
routing, node failure mid-request, elastic rate refresh.

Run: PYTHONPATH=src python examples/decentralized_serve.py
"""

import dataclasses

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.network import DeviceSpec
from repro.core.power import dynamic_policy
from repro.ft import ElasticController
from repro.models import build_model, init_from_template
from repro.serving import PipelineServer

cfg = dataclasses.replace(get_smoke_config("phi4-mini-3.8b"),
                          dtype="float32", param_dtype="float32")
model = build_model(cfg)
params = init_from_template(model.template, jax.random.PRNGKey(0), "float32")

server = PipelineServer(model, params, n_groups=2, n_replicas=3,
                        policy="adaptive", harvest_bounds=(10.0, 16.0),
                        max_len=96, seed=7)

# Elastic controller: long-term rates from the semi-Markov model.
pol = dynamic_policy(100)
specs = [[DeviceSpec(arrival_lo=8, arrival_hi=12, policy=pol)] * 3 for _ in range(2)]
ctl = ElasticController(server.router, specs)
rates = ctl.refresh()
print(f"long-term rates per group: {[np.round(r, 3).tolist() for r in rates]}")

req = server.submit(np.arange(8), n_tokens=6)
for _ in range(6):
    server.step()

g = req.stage
print(f"killing replica {req.replicas[g]} of group {g} mid-request...")
server.fail_replica(g, req.replicas[g])

while not (req.done or req.dropped):
    server.step()

print(f"request done={req.done}, generated {len(req.generated)} tokens, "
      f"rerouted_stages={server.stats.rerouted_stages}")
stats = server.run(n_slots=30, arrival_p=0.4, n_tokens=2)
print(f"steady state: jobs={stats.completed_jobs} tokens={stats.tokens_generated} "
      f"downtime={stats.downtime_fraction:.3f}")
