"""Paper reproduction walk-through: every figure's experiment, scripted.

Run: PYTHONPATH=src python examples/energy_sim.py
(Full Monte-Carlo counts live in benchmarks/; this uses smaller runs.)
"""

import dataclasses

from repro.core import (
    DeviceModel,
    SimConfig,
    dynamic_policy,
    fixed_policy,
    paper_topology,
    q_lim,
    simulate,
    simulate_single_device,
    uniform_mdf,
)

print("=== Fig 2a: power modes on one device (100 slots) ===")
base = SimConfig(n_groups=1, n_per_group=1, n_steps=100, p_arrival=0.62)
for name, thr, allowed in (
    ("15W", (), (1,)),
    ("30W", (), (2,)),
    ("60W", (), (3,)),
    ("dynamic", (40.0, 60.0), (1, 2, 3)),
):
    cfg = dataclasses.replace(base, pm_thresholds=thr, pm_allowed=allowed)
    res = simulate_single_device(cfg, 7, 13, n_runs=100)
    print(f"  {name:8s} jobs={res.completed.mean():5.1f} "
          f"battery={res.mean_battery.mean():5.1f}% "
          f"downtime={res.downtime_fraction.mean():.3f}")

print("=== Fig 2b: q_lim under xi_lim=0.01 (Brent on Eq. 3) ===")
for name, pol in (("15W", fixed_policy(1)), ("30W", fixed_policy(2)),
                  ("60W", fixed_policy(3)), ("dynamic", dynamic_policy(100))):
    dev = DeviceModel(mdf=uniform_mdf(6, 10), policy=pol, e_max=100)
    lims = q_lim(dev, 0.01)
    print(f"  {name:8s} q_lim={lims.q_lim:.3f} binding={lims.binding}")

print("=== Fig 3/4: scheduling policies on the 3x3 network ===")
topo = paper_topology(arrival_means=(3.0, 5.0, 7.0))
for policy in ("uniform", "long_term", "adaptive"):
    cfg = SimConfig(n_groups=3, n_per_group=3, n_steps=200, p_arrival=0.7,
                    policy=policy)
    res = simulate(topo, cfg, n_runs=50)
    s = res.summary()
    print(f"  {policy:9s} downtime={s['downtime_fraction']:.4f} "
          f"throughput={s['normalized_throughput']:.3f} "
          f"dropped={s['dropped']:.1f}")
