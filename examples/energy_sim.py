"""Paper reproduction walk-through: every figure's experiment, scripted.

Run: PYTHONPATH=src python examples/energy_sim.py
(Full Monte-Carlo counts live in benchmarks/; this uses smaller runs.)

Each figure's whole parameter grid runs as ONE ``simulate_sweep`` call —
a single jit compile per network shape, however many scenarios.
"""

import numpy as np

from repro.core import (
    DeviceModel,
    SimConfig,
    dynamic_policy,
    fixed_policy,
    paper_topology,
    q_lim,
    scenario_from_config,
    simulate_sweep,
    uniform_mdf,
)

print("=== Fig 2a: power modes on one device (100 slots, one sweep) ===")
strategies = (
    ("15W", (), (1,)),
    ("30W", (), (2,)),
    ("60W", (), (3,)),
    ("dynamic", (40.0, 60.0), (1, 2, 3)),
)
scenarios = [
    scenario_from_config(
        SimConfig(n_groups=1, n_per_group=1, n_steps=100, p_arrival=0.62,
                  pm_thresholds=thr, pm_allowed=allowed),
        np.array([[7]]), np.array([[13]]),
        n_thresholds=max(len(t) for _, t, _ in strategies),
    )
    for _, thr, allowed in strategies
]
res = simulate_sweep(None, scenarios, n_runs=100, n_steps=100)
for i, (name, _, _) in enumerate(strategies):
    print(f"  {name:8s} jobs={res.completed[i].mean():5.1f} "
          f"battery={res.mean_battery[i].mean():5.1f}% "
          f"downtime={res.downtime_fraction[i].mean():.3f}")

print("=== Fig 2b: q_lim under xi_lim=0.01 (Brent on Eq. 3) ===")
for name, pol in (("15W", fixed_policy(1)), ("30W", fixed_policy(2)),
                  ("60W", fixed_policy(3)), ("dynamic", dynamic_policy(100))):
    dev = DeviceModel(mdf=uniform_mdf(6, 10), policy=pol, e_max=100)
    lims = q_lim(dev, 0.01)
    print(f"  {name:8s} q_lim={lims.q_lim:.3f} binding={lims.binding}")

print("=== Fig 3/4: scheduling policies on the 3x3 network (one sweep) ===")
topo = paper_topology(arrival_means=(3.0, 5.0, 7.0))
policies = ("uniform", "long_term", "adaptive")
cfgs = [
    SimConfig(n_groups=3, n_per_group=3, n_steps=200, p_arrival=0.7, policy=p)
    for p in policies
]
res = simulate_sweep(topo, cfgs, n_runs=50)
for i, policy in enumerate(policies):
    s = res[i].summary()
    print(f"  {policy:9s} downtime={s['downtime_fraction']:.4f} "
          f"throughput={s['normalized_throughput']:.3f} "
          f"dropped={s['dropped']:.1f}")
