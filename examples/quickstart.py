"""Quickstart: the paper's pipeline end-to-end in one minute on CPU.

1. Analyze a device with the semi-Markov model (q_lim via Brent).
2. Simulate the 3x3 network under all three scheduling policies.
3. Serve real decode traffic through the energy-aware engine.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses

import jax

from repro.configs import get_smoke_config
from repro.core import (
    DeviceModel,
    SimConfig,
    dynamic_policy,
    paper_topology,
    q_lim,
    simulate,
    uniform_mdf,
)
from repro.models import build_model, init_from_template
from repro.serving import PipelineServer

# --- 1. Device analytics (paper Secs. III-IV) ---------------------------
device = DeviceModel(mdf=uniform_mdf(6, 10), policy=dynamic_policy(100), e_max=100)
lims = q_lim(device, xi_lim=0.01)
print(f"[1] dynamic-mode device: q_lim={lims.q_lim:.3f} "
      f"(energy bound {lims.q_energy:.3f}, kappa_bar {lims.kappa_bar:.2f})")

# --- 2. Network simulation (paper Sec. V) --------------------------------
topo = paper_topology(arrival_means=(4.0, 6.0, 8.0))
for policy in ("uniform", "long_term", "adaptive"):
    cfg = SimConfig(n_groups=3, n_per_group=3, n_steps=200, p_arrival=0.7,
                    policy=policy)
    res = simulate(topo, cfg, n_runs=50)
    s = res.summary()
    print(f"[2] {policy:9s}: throughput={s['normalized_throughput']:.3f} "
          f"downtime={s['downtime_fraction']:.4f} dropped={s['dropped']:.1f}")

# --- 3. Real serving through the scheduler -------------------------------
mcfg = dataclasses.replace(get_smoke_config("stablelm-1.6b"),
                           dtype="float32", param_dtype="float32")
model = build_model(mcfg)
params = init_from_template(model.template, jax.random.PRNGKey(0), "float32")
server = PipelineServer(model, params, n_groups=2, n_replicas=2,
                        policy="adaptive", harvest_bounds=(8.0, 14.0),
                        max_len=64, seed=0)
stats = server.run(n_slots=40, arrival_p=0.5, prompt_len=6, n_tokens=2)
print(f"[3] engine: jobs={stats.completed_jobs} tokens={stats.tokens_generated} "
      f"downtime={stats.downtime_fraction:.3f}")
print("quickstart OK")
